//! Stage-wise basis addition (paper §3) on ONE live `Session`: grow m in
//! stages with `Session::grow_basis` — β warm-started by zero-extension,
//! only the new kernel columns computed — then compare against cold-start
//! training at the final m.
//!
//! This demonstrates the formulation-(4) advantage the paper highlights:
//! "for such a mode of operation, (3) requires incremental computation of
//! the SVD of W, which is messy and expensive. On the other hand, solution
//! of (4) does not pose any issues."
//!
//! Run: cargo run --release --example stagewise_basis

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{Backend, Settings};
use dkm::coordinator::{growth_settings, train, Session};
use dkm::data::synth;
use dkm::metrics::Table;
use dkm::runtime::make_backend;

fn main() -> dkm::Result<()> {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = 6_000;
    spec.n_test = 1_500;
    let (train_ds, test_ds) = synth::generate(&spec, 7);
    let settings = Settings {
        nodes: 8,
        max_iters: 120,
        ..Settings::default().with_dataset_defaults("covtype_like")
    };
    let backend = make_backend(Backend::Native, "artifacts")?;

    let stages = [128usize, 256, 512, 1024, 2048];
    println!("stage-wise training on one session, stages {stages:?}");
    let t0 = std::time::Instant::now();
    let staged_settings = growth_settings(&settings, &stages)?;
    let mut session = Session::build(
        &staged_settings,
        &train_ds,
        Arc::clone(&backend),
        CostModel::free(),
    )?;

    let mut table = Table::new(&["m", "warm f0", "final f", "tron iters", "accuracy", "solve secs"]);
    // Keep the staged-vs-cold comparison honest: the cold baseline below
    // times only training, so exclude the per-stage test scoring here.
    let mut scoring_secs = 0.0f64;
    for (i, &m) in stages.iter().enumerate() {
        if i > 0 {
            // O(new columns): only dirty C column tiles recompute.
            session.grow_basis(m)?;
        }
        let solve = session.solve()?;
        // Distributed, metered scoring on the same cluster.
        let t_score = std::time::Instant::now();
        let acc = session.accuracy(&test_ds)?;
        scoring_secs += t_score.elapsed().as_secs_f64();
        table.row(&[
            m.to_string(),
            format!("{:.1}", solve.stats.f0()),
            format!("{:.1}", solve.stats.final_f),
            solve.stats.iterations.to_string(),
            format!("{acc:.4}"),
            format!("{:.2}", solve.solve_wall_secs),
        ]);
    }
    let staged_total = t0.elapsed().as_secs_f64() - scoring_secs;
    print!("{}", table.render());

    // Cold-start comparison at the final m (the one-shot wrapper builds
    // and throws away a fresh session).
    let t1 = std::time::Instant::now();
    let cold = train(
        &Settings {
            m: *stages.last().unwrap(),
            ..settings.clone()
        },
        &train_ds,
        Arc::clone(&backend),
        CostModel::free(),
    )?;
    let cold_total = t1.elapsed().as_secs_f64();
    let cold_acc = cold.model.accuracy(backend.as_ref(), &test_ds)?;
    println!(
        "\ncold start at m={}: accuracy {:.4}, {} iters, {:.2}s",
        stages.last().unwrap(),
        cold_acc,
        cold.stats.iterations,
        cold_total
    );
    println!(
        "staged session: {:.2}s total for the whole accuracy-vs-m curve \
         (cold start gives one point in {:.2}s)",
        staged_total, cold_total
    );
    Ok(())
}
