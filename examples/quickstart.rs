//! Quickstart: train a Nyström kernel SVM (formulation (4)) on a small
//! synthetic dataset with the full three-layer stack (PJRT artifacts if
//! available, native fallback otherwise) and print the accuracy.
//!
//! Run: cargo run --release --example quickstart

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{Backend, Settings};
use dkm::coordinator::train;
use dkm::data::synth;
use dkm::runtime::make_backend;

fn main() -> dkm::Result<()> {
    // 1. A Covtype-like workload, scaled to run in seconds.
    let mut spec = synth::spec("covtype_like");
    spec.n_train = 4_000;
    spec.n_test = 1_000;
    let (train_ds, test_ds) = synth::generate(&spec, 42);
    println!(
        "dataset: {} (n={}, d={}, test={})",
        train_ds.name,
        train_ds.n(),
        train_ds.d(),
        test_ds.n()
    );

    // 2. Settings: m basis points, p simulated nodes, paper hyper-params.
    let settings = Settings {
        m: 512,
        nodes: 8,
        max_iters: 150,
        ..Settings::default().with_dataset_defaults("covtype_like")
    };

    // 3. Backend: the AOT JAX+Pallas artifacts through PJRT when built
    //    (`make artifacts`), pure-Rust math otherwise.
    let backend = match make_backend(Backend::Pjrt, "artifacts") {
        Ok(b) => b,
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); falling back to native");
            make_backend(Backend::Native, "artifacts")?
        }
    };
    println!("backend: {}", backend.name());

    // 4. Train (Algorithm 1) and evaluate.
    let out = train(&settings, &train_ds, Arc::clone(&backend), CostModel::hadoop_crude())?;
    let acc = out.model.accuracy(backend.as_ref(), &test_ds)?;

    println!(
        "trained m={} in {} TRON iterations ({} f/g evals, {} Hd evals)",
        settings.m,
        out.stats.iterations,
        out.fg_evals,
        out.hd_evals
    );
    println!(
        "objective: {:.2} -> {:.2}",
        out.stats.f_history.first().unwrap(),
        out.stats.final_f
    );
    println!("test accuracy: {acc:.4}");
    println!("\nsimulated 8-node ledger:\n{}", out.sim.report());
    Ok(())
}
