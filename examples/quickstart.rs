//! Quickstart: drive one stateful `Session` end to end — build the
//! sharded cluster once, solve (Algorithm 1's TRON), score the test set
//! through the distributed metered predict path, then warm re-solve the
//! SAME session at a second λ without recomputing the kernel blocks.
//!
//! Run: cargo run --release --example quickstart

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{Backend, Settings};
use dkm::coordinator::Session;
use dkm::data::synth;
use dkm::metrics::Step;
use dkm::runtime::make_backend;

fn main() -> dkm::Result<()> {
    // 1. A Covtype-like workload, scaled to run in seconds.
    let mut spec = synth::spec("covtype_like");
    spec.n_train = 4_000;
    spec.n_test = 1_000;
    let (train_ds, test_ds) = synth::generate(&spec, 42);
    println!(
        "dataset: {} (n={}, d={}, test={})",
        train_ds.name,
        train_ds.n(),
        train_ds.d(),
        test_ds.n()
    );

    // 2. Settings: m basis points, p simulated nodes, paper hyper-params.
    let settings = Settings {
        m: 512,
        nodes: 8,
        max_iters: 150,
        ..Settings::default().with_dataset_defaults("covtype_like")
    };

    // 3. Backend: the AOT JAX+Pallas artifacts through PJRT when built
    //    (`make artifacts`), pure-Rust math otherwise.
    let backend = match make_backend(Backend::Pjrt, "artifacts") {
        Ok(b) => b,
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); falling back to native");
            make_backend(Backend::Native, "artifacts")?
        }
    };
    println!("backend: {}", backend.name());

    // 4. Build the session (shard + basis + kernel blocks) and solve.
    let mut session = Session::build(
        &settings,
        &train_ds,
        Arc::clone(&backend),
        CostModel::hadoop_crude(),
    )?;
    let solve = session.solve()?;
    // Scoring is distributed over the live cluster and metered as its own
    // `predict` step in the ledgers below.
    let acc = session.accuracy(&test_ds)?;

    println!(
        "trained m={} in {} TRON iterations ({} f/g evals, {} Hd evals)",
        session.m(),
        solve.stats.iterations,
        solve.fg_evals,
        solve.hd_evals
    );
    println!(
        "objective: {:.2} -> {:.2}",
        solve.stats.f0(),
        solve.stats.final_f
    );
    println!("test accuracy: {acc:.4}");

    // 5. The session advantage: re-solve at a different λ on the SAME
    //    cluster — no resharding, no kernel recomputation, β warm-started.
    session.set_lambda(settings.lambda * 0.1)?;
    let resolve = session.solve()?;
    let acc2 = session.accuracy(&test_ds)?;
    println!(
        "warm re-solve at λ={}: {} iterations ({:.3}s), accuracy {acc2:.4}",
        session.lambda(),
        resolve.stats.iterations,
        resolve.solve_wall_secs
    );

    println!("\nsimulated 8-node ledger (both solves + prediction):");
    print!("{}", session.sim().report());
    println!(
        "predict wall: {:.3}s (one executor phase per batch); session totals: \
         {} barriers, {} AllReduce round-trips",
        session.wall().wall_secs(Step::Predict),
        session.sim().barriers(),
        session.sim().comm_rounds()
    );
    Ok(())
}
