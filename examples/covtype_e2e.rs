//! END-TO-END DRIVER: the full system on a real workload, proving all
//! three layers compose — L1 Pallas RBF kernel (inside the AOT HLO
//! artifacts), L2 JAX tile graphs (loaded via PJRT), L3 Rust coordinator
//! (one `Session` over a simulated 8-node cluster: AllReduce tree,
//! distributed TRON, distributed metered prediction).
//!
//! Trains a formulation-(4) kernel SVM on the Covtype-like workload
//! (24,000 train / 6,000 test — the scaled Table-3 spec), logs the loss
//! curve per TRON iteration, and prints the Algorithm-1 cost slicing plus
//! test accuracy. The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: make artifacts && cargo run --release --example covtype_e2e
//! (pass --fast for a 6k-row smoke version, --native to skip PJRT)

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{Backend, Settings};
use dkm::coordinator::Session;
use dkm::data::synth;
use dkm::metrics::{Step, Table};
use dkm::runtime::make_backend;

fn main() -> dkm::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let native = std::env::args().any(|a| a == "--native");
    let mut spec = synth::spec("covtype_like");
    if fast {
        spec.n_train = 6_000;
        spec.n_test = 1_500;
    }
    let (train_ds, test_ds) = synth::generate(&spec, 42);
    let settings = Settings {
        m: if fast { 512 } else { 1600 },
        nodes: 8,
        max_iters: 300,
        backend: if native { Backend::Native } else { Backend::Pjrt },
        ..Settings::default().with_dataset_defaults("covtype_like")
    };
    println!(
        "== covtype_e2e: n={} d={} ntest={} m={} p={} λ={} σ={} backend={:?} ==",
        train_ds.n(),
        train_ds.d(),
        test_ds.n(),
        settings.m,
        settings.nodes,
        settings.lambda,
        settings.sigma,
        settings.backend
    );

    let backend = make_backend(settings.backend, &settings.artifacts_dir)?;
    let t0 = std::time::Instant::now();
    let mut session = Session::build(
        &settings,
        &train_ds,
        Arc::clone(&backend),
        CostModel::hadoop_crude(),
    )?;
    let solve = session.solve()?;
    let train_secs = t0.elapsed().as_secs_f64();

    // Loss curve (every TRON iteration's objective, stamped with the
    // communication the solve had spent by then).
    println!("\n== loss curve (TRON objective per accepted iteration) ==");
    for (i, pt) in solve.stats.curve.iter().enumerate() {
        if i % 10 == 0 || i + 1 == solve.stats.curve.len() {
            println!(
                "iter {i:4}  f = {:.4e}  |g| = {:.3e}  ({} comm rounds in)",
                pt.f, pt.gnorm, pt.comm_rounds
            );
        }
    }

    // Distributed, metered scoring on the live cluster: shows up as the
    // `predict` row in both slicings below.
    let t1 = std::time::Instant::now();
    let acc = session.accuracy(&test_ds)?;
    let predict_secs = t1.elapsed().as_secs_f64();

    println!("\n== Algorithm-1 cost slicing (wall, single core) ==");
    let mut t = Table::new(&["step", "seconds", "fraction"]);
    let total = session.wall().total_secs();
    for step in Step::all() {
        let secs = session.wall().wall_secs(step);
        if secs > 0.0 {
            t.row(&[
                step.name().into(),
                format!("{secs:.2}"),
                format!("{:.3}", secs / total),
            ]);
        }
    }
    print!("{}", t.render());

    println!("\n== simulated 8-node Hadoop-crude ledger (incl. prediction) ==");
    print!("{}", session.sim().report());
    // The ~5N analytic claim is about TRAINING collectives, so read the
    // count from the solve-time snapshot (prediction traffic excluded).
    println!(
        "training comm instances: {}  (≈5N of the paper's analysis; N = {} TRON iters)",
        solve.sim.comm_instances(),
        solve.stats.iterations
    );

    println!("\ntrain wall: {train_secs:.1}s   predict wall: {predict_secs:.1}s");
    println!("backend dispatches: {}", backend.call_count());
    println!("TEST ACCURACY: {acc:.4}");
    println!(
        "(objective {:.1} -> {:.1}, converged={})",
        solve.stats.f0(),
        solve.stats.final_f,
        solve.stats.converged
    );
    Ok(())
}
