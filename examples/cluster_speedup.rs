//! Parallel speed-up on the simulated cluster (the Fig-2 experiment in
//! miniature): sweep the node count p — one `Session` per p, solved once —
//! report simulated Total time and Other (non-TRON) time, and show the
//! latency-accumulation effect that flattens Covtype's total-time speed-up
//! on a crude AllReduce.
//!
//! Run: cargo run --release --example cluster_speedup

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{Backend, Settings};
use dkm::coordinator::Session;
use dkm::data::synth;
use dkm::metrics::{Step, Table};
use dkm::runtime::make_backend;

fn main() -> dkm::Result<()> {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = 6_000;
    spec.n_test = 500;
    let (train_ds, _) = synth::generate(&spec, 11);
    let backend = make_backend(Backend::Native, "artifacts")?;

    let ps = [1usize, 2, 4, 8, 16, 32];
    let mut rows = Vec::new();
    for &p in &ps {
        let settings = Settings {
            m: 512,
            nodes: p,
            max_iters: 100,
            ..Settings::default().with_dataset_defaults("covtype_like")
        };
        let mut session = Session::build(
            &settings,
            &train_ds,
            Arc::clone(&backend),
            CostModel::hadoop_crude(),
        )?;
        let solve = session.solve()?;
        rows.push((
            p,
            solve.sim.total_secs(),
            solve.sim.other_secs(),
            solve.sim.comm_secs(Step::Tron),
        ));
    }
    let (_, t1, o1, _) = rows[0];
    let mut table = Table::new(&[
        "nodes", "total_s", "other_s", "tron_comm_s", "speedup(total)", "speedup(other)",
    ]);
    for &(p, total, other, comm) in &rows {
        table.row(&[
            p.to_string(),
            format!("{total:.2}"),
            format!("{other:.2}"),
            format!("{comm:.2}"),
            format!("{:.2}", t1 / total),
            format!("{:.2}", o1 / other),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nNote the Fig-2 mechanism: 'other' time (kernel compute) scales \
         nearly linearly with p, while total time flattens because the \
         ~5N per-iteration AllReduce latencies (N TRON iterations) do not \
         shrink with p."
    );
    Ok(())
}
