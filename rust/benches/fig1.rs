//! Figure 1: test accuracy versus m for Covtype-like (left) and CCAT-like
//! (right).
//!
//! Paper: accuracy rises fast at small m, then climbs slowly; Covtype does
//! not saturate even at m = 51200 (support vectors > n/2), CCAT saturates
//! early. Generated with stage-wise training (one kernel pass, graded m) —
//! itself one of formulation (4)'s selling points.

#[path = "common/mod.rs"]
mod common;

use dkm::coordinator::trainer::train_stagewise;
use dkm::metrics::Table;
use std::sync::Arc;

fn run(name: &str, n: usize, ntest: usize, stages: &[usize]) {
    let (train_ds, test_ds) = common::dataset(name, n, ntest, 42);
    let mut stages: Vec<usize> = stages
        .iter()
        .map(|&m| common::clamp_m(m, train_ds.n()))
        .collect();
    stages.dedup();
    let stages = &stages[..];
    let backend = common::backend();
    let s = common::settings(name, 0, 8);
    let outs = train_stagewise(&s, &train_ds, Arc::clone(&backend), common::free(), stages)
        .unwrap_or_else(|e| panic!("{name}: {e:#}"));
    let mut table = Table::new(&["m", "accuracy", "tron iters", "stage secs"]);
    let mut prev = 0.0f64;
    let mut series = Vec::new();
    for st in &outs {
        let acc = st.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        series.push((st.m, acc));
        table.row(&[
            st.m.to_string(),
            format!("{acc:.4}"),
            st.stats.iterations.to_string(),
            format!("{:.2}", st.stage_wall_secs),
        ]);
        prev = acc;
    }
    let _ = prev;
    println!("\n--- {name} (n={}) ---", train_ds.n());
    print!("{}", table.render());
    // ASCII sparkline of the accuracy curve.
    let lo = series.iter().map(|&(_, a)| a).fold(1.0f64, f64::min);
    let hi = series.iter().map(|&(_, a)| a).fold(0.0f64, f64::max);
    let bars: String = series
        .iter()
        .map(|&(_, a)| {
            let t = if hi > lo { (a - lo) / (hi - lo) } else { 1.0 };
            [' ', '.', ':', '-', '=', '#'][(t * 5.0).round() as usize]
        })
        .collect();
    println!("accuracy curve (low→high m): [{bars}]  range {lo:.3}..{hi:.3}");
}

fn main() {
    common::header(
        "FIGURE 1 — test accuracy vs m",
        "Fig 1 (§4.2): 'Need for large m'",
    );
    run("covtype_like", 12_000, 3_000, &[100, 200, 400, 800, 1600, 3200]);
    run("ccat_like", 8_000, 2_000, &[100, 200, 400, 800, 1600]);
    println!(
        "\nshape check vs paper: covtype_like keeps climbing at the largest\n\
         m (unsaturated — Fig 1 left), ccat_like flattens early (Fig 1 right)."
    );
}
