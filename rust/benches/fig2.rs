//! Figure 2: parallel speed-up versus node count, Covtype-like (left) and
//! MNIST8m-like (right).
//!
//! Paper: on the crude Hadoop AllReduce, Covtype's *Total time* speed-up
//! flattens (the 5N·C latency term is independent of p and dominates when
//! local compute is small), while *Other time* (the non-TRON Algorithm-1
//! steps — test-set prediction is NOT one and is excluded) scales well;
//! MNIST8m's heavy kernel compute makes even Total time scale
//! near-linearly. p is swept on the simulated-time ledger: per-node
//! compute is measured, communication is priced C + D·B per tree level.
//! Covtype used 25 nodes as reference in the paper; MNIST8m used 100.
//!
//! Runs use the default FUSED evaluation pipeline (one AllReduce
//! round-trip per TRON evaluation); each sweep ends with a fused-vs-split
//! comparison at the largest p, where the latency term the fusion halves
//! is most dominant.

#[path = "common/mod.rs"]
mod common;

use dkm::cluster::CostModel;
use dkm::config::settings::EvalPipeline;
use dkm::coordinator::train;
use dkm::metrics::{Step, Table};
use std::sync::Arc;

/// The crude-Hadoop latency scaled by the same ~10x factor as the
/// workloads (DESIGN.md §2: the observable is the compute:latency ratio;
/// keeping the paper's absolute 30 ms against 100x-smaller datasets would
/// put EVERY dataset in the latency-collapse regime, not just Covtype).
fn scaled_hadoop() -> CostModel {
    CostModel {
        latency_s: 3e-3,
        per_byte_s: 1.0 / 100e6,
    }
}

fn run(name: &str, n: usize, ntest: usize, m: usize, ps: &[usize]) {
    let (train_ds, _) = common::dataset(name, n, ntest, 42);
    let m = common::clamp_m(m, train_ds.n());
    let backend = common::backend();
    let mut rows = Vec::new();
    for &p in ps {
        let s = common::settings(name, m, p);
        let out = train(&s, &train_ds, Arc::clone(&backend), scaled_hadoop()).unwrap();
        rows.push((
            p,
            out.sim.total_secs(),
            out.sim.other_secs(),
            out.sim.comm_secs(Step::Tron),
            out.sim.comm_rounds(),
            out.stats.iterations,
        ));
        println!("  done {name} p={p}");
    }
    let (_, t_ref, o_ref, _, _, _) = rows[0];
    println!("\n--- {name} (n={}, m={m}; reference p={}) ---", train_ds.n(), ps[0]);
    let mut table = Table::new(&[
        "nodes",
        "total_s",
        "other_s",
        "tron_comm_s",
        "reduce_rts",
        "speedup total",
        "speedup other",
        "iters",
    ]);
    for &(p, total, other, comm, rts, iters) in &rows {
        table.row(&[
            p.to_string(),
            format!("{total:.2}"),
            format!("{other:.2}"),
            format!("{comm:.2}"),
            rts.to_string(),
            format!("{:.2}", t_ref / total * ps[0] as f64),
            format!("{:.2}", o_ref / other * ps[0] as f64),
            iters.to_string(),
        ]);
    }
    print!("{}", table.render());

    // Fused-vs-split at the largest p — the latency-collapse regime where
    // halving the AllReduce round-trips matters most.
    let p = *ps.last().unwrap();
    let mut s = common::settings(name, m, p);
    s.eval_pipeline = EvalPipeline::Split;
    let split = train(&s, &train_ds, Arc::clone(&backend), scaled_hadoop()).unwrap();
    let &(_, fused_total, _, fused_comm, fused_rts, _) = rows.last().unwrap();
    let evals = (split.fg_evals + split.hd_evals) as f64;
    println!(
        "fused vs split at p={p}: {fused_rts} vs {} reduce round-trips \
         ({:.2} vs {:.2} rts/eval), tron comm {fused_comm:.2}s vs {:.2}s, \
         total {fused_total:.2}s vs {:.2}s",
        split.sim.comm_rounds(),
        fused_rts as f64 / evals,
        split.sim.comm_rounds() as f64 / evals,
        split.sim.comm_secs(Step::Tron),
        split.sim.total_secs(),
    );
}

fn main() {
    common::header(
        "FIGURE 2 — parallel speed-up vs nodes (simulated-time ledger)",
        "Fig 2 (§4.4): latency accumulation flattens Covtype's total-time speed-up",
    );
    run("covtype_like", 8_000, 1_000, 512, &[1, 2, 4, 8, 16, 32]);
    run("mnist8m_like", 16_000, 1_000, 1600, &[1, 2, 4, 8, 16, 32]);
    println!(
        "\nshape check vs paper: covtype_like total-time speed-up flattens\n\
         (comm ≈ constant in p, local compute small); its other-time\n\
         speed-up stays near-linear. mnist8m_like's kernel compute\n\
         dominates, so total-time speed-up is near-linear (Fig 2 right)."
    );
}
