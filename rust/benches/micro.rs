//! Micro benchmarks: the building-block costs behind every table —
//! AllReduce round-trips, kernel-tile throughput (PJRT vs native), SIMD
//! microkernel GFLOP/s vs a naive scalar baseline, tile dispatch
//! overhead, TRON op latency, and dispatches per TRON evaluation
//! (per-tile drivers vs the whole-node block ops).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use dkm::cluster::{Cluster, CostModel};
use dkm::config::settings::{CStorage, Loss};
use dkm::coordinator::make_store;
use dkm::linalg::Mat;
use dkm::metrics::{Step, Table};
use dkm::rng::Rng;
use dkm::runtime::backend::NativeCompute;
use dkm::runtime::native;
use dkm::runtime::tiles::{TB, TM};
use dkm::runtime::Compute;

// ---- naive scalar baselines (the "before" of the SIMD microkernels) ----
// Sequential-accumulation textbook forms: the reductions cannot be
// auto-vectorized (f32 addition is not associative), so these measure what
// the microkernels replaced.

fn kernel_block_naive(x: &[f32], z: &[f32], d: usize, gamma: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; TB * TM];
    for i in 0..TB {
        for k in 0..TM {
            let mut d2 = 0.0f32;
            for t in 0..d {
                let diff = x[i * d + t] - z[k * d + t];
                d2 += diff * diff;
            }
            out[i * TM + k] = (-gamma * d2).exp();
        }
    }
    out
}

fn gemm_nn_naive(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0f32;
            for k in 0..a.cols() {
                s += a.at(i, k) * b.at(k, j);
            }
            *out.at_mut(i, j) = s;
        }
    }
    out
}

fn matvec_naive(a: &Mat, x: &[f32], y: &mut [f32]) {
    for i in 0..a.rows() {
        let mut s = 0.0f32;
        for (av, xv) in a.row(i).iter().zip(x) {
            s += av * xv;
        }
        y[i] = s;
    }
}

fn matvec_t_naive(a: &Mat, r: &[f32], y: &mut [f32]) {
    for j in 0..a.cols() {
        let mut s = 0.0f32;
        for i in 0..a.rows() {
            s += r[i] * a.at(i, j);
        }
        y[j] = s;
    }
}

fn time<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    // one warmup
    std::hint::black_box(f());
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    common::header("MICRO — building-block costs", "§3.1 cost analysis");
    let mut rng = Rng::new(1);

    // --- AllReduce round trip (data movement, not the priced ledger) ---
    println!("\nallreduce wall time per call (in-process tree):");
    let mut table = Table::new(&["p", "len", "usec/call"]);
    for p in [4usize, 16, 64] {
        for len in [256usize, 4096, 65536] {
            let partials: Vec<Vec<f32>> = (0..p)
                .map(|_| (0..len).map(|_| rng.normal_f32()).collect())
            .collect();
            let secs = time(20, || {
                let mut cl = Cluster::new(vec![(); p], 2, CostModel::free());
                cl.allreduce_sum(Step::Tron, partials.clone())
            });
            table.row(&[p.to_string(), len.to_string(), format!("{:.1}", secs * 1e6)]);
        }
    }
    print!("{}", table.render());

    // --- kernel tile throughput: PJRT vs native ---
    println!("\nRBF kernel tile (TB x TM), GFLOP/s (2*TB*TM*D flops):");
    let pjrt = common::backend();
    let native = common::native_backend();
    let mut table = Table::new(&["D", "pjrt ms", "pjrt GF/s", "native ms", "native GF/s"]);
    for d in [64usize, 256, 1024] {
        let x: Vec<f32> = (0..TB * d).map(|_| rng.normal_f32()).collect();
        let z: Vec<f32> = (0..TM * d).map(|_| rng.normal_f32()).collect();
        let flops = (2 * TB * TM * d) as f64;
        let sp = time(10, || pjrt.kernel_block(&x, &z, d, 0.5).unwrap());
        let sn = time(10, || native.kernel_block(&x, &z, d, 0.5).unwrap());
        table.row(&[
            d.to_string(),
            format!("{:.2}", sp * 1e3),
            format!("{:.2}", flops / sp / 1e9),
            format!("{:.2}", sn * 1e3),
            format!("{:.2}", flops / sn / 1e9),
        ]);
    }
    print!("{}", table.render());

    // --- SIMD microkernels vs naive scalar baselines, GFLOP/s ---
    println!(
        "\nSIMD microkernels vs naive scalar baselines \
         (kernel/gemm: TBxTMxd; matvec: TBxd), GFLOP/s:"
    );
    let mut table = Table::new(&["op", "d", "scalar GF/s", "simd GF/s", "speedup"]);
    let mut min_speedup_at_256p = f64::INFINITY;
    for d in [64usize, 256, 784] {
        let x: Vec<f32> = (0..TB * d).map(|_| rng.normal_f32()).collect();
        let z: Vec<f32> = (0..TM * d).map(|_| rng.normal_f32()).collect();
        let a = Mat::from_vec(TB, d, x.clone());
        let b = Mat::from_vec(d, TM, (0..d * TM).map(|_| rng.normal_f32()).collect());
        let xv: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let rv: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
        let mut yb = vec![0.0f32; TB];
        let mut yd = vec![0.0f32; d];
        let tile_flops = (2 * TB * TM * d) as f64;
        let mv_flops = (2 * TB * d) as f64;
        let reps = if d >= 784 { 5 } else { 10 };
        let cases: [(&str, f64, f64, f64); 4] = [
            (
                "kernel_block",
                tile_flops,
                time(reps, || kernel_block_naive(&x, &z, d, 0.5)),
                time(reps, || native::kernel_block(&x, &z, d, 0.5)),
            ),
            (
                "gemm_nn",
                tile_flops,
                time(reps, || gemm_nn_naive(&a, &b)),
                time(reps, || a.gemm_nn(&b)),
            ),
            (
                "matvec",
                mv_flops,
                time(50, || matvec_naive(&a, &xv, &mut yb)),
                time(50, || a.matvec(&xv, &mut yb)),
            ),
            (
                "matvec_t",
                mv_flops,
                time(50, || matvec_t_naive(&a, &rv, &mut yd)),
                time(50, || a.matvec_t(&rv, &mut yd)),
            ),
        ];
        for (op, flops, s_naive, s_simd) in cases {
            let speedup = s_naive / s_simd;
            if d >= 256 && (op == "kernel_block" || op == "gemm_nn") {
                min_speedup_at_256p = min_speedup_at_256p.min(speedup);
            }
            table.row(&[
                op.into(),
                d.to_string(),
                format!("{:.2}", flops / s_naive / 1e9),
                format!("{:.2}", flops / s_simd / 1e9),
                format!("{:.1}x", speedup),
            ]);
        }
    }
    print!("{}", table.render());
    // The tentpole throughput contract: register-blocked kernels at least
    // double the scalar baseline on the wide shapes. (Skipped under the
    // scalar-fallback CI feature, whose whole point is to defeat SIMD.)
    if cfg!(not(feature = "scalar-fallback")) {
        assert!(
            min_speedup_at_256p >= 2.0,
            "kernel_block/gemm_nn speedup at d >= 256 fell below 2x: {min_speedup_at_256p:.2}x"
        );
    }

    // --- dispatch overhead: smallest op round trip ---
    let o: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
    let y = vec![1.0f32; TB];
    let mask = vec![1.0f32; TB];
    let s_pjrt = time(
        50,
        || pjrt.loss_stage(dkm::config::settings::Loss::SqHinge, &o, &y, &mask).unwrap(),
    );
    let s_nat = time(
        50,
        || native.loss_stage(dkm::config::settings::Loss::SqHinge, &o, &y, &mask).unwrap(),
    );
    println!(
        "\nsmallest-op dispatch (loss tile): pjrt {:.1} us, native {:.1} us -> \
         PJRT per-call overhead ≈ {:.1} us",
        s_pjrt * 1e6,
        s_nat * 1e6,
        (s_pjrt - s_nat) * 1e6
    );

    // --- matvec family per-tile ---
    let c: Vec<f32> = (0..TB * TM).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..TM).map(|_| rng.normal_f32()).collect();
    let r: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
    let mut table = Table::new(&["op", "pjrt us", "native us"]);
    table.row(&[
        "matvec".into(),
        format!("{:.1}", time(50, || pjrt.matvec(&c, &v).unwrap()) * 1e6),
        format!("{:.1}", time(50, || native.matvec(&c, &v).unwrap()) * 1e6),
    ]);
    table.row(&[
        "matvec_t".into(),
        format!("{:.1}", time(50, || pjrt.matvec_t(&c, &r).unwrap()) * 1e6),
        format!("{:.1}", time(50, || native.matvec_t(&c, &r).unwrap()) * 1e6),
    ]);
    table.row(&[
        "fgrad fused".into(),
        format!(
            "{:.1}",
            time(50, || pjrt
                .fgrad(dkm::config::settings::Loss::SqHinge, &c, &v, &y, &mask)
                .unwrap())
                * 1e6
        ),
        format!(
            "{:.1}",
            time(50, || native
                .fgrad(dkm::config::settings::Loss::SqHinge, &c, &v, &y, &mask)
                .unwrap())
                * 1e6
        ),
    ]);
    print!("{}", table.render());

    // --- prepared-operand (persistent device buffer) hot path ---
    println!("\nprepared-operand path (C tile uploaded once — the §Perf optimization):");
    let loss = dkm::config::settings::Loss::SqHinge;
    let cp = pjrt.prepare(&c, &[TB, TM]).unwrap();
    let yp = pjrt.prepare(&y, &[TB]).unwrap();
    let mp = pjrt.prepare(&mask, &[TB]).unwrap();
    let cn = native.prepare(&c, &[TB, TM]).unwrap();
    let yn = native.prepare(&y, &[TB]).unwrap();
    let mn = native.prepare(&mask, &[TB]).unwrap();
    let mut table = Table::new(&["op", "pjrt us", "native us", "pjrt speedup vs unprepared"]);
    let un_mv = time(50, || pjrt.matvec(&c, &v).unwrap());
    let p_mv = time(50, || pjrt.matvec_p(&cp, &v).unwrap());
    table.row(&[
        "matvec_p".into(),
        format!("{:.1}", p_mv * 1e6),
        format!("{:.1}", time(50, || native.matvec_p(&cn, &v).unwrap()) * 1e6),
        format!("{:.1}x", un_mv / p_mv),
    ]);
    let un_fg = time(50, || pjrt.fgrad(loss, &c, &v, &y, &mask).unwrap());
    let p_fg = time(50, || pjrt.fgrad_p(loss, &cp, &v, &yp, &mp).unwrap());
    table.row(&[
        "fgrad_p".into(),
        format!("{:.1}", p_fg * 1e6),
        format!(
            "{:.1}",
            time(50, || native.fgrad_p(loss, &cn, &v, &yn, &mn).unwrap()) * 1e6
        ),
        format!("{:.1}x", un_fg / p_fg),
    ]);
    let dcoef = vec![1.0f32; TB];
    let un_hd = time(50, || pjrt.hd_tile(&c, &v, &dcoef).unwrap());
    let p_hd = time(50, || pjrt.hd_p(&cp, &v, &dcoef).unwrap());
    table.row(&[
        "hd_p".into(),
        format!("{:.1}", p_hd * 1e6),
        format!("{:.1}", time(50, || native.hd_p(&cn, &v, &dcoef).unwrap()) * 1e6),
        format!("{:.1}x", un_hd / p_hd),
    ]);
    print!("{}", table.render());

    // --- streaming (from-features) ops: the --c-storage streaming cost ---
    println!("\nstreaming C ops (kernel tile recomputed per dispatch) vs prepared C:");
    let d = 64usize;
    let xs: Vec<f32> = (0..TB * d).map(|_| rng.normal_f32()).collect();
    let zs: Vec<f32> = (0..TM * d).map(|_| rng.normal_f32()).collect();
    let xp = native.prepare(&xs, &[TB, d]).unwrap();
    let zp = native.prepare(&zs, &[TM, d]).unwrap();
    let cs = native.kernel_block(&xs, &zs, d, 0.5).unwrap();
    let csp = native.prepare(&cs, &[TB, TM]).unwrap();
    let mut table = Table::new(&["op", "prepared us", "from_x us", "recompute factor"]);
    let p_fgx = time(50, || native.fgrad_p(loss, &csp, &v, &yn, &mn).unwrap());
    let s_fgx = time(
        50,
        || native.fgrad_from_x(loss, &xp, &zp, d, 0.5, &v, &yn, &mn).unwrap(),
    );
    table.row(&[
        "fgrad".into(),
        format!("{:.1}", p_fgx * 1e6),
        format!("{:.1}", s_fgx * 1e6),
        format!("{:.1}x", s_fgx / p_fgx),
    ]);
    let p_hdx = time(50, || native.hd_p(&csp, &v, &dcoef).unwrap());
    let s_hdx = time(50, || native.hd_from_x(&xp, &zp, d, 0.5, &v, &dcoef).unwrap());
    table.row(&[
        "hd".into(),
        format!("{:.1}", p_hdx * 1e6),
        format!("{:.1}", s_hdx * 1e6),
        format!("{:.1}x", s_hdx / p_hdx),
    ]);
    print!("{}", table.render());

    // --- dispatches per TRON evaluation: per-tile vs whole-node block ---
    // One node, 2 row tiles, driven through its CBlockStore three ways:
    // the split per-tile loop (matvec + loss stage + matvec_t per column
    // tile), the fused per-tile ops (single column tile only), and the
    // whole-node block ops — backend call-count deltas per f/g and Hd
    // evaluation. The block ops cost ONE dispatch regardless of shape.
    println!("\ndispatches per evaluation (one node, 2 row tiles, materialized C):");
    let nb = NativeCompute::new();
    let dd = 64usize;
    let rows = 300usize; // 2 row tiles of TB
    let rt = 2usize;
    let x_tiles: Vec<Vec<f32>> = (0..rt)
        .map(|_| (0..TB * dd).map(|_| rng.normal_f32()).collect())
        .collect();
    let x_prep = Arc::new(
        x_tiles
            .iter()
            .map(|t| nb.prepare(t, &[TB, dd]).unwrap())
            .collect::<Vec<_>>(),
    );
    let y_tiles: Vec<Vec<f32>> = (0..rt).map(|_| vec![1.0f32; TB]).collect();
    let masks: Vec<Vec<f32>> = (0..rt).map(|_| vec![1.0f32; TB]).collect();
    let y_prep: Vec<_> = y_tiles.iter().map(|t| nb.prepare(t, &[TB]).unwrap()).collect();
    let mask_prep: Vec<_> = masks.iter().map(|t| nb.prepare(t, &[TB]).unwrap()).collect();
    let mut table = Table::new(&["driver", "col tiles", "f/g dispatches", "Hd dispatches"]);
    for m_cols in [200usize, 300] {
        let ct = m_cols.div_ceil(TM).max(1);
        let z_tiles: Vec<Vec<f32>> = (0..ct)
            .map(|_| (0..TM * dd).map(|_| rng.normal_f32()).collect())
            .collect();
        let z_prep = Arc::new(
            z_tiles
                .iter()
                .map(|t| nb.prepare(t, &[TM, dd]).unwrap())
                .collect::<Vec<_>>(),
        );
        let mut store = make_store(CStorage::Materialized, 0);
        store
            .rebuild(&nb, &x_prep, &z_prep, rows, m_cols, 0.5, dd, 0..ct, &[])
            .unwrap();
        let v_tiles: Vec<Vec<f32>> = (0..ct)
            .map(|_| (0..TM).map(|_| rng.normal_f32()).collect())
            .collect();

        // Whole-node block drive (also yields dcoef for the per-tile Hd).
        let c0 = nb.call_count();
        let blk = store
            .fgrad_block(&nb, Loss::SqHinge, &v_tiles, &y_prep, &mask_prep, &y_tiles, &masks)
            .unwrap();
        let block_fg = nb.call_count() - c0;
        let c0 = nb.call_count();
        store.hd_block(&nb, &v_tiles, &blk.dcoef).unwrap();
        let block_hd = nb.call_count() - c0;

        // Split per-tile drive: the pre-block coordinator loop.
        let c0 = nb.call_count();
        for i in 0..rt {
            let mut o = vec![0.0f32; TB];
            for (j, vj) in v_tiles.iter().enumerate() {
                let part = store.matvec_tile(&nb, i, j, vj).unwrap();
                for (av, bv) in o.iter_mut().zip(&part) {
                    *av += bv;
                }
            }
            let stage = nb.loss_stage(Loss::SqHinge, &o, &y_tiles[i], &masks[i]).unwrap();
            for j in 0..ct {
                store.matvec_t_tile(&nb, i, j, &stage.vec).unwrap();
            }
        }
        let split_fg = nb.call_count() - c0;
        let c0 = nb.call_count();
        for i in 0..rt {
            let mut zv = vec![0.0f32; TB];
            for (j, vj) in v_tiles.iter().enumerate() {
                let part = store.matvec_tile(&nb, i, j, vj).unwrap();
                for (av, bv) in zv.iter_mut().zip(&part) {
                    *av += bv;
                }
            }
            for (zi, w) in zv.iter_mut().zip(&blk.dcoef[i]) {
                *zi *= w;
            }
            for j in 0..ct {
                store.matvec_t_tile(&nb, i, j, &zv).unwrap();
            }
        }
        let split_hd = nb.call_count() - c0;
        table.row(&[
            format!("per-tile split (2x{ct})"),
            ct.to_string(),
            split_fg.to_string(),
            split_hd.to_string(),
        ]);

        // Fused per-tile ops exist for the single-column-tile shape only.
        if ct == 1 {
            let c0 = nb.call_count();
            for i in 0..rt {
                store
                    .fgrad_tile(&nb, Loss::SqHinge, i, &v_tiles[0], &y_prep[i], &mask_prep[i])
                    .unwrap();
            }
            let fused_fg = nb.call_count() - c0;
            let c0 = nb.call_count();
            for i in 0..rt {
                store.hd_tile(&nb, i, &v_tiles[0], &blk.dcoef[i]).unwrap();
            }
            let fused_hd = nb.call_count() - c0;
            table.row(&[
                format!("per-tile fused (2x{ct})"),
                ct.to_string(),
                fused_fg.to_string(),
                fused_hd.to_string(),
            ]);
        }
        table.row(&[
            format!("whole-node block (2x{ct})"),
            ct.to_string(),
            block_fg.to_string(),
            block_hd.to_string(),
        ]);
        assert_eq!(block_fg, 1, "block f/g must be one dispatch");
        assert_eq!(block_hd, 1, "block Hd must be one dispatch");
    }
    print!("{}", table.render());

    // --- matvec_t guard: when does the xi != 0 sparsity skip pay? ---
    // Mat::matvec_t keeps its guard (sq-hinge residuals are mostly exact
    // zeros near convergence); Mat::gemm_nn dropped its copy (kernel-matrix
    // operands are never zero). This section is the measurement behind both
    // decisions.
    println!("\nMat::matvec_t sparsity guard (1000x400), usec/call:");
    let a = Mat::from_fn(1000, 400, |_, _| rng.normal_f32());
    let dense_r: Vec<f32> = (0..1000).map(|_| rng.normal_f32()).collect();
    // 90% exact zeros — a converged sq-hinge residual profile.
    let sparse_r: Vec<f32> = (0..1000)
        .map(|i| if i % 10 == 0 { rng.normal_f32() } else { 0.0 })
        .collect();
    let mut y_out = vec![0.0f32; 400];
    let mut table = Table::new(&["input", "guarded us", "unguarded us"]);
    for (name, r) in [("dense", &dense_r), ("90% zeros", &sparse_r)] {
        let guarded = time(200, || a.matvec_t(r, &mut y_out));
        let unguarded = time(200, || {
            // The no-guard variant gemm_nn now uses, inlined on a vector.
            y_out.fill(0.0);
            for i in 0..1000 {
                dkm::linalg::mat::axpy(r[i], a.row(i), &mut y_out);
            }
        });
        table.row(&[
            name.into(),
            format!("{:.1}", guarded * 1e6),
            format!("{:.1}", unguarded * 1e6),
        ]);
    }
    print!("{}", table.render());
}
