//! Micro benchmarks: the building-block costs behind every table —
//! AllReduce round-trips, kernel-tile throughput (PJRT vs native), tile
//! dispatch overhead, TRON op latency.

#[path = "common/mod.rs"]
mod common;

use dkm::cluster::{Cluster, CostModel};
use dkm::metrics::{Step, Table};
use dkm::rng::Rng;
use dkm::runtime::tiles::{TB, TM};

fn time<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    // one warmup
    std::hint::black_box(f());
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    common::header("MICRO — building-block costs", "§3.1 cost analysis");
    let mut rng = Rng::new(1);

    // --- AllReduce round trip (data movement, not the priced ledger) ---
    println!("\nallreduce wall time per call (in-process tree):");
    let mut table = Table::new(&["p", "len", "usec/call"]);
    for p in [4usize, 16, 64] {
        for len in [256usize, 4096, 65536] {
            let partials: Vec<Vec<f32>> = (0..p)
                .map(|_| (0..len).map(|_| rng.normal_f32()).collect())
            .collect();
            let secs = time(20, || {
                let mut cl = Cluster::new(vec![(); p], 2, CostModel::free());
                cl.allreduce_sum(Step::Tron, partials.clone())
            });
            table.row(&[p.to_string(), len.to_string(), format!("{:.1}", secs * 1e6)]);
        }
    }
    print!("{}", table.render());

    // --- kernel tile throughput: PJRT vs native ---
    println!("\nRBF kernel tile (TB x TM), GFLOP/s (2*TB*TM*D flops):");
    let pjrt = common::backend();
    let native = common::native_backend();
    let mut table = Table::new(&["D", "pjrt ms", "pjrt GF/s", "native ms", "native GF/s"]);
    for d in [64usize, 256, 1024] {
        let x: Vec<f32> = (0..TB * d).map(|_| rng.normal_f32()).collect();
        let z: Vec<f32> = (0..TM * d).map(|_| rng.normal_f32()).collect();
        let flops = (2 * TB * TM * d) as f64;
        let sp = time(10, || pjrt.kernel_block(&x, &z, d, 0.5).unwrap());
        let sn = time(10, || native.kernel_block(&x, &z, d, 0.5).unwrap());
        table.row(&[
            d.to_string(),
            format!("{:.2}", sp * 1e3),
            format!("{:.2}", flops / sp / 1e9),
            format!("{:.2}", sn * 1e3),
            format!("{:.2}", flops / sn / 1e9),
        ]);
    }
    print!("{}", table.render());

    // --- dispatch overhead: smallest op round trip ---
    let o: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
    let y = vec![1.0f32; TB];
    let mask = vec![1.0f32; TB];
    let s_pjrt = time(
        50,
        || pjrt.loss_stage(dkm::config::settings::Loss::SqHinge, &o, &y, &mask).unwrap(),
    );
    let s_nat = time(
        50,
        || native.loss_stage(dkm::config::settings::Loss::SqHinge, &o, &y, &mask).unwrap(),
    );
    println!(
        "\nsmallest-op dispatch (loss tile): pjrt {:.1} us, native {:.1} us -> \
         PJRT per-call overhead ≈ {:.1} us",
        s_pjrt * 1e6,
        s_nat * 1e6,
        (s_pjrt - s_nat) * 1e6
    );

    // --- matvec family per-tile ---
    let c: Vec<f32> = (0..TB * TM).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..TM).map(|_| rng.normal_f32()).collect();
    let r: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
    let mut table = Table::new(&["op", "pjrt us", "native us"]);
    table.row(&[
        "matvec".into(),
        format!("{:.1}", time(50, || pjrt.matvec(&c, &v).unwrap()) * 1e6),
        format!("{:.1}", time(50, || native.matvec(&c, &v).unwrap()) * 1e6),
    ]);
    table.row(&[
        "matvec_t".into(),
        format!("{:.1}", time(50, || pjrt.matvec_t(&c, &r).unwrap()) * 1e6),
        format!("{:.1}", time(50, || native.matvec_t(&c, &r).unwrap()) * 1e6),
    ]);
    table.row(&[
        "fgrad fused".into(),
        format!(
            "{:.1}",
            time(50, || pjrt
                .fgrad(dkm::config::settings::Loss::SqHinge, &c, &v, &y, &mask)
                .unwrap())
                * 1e6
        ),
        format!(
            "{:.1}",
            time(50, || native
                .fgrad(dkm::config::settings::Loss::SqHinge, &c, &v, &y, &mask)
                .unwrap())
                * 1e6
        ),
    ]);
    print!("{}", table.render());

    // --- prepared-operand (persistent device buffer) hot path ---
    println!("\nprepared-operand path (C tile uploaded once — the §Perf optimization):");
    let loss = dkm::config::settings::Loss::SqHinge;
    let cp = pjrt.prepare(&c, &[TB, TM]).unwrap();
    let yp = pjrt.prepare(&y, &[TB]).unwrap();
    let mp = pjrt.prepare(&mask, &[TB]).unwrap();
    let cn = native.prepare(&c, &[TB, TM]).unwrap();
    let yn = native.prepare(&y, &[TB]).unwrap();
    let mn = native.prepare(&mask, &[TB]).unwrap();
    let mut table = Table::new(&["op", "pjrt us", "native us", "pjrt speedup vs unprepared"]);
    let un_mv = time(50, || pjrt.matvec(&c, &v).unwrap());
    let p_mv = time(50, || pjrt.matvec_p(&cp, &v).unwrap());
    table.row(&[
        "matvec_p".into(),
        format!("{:.1}", p_mv * 1e6),
        format!("{:.1}", time(50, || native.matvec_p(&cn, &v).unwrap()) * 1e6),
        format!("{:.1}x", un_mv / p_mv),
    ]);
    let un_fg = time(50, || pjrt.fgrad(loss, &c, &v, &y, &mask).unwrap());
    let p_fg = time(50, || pjrt.fgrad_p(loss, &cp, &v, &yp, &mp).unwrap());
    table.row(&[
        "fgrad_p".into(),
        format!("{:.1}", p_fg * 1e6),
        format!(
            "{:.1}",
            time(50, || native.fgrad_p(loss, &cn, &v, &yn, &mn).unwrap()) * 1e6
        ),
        format!("{:.1}x", un_fg / p_fg),
    ]);
    let dcoef = vec![1.0f32; TB];
    let un_hd = time(50, || pjrt.hd_tile(&c, &v, &dcoef).unwrap());
    let p_hd = time(50, || pjrt.hd_p(&cp, &v, &dcoef).unwrap());
    table.row(&[
        "hd_p".into(),
        format!("{:.1}", p_hd * 1e6),
        format!("{:.1}", time(50, || native.hd_p(&cn, &v, &dcoef).unwrap()) * 1e6),
        format!("{:.1}x", un_hd / p_hd),
    ]);
    print!("{}", table.render());
}
