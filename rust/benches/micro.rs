//! Micro benchmarks: the building-block costs behind every table —
//! AllReduce round-trips, kernel-tile throughput (PJRT vs native), tile
//! dispatch overhead, TRON op latency.

#[path = "common/mod.rs"]
mod common;

use dkm::cluster::{Cluster, CostModel};
use dkm::linalg::Mat;
use dkm::metrics::{Step, Table};
use dkm::rng::Rng;
use dkm::runtime::tiles::{TB, TM};

fn time<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    // one warmup
    std::hint::black_box(f());
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    common::header("MICRO — building-block costs", "§3.1 cost analysis");
    let mut rng = Rng::new(1);

    // --- AllReduce round trip (data movement, not the priced ledger) ---
    println!("\nallreduce wall time per call (in-process tree):");
    let mut table = Table::new(&["p", "len", "usec/call"]);
    for p in [4usize, 16, 64] {
        for len in [256usize, 4096, 65536] {
            let partials: Vec<Vec<f32>> = (0..p)
                .map(|_| (0..len).map(|_| rng.normal_f32()).collect())
            .collect();
            let secs = time(20, || {
                let mut cl = Cluster::new(vec![(); p], 2, CostModel::free());
                cl.allreduce_sum(Step::Tron, partials.clone())
            });
            table.row(&[p.to_string(), len.to_string(), format!("{:.1}", secs * 1e6)]);
        }
    }
    print!("{}", table.render());

    // --- kernel tile throughput: PJRT vs native ---
    println!("\nRBF kernel tile (TB x TM), GFLOP/s (2*TB*TM*D flops):");
    let pjrt = common::backend();
    let native = common::native_backend();
    let mut table = Table::new(&["D", "pjrt ms", "pjrt GF/s", "native ms", "native GF/s"]);
    for d in [64usize, 256, 1024] {
        let x: Vec<f32> = (0..TB * d).map(|_| rng.normal_f32()).collect();
        let z: Vec<f32> = (0..TM * d).map(|_| rng.normal_f32()).collect();
        let flops = (2 * TB * TM * d) as f64;
        let sp = time(10, || pjrt.kernel_block(&x, &z, d, 0.5).unwrap());
        let sn = time(10, || native.kernel_block(&x, &z, d, 0.5).unwrap());
        table.row(&[
            d.to_string(),
            format!("{:.2}", sp * 1e3),
            format!("{:.2}", flops / sp / 1e9),
            format!("{:.2}", sn * 1e3),
            format!("{:.2}", flops / sn / 1e9),
        ]);
    }
    print!("{}", table.render());

    // --- dispatch overhead: smallest op round trip ---
    let o: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
    let y = vec![1.0f32; TB];
    let mask = vec![1.0f32; TB];
    let s_pjrt = time(
        50,
        || pjrt.loss_stage(dkm::config::settings::Loss::SqHinge, &o, &y, &mask).unwrap(),
    );
    let s_nat = time(
        50,
        || native.loss_stage(dkm::config::settings::Loss::SqHinge, &o, &y, &mask).unwrap(),
    );
    println!(
        "\nsmallest-op dispatch (loss tile): pjrt {:.1} us, native {:.1} us -> \
         PJRT per-call overhead ≈ {:.1} us",
        s_pjrt * 1e6,
        s_nat * 1e6,
        (s_pjrt - s_nat) * 1e6
    );

    // --- matvec family per-tile ---
    let c: Vec<f32> = (0..TB * TM).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..TM).map(|_| rng.normal_f32()).collect();
    let r: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
    let mut table = Table::new(&["op", "pjrt us", "native us"]);
    table.row(&[
        "matvec".into(),
        format!("{:.1}", time(50, || pjrt.matvec(&c, &v).unwrap()) * 1e6),
        format!("{:.1}", time(50, || native.matvec(&c, &v).unwrap()) * 1e6),
    ]);
    table.row(&[
        "matvec_t".into(),
        format!("{:.1}", time(50, || pjrt.matvec_t(&c, &r).unwrap()) * 1e6),
        format!("{:.1}", time(50, || native.matvec_t(&c, &r).unwrap()) * 1e6),
    ]);
    table.row(&[
        "fgrad fused".into(),
        format!(
            "{:.1}",
            time(50, || pjrt
                .fgrad(dkm::config::settings::Loss::SqHinge, &c, &v, &y, &mask)
                .unwrap())
                * 1e6
        ),
        format!(
            "{:.1}",
            time(50, || native
                .fgrad(dkm::config::settings::Loss::SqHinge, &c, &v, &y, &mask)
                .unwrap())
                * 1e6
        ),
    ]);
    print!("{}", table.render());

    // --- prepared-operand (persistent device buffer) hot path ---
    println!("\nprepared-operand path (C tile uploaded once — the §Perf optimization):");
    let loss = dkm::config::settings::Loss::SqHinge;
    let cp = pjrt.prepare(&c, &[TB, TM]).unwrap();
    let yp = pjrt.prepare(&y, &[TB]).unwrap();
    let mp = pjrt.prepare(&mask, &[TB]).unwrap();
    let cn = native.prepare(&c, &[TB, TM]).unwrap();
    let yn = native.prepare(&y, &[TB]).unwrap();
    let mn = native.prepare(&mask, &[TB]).unwrap();
    let mut table = Table::new(&["op", "pjrt us", "native us", "pjrt speedup vs unprepared"]);
    let un_mv = time(50, || pjrt.matvec(&c, &v).unwrap());
    let p_mv = time(50, || pjrt.matvec_p(&cp, &v).unwrap());
    table.row(&[
        "matvec_p".into(),
        format!("{:.1}", p_mv * 1e6),
        format!("{:.1}", time(50, || native.matvec_p(&cn, &v).unwrap()) * 1e6),
        format!("{:.1}x", un_mv / p_mv),
    ]);
    let un_fg = time(50, || pjrt.fgrad(loss, &c, &v, &y, &mask).unwrap());
    let p_fg = time(50, || pjrt.fgrad_p(loss, &cp, &v, &yp, &mp).unwrap());
    table.row(&[
        "fgrad_p".into(),
        format!("{:.1}", p_fg * 1e6),
        format!(
            "{:.1}",
            time(50, || native.fgrad_p(loss, &cn, &v, &yn, &mn).unwrap()) * 1e6
        ),
        format!("{:.1}x", un_fg / p_fg),
    ]);
    let dcoef = vec![1.0f32; TB];
    let un_hd = time(50, || pjrt.hd_tile(&c, &v, &dcoef).unwrap());
    let p_hd = time(50, || pjrt.hd_p(&cp, &v, &dcoef).unwrap());
    table.row(&[
        "hd_p".into(),
        format!("{:.1}", p_hd * 1e6),
        format!("{:.1}", time(50, || native.hd_p(&cn, &v, &dcoef).unwrap()) * 1e6),
        format!("{:.1}x", un_hd / p_hd),
    ]);
    print!("{}", table.render());

    // --- streaming (from-features) ops: the --c-storage streaming cost ---
    println!("\nstreaming C ops (kernel tile recomputed per dispatch) vs prepared C:");
    let d = 64usize;
    let xs: Vec<f32> = (0..TB * d).map(|_| rng.normal_f32()).collect();
    let zs: Vec<f32> = (0..TM * d).map(|_| rng.normal_f32()).collect();
    let xp = native.prepare(&xs, &[TB, d]).unwrap();
    let zp = native.prepare(&zs, &[TM, d]).unwrap();
    let cs = native.kernel_block(&xs, &zs, d, 0.5).unwrap();
    let csp = native.prepare(&cs, &[TB, TM]).unwrap();
    let mut table = Table::new(&["op", "prepared us", "from_x us", "recompute factor"]);
    let p_fgx = time(50, || native.fgrad_p(loss, &csp, &v, &yn, &mn).unwrap());
    let s_fgx = time(
        50,
        || native.fgrad_from_x(loss, &xp, &zp, d, 0.5, &v, &yn, &mn).unwrap(),
    );
    table.row(&[
        "fgrad".into(),
        format!("{:.1}", p_fgx * 1e6),
        format!("{:.1}", s_fgx * 1e6),
        format!("{:.1}x", s_fgx / p_fgx),
    ]);
    let p_hdx = time(50, || native.hd_p(&csp, &v, &dcoef).unwrap());
    let s_hdx = time(50, || native.hd_from_x(&xp, &zp, d, 0.5, &v, &dcoef).unwrap());
    table.row(&[
        "hd".into(),
        format!("{:.1}", p_hdx * 1e6),
        format!("{:.1}", s_hdx * 1e6),
        format!("{:.1}x", s_hdx / p_hdx),
    ]);
    print!("{}", table.render());

    // --- matvec_t guard: when does the xi != 0 sparsity skip pay? ---
    // Mat::matvec_t keeps its guard (sq-hinge residuals are mostly exact
    // zeros near convergence); Mat::gemm_nn dropped its copy (kernel-matrix
    // operands are never zero). This section is the measurement behind both
    // decisions.
    println!("\nMat::matvec_t sparsity guard (1000x400), usec/call:");
    let a = Mat::from_fn(1000, 400, |_, _| rng.normal_f32());
    let dense_r: Vec<f32> = (0..1000).map(|_| rng.normal_f32()).collect();
    // 90% exact zeros — a converged sq-hinge residual profile.
    let sparse_r: Vec<f32> = (0..1000)
        .map(|i| if i % 10 == 0 { rng.normal_f32() } else { 0.0 })
        .collect();
    let mut y_out = vec![0.0f32; 400];
    let mut table = Table::new(&["input", "guarded us", "unguarded us"]);
    for (name, r) in [("dense", &dense_r), ("90% zeros", &sparse_r)] {
        let guarded = time(200, || a.matvec_t(r, &mut y_out));
        let unguarded = time(200, || {
            // The no-guard variant gemm_nn now uses, inlined on a vector.
            y_out.fill(0.0);
            for i in 0..1000 {
                dkm::linalg::mat::axpy(r[i], a.row(i), &mut y_out);
            }
        });
        table.row(&[
            name.into(),
            format!("{:.1}", guarded * 1e6),
            format!("{:.1}", unguarded * 1e6),
        ]);
    }
    print!("{}", table.render());
}
