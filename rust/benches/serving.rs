//! SERVING — the concurrent prediction pipeline under load.
//!
//! Three sections:
//!
//! 1. **Overlap.** The same B test-set batches scored (a) one lockstep
//!    dispatch per batch and (b) as ONE multi-slot `predict_many`
//!    dispatch on the pooled executor, where workers pull (batch, shard)
//!    items from any in-flight batch. Every score is asserted bit-identical
//!    to the serial `predict.rs` reference, and on a multi-core host the
//!    bench demonstrates >1 batch genuinely in flight (per-slot execution
//!    spans overlap, or the grouped wall beats the summed per-batch walls).
//! 2. **Closed loop.** N clients with exponential think time against the
//!    bounded micro-batching queue (`dkm serve`'s loop, in-process):
//!    qps + p50/p99 latency on the wall clock, barriers/batch + predict
//!    seconds on the simulated ledger, every reply checked bit-identical.
//! 3. **Skewed fleet.** One simulated shard-server slowed 4×: static vs
//!    work-stealing scheduling on the same batches — scores bit-identical,
//!    ledger bytes/barriers pinned, stolen predict wall under the
//!    straggler bound.
//! 4. **Machine-readable trajectory.** The headline numbers land in
//!    `BENCH_serving.json` so later PRs can diff them.
//!
//! Run: cargo bench --bench serving
//! (DKM_BENCH_SCALE scales the dataset; DKM_THREADS caps the workers.)

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use dkm::cluster::{Executor, Sched, Skew};
use dkm::config::Json;
use dkm::coordinator::{train, ServingSession};
use dkm::linalg::Mat;
use dkm::metrics::{Step, Table};
use dkm::serve::{run as serve_run, ServeConfig};

fn main() {
    common::header(
        "SERVING — multi-slot concurrent batches + closed-loop micro-batching",
        "ROADMAP serving tier; cf. Tu et al. (block saturation), Sindhwani & Avron (serving layer)",
    );
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cap: usize = std::env::var("DKM_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let workers = if cap == 0 { cores } else { cap };
    println!("host cores: {cores}; serving workers: {workers}");

    let (train_ds, test_ds) = common::dataset("covtype_like", 12_000, 1_000, 42);
    let backend = common::native_backend();
    let m = common::clamp_m(400, train_ds.n());
    let nodes = 8;
    let s = common::settings("covtype_like", m, nodes);
    let out = train(&s, &train_ds, Arc::clone(&backend), common::free()).expect("training failed");
    let model = out.model;

    // The request pool and its serial reference scores (predict.rs — the
    // bit-identity anchor for EVERYTHING below).
    let expected = model
        .predict(backend.as_ref(), &test_ds.x)
        .expect("serial predict failed");

    // --- section 1: lockstep per-batch dispatch vs one multi-slot phase ---
    let nb = 8usize;
    let bs = (test_ds.n() / nb).max(1);
    let batches: Vec<Mat> = (0..nb)
        .map(|b| {
            let r0 = b * bs;
            let r1 = ((b + 1) * bs).min(test_ds.n());
            Mat::from_vec(
                r1 - r0,
                test_ds.x.cols(),
                test_ds.x.row_panel(r0, r1).to_vec(),
            )
        })
        .collect();
    let refs: Vec<&Mat> = batches.iter().collect();

    let pooled = ServingSession::load(
        &model,
        Arc::clone(&backend),
        nodes,
        Executor::pooled(workers),
        common::free(),
    )
    .expect("serving load failed");

    // (a) one dispatch per batch (the lockstep shape Session::predict has).
    let t0 = std::time::Instant::now();
    let mut lockstep_scores = Vec::with_capacity(nb);
    let mut per_batch_sum = 0.0f64;
    for x in &refs {
        let t = std::time::Instant::now();
        lockstep_scores.push(pooled.predict_batch(x).expect("predict failed"));
        per_batch_sum += t.elapsed().as_secs_f64();
    }
    let lockstep_wall = t0.elapsed().as_secs_f64();

    // (b) ALL batches in one multi-slot dispatch; a few rounds so one bad
    // scheduling window can't hide the overlap.
    let mut grouped_wall = f64::INFINITY;
    let mut grouped_scores = Vec::new();
    for _ in 0..3 {
        let t = std::time::Instant::now();
        grouped_scores = pooled.predict_many(&refs).expect("predict_many failed");
        grouped_wall = grouped_wall.min(t.elapsed().as_secs_f64());
    }

    // Bit-identity: serial reference vs both paths, per batch.
    let mut at = 0usize;
    for (b, x) in refs.iter().enumerate() {
        let want = &expected[at..at + x.rows()];
        at += x.rows();
        for (path, scores) in [("lockstep", &lockstep_scores[b]), ("grouped", &grouped_scores[b])] {
            assert_eq!(scores.len(), want.len(), "batch {b} {path} length");
            for (i, (a, w)) in scores.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    w.to_bits(),
                    "batch {b} row {i}: {path} path diverged from serial ({a} vs {w})"
                );
            }
        }
    }
    let peak = pooled.peak_slots_in_flight();
    let mut t = Table::new(&["path", "dispatches", "barriers", "wall_s"]);
    t.row(&[
        "one-phase-per-batch".into(),
        format!("{nb}"),
        format!("{nb}"),
        format!("{lockstep_wall:.4}"),
    ]);
    t.row(&[
        "multi-slot (1 dispatch)".into(),
        "1".into(),
        "1".into(),
        format!("{grouped_wall:.4}"),
    ]);
    print!("{}", t.render());
    println!(
        "peak batches in flight: {peak} | grouped {grouped_wall:.4}s vs per-batch sum {per_batch_sum:.4}s ({:.2}x)",
        per_batch_sum / grouped_wall.max(1e-12),
    );
    println!("all {nb} batches bit-identical to the serial scoring loop: YES");
    let overlapped = peak >= 2 || grouped_wall < per_batch_sum;
    if workers >= 2 && nodes >= 2 {
        assert!(
            overlapped,
            ">1 batch should be in flight on a multi-core host \
             (peak {peak}, grouped {grouped_wall:.4}s, summed {per_batch_sum:.4}s)"
        );
    } else {
        println!("single worker: overlap not expected (peak {peak})");
    }

    // --- section 2: closed-loop clients through the micro-batching queue ---
    let cfg = ServeConfig {
        clients: 8,
        requests_per_client: common::scaled(256) / 8,
        mean_think_ms: 0.2,
        max_batch: 32,
        max_delay_ms: 1.0,
        slots: 4,
        queue_cap: 512,
        seed: 7,
    };
    let report = serve_run(&pooled, &test_ds.x, Some(&expected), &cfg).expect("serve run failed");
    println!(
        "\nclosed loop: {} clients × {} requests, flush at {} rows or {}ms, ≤{} micro-batches/dispatch",
        cfg.clients, cfg.requests_per_client, cfg.max_batch, cfg.max_delay_ms, cfg.slots
    );
    print!("{}", report.render());
    assert_eq!(report.mismatches, 0, "served replies diverged from serial");
    assert!(
        report.barriers_per_batch <= 1.0 + 1e-12,
        "micro-batching must never cost more than one barrier per batch \
         (got {:.3})",
        report.barriers_per_batch
    );

    // --- section 2.5: skewed fleet — static vs work-stealing serving ---
    // One simulated shard-server slowed 4× (`--skew 0=4`). Serial executor
    // so the comparison is a pure ledger experiment: identical scores,
    // identical bytes/barriers, but the stolen schedule's simulated
    // predict wall must land well under the static slowest-node bound.
    let skew = Skew::parse("0=4").expect("skew spec");
    let mut skew_sessions = Vec::new();
    for sched in [Sched::Static, Sched::Steal { grain: 4 }] {
        let sess = ServingSession::load(
            &model,
            Arc::clone(&backend),
            nodes,
            Executor::serial(),
            common::free(),
        )
        .expect("serving load failed")
        .with_sched(sched)
        .with_skew(skew.clone());
        let scores = sess.predict_many(&refs).expect("predict_many failed");
        for (b, batch_scores) in scores.iter().enumerate() {
            for (i, (a, w)) in batch_scores.iter().zip(&lockstep_scores[b]).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    w.to_bits(),
                    "skewed {} batch {b} row {i} diverged",
                    sched.name()
                );
            }
        }
        skew_sessions.push((sched, sess));
    }
    let (_, skew_static) = &skew_sessions[0];
    let (_, skew_steal) = &skew_sessions[1];
    let (static_sim, steal_sim) = (skew_static.sim(), skew_steal.sim());
    assert_eq!(static_sim.barriers(), steal_sim.barriers());
    assert_eq!(static_sim.comm_bytes(), steal_sim.comm_bytes());
    let static_wall = static_sim.compute_secs(Step::Predict);
    let steal_wall = steal_sim.compute_secs(Step::Predict);
    assert!(
        steal_wall < 0.8 * static_wall,
        "stealing failed to beat static serving under skew: {steal_wall:.4}s vs {static_wall:.4}s"
    );
    println!(
        "\nskewed fleet ({}, {nodes} shards, serial executor): static predict \
         {static_wall:.4} sim-s (straggler ratio {:.2}x) vs steal:4 {steal_wall:.4} sim-s ({:.2}x faster), \
         scores bit-identical",
        skew.name(),
        static_sim.straggler_ratio(nodes),
        static_wall / steal_wall.max(1e-12),
    );

    // --- section 3: machine-readable trajectory ---
    let mut o = BTreeMap::new();
    let mut num = |k: &str, v: f64| {
        o.insert(k.to_string(), Json::Num(v));
    };
    num("qps", report.qps);
    num("p50_ms", report.p50_ms);
    num("p99_ms", report.p99_ms);
    num("mean_ms", report.mean_ms);
    num("requests", report.requests as f64);
    num("batches", report.batches as f64);
    num("barriers_per_batch", report.barriers_per_batch);
    num("sim_predict_secs", report.sim_predict_secs);
    num("peak_slots_in_flight", pooled.peak_slots_in_flight() as f64);
    num("grouped_wall_s", grouped_wall);
    num("per_batch_sum_s", per_batch_sum);
    num("mismatches", report.mismatches as f64);
    num("skew_static_predict_sim_s", static_wall);
    num("skew_steal_predict_sim_s", steal_wall);
    num("skew_straggler_ratio", static_sim.straggler_ratio(nodes));
    common::write_json("serving", &Json::Obj(o));
}
