//! Table 4: cost slicing of Algorithm-1 steps across datasets and m.
//!
//! Paper: per-dataset, per-m wall seconds for steps 1 (load), 2 (basis
//! bcast), 3 (kernel computation), 4 (TRON). The regime flips the paper
//! calls out: MNIST8m (d=784) is kernel-compute-bound; Covtype (many TRON
//! iterations) is TRON-bound.

#[path = "common/mod.rs"]
mod common;

use dkm::coordinator::train;
use dkm::metrics::{Step, Table};
use std::sync::Arc;

fn main() {
    common::header(
        "TABLE 4 — Algorithm-1 step costs",
        "Table 4 (§4.3): 'Slicing of computational costs' (+ Table 3 specs)",
    );
    // Table 3 echo: the dataset inventory.
    let mut t3 = Table::new(&["dataset", "n(paper)", "n(ours)", "d", "lambda", "sigma"]);
    for (name, n_paper, n_ours, ntest) in [
        ("vehicle_like", "78,823", "6,000", 1_500usize),
        ("covtype_like", "522,910", "12,000", 3_000),
        ("ccat_like", "781,265", "8,000", 2_000),
        ("mnist8m_like", "8,000,000", "12,000", 2_000),
    ] {
        let spec = dkm::data::synth::spec(name);
        let _ = ntest;
        t3.row(&[
            name.into(),
            n_paper.into(),
            n_ours.into(),
            spec.d.to_string(),
            spec.lambda.to_string(),
            spec.sigma.to_string(),
        ]);
    }
    println!("Table 3 (dataset inventory, paper n vs ours):");
    print!("{}", t3.render());

    let backend = common::backend();
    let mut table = Table::new(&[
        "dataset", "m", "1 load", "2 basis", "3 kernel", "4 tron", "tron iters", "regime",
    ]);
    let cases: &[(&str, usize, usize, &[usize])] = &[
        ("vehicle_like", 6_000, 1_500, &[100, 1000]),
        ("covtype_like", 12_000, 3_000, &[200, 3200]),
        ("ccat_like", 8_000, 2_000, &[400, 3200]),
        ("mnist8m_like", 12_000, 2_000, &[1000, 2000]),
    ];
    for &(name, n, ntest, ms) in cases {
        let (train_ds, _) = common::dataset(name, n, ntest, 42);
        for m in ms.iter().map(|&m| common::clamp_m(m, train_ds.n())) {
            let s = common::settings(name, m, 8);
            let out = train(&s, &train_ds, Arc::clone(&backend), common::free()).unwrap();
            let (l, b, k, tr) = (
                out.wall.wall_secs(Step::Load),
                out.wall.wall_secs(Step::BasisBcast),
                out.wall.wall_secs(Step::Kernel),
                out.wall.wall_secs(Step::Tron),
            );
            table.row(&[
                name.into(),
                m.to_string(),
                format!("{l:.2}"),
                format!("{b:.2}"),
                format!("{k:.2}"),
                format!("{tr:.2}"),
                out.stats.iterations.to_string(),
                if k > tr { "kernel-bound".into() } else { "TRON-bound".into() },
            ]);
            println!("  done {name} m={m}");
        }
    }
    print!("{}", table.render());
    println!(
        "shape check vs paper: mnist8m_like (d=784) is kernel-compute bound\n\
         (step 3 ≫ step 4); covtype_like needs hundreds of TRON iterations\n\
         and is TRON-bound (step 4 ≫ step 3); loading and basis broadcast\n\
         are small constants throughout."
    );
}
