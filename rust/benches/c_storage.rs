//! c_storage — the memory/compute dial of the C-block store: peak per-node
//! C bytes vs wall time across storage modes × executors, with the sim
//! ledger's kernel-tile recompute charge. Asserts β bit-identity across
//! every cell (the CBlockStore contract) while printing the honest
//! tradeoff: materialized = O(n_j·m) bytes / no recompute (held ONCE on
//! the native backend — the prepared copy aliases the host tile),
//! streaming = one tile / recompute every dispatch, streaming:rowbuf =
//! col_tiles tiles / ~half the recompute for m > TM, auto = wherever the
//! budget lands.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use dkm::config::settings::{CStorage, ExecutorChoice};
use dkm::coordinator::train;
use dkm::metrics::{Step, Table};
use dkm::runtime::tiles::{TB, TM};

fn main() {
    common::header(
        "C-STORAGE — peak C-block bytes vs wall time (bit-identical β)",
        "§3.1 memory discussion (O(nm/p) per node) + Sindhwani-Avron implicit operators",
    );
    let (train_ds, test_ds) = common::dataset("covtype_like", 24000, 4000, 3);
    let backend = common::backend();
    let m = common::clamp_m(512, train_ds.n());
    let nodes = 8;
    let ct = m.div_ceil(TM).max(1);

    let mut table = Table::new(&[
        "storage",
        "exec",
        "wall_s",
        "tron_s",
        "peak_C_MiB/node",
        "wcache_MiB/node",
        "recompute_GFLOP",
        "recomputed_tiles",
        "accuracy",
    ]);
    let mut reference: Option<Vec<u32>> = None;
    let mut streaming_tiles = 0u64;
    let mut rowbuf_tiles = 0u64;
    let mut materialized_peak = 0usize;
    let mut runs = 0usize;
    for storage in [
        CStorage::Materialized,
        CStorage::Streaming,
        CStorage::StreamingRowbuf,
        CStorage::Auto,
    ] {
        for exec in [
            ExecutorChoice::Serial,
            ExecutorChoice::Threads { cap: 0 },
            ExecutorChoice::Pool { cap: 0 },
        ] {
            let mut s = common::settings("covtype_like", m, nodes);
            s.executor = exec;
            s.c_storage = storage;
            if storage == CStorage::Auto {
                // Budget for one materialized row of tiles per node — a
                // genuine mix on any shard larger than TB rows. (One row
                // costs ct tiles where prepared operands alias host tiles,
                // 2·ct where they are uploaded copies.)
                let per_row = if backend.prepared_aliases_host() { 1 } else { 2 };
                s.c_memory_budget = ct * TB * TM * 4 * per_row;
            }
            let t0 = std::time::Instant::now();
            let out = train(&s, &train_ds, Arc::clone(&backend), common::free())
                .expect("train");
            let wall = t0.elapsed().as_secs_f64();
            runs += 1;
            let acc = out
                .model
                .accuracy(backend.as_ref(), &test_ds)
                .expect("accuracy");
            let bits: Vec<u32> = out.model.beta.iter().map(|b| b.to_bits()).collect();
            match &reference {
                None => reference = Some(bits),
                Some(want) => assert_eq!(
                    want, &bits,
                    "β must be bit-identical across storage modes and executors"
                ),
            }
            match storage {
                CStorage::Materialized => materialized_peak = out.peak_c_bytes,
                CStorage::Streaming => streaming_tiles = out.recomputed_tiles,
                CStorage::StreamingRowbuf => rowbuf_tiles = out.recomputed_tiles,
                CStorage::Auto => {}
            }
            table.row(&[
                storage.name().into(),
                s.executor.name(),
                format!("{wall:.2}"),
                format!("{:.2}", out.wall.wall_secs(Step::Tron)),
                format!("{:.2}", out.peak_c_bytes as f64 / (1 << 20) as f64),
                format!("{:.2}", out.peak_w_cache_bytes as f64 / (1 << 20) as f64),
                format!("{:.3}", out.sim.recompute_flops() as f64 / 1e9),
                out.recomputed_tiles.to_string(),
                format!("{acc:.4}"),
            ]);
        }
    }
    print!("{}", table.render());

    // Materialized holds the C grid once on the native backend: the peak
    // is exactly row_tiles × col_tiles tiles per node (2× under PJRT,
    // where the device copy cannot alias host memory).
    if backend.prepared_aliases_host() {
        let rows_per_node = train_ds.n().div_ceil(nodes);
        let rt = rows_per_node.div_ceil(TB).max(1);
        assert_eq!(
            materialized_peak,
            rt * ct * TB * TM * 4,
            "materialized peak must be the tile grid held once"
        );
    }
    if m > TM {
        assert!(
            rowbuf_tiles * 100 < streaming_tiles * 55,
            "rowbuf must perform ~half the recomputes of plain streaming \
             for m > TM: {rowbuf_tiles} vs {streaming_tiles}"
        );
    }
    println!(
        "\nall {runs} runs produced bit-identical β — storage × executor \
         equivalence holds; memory is a dial, not a cap."
    );
    if m > TM {
        println!(
            "streaming:rowbuf recomputed {} tiles vs plain streaming's {} \
             (~{:.0}%) at O(col_tiles)-tile extra memory.",
            rowbuf_tiles,
            streaming_tiles,
            rowbuf_tiles as f64 / streaming_tiles.max(1) as f64 * 100.0,
        );
    } else {
        println!(
            "m <= TM here (scaled-down run): the fused single-tile path is \
             in use, so streaming:rowbuf matches plain streaming's \
             recompute ({rowbuf_tiles} vs {streaming_tiles} tiles)."
        );
    }
}
