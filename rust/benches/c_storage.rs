//! c_storage — the memory/compute dial of the C-block store: peak per-node
//! C bytes vs wall time across storage modes × executors, with the sim
//! ledger's kernel-tile recompute charge. Asserts β bit-identity across
//! every cell (the CBlockStore contract) while printing the honest
//! tradeoff: materialized = O(n_j·m) bytes / no recompute, streaming =
//! one tile / recompute every dispatch, auto = wherever the budget lands.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use dkm::config::settings::{CStorage, ExecutorChoice};
use dkm::coordinator::train;
use dkm::metrics::{Step, Table};
use dkm::runtime::tiles::{TB, TM};

fn main() {
    common::header(
        "C-STORAGE — peak C-block bytes vs wall time (bit-identical β)",
        "§3.1 memory discussion (O(nm/p) per node) + Sindhwani-Avron implicit operators",
    );
    let (train_ds, test_ds) = common::dataset("covtype_like", 24000, 4000, 3);
    let backend = common::backend();
    let m = common::clamp_m(512, train_ds.n());
    let nodes = 8;

    let mut table = Table::new(&[
        "storage",
        "exec",
        "wall_s",
        "tron_s",
        "peak_C_MiB/node",
        "wcache_MiB/node",
        "recompute_GFLOP",
        "accuracy",
    ]);
    let mut reference: Option<Vec<u32>> = None;
    for storage in [CStorage::Materialized, CStorage::Streaming, CStorage::Auto] {
        for exec in [ExecutorChoice::Serial, ExecutorChoice::Threads { cap: 0 }] {
            let mut s = common::settings("covtype_like", m, nodes);
            s.executor = exec;
            s.c_storage = storage;
            if storage == CStorage::Auto {
                // Budget for one materialized row of tiles per node — a
                // genuine mix on any shard larger than TB rows.
                s.c_memory_budget = m.div_ceil(TM).max(1) * TB * TM * 4 * 2;
            }
            let t0 = std::time::Instant::now();
            let out = train(&s, &train_ds, Arc::clone(&backend), common::free())
                .expect("train");
            let wall = t0.elapsed().as_secs_f64();
            let acc = out
                .model
                .accuracy(backend.as_ref(), &test_ds)
                .expect("accuracy");
            let bits: Vec<u32> = out.model.beta.iter().map(|b| b.to_bits()).collect();
            match &reference {
                None => reference = Some(bits),
                Some(want) => assert_eq!(
                    want, &bits,
                    "β must be bit-identical across storage modes and executors"
                ),
            }
            table.row(&[
                storage.name().into(),
                s.executor.name(),
                format!("{wall:.2}"),
                format!("{:.2}", out.wall.wall_secs(Step::Tron)),
                format!("{:.2}", out.peak_c_bytes as f64 / (1 << 20) as f64),
                format!("{:.2}", out.peak_w_cache_bytes as f64 / (1 << 20) as f64),
                format!("{:.3}", out.sim.recompute_flops() as f64 / 1e9),
                format!("{acc:.4}"),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "\nall six runs produced bit-identical β — storage × executor \
         equivalence holds; memory is a dial, not a cap."
    );
}
