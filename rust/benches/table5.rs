//! Table 5: our method vs P-packSVM on the MNIST8m-like dataset.
//!
//! Paper:
//!               nodes  accuracy  total time (s)
//!   P-packSVM   512    0.9948    12880      (1 epoch, MPI cluster)
//!   Our method  200    0.9963    8779       (m=10000, Hadoop AllReduce)
//!
//! Ours: same substrate for both sides (fairer than the paper). P-packSVM
//! is priced on the MPI cost model (its native habitat), our method on the
//! crude-Hadoop model — the paper's exact configuration.

#[path = "common/mod.rs"]
mod common;

use dkm::baselines::{train_ppacksvm, PPackOptions};
use dkm::cluster::CostModel;
use dkm::coordinator::train;
use dkm::metrics::Table;
use std::sync::Arc;

fn main() {
    common::header(
        "TABLE 5 — our method vs P-packSVM, mnist8m_like",
        "Table 5 (§4.5): beats 1-epoch P-packSVM on time, slightly on accuracy",
    );
    let (train_ds, test_ds) = common::dataset("mnist8m_like", 12_000, 2_000, 42);
    let backend = common::backend();

    // Our method: m = 1600 (scaled from the paper's 10k), 8 nodes, Hadoop.
    let s = common::settings("mnist8m_like", common::clamp_m(1_600, train_ds.n()), 8);
    let t0 = std::time::Instant::now();
    let ours = train(&s, &train_ds, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap();
    let ours_wall = t0.elapsed().as_secs_f64();
    let ours_acc = ours.model.accuracy(backend.as_ref(), &test_ds).unwrap();
    println!("  done ours");

    // P-packSVM: 1 epoch, pack 100, MPI pricing (its native habitat), more
    // nodes (512:200 in the paper ≈ 2.5x ours).
    let opts = PPackOptions {
        pack: 100,
        epochs: 1,
        lambda: 8.0 / train_ds.n() as f32,
        seed: 42,
        nodes: 20,
    };
    let t1 = std::time::Instant::now();
    let ppack = train_ppacksvm(&train_ds, s.gamma(), &opts, CostModel::mpi()).unwrap();
    let ppack_wall = t1.elapsed().as_secs_f64();
    let ppack_acc = ppack.model.accuracy(backend.as_ref(), &test_ds).unwrap();
    println!("  done p-packsvm (support size {})", ppack.n_support);

    let mut table = Table::new(&[
        "method", "nodes", "accuracy", "sim total s", "wall s", "notes",
    ]);
    table.row(&[
        "P-packSVM".into(),
        "20 (MPI)".into(),
        format!("{ppack_acc:.4}"),
        format!("{:.1}", ppack.sim.total_secs()),
        format!("{ppack_wall:.1}"),
        format!("1 epoch, {} rounds, {} SVs", ppack.rounds, ppack.n_support),
    ]);
    table.row(&[
        "Ours (m=1600)".into(),
        "8 (Hadoop)".into(),
        format!("{ours_acc:.4}"),
        format!("{:.1}", ours.sim.total_secs()),
        format!("{ours_wall:.1}"),
        format!("{} TRON iters", ours.stats.iterations),
    ]);
    print!("{}", table.render());
    println!(
        "shape check vs paper: our method matches or beats 1-epoch\n\
         P-packSVM accuracy with fewer nodes and less total time, despite\n\
         P-packSVM getting the low-latency network."
    );
}
