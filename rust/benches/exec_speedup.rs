//! EXECUTOR — real wall-clock speedup of the threaded execution layer.
//!
//! Trains the same covtype-like workload twice — once on the serial
//! executor (the metering reference) and once on scoped worker threads —
//! and reports, per Algorithm-1 step, the *host* wall-clock times side by
//! side with the simulated p-node ledger. The trained β must be
//! bit-identical between the two runs (the executor contract); only real
//! time changes. On a multi-core host the kernel + TRON steps should show
//! >1.5× wall speedup.
//!
//! Run: cargo bench --bench exec_speedup
//! (DKM_BENCH_SCALE scales the dataset; DKM_THREADS caps the workers.)

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use dkm::config::settings::ExecutorChoice;
use dkm::coordinator::train;
use dkm::metrics::{Step, Table};

fn main() {
    common::header(
        "EXECUTOR — serial vs threaded wall clock (bit-identical training)",
        "tentpole: pluggable execution layer; cf. Hsieh et al. block-parallel training",
    );
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cap: usize = std::env::var("DKM_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    println!(
        "host cores: {cores}; worker cap: {}",
        if cap == 0 { "auto (one per core)".to_string() } else { cap.to_string() }
    );

    let (train_ds, test_ds) = common::dataset("covtype_like", 12_000, 1_000, 42);
    let backend = common::native_backend();
    let m = common::clamp_m(800, train_ds.n());
    let nodes = 8;

    let mut outs = Vec::new();
    for exec in [ExecutorChoice::Serial, ExecutorChoice::Threads { cap }] {
        let mut s = common::settings("covtype_like", m, nodes);
        s.executor = exec;
        let out = train(&s, &train_ds, Arc::clone(&backend), common::free())
            .expect("training failed");
        outs.push((exec.name(), out));
    }
    let (_, serial) = &outs[0];
    let (threads_name, threaded) = &outs[1];

    let mut t = Table::new(&["step", "serial_s", "threads_s", "wall speedup"]);
    let mut hot_serial = 0.0;
    let mut hot_threaded = 0.0;
    for step in [Step::Kernel, Step::Tron] {
        let a = serial.wall.wall_secs(step);
        let b = threaded.wall.wall_secs(step);
        hot_serial += a;
        hot_threaded += b;
        t.row(&[
            step.name().into(),
            format!("{a:.3}"),
            format!("{b:.3}"),
            format!("{:.2}x", a / b.max(1e-9)),
        ]);
    }
    t.row(&[
        "kernel+tron".into(),
        format!("{hot_serial:.3}"),
        format!("{hot_threaded:.3}"),
        format!("{:.2}x", hot_serial / hot_threaded.max(1e-9)),
    ]);
    let (ta, tb) = (serial.wall.total_secs(), threaded.wall.total_secs());
    t.row(&[
        "total".into(),
        format!("{ta:.3}"),
        format!("{tb:.3}"),
        format!("{:.2}x", ta / tb.max(1e-9)),
    ]);
    print!("{}", t.render());

    let bit_identical = serial.model.beta.len() == threaded.model.beta.len()
        && serial
            .model
            .beta
            .iter()
            .zip(&threaded.model.beta)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "\nβ bit-identical across executors: {} | evals serial fg={} hd={} vs {} fg={} hd={}",
        if bit_identical { "YES" } else { "NO (BUG!)" },
        serial.fg_evals,
        serial.hd_evals,
        threads_name,
        threaded.fg_evals,
        threaded.hd_evals,
    );
    let acc = threaded
        .model
        .accuracy(backend.as_ref(), &test_ds)
        .unwrap();
    println!("test accuracy (threaded run): {acc:.4}");
    println!(
        "\nsimulated {nodes}-node ledger of the threaded run (comm is priced \
         identically to serial; measured compute can include cross-worker \
         contention — use --exec serial for ledger-grade numbers):\n{}",
        threaded.sim.report()
    );
    assert!(bit_identical, "executor equivalence violated");
}
