//! EXECUTOR — real wall-clock speedup of the threaded execution layer.
//!
//! Trains the same covtype-like workload three times — serial executor
//! (the metering reference), scoped threads spawned per phase, and the
//! persistent worker pool — and reports, per Algorithm-1 step, the *host*
//! wall-clock times side by side with the simulated p-node ledger. The
//! trained β must be bit-identical across all three (the executor
//! contract); only real time changes. On a multi-core host the kernel +
//! TRON steps should show >1.5× wall speedup.
//!
//! A second section isolates dispatch overhead: many tiny phases (the
//! shape streaming C storage produces) on spawn-per-phase threads vs the
//! parked pool. The pool must be at parity or better — that is the whole
//! point of parking the workers.
//!
//! A third section compares the FUSED evaluation pipeline (one
//! compute+reduce phase — one barrier, one AllReduce round-trip — per
//! TRON evaluation) against the split reference (barrier + 2 reductions
//! per f/g): reduce round-trips per evaluation, µs per evaluation, and
//! the simulated comm seconds, with β bit-identity asserted.
//!
//! A fourth section injects a 4× straggler (`--skew 0=4`) into the
//! simulated fleet and reruns the same training under `--sched static`
//! vs `--sched steal:4`: β stays bit-identical and every communication
//! counter is pinned, but work-stealing's simulated phase wall must drop
//! well below the static slowest-node bound.
//!
//! A fifth section injects random task deaths (`--faults rand:p`) and
//! measures the resilience subsystem's recovery bill: deaths, re-launches
//! and the simulated backoff seconds — with β bit-identical to the clean
//! run and the communication ledger pinned (recovery is retry-only; it
//! never re-enters a collective).
//!
//! Run: cargo bench --bench exec_speedup
//! (DKM_BENCH_SCALE scales the dataset; DKM_THREADS caps the workers.)

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use dkm::cluster::{CostModel, Cluster, Executor, Sched, Skew};
use dkm::config::settings::{EvalPipeline, ExecutorChoice};
use dkm::coordinator::train;
use dkm::metrics::{Step, Table};

/// Many tiny phases against p nodes: total wall time per executor.
fn many_small_dispatches(exec: Executor, phases: usize, p: usize) -> f64 {
    let mut cl = Cluster::new(vec![0u64; p], 2, CostModel::free()).with_executor(exec);
    let t0 = std::time::Instant::now();
    for _ in 0..phases {
        // O(µs) of per-node work: dispatch overhead dominates by design.
        cl.par_compute(Step::Tron, |j, n| {
            let mut acc = *n ^ j as u64;
            for k in 0..64u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            *n = acc;
            acc
        });
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    common::header(
        "EXECUTOR — serial vs threads vs pool wall clock (bit-identical training)",
        "tentpole: persistent worker pool; cf. Hsieh et al. block-parallel training",
    );
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cap: usize = std::env::var("DKM_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    println!(
        "host cores: {cores}; worker cap: {}",
        if cap == 0 { "auto (one per core)".to_string() } else { cap.to_string() }
    );

    let (train_ds, test_ds) = common::dataset("covtype_like", 12_000, 1_000, 42);
    let backend = common::native_backend();
    let m = common::clamp_m(800, train_ds.n());
    let nodes = 8;

    let mut outs = Vec::new();
    for exec in [
        ExecutorChoice::Serial,
        ExecutorChoice::Threads { cap },
        ExecutorChoice::Pool { cap },
    ] {
        let mut s = common::settings("covtype_like", m, nodes);
        s.executor = exec;
        let out = train(&s, &train_ds, Arc::clone(&backend), common::free())
            .expect("training failed");
        outs.push((exec.name(), out));
    }
    let (_, serial) = &outs[0];

    let mut t = Table::new(&[
        "step",
        "serial_s",
        "threads_s",
        "pool_s",
        "threads speedup",
        "pool speedup",
    ]);
    let mut hot: [f64; 3] = [0.0; 3];
    for step in [Step::Kernel, Step::Tron] {
        let secs: Vec<f64> = outs.iter().map(|(_, o)| o.wall.wall_secs(step)).collect();
        for (h, s) in hot.iter_mut().zip(&secs) {
            *h += s;
        }
        t.row(&[
            step.name().into(),
            format!("{:.3}", secs[0]),
            format!("{:.3}", secs[1]),
            format!("{:.3}", secs[2]),
            format!("{:.2}x", secs[0] / secs[1].max(1e-9)),
            format!("{:.2}x", secs[0] / secs[2].max(1e-9)),
        ]);
    }
    t.row(&[
        "kernel+tron".into(),
        format!("{:.3}", hot[0]),
        format!("{:.3}", hot[1]),
        format!("{:.3}", hot[2]),
        format!("{:.2}x", hot[0] / hot[1].max(1e-9)),
        format!("{:.2}x", hot[0] / hot[2].max(1e-9)),
    ]);
    let totals: Vec<f64> = outs.iter().map(|(_, o)| o.wall.total_secs()).collect();
    t.row(&[
        "total".into(),
        format!("{:.3}", totals[0]),
        format!("{:.3}", totals[1]),
        format!("{:.3}", totals[2]),
        format!("{:.2}x", totals[0] / totals[1].max(1e-9)),
        format!("{:.2}x", totals[0] / totals[2].max(1e-9)),
    ]);
    print!("{}", t.render());

    let mut bit_identical = true;
    for (name, other) in &outs[1..] {
        let same = serial.model.beta.len() == other.model.beta.len()
            && serial
                .model
                .beta
                .iter()
                .zip(&other.model.beta)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        println!(
            "β bit-identical serial vs {name}: {} | fg={} hd={} vs fg={} hd={}",
            if same { "YES" } else { "NO (BUG!)" },
            serial.fg_evals,
            serial.hd_evals,
            other.fg_evals,
            other.hd_evals,
        );
        bit_identical &= same;
    }
    let (_, pooled) = &outs[2];
    let acc = pooled
        .model
        .accuracy(backend.as_ref(), &test_ds)
        .unwrap();
    println!("test accuracy (pool run): {acc:.4}");

    // --- dispatch overhead: many tiny phases (the streaming shape) ---
    let p = 8;
    let phases = 700;
    let rounds = 3;
    let workers = if cap == 0 { cores.min(p) } else { cap.min(p) };
    // Warm both paths (same executors as the measurement — the pool's
    // workers are spawned and scheduled before its timed window starts),
    // then take the best of interleaved rounds so one bad scheduling
    // window on a loaded CI host cannot fail the parity assertion.
    let spawn_exec = Executor::threaded(workers);
    let pool_exec = Executor::pooled(workers);
    many_small_dispatches(spawn_exec.clone(), 50, p);
    many_small_dispatches(pool_exec.clone(), 50, p);
    let mut spawn_secs = f64::INFINITY;
    let mut pool_secs = f64::INFINITY;
    for _ in 0..rounds {
        spawn_secs = spawn_secs.min(many_small_dispatches(spawn_exec.clone(), phases, p));
        pool_secs = pool_secs.min(many_small_dispatches(pool_exec.clone(), phases, p));
    }
    println!(
        "\n{phases} tiny phases × {p} nodes ({workers} workers, best of {rounds}): \
         spawn-per-phase {:.1} µs/phase, pool {:.1} µs/phase ({:.2}x)",
        spawn_secs / phases as f64 * 1e6,
        pool_secs / phases as f64 * 1e6,
        spawn_secs / pool_secs.max(1e-12),
    );
    // Parity-or-better, with headroom for scheduling noise on loaded CI
    // hosts; in practice the pool wins this shape by a wide margin.
    assert!(
        pool_secs <= spawn_secs * 1.5,
        "pool dispatch slower than spawn-per-phase: {pool_secs:.4}s vs {spawn_secs:.4}s"
    );

    // --- fused vs split evaluation pipeline (rounds + µs per evaluation) ---
    // The fused pipeline runs each TRON evaluation as ONE compute+reduce
    // phase (one barrier, one AllReduce round-trip); the split pipeline is
    // the paper's literal barrier + 2 reductions per f/g. Same bytes, same
    // β bits — only synchronization rounds (and hence latency) change.
    let mut pipe_outs = Vec::new();
    for pipeline in [EvalPipeline::Fused, EvalPipeline::Split] {
        let mut s = common::settings("covtype_like", m, nodes);
        s.executor = ExecutorChoice::Pool { cap };
        s.eval_pipeline = pipeline;
        let out = train(&s, &train_ds, Arc::clone(&backend), CostModel::hadoop_crude())
            .expect("training failed");
        pipe_outs.push((pipeline, out));
    }
    let mut pt = Table::new(&[
        "pipeline",
        "evals",
        "reduce_rts",
        "rts/eval",
        "barriers",
        "dispatches",
        "disp/eval",
        "tron_wall_us/eval",
        "sim_tron_comm_s",
    ]);
    for (pipeline, out) in &pipe_outs {
        let evals = (out.fg_evals + out.hd_evals) as f64;
        pt.row(&[
            pipeline.name().into(),
            format!("{}", out.fg_evals + out.hd_evals),
            format!("{}", out.sim.comm_rounds()),
            format!("{:.2}", out.sim.comm_rounds() as f64 / evals),
            format!("{}", out.sim.barriers()),
            format!("{}", out.sim.dispatches()),
            format!("{:.2}", out.sim.dispatches() as f64 / evals),
            format!("{:.1}", out.wall.wall_secs(Step::Tron) / evals * 1e6),
            format!("{:.3}", out.sim.comm_secs(Step::Tron)),
        ]);
    }
    println!("\nfused vs split evaluation pipeline (pool executor, hadoop-crude comm):");
    print!("{}", pt.render());
    let (_, fused_out) = &pipe_outs[0];
    let (_, split_out) = &pipe_outs[1];
    let same_pipeline = fused_out
        .model
        .beta
        .iter()
        .zip(&split_out.model.beta)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "β bit-identical fused vs split: {}",
        if same_pipeline { "YES" } else { "NO (BUG!)" }
    );
    // The fused contract: exactly one reduce round-trip per evaluation,
    // and never more simulated comm time than the split path.
    assert_eq!(
        fused_out.sim.comm_rounds(),
        (fused_out.fg_evals + fused_out.hd_evals) as u64,
        "fused path must cost one round-trip per evaluation"
    );
    assert!(
        fused_out.sim.comm_secs(Step::Tron) <= split_out.sim.comm_secs(Step::Tron),
        "fused simulated comm regressed past split"
    );
    // The whole-node block ops: ONE backend dispatch per node per TRON
    // evaluation on the native backend (this workload spans multiple
    // column tiles), independent of the communication pipeline.
    for (pipeline, out) in &pipe_outs {
        assert_eq!(
            out.sim.dispatches(),
            nodes as u64 * (out.fg_evals + out.hd_evals) as u64,
            "{}: expected one dispatch per node per evaluation",
            pipeline.name()
        );
    }
    assert!(same_pipeline, "pipeline equivalence violated");

    println!(
        "\nsimulated {nodes}-node ledger of the pool run (comm is priced \
         identically to serial; measured compute can include cross-worker \
         contention — use --exec serial for ledger-grade numbers):\n{}",
        pooled.sim.report()
    );
    assert!(bit_identical, "executor equivalence violated");

    // --- straggler-proof scheduling: 4× skew on node 0, static vs steal ---
    // Serial executor for ledger-grade numbers: the simulated fleet is
    // what's skewed, not the host, so the schedule comparison is exact
    // and deterministic. The ISSUE acceptance bar: under a 4× single-node
    // skew at p = 8, stealing must reduce the simulated phase wall vs the
    // static schedule with β bit-identical and the communication ledger
    // (barriers, reduce round-trips, bytes, dispatches) unchanged.
    let skew = Skew::parse("0=4").expect("skew spec");
    let mut skew_outs = Vec::new();
    for sched in [Sched::Static, Sched::Steal { grain: 4 }] {
        let mut s = common::settings("covtype_like", m, nodes);
        s.executor = ExecutorChoice::Serial;
        s.sched = sched;
        s.skew = skew.clone();
        let out = train(&s, &train_ds, Arc::clone(&backend), common::free())
            .expect("training failed");
        skew_outs.push((sched, out));
    }
    let (_, skew_static) = &skew_outs[0];
    let (_, skew_steal) = &skew_outs[1];
    let mut st = Table::new(&[
        "sched",
        "sim_compute_s",
        "slowest_node_s",
        "node_work_s",
        "straggler_ratio",
        "barriers",
        "reduce_rts",
        "comm_bytes",
    ]);
    for (sched, out) in &skew_outs {
        st.row(&[
            sched.name(),
            format!("{:.3}", out.sim.compute_secs(Step::Kernel) + out.sim.compute_secs(Step::Tron)),
            format!("{:.3}", out.sim.max_node_secs()),
            format!("{:.3}", out.sim.sum_node_secs()),
            format!("{:.2}x", out.sim.straggler_ratio(nodes)),
            format!("{}", out.sim.barriers()),
            format!("{}", out.sim.comm_rounds()),
            format!("{}", out.sim.comm_bytes()),
        ]);
    }
    println!("\nskewed fleet ({} on {nodes} simulated nodes, serial executor):", skew.name());
    print!("{}", st.render());
    let same_skew = skew_static
        .model
        .beta
        .iter()
        .zip(&skew_steal.model.beta)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "β bit-identical static vs steal under skew: {}",
        if same_skew { "YES" } else { "NO (BUG!)" }
    );
    assert!(same_skew, "scheduling equivalence violated under skew");
    assert_eq!(skew_static.sim.barriers(), skew_steal.sim.barriers());
    assert_eq!(skew_static.sim.comm_rounds(), skew_steal.sim.comm_rounds());
    assert_eq!(skew_static.sim.comm_bytes(), skew_steal.sim.comm_bytes());
    assert_eq!(skew_static.sim.dispatches(), skew_steal.sim.dispatches());
    let static_sim = skew_static.sim.compute_secs(Step::Kernel) + skew_static.sim.compute_secs(Step::Tron);
    let steal_sim = skew_steal.sim.compute_secs(Step::Kernel) + skew_steal.sim.compute_secs(Step::Tron);
    // With only 1 of 8 nodes slowed 4×, the other 7 workers absorb the
    // straggler's surplus: the stolen schedule must come in well under
    // the static slowest-node wall (1.5c vs 4.0c in the uniform model).
    assert!(
        steal_sim < 0.8 * static_sim,
        "stealing failed to beat the static schedule under skew: {steal_sim:.3}s vs {static_sim:.3}s"
    );
    assert!(
        skew_static.sim.straggler_ratio(nodes) > 1.5,
        "skew injection did not produce a straggler-bound ledger"
    );
    println!(
        "stealing cut the simulated compute wall {:.2}x under a 4x straggler",
        static_sim / steal_sim.max(1e-12)
    );

    // --- fault recovery: injected task deaths, retries, backoff bill ---
    // Serial executor again for ledger-grade numbers. The faulty run must
    // train to the SAME β bits with the SAME communication ledger; its
    // whole overhead is the re-launch backoff charged as compute.
    let plans = [
        ("none", dkm::cluster::FaultPlan::none()),
        ("rand:0.02", dkm::cluster::FaultPlan::parse("rand:0.02:1234").expect("fault spec")),
        ("rand:0.10", dkm::cluster::FaultPlan::parse("rand:0.10:1234").expect("fault spec")),
    ];
    let mut fault_outs = Vec::new();
    for (name, plan) in &plans {
        let mut s = common::settings("covtype_like", m, nodes);
        s.executor = ExecutorChoice::Serial;
        s.faults = plan.clone();
        s.retries = 6;
        s.retry_backoff = 0.05;
        let out = train(&s, &train_ds, Arc::clone(&backend), common::free())
            .expect("training failed under injected faults");
        fault_outs.push((*name, out));
    }
    let (_, fault_clean) = &fault_outs[0];
    let mut ft = Table::new(&[
        "faults",
        "deaths",
        "retries",
        "backoff_s",
        "sim_total_s",
        "overhead",
        "barriers",
        "comm_bytes",
    ]);
    let clean_total = fault_clean.sim.total_secs();
    for (name, out) in &fault_outs {
        let backoff = out.sim.retries() as f64 * 0.05;
        ft.row(&[
            (*name).into(),
            format!("{}", out.sim.faults()),
            format!("{}", out.sim.retries()),
            format!("{backoff:.2}"),
            format!("{:.3}", out.sim.total_secs()),
            format!("{:.1}%", (out.sim.total_secs() / clean_total.max(1e-12) - 1.0) * 100.0),
            format!("{}", out.sim.barriers()),
            format!("{}", out.sim.comm_bytes()),
        ]);
    }
    println!("\ninjected-fault recovery bill (serial executor, retry backoff 0.05s):");
    print!("{}", ft.render());
    let (_, fault_heavy) = &fault_outs[2];
    let same_fault = fault_clean
        .model
        .beta
        .iter()
        .zip(&fault_heavy.model.beta)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "β bit-identical clean vs rand:0.10: {}",
        if same_fault { "YES" } else { "NO (BUG!)" }
    );
    assert!(same_fault, "fault recovery moved β");
    assert!(fault_heavy.sim.faults() > 0, "the 10% plan never fired");
    assert_eq!(
        fault_clean.sim.barriers(),
        fault_heavy.sim.barriers(),
        "recovery must not add barriers"
    );
    assert_eq!(
        fault_clean.sim.comm_bytes(),
        fault_heavy.sim.comm_bytes(),
        "recovery must not move bytes"
    );

    let mut o = std::collections::BTreeMap::new();
    let mut num = |k: &str, v: f64| {
        o.insert(k.to_string(), dkm::config::Json::Num(v));
    };
    num("kernel_tron_serial_s", hot[0]);
    num("kernel_tron_threads_s", hot[1]);
    num("kernel_tron_pool_s", hot[2]);
    num("threads_speedup", hot[0] / hot[1].max(1e-9));
    num("pool_speedup", hot[0] / hot[2].max(1e-9));
    num("spawn_us_per_phase", spawn_secs / phases as f64 * 1e6);
    num("pool_us_per_phase", pool_secs / phases as f64 * 1e6);
    let fused_evals = (fused_out.fg_evals + fused_out.hd_evals) as f64;
    let split_evals = (split_out.fg_evals + split_out.hd_evals) as f64;
    num("fused_rts_per_eval", fused_out.sim.comm_rounds() as f64 / fused_evals);
    num("split_rts_per_eval", split_out.sim.comm_rounds() as f64 / split_evals);
    num("skew_static_sim_s", static_sim);
    num("skew_steal_sim_s", steal_sim);
    num("skew_steal_speedup", static_sim / steal_sim.max(1e-12));
    num("skew_straggler_ratio", skew_static.sim.straggler_ratio(nodes));
    num("fault_deaths", fault_heavy.sim.faults() as f64);
    num("fault_retries", fault_heavy.sim.retries() as f64);
    num("fault_backoff_s", fault_heavy.sim.retries() as f64 * 0.05);
    num(
        "fault_overhead_frac",
        fault_heavy.sim.total_secs() / clean_total.max(1e-12) - 1.0,
    );
    common::write_json("exec_speedup", &dkm::config::Json::Obj(o));
}
