//! Solver head-to-head: TRON (one global Newton step per round, m-vector
//! AllReduce + full-β broadcast per evaluation) versus distributed block
//! coordinate descent (one β column block per round, O(block) bytes) on
//! the SAME cluster substrate and the same scaled-Hadoop cost model the
//! Fig-2 sweep uses.
//!
//! The observable is round economics: AllReduce round-trips, barriers and
//! bytes against objective decrease per simulated second. In the
//! latency-collapse regime (small local compute, fixed per-round latency)
//! BCD's cheap rounds buy more objective decrease per round-trip early;
//! TRON's second-order steps win once near the optimum — the tradeoff
//! Hsieh et al. (arXiv:1608.02010) build on.

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::SolverChoice;
use dkm::config::Json;
use dkm::coordinator::{train, TrainOutput};
use dkm::metrics::Table;

/// Same scaled crude-Hadoop AllReduce as the Fig-2 bench (DESIGN.md §2).
fn scaled_hadoop() -> CostModel {
    CostModel {
        latency_s: 3e-3,
        per_byte_s: 1.0 / 100e6,
    }
}

struct Row {
    solver: &'static str,
    p: usize,
    out: TrainOutput,
}

impl Row {
    /// Simulated seconds the solve itself spent (curve stamps are deltas
    /// from solve start, so the kernel/basis build is excluded).
    fn solve_secs(&self) -> f64 {
        self.out.stats.curve.last().map(|c| c.cum_secs).unwrap_or(0.0)
    }

    fn decrease_per_sec(&self) -> f64 {
        (self.out.stats.f0() - self.out.stats.final_f) / self.solve_secs().max(1e-9)
    }
}

fn main() {
    common::header(
        "SOLVERS — TRON vs distributed block coordinate descent",
        "round economics on the shared substrate (Hsieh et al. 1608.02010 style BCD)",
    );
    let name = "covtype_like";
    let (train_ds, _) = common::dataset(name, 6_000, 800, 42);
    let m = common::clamp_m(256, train_ds.n());
    let backend = common::backend();

    let ps = [4usize, 16];
    let mut rows = Vec::new();
    for &p in &ps {
        let mut st = common::settings(name, m, p);
        st.tol = 1e-3;
        let tron = train(&st, &train_ds, Arc::clone(&backend), scaled_hadoop()).unwrap();
        rows.push(Row { solver: "tron", p, out: tron });
        println!("  done tron p={p}");

        let mut sb = common::settings(name, m, p);
        sb.solver = SolverChoice::Bcd { block: 64 };
        sb.tol = 1e-3;
        // BCD rounds are much cheaper than TRON iterations; give it a
        // proportionally larger round budget for a comparable f.
        sb.max_iters = 600;
        let bcd = train(&sb, &train_ds, Arc::clone(&backend), scaled_hadoop()).unwrap();
        rows.push(Row { solver: "bcd", p, out: bcd });
        println!("  done bcd  p={p}");
    }

    println!("\n--- {name} (n={}, m={m}, λ/σ per dataset defaults) ---", train_ds.n());
    let mut table = Table::new(&[
        "solver",
        "nodes",
        "rounds",
        "reduce_rts",
        "barriers",
        "comm_MB",
        "final_f",
        "solve_sim_s",
        "decrease/s",
    ]);
    for r in &rows {
        table.row(&[
            r.solver.to_string(),
            r.p.to_string(),
            r.out.stats.iterations.to_string(),
            r.out.sim.comm_rounds().to_string(),
            r.out.sim.barriers().to_string(),
            format!("{:.2}", r.out.sim.comm_bytes() as f64 / 1e6),
            format!("{:.2}", r.out.stats.final_f),
            format!("{:.2}", r.solve_secs()),
            format!("{:.1}", r.decrease_per_sec()),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nreading the table: BCD pays ONE barrier + ONE AllReduce of \
         block+2 floats per round (the solver suite pins this), TRON one \
         full-β round-trip per f/g and Hd evaluation — compare decrease/s \
         at each p to see which round economics win where."
    );

    let mut o = BTreeMap::new();
    for r in &rows {
        let k = |field: &str| format!("{}_p{}_{}", r.solver, r.p, field);
        o.insert(k("rounds"), Json::Num(r.out.stats.iterations as f64));
        o.insert(k("reduce_rts"), Json::Num(r.out.sim.comm_rounds() as f64));
        o.insert(k("barriers"), Json::Num(r.out.sim.barriers() as f64));
        o.insert(k("comm_bytes"), Json::Num(r.out.sim.comm_bytes() as f64));
        o.insert(k("final_f"), Json::Num(r.out.stats.final_f));
        o.insert(k("solve_sim_s"), Json::Num(r.solve_secs()));
        o.insert(k("decrease_per_s"), Json::Num(r.decrease_per_sec()));
    }
    common::write_json("solvers", &Json::Obj(o));
}
