//! Shared helpers for the paper-table benches (no criterion offline; each
//! bench is a `harness = false` binary that prints the paper-style table).

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{Backend, Settings};
use dkm::data::{synth, Dataset};
use dkm::runtime::{make_backend, Compute};

/// Scale factor for bench sizes: DKM_BENCH_SCALE=0.25 quarters every n.
pub fn scale() -> f64 {
    std::env::var("DKM_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(64)
}

/// Generate a dataset from its Table-3 spec with scaled sizes.
pub fn dataset(name: &str, n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec(name);
    spec.n_train = scaled(n_train);
    spec.n_test = scaled(n_test);
    synth::generate(&spec, seed)
}

/// Settings preset from the dataset spec.
pub fn settings(name: &str, m: usize, nodes: usize) -> Settings {
    Settings {
        m,
        nodes,
        max_iters: 150,
        ..Settings::default().with_dataset_defaults(name)
    }
}

/// Default backend for benches: PJRT when artifacts exist, else native.
pub fn backend() -> Arc<dyn Compute> {
    match make_backend(Backend::Pjrt, "artifacts") {
        Ok(b) => b,
        Err(_) => make_backend(Backend::Native, "artifacts").expect("native backend"),
    }
}

pub fn native_backend() -> Arc<dyn Compute> {
    make_backend(Backend::Native, "artifacts").expect("native backend")
}

pub fn free() -> CostModel {
    CostModel::free()
}

pub fn header(title: &str, paper: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("paper reference: {paper}");
    println!("bench scale: {} (set DKM_BENCH_SCALE to adjust)", scale());
    println!("================================================================");
}

/// Clamp a basis size to the (scaled) training size.
pub fn clamp_m(m: usize, n_train: usize) -> usize {
    m.min(n_train / 2).max(16)
}

/// Write a machine-readable bench artifact (`BENCH_<name>.json`, in the
/// directory the bench runs from) so the perf trajectory can be tracked
/// across PRs. Failure to write is reported, never fatal — the printed
/// table stays the source of truth.
pub fn write_json(name: &str, json: &dkm::config::Json) {
    let path = format!("BENCH_{name}.json");
    match std::fs::write(&path, format!("{json}\n")) {
        Ok(()) => println!("machine-readable report: {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
