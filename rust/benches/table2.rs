//! Table 2: K-means vs random basis selection on Covtype-like.
//!
//! Paper (Covtype):
//!             m = 1600                      m = 51200
//!             acc     kmeans_s  total_s     acc     kmeans_s  total_s
//!   K-means   0.8087  49.49     355.97      0.9493  1399.28   3899.97
//!   Random    0.7932  —         300.98      0.9428  —         2678.74
//!
//! Expected shape: K-means wins accuracy at small m; at large m the gap
//! shrinks while its selection cost becomes a big fraction of total time.

#[path = "common/mod.rs"]
mod common;

use dkm::config::settings::BasisSelection;
use dkm::coordinator::train;
use dkm::metrics::{Step, Table};
use std::sync::Arc;

fn main() {
    common::header(
        "TABLE 2 — K-means vs random basis, covtype_like",
        "Table 2 (§3.2): K-means helps at small m, wasteful at large m",
    );
    let (train_ds, test_ds) = common::dataset("covtype_like", 12_000, 3_000, 42);
    let backend = common::backend();
    let mut table = Table::new(&["m", "selection", "accuracy", "kmeans s", "total s"]);
    for m in [400usize, 3200].map(|m| common::clamp_m(m, train_ds.n())) {
        for (label, basis) in [("kmeans", BasisSelection::KMeans), ("random", BasisSelection::Random)] {
            let mut s = common::settings("covtype_like", m, 8);
            s.basis = basis;
            s.kmeans_iters = 3; // the paper's Table-2 setting
            let t0 = std::time::Instant::now();
            let out = train(&s, &train_ds, Arc::clone(&backend), common::free()).unwrap();
            let total = t0.elapsed().as_secs_f64();
            let acc = out.model.accuracy(backend.as_ref(), &test_ds).unwrap();
            let kmeans_secs = out.wall.wall_secs(Step::BasisBcast);
            table.row(&[
                m.to_string(),
                label.into(),
                format!("{acc:.4}"),
                if basis == BasisSelection::KMeans { format!("{kmeans_secs:.2}") } else { "-".into() },
                format!("{total:.2}"),
            ]);
            println!("  done m={m} {label}");
        }
    }
    print!("{}", table.render());
    println!(
        "shape check vs paper: at small m K-means buys accuracy for a\n\
         modest cost; at large m its cost fraction grows while the\n\
         accuracy advantage over random shrinks."
    );
}
