//! Ablations over DESIGN.md-called-out choices:
//!   A. AllReduce tree arity (comm rounds vs fan-out)
//!   B. Latency sensitivity (the C in C + D·B) — the Fig-2 mechanism knob
//!   C. Fused fgrad tile vs unfused matvec+loss+matvec_t (m <= TM case)
//!   D. P-packSVM packing size r (accuracy & simulated time)

#[path = "common/mod.rs"]
mod common;

use dkm::baselines::{train_ppacksvm, PPackOptions};
use dkm::cluster::{Cluster, CostModel};
use dkm::coordinator::train;
use dkm::metrics::{Step, Table};
use std::sync::Arc;

fn main() {
    common::header("ABLATIONS", "design choices called out in DESIGN.md");

    // --- A: tree arity ---
    println!("\nA. AllReduce tree arity (p=64, priced rounds for a 4 KiB vector):");
    let mut table = Table::new(&["arity", "depth", "sim comm s/call"]);
    for arity in [2usize, 4, 8, 16] {
        let mut cl = Cluster::new(vec![(); 64], arity, CostModel::hadoop_crude());
        let partials: Vec<Vec<f32>> = vec![vec![1.0; 1024]; 64];
        cl.allreduce_sum(Step::Tron, partials);
        table.row(&[
            arity.to_string(),
            cl.tree().depth().to_string(),
            format!("{:.4}", cl.clock.comm_secs(Step::Tron)),
        ]);
    }
    print!("{}", table.render());

    // --- B: latency sensitivity ---
    println!("\nB. latency sensitivity (covtype_like n=4000 m=256 p=8):");
    let (train_ds, _) = common::dataset("covtype_like", 4_000, 500, 42);
    let backend = common::backend();
    let mut table = Table::new(&["latency C", "sim total s", "tron comm s", "comm share"]);
    for (label, lat) in [("1 ms", 1e-3), ("30 ms (hadoop)", 30e-3), ("100 ms", 100e-3)] {
        let cost = CostModel {
            latency_s: lat,
            per_byte_s: 1e-8,
        };
        let s = common::settings("covtype_like", 256, 8);
        let out = train(&s, &train_ds, Arc::clone(&backend), cost).unwrap();
        let total = out.sim.total_secs();
        let comm = out.sim.comm_secs(Step::Tron);
        table.row(&[
            label.into(),
            format!("{total:.2}"),
            format!("{comm:.2}"),
            format!("{:.2}", comm / total),
        ]);
    }
    print!("{}", table.render());

    // --- C: fused vs unfused f/g tiles ---
    println!("\nC. fused fgrad tile vs unfused 3-op pipeline (m=256 fits one tile):");
    use dkm::rng::Rng;
    use dkm::runtime::tiles::{TB, TM};
    let mut rng = Rng::new(3);
    let c: Vec<f32> = (0..TB * TM).map(|_| rng.normal_f32()).collect();
    let beta: Vec<f32> = (0..TM).map(|_| 0.1 * rng.normal_f32()).collect();
    let y: Vec<f32> = (0..TB).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let mask = vec![1.0f32; TB];
    let loss = dkm::config::settings::Loss::SqHinge;
    let reps = 200;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(backend.fgrad(loss, &c, &beta, &y, &mask).unwrap());
    }
    let fused = t0.elapsed().as_secs_f64() / reps as f64;
    let t1 = std::time::Instant::now();
    for _ in 0..reps {
        let o = backend.matvec(&c, &beta).unwrap();
        let st = backend.loss_stage(loss, &o, &y, &mask).unwrap();
        std::hint::black_box(backend.matvec_t(&c, &st.vec).unwrap());
    }
    let unfused = t1.elapsed().as_secs_f64() / reps as f64;
    println!(
        "fused: {:.1} us   unfused: {:.1} us   saving: {:.1}%",
        fused * 1e6,
        unfused * 1e6,
        (1.0 - fused / unfused) * 100.0
    );

    // --- D: P-packSVM pack size ---
    println!("\nD. P-packSVM pack size r (mnist8m_like n=3000, hadoop pricing):");
    let (tr, te) = common::dataset("mnist8m_like", 3_000, 600, 42);
    let gamma = 1.0 / (2.0 * 18.0f32 * 18.0);
    let mut table = Table::new(&["r", "rounds", "accuracy", "sim comm s", "wall s"]);
    for pack in [10usize, 100, 500] {
        let opts = PPackOptions {
            pack,
            epochs: 1,
            lambda: 1e-4,
            seed: 42,
            nodes: 8,
        };
        let t0 = std::time::Instant::now();
        let out = train_ppacksvm(&tr, gamma, &opts, CostModel::hadoop_crude()).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let acc = out.model.accuracy(backend.as_ref(), &te).unwrap();
        table.row(&[
            pack.to_string(),
            out.rounds.to_string(),
            format!("{acc:.4}"),
            format!("{:.1}", out.sim.comm_secs(Step::Tron)),
            format!("{wall:.1}"),
        ]);
        println!("  done r={pack}");
    }
    print!("{}", table.render());
    println!("(larger r cuts communication rounds at O(r²) extra master work — §1.1)");
}
