//! Table 1: formulations (4) vs (3) on the Vehicle-like dataset.
//!
//! Paper (Vehicle, λ=8, σ=2):
//!   m                     100     1000    10000
//!   (4) total time (s)    87.4    693     6704      — grows O(nm)
//!   (3) total time (s)    —       713     —
//!   fraction of time for A 0.0017 0.0148  0.2893    — grows O(m³)+O(nm²)
//!
//! Ours (vehicle_like, scaled ~10x down): same λ/σ, m ∈ {100, 400, 1600}.
//! Expected shape: (4) grows ~linearly in m; (3)'s eig+A share explodes.

#[path = "common/mod.rs"]
mod common;

use dkm::baselines::train_linearized;
use dkm::coordinator::train;
use dkm::metrics::Table;
use std::sync::Arc;

fn main() {
    common::header(
        "TABLE 1 — formulation (4) vs (3), vehicle_like",
        "Table 1 (§3): '(4) avoids the pseudo-inverse computation'",
    );
    let (train_ds, test_ds) = common::dataset("vehicle_like", 6_000, 1_500, 42);
    let backend = common::native_backend();
    let mut table = Table::new(&[
        "m",
        "(4) total s",
        "(4) acc",
        "(3) total s",
        "(3) acc",
        "(3) eig s",
        "(3) A s",
        "(3) frac for A",
    ]);
    for m in [100usize, 400, 1600].map(|m| common::clamp_m(m, train_ds.n())) {
        let s = common::settings("vehicle_like", m, 1);
        let t0 = std::time::Instant::now();
        let f4 = train(&s, &train_ds, Arc::clone(&backend), common::free()).unwrap();
        let f4_secs = t0.elapsed().as_secs_f64();
        let f4_acc = f4.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        let f3 = train_linearized(&s, &train_ds).unwrap();
        let f3_acc = f3.accuracy(&test_ds);
        table.row(&[
            m.to_string(),
            format!("{f4_secs:.2}"),
            format!("{f4_acc:.4}"),
            format!("{:.2}", f3.total_secs),
            format!("{f3_acc:.4}"),
            format!("{:.2}", f3.eig_secs),
            format!("{:.2}", f3.a_secs),
            format!("{:.4}", f3.a_fraction()),
        ]);
        println!("  done m={m}");
    }
    print!("{}", table.render());
    println!(
        "shape check vs paper: (4) time grows ~linearly with m; (3)'s\n\
         eig+A fraction grows superlinearly (O(m³) + O(nm²)) and dominates\n\
         at large m, while accuracies match ((3) ≡ (4) reparameterized)."
    );
}
