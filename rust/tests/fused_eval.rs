//! Fused evaluation pipeline: the tentpole contract that driving every
//! TRON evaluation through ONE fused compute+reduce phase (one barrier,
//! one AllReduce round-trip) is BIT-IDENTICAL to the split reference
//! pipeline (compute barrier + separate scalar and m-vector AllReduces) —
//! across executors, C-storage modes, multi-tile m and stage-wise growth —
//! while the metered synchronization counts drop exactly as advertised:
//! `comm_rounds()` = fg_evals + hd_evals (split: 2·fg + hd) and the
//! per-evaluation barrier count drops to one.
//!
//! Test names end in `serial_exec` / `threads_exec` / `pool_exec`; CI runs
//! each group explicitly next to the c_storage matrix.

use std::sync::Arc;

use dkm::cluster::{CostModel, Tree};
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings,
};
use dkm::coordinator::trainer::train_stagewise;
use dkm::coordinator::{train, TrainOutput};
use dkm::data::{synth, Dataset};
use dkm::runtime::make_backend;

fn settings(
    m: usize,
    nodes: usize,
    executor: ExecutorChoice,
    pipeline: EvalPipeline,
) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: CStorage::Materialized,
        eval_pipeline: pipeline,
        c_memory_budget: 256 << 20,
        max_iters: 40,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

fn assert_bit_identical(a: &TrainOutput, b: &TrainOutput, what: &str) {
    assert_eq!(a.model.beta.len(), b.model.beta.len(), "{what}");
    for (i, (x, y)) in a.model.beta.iter().zip(&b.model.beta).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: beta[{i}] {x} vs {y}");
    }
    assert_eq!(a.fg_evals, b.fg_evals, "{what}");
    assert_eq!(a.hd_evals, b.hd_evals, "{what}");
    assert_eq!(a.stats.iterations, b.stats.iterations, "{what}");
    assert_eq!(
        a.stats.final_f.to_bits(),
        b.stats.final_f.to_bits(),
        "{what}"
    );
}

/// Fused vs split full training on the serial reference executor, for
/// every C-storage mode: β bits, eval counts and the byte ledger must
/// match exactly — only latency rounds may differ.
#[test]
fn fused_matches_split_all_storage_modes_serial_exec() {
    let (tr, _) = data(1500, 200, 7);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    for storage in [
        CStorage::Materialized,
        CStorage::Streaming,
        CStorage::StreamingRowbuf,
        CStorage::Auto,
    ] {
        let run = |pipeline| {
            let mut s = settings(96, 6, ExecutorChoice::Serial, pipeline);
            s.c_storage = storage;
            train(&s, &tr, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap()
        };
        let fused = run(EvalPipeline::Fused);
        let split = run(EvalPipeline::Split);
        assert_bit_identical(&fused, &split, storage.name());
        assert_eq!(
            fused.sim.comm_bytes(),
            split.sim.comm_bytes(),
            "{}: fusion must not change the byte volume",
            storage.name()
        );
    }
}

/// Fused vs split under spawn-per-phase worker threads, multi-tile m (two
/// basis column tiles — the unfused matvec/matvec_t partial shape).
#[test]
fn fused_matches_split_multi_tile_m_threads_exec() {
    let (tr, _) = data(1400, 200, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mut outs = Vec::new();
    for pipeline in [EvalPipeline::Fused, EvalPipeline::Split] {
        let mut s = settings(300, 5, ExecutorChoice::Threads { cap: 4 }, pipeline);
        s.max_iters = 25;
        outs.push(train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap());
    }
    assert_bit_identical(&outs[0], &outs[1], "multi-tile threads");
    // Multi-tile serial reference: the executor contract and the pipeline
    // contract must compose.
    let mut s = settings(300, 5, ExecutorChoice::Serial, EvalPipeline::Fused);
    s.max_iters = 25;
    let serial = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    assert_bit_identical(&outs[0], &serial, "fused threads vs fused serial");
}

/// Fused vs split on the persistent pool (the executor whose re-park the
/// fusion eliminates), plus stage-wise growth riding the fused path.
#[test]
fn fused_matches_split_and_stagewise_pool_exec() {
    let (tr, _) = data(1300, 150, 17);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let fused = train(
        &settings(96, 8, ExecutorChoice::Pool { cap: 4 }, EvalPipeline::Fused),
        &tr,
        Arc::clone(&backend),
        CostModel::hadoop_crude(),
    )
    .unwrap();
    let split = train(
        &settings(96, 8, ExecutorChoice::Pool { cap: 4 }, EvalPipeline::Split),
        &tr,
        Arc::clone(&backend),
        CostModel::hadoop_crude(),
    )
    .unwrap();
    assert_bit_identical(&fused, &split, "pool");

    // Stage-wise growth (dirty-column recompute, warm-started β): the
    // fused pipeline on the pool must match the split pipeline serially.
    let stages = [32usize, 96, 192];
    let mut sf = settings(32, 4, ExecutorChoice::Pool { cap: 4 }, EvalPipeline::Fused);
    sf.max_iters = 30;
    let mut ss = settings(32, 4, ExecutorChoice::Serial, EvalPipeline::Split);
    ss.max_iters = 30;
    let fused_stages =
        train_stagewise(&sf, &tr, Arc::clone(&backend), CostModel::free(), &stages).unwrap();
    let split_stages =
        train_stagewise(&ss, &tr, Arc::clone(&backend), CostModel::free(), &stages).unwrap();
    assert_eq!(fused_stages.len(), split_stages.len());
    for (stage, (a, b)) in fused_stages.iter().zip(&split_stages).enumerate() {
        assert_eq!(a.m, b.m, "stage {stage}");
        assert_eq!(a.stats.iterations, b.stats.iterations, "stage {stage}");
        for (i, (x, y)) in a.model.beta.iter().zip(&b.model.beta).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "stage {stage} beta[{i}]");
        }
    }
}

/// The metering acceptance criterion: on the fused path every f/g AND
/// every Hd evaluation costs exactly ONE barrier and ONE AllReduce
/// round-trip — comm_rounds() == fg + hd — where the split path pays two
/// round-trips per f/g (comm_rounds() == 2·fg + hd) and a barrier per
/// collective. Byte volume is identical; only latency rounds drop.
#[test]
fn fused_metering_drops_rounds_and_barriers_serial_exec() {
    let (tr, _) = data(1200, 150, 23);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let p = 6;
    let lat = CostModel {
        latency_s: 0.01,
        per_byte_s: 0.0,
    };
    let fused = train(
        &settings(96, p, ExecutorChoice::Serial, EvalPipeline::Fused),
        &tr,
        Arc::clone(&backend),
        lat,
    )
    .unwrap();
    let split = train(
        &settings(96, p, ExecutorChoice::Serial, EvalPipeline::Split),
        &tr,
        Arc::clone(&backend),
        lat,
    )
    .unwrap();
    let (fg, hd) = (fused.fg_evals as u64, fused.hd_evals as u64);
    assert_eq!(split.fg_evals as u64, fg, "same trajectory");
    assert!(fg > 0 && hd > 0);

    // Round-trips: exactly one per evaluation on the fused path (the
    // random-basis run issues no other collectives).
    assert_eq!(fused.sim.comm_rounds(), fg + hd);
    assert_eq!(split.sim.comm_rounds(), 2 * fg + hd);
    // Barriers: fused saves the 2 extra sync points per f/g (scalar +
    // gradient AllReduce) and 1 per Hd (its AllReduce).
    assert_eq!(
        split.sim.barriers() - fused.sim.barriers(),
        2 * fg + hd,
        "fused {} vs split {}",
        fused.sim.barriers(),
        split.sim.barriers()
    );
    // The wall-clock metrics mirror the ledger counters.
    assert_eq!(fused.wall.comm_rounds(), fused.sim.comm_rounds());
    assert_eq!(fused.wall.barriers(), fused.sim.barriers());

    // Same bytes through the tree; the saving is pure latency: with a
    // per-byte-free model the split path pays exactly 2·depth extra
    // latency rounds per f/g evaluation.
    assert_eq!(fused.sim.comm_bytes(), split.sim.comm_bytes());
    let depth = Tree::new(p, 2).depth() as f64;
    let fused_tron = fused.sim.comm_secs(dkm::metrics::Step::Tron);
    let split_tron = split.sim.comm_secs(dkm::metrics::Step::Tron);
    let want_saving = fg as f64 * 2.0 * depth * 0.01;
    assert!(
        (split_tron - fused_tron - want_saving).abs() < 1e-9,
        "fused {fused_tron} split {split_tron} want saving {want_saving}"
    );
    // And the split path's compute seconds describe the same work: the
    // fused phase meters compute identically (max over nodes, fold
    // excluded), so both totals are the same order — not a bit-compare
    // (they are measured wall times), but both strictly positive.
    assert!(fused.sim.compute_secs(dkm::metrics::Step::Tron) > 0.0);
    assert!(split.sim.compute_secs(dkm::metrics::Step::Tron) > 0.0);
}

/// Same metering law under the pool executor with streaming storage — the
/// combination the fusion was built for (many small dispatches, workers
/// never re-park between compute and reduce).
#[test]
fn fused_metering_drops_rounds_streaming_pool_exec() {
    let (tr, _) = data(900, 100, 29);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let run = |pipeline| {
        let mut s = settings(64, 4, ExecutorChoice::Pool { cap: 3 }, pipeline);
        s.c_storage = CStorage::StreamingRowbuf;
        train(&s, &tr, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap()
    };
    let fused = run(EvalPipeline::Fused);
    let split = run(EvalPipeline::Split);
    assert_bit_identical(&fused, &split, "streaming pool");
    let (fg, hd) = (fused.fg_evals as u64, fused.hd_evals as u64);
    assert_eq!(fused.sim.comm_rounds(), fg + hd);
    assert_eq!(split.sim.comm_rounds(), 2 * fg + hd);
    assert_eq!(fused.sim.comm_bytes(), split.sim.comm_bytes());
    assert!(
        fused.sim.comm_secs(dkm::metrics::Step::Tron)
            < split.sim.comm_secs(dkm::metrics::Step::Tron)
    );
}
