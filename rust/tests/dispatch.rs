//! Dispatch metering: with the whole-node block ops, a TRON evaluation
//! costs exactly ONE backend dispatch per node — per f/g and per Hd —
//! regardless of how many (row × column) tiles the node holds, which
//! C-storage mode it runs, and which communication pipeline drives the
//! cluster. The communication counters (AllReduce round-trips, barriers)
//! must stay exactly at the fused-pipeline contract: blocking dispatches
//! changes compute fan-out only, never the comm schedule.

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings,
};
use dkm::coordinator::train;
use dkm::data::{synth, Dataset};
use dkm::runtime::make_backend;

fn settings(
    m: usize,
    nodes: usize,
    executor: ExecutorChoice,
    pipeline: EvalPipeline,
) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        // Random basis: the FromC W shares read cached C rows on the host,
        // so TRON evaluations issue ONLY the block dispatches — the count
        // below is exact, not a bound.
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: CStorage::Materialized,
        eval_pipeline: pipeline,
        c_memory_budget: 256 << 20,
        max_iters: 25,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

/// Multi-column-tile m (m = 300 spans two basis tiles): one dispatch per
/// node per evaluation on both pipelines, with the PR-4 communication
/// contract unchanged (fused: one round-trip per evaluation; split:
/// 2·fg + hd; barrier difference exactly 2·fg + hd).
#[test]
fn one_dispatch_per_node_per_eval_multi_tile() {
    let (tr, _) = data(1400, 200, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let nodes = 5u64;
    let mut outs = Vec::new();
    for pipeline in [EvalPipeline::Fused, EvalPipeline::Split] {
        let s = settings(300, nodes as usize, ExecutorChoice::Serial, pipeline);
        let out = train(&s, &tr, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap();
        let (fg, hd) = (out.fg_evals as u64, out.hd_evals as u64);
        assert!(fg > 0 && hd > 0, "degenerate run");
        assert_eq!(
            out.sim.dispatches(),
            nodes * (fg + hd),
            "{pipeline:?}: expected exactly one dispatch per node per evaluation"
        );
        // The wall-metrics mirror must agree with the simulated ledger.
        assert_eq!(out.wall.dispatches(), out.sim.dispatches(), "{pipeline:?}");
        match pipeline {
            EvalPipeline::Fused => {
                assert_eq!(out.sim.comm_rounds(), fg + hd, "fused comm contract")
            }
            EvalPipeline::Split => {
                assert_eq!(out.sim.comm_rounds(), 2 * fg + hd, "split comm contract")
            }
        }
        outs.push(out);
    }
    // Same trajectory on both pipelines, so the barrier saving of the
    // fused pipeline is still exactly 2·fg + hd — blocking the node-local
    // dispatches did not change any synchronization point.
    let (fused, split) = (&outs[0], &outs[1]);
    assert_eq!(fused.fg_evals, split.fg_evals);
    assert_eq!(fused.hd_evals, split.hd_evals);
    assert_eq!(
        split.sim.barriers() - fused.sim.barriers(),
        2 * fused.fg_evals as u64 + fused.hd_evals as u64
    );
    assert_eq!(fused.sim.dispatches(), split.sim.dispatches());
}

/// Single-column-tile m with several row tiles per node (2 nodes × 700
/// rows = 3 row tiles each): still one dispatch per node per evaluation —
/// the block op covers all row tiles, where the per-tile fused ops cost
/// one dispatch per row tile.
#[test]
fn one_dispatch_per_node_single_col_tile_many_row_tiles_pool_exec() {
    let (tr, _) = data(1400, 200, 7);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let s = settings(
        96,
        2,
        ExecutorChoice::Pool { cap: 2 },
        EvalPipeline::Fused,
    );
    let out = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let evals = (out.fg_evals + out.hd_evals) as u64;
    assert!(evals > 0);
    assert_eq!(out.sim.dispatches(), 2 * evals);
    assert_eq!(out.wall.dispatches(), out.sim.dispatches());
}

/// The dispatch count is storage-independent: streaming modes recompute
/// kernel tiles INSIDE the node's single block dispatch, so the per-node
/// dispatch count never grows with recompute — only `recomputed_tiles`
/// does. β stays bit-identical across modes (the block ops replicate the
/// per-tile accumulation order exactly).
#[test]
fn dispatch_count_is_storage_independent_multi_tile() {
    let (tr, _) = data(1200, 200, 13);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let nodes = 4u64;
    let mut reference: Option<(Vec<u32>, u64)> = None;
    for storage in [
        CStorage::Materialized,
        CStorage::Streaming,
        CStorage::StreamingRowbuf,
        CStorage::Auto,
    ] {
        let mut s = settings(
            300,
            nodes as usize,
            ExecutorChoice::Serial,
            EvalPipeline::Fused,
        );
        s.c_storage = storage;
        let out = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
        let evals = (out.fg_evals + out.hd_evals) as u64;
        assert_eq!(
            out.sim.dispatches(),
            nodes * evals,
            "{}: dispatches must not scale with streamed recompute",
            storage.name()
        );
        let bits: Vec<u32> = out.model.beta.iter().map(|b| b.to_bits()).collect();
        match &reference {
            None => reference = Some((bits, out.sim.dispatches())),
            Some((ref_bits, ref_disp)) => {
                assert_eq!(&bits, ref_bits, "{}: β must be bit-identical", storage.name());
                assert_eq!(out.sim.dispatches(), *ref_disp, "{}", storage.name());
            }
        }
    }
}
