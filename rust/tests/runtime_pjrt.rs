//! Integration tests over the PJRT runtime: every AOT module is executed on
//! the CPU PJRT client and differential-tested against the native oracle,
//! then the full Algorithm-1 pipeline is compared PJRT-vs-native.
//!
//! Requires the `pjrt` cargo feature (the whole suite is compiled out
//! otherwise) and `make artifacts` (skipped with a clear message if the
//! artifacts directory is missing).
#![cfg(feature = "pjrt")]

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{Backend, BasisSelection, ExecutorChoice, Loss, Settings};
use dkm::coordinator::train;
use dkm::data::synth;
use dkm::rng::Rng;
use dkm::runtime::backend::{NativeCompute, PjrtCompute};
use dkm::runtime::tiles::{TB, TM};
use dkm::runtime::{make_backend, Compute};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| scale * rng.normal_f32()).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: pjrt {x} vs native {y}"
        );
    }
}

#[test]
fn kernel_block_pjrt_matches_native_all_widths() {
    require_artifacts!();
    let pjrt = PjrtCompute::new("artifacts").unwrap();
    let native = NativeCompute::new();
    let mut rng = Rng::new(1);
    for d in [32usize, 64, 128] {
        let x = rand_vec(&mut rng, TB * d, 1.0);
        let z = rand_vec(&mut rng, TM * d, 1.0);
        let a = pjrt.kernel_block(&x, &z, d, 0.37).unwrap();
        let b = native.kernel_block(&x, &z, d, 0.37).unwrap();
        assert_close(&a, &b, 1e-4, &format!("kernel_block d={d}"));
    }
}

#[test]
fn matvec_family_pjrt_matches_native() {
    require_artifacts!();
    let pjrt = PjrtCompute::new("artifacts").unwrap();
    let native = NativeCompute::new();
    let mut rng = Rng::new(2);
    let c = rand_vec(&mut rng, TB * TM, 0.5);
    let v = rand_vec(&mut rng, TM, 1.0);
    let r = rand_vec(&mut rng, TB, 1.0);
    assert_close(
        &pjrt.matvec(&c, &v).unwrap(),
        &native.matvec(&c, &v).unwrap(),
        1e-3,
        "matvec",
    );
    assert_close(
        &pjrt.matvec_t(&c, &r).unwrap(),
        &native.matvec_t(&c, &r).unwrap(),
        1e-3,
        "matvec_t",
    );
}

#[test]
fn loss_stages_pjrt_match_native() {
    require_artifacts!();
    let pjrt = PjrtCompute::new("artifacts").unwrap();
    let native = NativeCompute::new();
    let mut rng = Rng::new(3);
    let o = rand_vec(&mut rng, TB, 2.0);
    let y: Vec<f32> = (0..TB).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let mut mask = vec![1.0f32; TB];
    mask[200..].fill(0.0); // partial tile
    for loss in [Loss::SqHinge, Loss::Logistic, Loss::Squared] {
        let a = pjrt.loss_stage(loss, &o, &y, &mask).unwrap();
        let b = native.loss_stage(loss, &o, &y, &mask).unwrap();
        assert!(
            (a.loss - b.loss).abs() < 1e-3 * (1.0 + b.loss.abs()),
            "{}: loss {} vs {}",
            loss.name(),
            a.loss,
            b.loss
        );
        assert_close(&a.vec, &b.vec, 1e-4, &format!("{} resid", loss.name()));
        assert_close(&a.dcoef, &b.dcoef, 1e-4, &format!("{} dcoef", loss.name()));
    }
}

#[test]
fn fused_fgrad_and_hd_pjrt_match_native() {
    require_artifacts!();
    let pjrt = PjrtCompute::new("artifacts").unwrap();
    let native = NativeCompute::new();
    let mut rng = Rng::new(4);
    let c = rand_vec(&mut rng, TB * TM, 0.4);
    let beta = rand_vec(&mut rng, TM, 0.2);
    let y: Vec<f32> = (0..TB).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
    let mask = vec![1.0f32; TB];
    for loss in [Loss::SqHinge, Loss::Logistic, Loss::Squared] {
        let a = pjrt.fgrad(loss, &c, &beta, &y, &mask).unwrap();
        let b = native.fgrad(loss, &c, &beta, &y, &mask).unwrap();
        assert!((a.loss - b.loss).abs() < 1e-3 * (1.0 + b.loss.abs()));
        assert_close(&a.vec, &b.vec, 1e-3, &format!("fgrad {}", loss.name()));
    }
    let d = rand_vec(&mut rng, TM, 0.3);
    let dcoef: Vec<f32> = (0..TB).map(|i| (i % 2) as f32).collect();
    assert_close(
        &pjrt.hd_tile(&c, &d, &dcoef).unwrap(),
        &native.hd_tile(&c, &d, &dcoef).unwrap(),
        1e-3,
        "hd_tile",
    );
}

#[test]
fn kmeans_and_predict_pjrt_match_native() {
    require_artifacts!();
    let pjrt = PjrtCompute::new("artifacts").unwrap();
    let native = NativeCompute::new();
    let mut rng = Rng::new(5);
    let d = 64;
    let x = rand_vec(&mut rng, TB * d, 1.0);
    let cent = rand_vec(&mut rng, TM * d, 1.0);
    let mut cmask = vec![0.0f32; TM];
    cmask[..30].fill(1.0);
    let mut rmask = vec![1.0f32; TB];
    rmask[180..].fill(0.0);
    let a = pjrt.kmeans_assign(&x, &cent, &cmask, &rmask, d).unwrap();
    let b = native.kmeans_assign(&x, &cent, &cmask, &rmask, d).unwrap();
    // Live rows must agree exactly on assignment.
    for i in 0..180 {
        assert_eq!(a.idx[i], b.idx[i], "row {i}");
    }
    assert_close(&a.counts, &b.counts, 1e-5, "counts");
    assert!((a.inertia - b.inertia).abs() < 1e-2 * (1.0 + b.inertia.abs()));

    let beta = rand_vec(&mut rng, TM, 0.1);
    let z = rand_vec(&mut rng, TM * d, 1.0);
    assert_close(
        &pjrt.predict_block(&x, &z, 0.3, &beta, d).unwrap(),
        &native.predict_block(&x, &z, 0.3, &beta, d).unwrap(),
        1e-3,
        "predict_block",
    );

    assert_close(
        &pjrt.dist2_block(&x, &z, d).unwrap(),
        &native.dist2_block(&x, &z, d).unwrap(),
        1e-3,
        "dist2_block",
    );
}

#[test]
fn streaming_from_x_ops_pjrt_match_native() {
    require_artifacts!();
    let pjrt = PjrtCompute::new("artifacts").unwrap();
    let native = NativeCompute::new();
    let mut rng = Rng::new(12);
    let d = 64usize;
    let x = rand_vec(&mut rng, TB * d, 1.0);
    let z = rand_vec(&mut rng, TM * d, 1.0);
    let beta = rand_vec(&mut rng, TM, 0.2);
    let r = rand_vec(&mut rng, TB, 0.5);
    let y: Vec<f32> = (0..TB).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let mask = vec![1.0f32; TB];
    let dcoef = vec![1.0f32; TB];
    let xp = pjrt.prepare(&x, &[TB, d]).unwrap();
    let zp = pjrt.prepare(&z, &[TM, d]).unwrap();
    let yp = pjrt.prepare(&y, &[TB]).unwrap();
    let mp = pjrt.prepare(&mask, &[TB]).unwrap();
    let xn = native.prepare(&x, &[TB, d]).unwrap();
    let zn = native.prepare(&z, &[TM, d]).unwrap();
    let yn = native.prepare(&y, &[TB]).unwrap();
    let mn = native.prepare(&mask, &[TB]).unwrap();
    let a = pjrt
        .fgrad_from_x(Loss::SqHinge, &xp, &zp, d, 0.4, &beta, &yp, &mp)
        .unwrap();
    let b = native
        .fgrad_from_x(Loss::SqHinge, &xn, &zn, d, 0.4, &beta, &yn, &mn)
        .unwrap();
    assert!((a.loss - b.loss).abs() < 1e-3 * (1.0 + b.loss.abs()));
    assert_close(&a.vec, &b.vec, 1e-3, "fgrad_from_x");
    assert_close(
        &pjrt.hd_from_x(&xp, &zp, d, 0.4, &beta, &dcoef).unwrap(),
        &native.hd_from_x(&xn, &zn, d, 0.4, &beta, &dcoef).unwrap(),
        1e-3,
        "hd_from_x",
    );
    assert_close(
        &pjrt.matvec_from_x(&xp, &zp, d, 0.4, &beta).unwrap(),
        &native.matvec_from_x(&xn, &zn, d, 0.4, &beta).unwrap(),
        1e-3,
        "matvec_from_x",
    );
    assert_close(
        &pjrt.matvec_t_from_x(&xp, &zp, d, 0.4, &r).unwrap(),
        &native.matvec_t_from_x(&xn, &zn, d, 0.4, &r).unwrap(),
        1e-3,
        "matvec_t_from_x",
    );
}

#[test]
fn end_to_end_training_pjrt_equals_native() {
    require_artifacts!();
    let mut spec = synth::spec("covtype_like");
    spec.n_train = 900;
    spec.n_test = 300;
    let (train_ds, test_ds) = synth::generate(&spec, 7);
    let settings = Settings {
        dataset: "covtype_like".into(),
        m: 96,
        nodes: 3,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Pjrt,
        executor: ExecutorChoice::Serial,
        c_storage: dkm::config::settings::CStorage::Materialized,
        eval_pipeline: dkm::config::settings::EvalPipeline::Fused,
        c_memory_budget: 256 << 20,
        max_iters: 40,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    };
    let pjrt = make_backend(Backend::Pjrt, "artifacts").unwrap();
    let native = make_backend(Backend::Native, "artifacts").unwrap();
    let out_p = train(&settings, &train_ds, Arc::clone(&pjrt), CostModel::free()).unwrap();
    let out_n = train(&settings, &train_ds, Arc::clone(&native), CostModel::free()).unwrap();
    // Same seed → same basis; optimization paths may diverge slightly in fp
    // but final objective and accuracy must agree closely.
    let rel_f = (out_p.stats.final_f - out_n.stats.final_f).abs()
        / out_n.stats.final_f.abs().max(1.0);
    assert!(rel_f < 2e-2, "final f: pjrt {} native {}", out_p.stats.final_f, out_n.stats.final_f);
    let acc_p = out_p.model.accuracy(pjrt.as_ref(), &test_ds).unwrap();
    let acc_n = out_n.model.accuracy(native.as_ref(), &test_ds).unwrap();
    assert!((acc_p - acc_n).abs() < 0.03, "acc: pjrt {acc_p} native {acc_n}");
    assert!(pjrt.call_count() > 0, "pjrt path was not exercised");
}

#[test]
fn engine_rejects_missing_artifacts_dir() {
    let err = PjrtCompute::new("definitely_not_here").err();
    assert!(err.is_some());
    let msg = format!("{:#}", err.unwrap());
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn multi_tile_m_training_works_on_pjrt() {
    require_artifacts!();
    // m > TM exercises the unfused matvec/matvec_t path.
    let mut spec = synth::spec("covtype_like");
    spec.n_train = 700;
    spec.n_test = 200;
    let (train_ds, test_ds) = synth::generate(&spec, 9);
    let settings = Settings {
        m: 300, // 2 basis tiles
        nodes: 2,
        lambda: 0.01,
        sigma: 2.0,
        max_iters: 25,
        ..Settings::default()
    };
    let pjrt = make_backend(Backend::Pjrt, "artifacts").unwrap();
    let out = train(&settings, &train_ds, Arc::clone(&pjrt), CostModel::free()).unwrap();
    let acc = out.model.accuracy(pjrt.as_ref(), &test_ds).unwrap();
    assert!(acc > 0.5, "accuracy {acc}");
}
