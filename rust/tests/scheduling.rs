//! Scheduling equivalence: work-stealing phase scheduling (`--sched
//! steal[:grain]`) is BIT-IDENTICAL to static chunking — same β bits,
//! same evaluation counts, same barriers/AllReduce rounds/dispatches/
//! bytes — across executors × C-storage modes × eval pipelines ×
//! solvers. Only the wall clocks may move: the real host wall (idle
//! workers steal leftover nodes) and, under an injected `--skew`, the
//! simulated wall (the ledger charges the stealing makespan instead of
//! the slowest-node max). Plus the straggler metering regression and
//! the error/panic contracts re-proven under the shared claim cursor.
//!
//! Test names end in `serial_exec` / `threads_exec` / `pool_exec` so CI
//! can run the suite per executor group.

use std::sync::Arc;

use dkm::cluster::{Cluster, CostModel, Executor, Sched, Skew, SlotWork, Tree};
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings, SolverChoice,
};
use dkm::coordinator::train;
use dkm::data::{synth, Dataset};
use dkm::metrics::Step;
use dkm::runtime::make_backend;

fn settings(m: usize, nodes: usize, executor: ExecutorChoice, sched: Sched) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        sched,
        max_iters: 12,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

/// The tentpole grid: static-serial (the metering reference) vs stealing
/// on `exec_steal`, across storage × pipeline × solver. β bits, eval
/// counts and every synchronization counter must be identical.
fn stealing_matches_static_grid(exec_steal: ExecutorChoice) {
    let (tr, _) = data(900, 100, 23);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    for c_storage in [CStorage::Materialized, CStorage::Streaming] {
        for pipeline in [EvalPipeline::Fused, EvalPipeline::Split] {
            for solver in [SolverChoice::Tron, SolverChoice::Bcd { block: 32 }] {
                let label = format!(
                    "steal-exec={} storage={} pipeline={} solver={}",
                    exec_steal.name(),
                    c_storage.name(),
                    pipeline.name(),
                    solver.name(),
                );
                let mut a = settings(96, 8, ExecutorChoice::Serial, Sched::Static);
                a.c_storage = c_storage;
                a.eval_pipeline = pipeline;
                a.solver = solver;
                let mut b = settings(96, 8, exec_steal, Sched::Steal { grain: 2 });
                b.c_storage = c_storage;
                b.eval_pipeline = pipeline;
                b.solver = solver;
                let sa = train(&a, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
                let sb = train(&b, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
                assert_eq!(sa.model.beta.len(), sb.model.beta.len(), "{label}");
                for (i, (x, y)) in sa.model.beta.iter().zip(&sb.model.beta).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "{label} beta[{i}]: {x} vs {y}");
                }
                assert_eq!(sa.fg_evals, sb.fg_evals, "{label}");
                assert_eq!(sa.hd_evals, sb.hd_evals, "{label}");
                assert_eq!(sa.stats.iterations, sb.stats.iterations, "{label}");
                assert_eq!(
                    sa.stats.final_f.to_bits(),
                    sb.stats.final_f.to_bits(),
                    "{label}"
                );
                // The whole synchronization ledger is scheduler-independent.
                assert_eq!(sa.sim.barriers(), sb.sim.barriers(), "{label}");
                assert_eq!(sa.sim.comm_rounds(), sb.sim.comm_rounds(), "{label}");
                assert_eq!(sa.sim.dispatches(), sb.sim.dispatches(), "{label}");
                assert_eq!(sa.sim.comm_bytes(), sb.sim.comm_bytes(), "{label}");
            }
        }
    }
}

#[test]
fn stealing_matches_static_training_serial_exec() {
    // On the serial executor the claim cursor is moot for execution but
    // the STEAL pricing model is still selected — β and counters must
    // not notice either way.
    stealing_matches_static_grid(ExecutorChoice::Serial);
}

#[test]
fn stealing_matches_static_training_threads_exec() {
    stealing_matches_static_grid(ExecutorChoice::Threads { cap: 4 });
}

#[test]
fn stealing_matches_static_training_pool_exec() {
    stealing_matches_static_grid(ExecutorChoice::Pool { cap: 4 });
}

/// Metering regression: same skewed fleet, static vs stealing — every
/// synchronization counter pinned equal, β bit-identical, but the
/// stealing ledger's simulated compute drops well below the static
/// (slowest-node) charge, and the straggler observables expose the skew.
fn skew_metering_regression(exec: ExecutorChoice) {
    let (tr, _) = data(900, 100, 29);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mk = |sched: Sched| {
        let mut s = settings(96, 8, exec, sched);
        s.skew = Skew::parse("0=4").unwrap();
        s
    };
    let st = train(&mk(Sched::Static), &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let sl = train(
        &mk(Sched::Steal { grain: 4 }),
        &tr,
        Arc::clone(&backend),
        CostModel::free(),
    )
    .unwrap();
    for (x, y) in st.model.beta.iter().zip(&sl.model.beta) {
        assert_eq!(x.to_bits(), y.to_bits(), "skew must not touch β");
    }
    assert_eq!(st.sim.barriers(), sl.sim.barriers());
    assert_eq!(st.sim.comm_rounds(), sl.sim.comm_rounds());
    assert_eq!(st.sim.dispatches(), sl.sim.dispatches());
    assert_eq!(st.sim.comm_bytes(), sl.sim.comm_bytes());
    // The simulated TRON wall: static pays node 0's 4× rate on every
    // phase; stealing spreads the oversplit items across the fleet.
    let static_secs = st.sim.compute_secs(Step::Tron);
    let steal_secs = sl.sim.compute_secs(Step::Tron);
    assert!(
        steal_secs < 0.8 * static_secs,
        "stealing must beat the straggler bound: {steal_secs} vs {static_secs}"
    );
    // Straggler observables: a 4×-skewed node at p=8 over roughly even
    // shards sits near 32/11 ≈ 2.9; noise tolerance down to 1.5.
    assert!(
        st.sim.straggler_ratio(8) > 1.5,
        "ratio {}",
        st.sim.straggler_ratio(8)
    );
    // ...and they are mirrored into the wall metrics (µs quantization).
    assert!(st.wall.max_node_secs() > 0.0);
    assert!(
        (st.wall.max_node_secs() - st.sim.max_node_secs()).abs() < 1e-3,
        "wall mirror {} vs ledger {}",
        st.wall.max_node_secs(),
        st.sim.max_node_secs()
    );
}

#[test]
fn skew_drops_sim_wall_with_counters_pinned_serial_exec() {
    skew_metering_regression(ExecutorChoice::Serial);
}

#[test]
fn skew_drops_sim_wall_with_counters_pinned_threads_exec() {
    skew_metering_regression(ExecutorChoice::Threads { cap: 8 });
}

/// Node failures under stealing surface the FIRST error in node order —
/// not claim order, not completion order — exactly like static chunking.
fn stealing_error_order(exec: Executor) {
    let name = exec.name();
    let mut cl = Cluster::new(vec![0u32; 9], 2, CostModel::free())
        .with_sched(Sched::Steal { grain: 1 })
        .with_executor(exec);
    let err = cl
        .try_par_compute(Step::Kernel, |j, n: &mut u32| {
            *n += 1;
            if j == 1 || j == 5 {
                anyhow::bail!("shard {j} corrupt")
            }
            Ok(j)
        })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("node 1"), "{name}: {msg}");
    assert!(msg.contains("shard 1 corrupt"), "{name}: {msg}");
    // The fused path reports the same node-ordered error.
    let err = cl
        .try_par_compute_reduce(Step::Tron, |j, _| {
            if j >= 4 {
                anyhow::bail!("partial {j} corrupt")
            }
            Ok(vec![j as f32])
        })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("node 4"), "{name}: {msg}");
    // A synchronous phase still ran every node despite the failures.
    for j in 0..9 {
        assert_eq!(cl.node(j), &1, "{name}: node {j} skipped");
    }
}

#[test]
fn stealing_reports_first_error_in_node_order_threads_exec() {
    stealing_error_order(Executor::threaded(3));
}

#[test]
fn stealing_reports_first_error_in_node_order_pool_exec() {
    stealing_error_order(Executor::pooled(3));
}

/// A worker panic mid-phase under stealing propagates to the caller and
/// the pool keeps serving later phases — including fused reduces.
#[test]
fn stealing_panic_propagates_and_pool_survives_pool_exec() {
    let mut cl = Cluster::new(vec![0u32; 6], 2, CostModel::free())
        .with_sched(Sched::Steal { grain: 2 })
        .with_executor(Executor::pooled(3));
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cl.par_compute(Step::Kernel, |j, _| {
            if j == 4 {
                panic!("worker died on node 4 under stealing");
            }
        });
    }));
    assert!(caught.is_err(), "worker panic must reach the caller");
    let out = cl.par_compute_reduce(Step::Tron, |j, n| {
        *n = j as u32 + 1;
        vec![1.0f32]
    });
    assert_eq!(out, vec![6.0]);
    assert_eq!(cl.node(5), &6);
}

/// The wakeup-audit lock (see the worker-loop comment in
/// `cluster/exec.rs`): rapid alternation of `run`, `run_reduce` and
/// `run_concurrent` phases on ONE pool under the shared claim cursor.
/// A missed wakeup would deadlock a phase; a stale-epoch double run
/// would corrupt node state or the claim-once cells — 500 rounds of
/// all three phase kinds lock the protocol's behavior.
#[test]
fn rapid_phase_alternation_under_stealing_pool_exec() {
    let exec = Executor::pooled(3).with_sched(Sched::Steal { grain: 1 });
    let tree = Tree::new(7, 2);
    let mut nodes: Vec<u64> = vec![0; 7];
    for round in 0..500u64 {
        let (out, secs) = exec.run(&mut nodes, &|j, n: &mut u64| {
            *n += 1;
            (round, j)
        });
        assert_eq!(out, (0..7).map(|j| (round, j)).collect::<Vec<_>>());
        assert_eq!(secs.len(), 7, "per-node seconds for every node");
        let (red, _) = exec.run_reduce(&tree, &mut nodes, &|j, n: &mut u64| {
            *n += 1;
            Ok(vec![j as f32])
        });
        assert_eq!(red.unwrap(), vec![21.0]);
        let slot_run = |i: usize| i as u64 + round;
        let slots = [
            SlotWork {
                items: 5,
                run: &slot_run,
            },
            SlotWork {
                items: 3,
                run: &slot_run,
            },
        ];
        let res = exec.run_concurrent(&slots);
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].items, (0..5).map(|i| i + round).collect::<Vec<_>>());
        assert_eq!(res[1].items, (0..3).map(|i| i + round).collect::<Vec<_>>());
    }
    // Every node saw every run AND every run_reduce exactly once.
    for (j, n) in nodes.iter().enumerate() {
        assert_eq!(*n, 1000, "node {j} missed or double-ran a phase");
    }
}
