//! The solver abstraction contract: TRON behind the `Solver` trait is the
//! SAME numerical path as before the refactor (pinned as trait-dispatch vs
//! direct `minimize` bit-identity plus cross-storage / cross-executor
//! invariance), and the BCD peer holds the substrate's reproducibility
//! contract — β bit-identical across executors, storage modes and the
//! fused/split pipelines — while its round economics are metered at
//! exactly ONE barrier + ONE AllReduce round-trip per outer block round.
//!
//! Test names end in `serial_exec` / `threads_exec` / `pool_exec`; CI runs
//! each group explicitly next to the fused_eval and c_storage matrices.

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings, SolverChoice,
};
use dkm::coordinator::dist::DistProblem;
use dkm::coordinator::solver::{make_solver, tron, TronOptions};
use dkm::coordinator::trainer::build_cluster;
use dkm::coordinator::{basis, train, TrainOutput};
use dkm::data::{synth, Dataset};
use dkm::metrics::Step;
use dkm::runtime::make_backend;

fn settings(
    m: usize,
    nodes: usize,
    executor: ExecutorChoice,
    solver: SolverChoice,
) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: CStorage::Materialized,
        eval_pipeline: EvalPipeline::Fused,
        c_memory_budget: 256 << 20,
        max_iters: 40,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

fn assert_bit_identical(a: &TrainOutput, b: &TrainOutput, what: &str) {
    assert_eq!(a.model.beta.len(), b.model.beta.len(), "{what}");
    for (i, (x, y)) in a.model.beta.iter().zip(&b.model.beta).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: beta[{i}] {x} vs {y}");
    }
    assert_eq!(a.stats.iterations, b.stats.iterations, "{what}");
    assert_eq!(a.fg_evals, b.fg_evals, "{what}");
    assert_eq!(a.hd_evals, b.hd_evals, "{what}");
    assert_eq!(
        a.stats.final_f.to_bits(),
        b.stats.final_f.to_bits(),
        "{what}"
    );
}

/// Refactored TRON (behind the trait) must produce one β regardless of
/// C-storage mode — the cross-config pin that the move into
/// `coordinator/solver/` did not perturb the numerical path.
#[test]
fn tron_beta_bit_identical_across_storage_serial_exec() {
    let (tr, _) = data(1200, 150, 7);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let reference = {
        let s = settings(96, 5, ExecutorChoice::Serial, SolverChoice::Tron);
        train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap()
    };
    assert_eq!(reference.stats.solver, "tron");
    assert!(reference.stats.final_f < reference.stats.f0());
    assert_eq!(reference.stats.curve.len(), reference.stats.iterations + 1);
    for storage in [CStorage::Streaming, CStorage::StreamingRowbuf, CStorage::Auto] {
        let mut s = settings(96, 5, ExecutorChoice::Serial, SolverChoice::Tron);
        s.c_storage = storage;
        let out = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
        assert_bit_identical(&reference, &out, storage.name());
    }
}

/// Same pin across the worker-thread executors: spawn-per-phase threads
/// and the persistent pool must reproduce the serial β bit for bit.
#[test]
fn tron_beta_bit_identical_threads_exec() {
    let (tr, _) = data(1100, 150, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let serial = train(
        &settings(96, 6, ExecutorChoice::Serial, SolverChoice::Tron),
        &tr,
        Arc::clone(&backend),
        CostModel::free(),
    )
    .unwrap();
    let threads = train(
        &settings(96, 6, ExecutorChoice::Threads { cap: 4 }, SolverChoice::Tron),
        &tr,
        Arc::clone(&backend),
        CostModel::free(),
    )
    .unwrap();
    assert_bit_identical(&serial, &threads, "tron serial vs threads");
}

#[test]
fn tron_beta_bit_identical_pool_exec() {
    let (tr, _) = data(1100, 150, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let serial = train(
        &settings(96, 6, ExecutorChoice::Serial, SolverChoice::Tron),
        &tr,
        Arc::clone(&backend),
        CostModel::free(),
    )
    .unwrap();
    let pool = train(
        &settings(96, 6, ExecutorChoice::Pool { cap: 3 }, SolverChoice::Tron),
        &tr,
        Arc::clone(&backend),
        CostModel::free(),
    )
    .unwrap();
    assert_bit_identical(&serial, &pool, "tron serial vs pool");
}

/// The trait shell is the standalone function: driving the SAME manually
/// built distributed problem through `make_solver` (what `Session::solve`
/// does) and through a direct `tron::minimize` call must agree bit for
/// bit — the refactor pin that needs no pre-refactor binary.
#[test]
fn tron_trait_dispatch_matches_direct_minimize_serial_exec() {
    let (tr, _) = data(700, 100, 13);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let (m, gamma, lambda, seed) = (48usize, 0.125f32, 0.05f32, 13u64);
    let dpad = backend.pad_d(tr.d()).unwrap();
    let build = |tr: &Dataset| {
        let mut cluster = build_cluster(tr, 4, dpad, CostModel::free());
        let b = basis::select_random(&mut cluster, m, tr.d(), dpad, seed).unwrap();
        basis::install_w_shares(&mut cluster, &backend, &b, gamma, dpad).unwrap();
        let zt = b.z_tiles.clone();
        let be = Arc::clone(&backend);
        cluster
            .try_par_compute(Step::Kernel, |_, n| {
                n.compute_c_block(be.as_ref(), &zt, m, gamma, 0..1)?;
                n.prepare_hot(be.as_ref())
            })
            .unwrap();
        cluster
    };

    let mut s = settings(m, 4, ExecutorChoice::Serial, SolverChoice::Tron);
    s.tol = 1e-4;
    s.max_iters = 50;

    let mut c1 = build(&tr);
    let mut p1 = DistProblem::new(&mut c1, Arc::clone(&backend), m, lambda, Loss::SqHinge);
    let opts = TronOptions {
        tol: s.tol,
        max_iters: s.max_iters,
        ..TronOptions::default()
    };
    let (beta_direct, st_direct) = tron::minimize(&mut p1, &vec![0.0f32; m], &opts).unwrap();

    let mut c2 = build(&tr);
    let mut p2 = DistProblem::new(&mut c2, Arc::clone(&backend), m, lambda, Loss::SqHinge);
    let mut solver = make_solver(&s);
    assert_eq!(solver.name(), "tron");
    let (beta_trait, st_trait) = solver.solve(&mut p2, &vec![0.0f32; m]).unwrap();

    for (i, (a, b)) in beta_direct.iter().zip(&beta_trait).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{i}]: {a} vs {b}");
    }
    assert_eq!(st_direct.iterations, st_trait.iterations);
    assert_eq!(st_direct.fg_evals, st_trait.fg_evals);
    assert_eq!(st_direct.hd_evals, st_trait.hd_evals);
    assert_eq!(st_direct.final_f.to_bits(), st_trait.final_f.to_bits());
    assert_eq!(st_direct.f_curve(), st_trait.f_curve());
}

/// BCD reproducibility on the serial reference executor: fused vs split
/// pipelines and every C-storage mode yield one β (same fixed-order
/// per-node math, same tree fold), with the byte volume unchanged by
/// fusion — the TRON pipeline contract, held by the new peer.
#[test]
fn bcd_bit_identical_pipelines_and_storage_serial_exec() {
    let (tr, _) = data(1000, 120, 17);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let bcd = SolverChoice::Bcd { block: 32 };
    let run = |pipeline, storage| {
        let mut s = settings(96, 5, ExecutorChoice::Serial, bcd);
        s.eval_pipeline = pipeline;
        s.c_storage = storage;
        s.max_iters = 24;
        train(&s, &tr, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap()
    };
    let reference = run(EvalPipeline::Fused, CStorage::Materialized);
    assert_eq!(reference.stats.solver, "bcd");
    assert_eq!(reference.hd_evals, 0, "BCD never evaluates Hd");
    assert!(reference.stats.final_f < reference.stats.f0());
    assert_eq!(reference.stats.curve.len(), reference.stats.iterations + 1);
    for storage in [
        CStorage::Materialized,
        CStorage::Streaming,
        CStorage::StreamingRowbuf,
        CStorage::Auto,
    ] {
        let fused = run(EvalPipeline::Fused, storage);
        let split = run(EvalPipeline::Split, storage);
        assert_bit_identical(&fused, &reference, storage.name());
        assert_bit_identical(&fused, &split, storage.name());
        assert_eq!(
            fused.sim.comm_bytes(),
            split.sim.comm_bytes(),
            "{}: fusion must not change the BCD byte volume",
            storage.name()
        );
    }
}

/// BCD across executors, multi-tile m (two basis column tiles, so block
/// order crosses a tile boundary and the last block is a remainder).
#[test]
fn bcd_bit_identical_multi_tile_threads_exec() {
    let (tr, _) = data(1200, 150, 19);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let run = |executor| {
        let mut s = settings(300, 5, executor, SolverChoice::Bcd { block: 64 });
        s.max_iters = 15;
        train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap()
    };
    let serial = run(ExecutorChoice::Serial);
    let threads = run(ExecutorChoice::Threads { cap: 4 });
    assert_bit_identical(&serial, &threads, "bcd serial vs threads");
}

#[test]
fn bcd_bit_identical_multi_tile_pool_exec() {
    let (tr, _) = data(1200, 150, 19);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let run = |executor| {
        let mut s = settings(300, 5, executor, SolverChoice::Bcd { block: 64 });
        s.max_iters = 15;
        train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap()
    };
    let serial = run(ExecutorChoice::Serial);
    let pool = run(ExecutorChoice::Pool { cap: 4 });
    assert_bit_identical(&serial, &pool, "bcd serial vs pool");
}

/// BCD and TRON minimize the SAME objective. With the squared loss the
/// block majorizer is the exact block Hessian, and with one block
/// covering all of m the first BCD step IS the Newton step to the global
/// minimum of the quadratic — so both solvers must land on the same f.
#[test]
fn bcd_reaches_tron_objective_serial_exec() {
    let (tr, _) = data(900, 120, 23);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();

    // Exact case: squared loss, single block.
    let mut st = settings(64, 4, ExecutorChoice::Serial, SolverChoice::Tron);
    st.loss = Loss::Squared;
    st.tol = 1e-5;
    st.max_iters = 200;
    let tron_out = train(&st, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let mut sb = settings(64, 4, ExecutorChoice::Serial, SolverChoice::Bcd { block: 64 });
    sb.loss = Loss::Squared;
    sb.tol = 1e-5;
    sb.max_iters = 50;
    let bcd_out = train(&sb, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let (ft, fb) = (tron_out.stats.final_f, bcd_out.stats.final_f);
    assert!(
        (ft - fb).abs() <= 1e-3 * ft.abs().max(1.0),
        "squared loss: tron {ft} vs bcd {fb}"
    );
    // The first block step already lands on the quadratic's minimum: the
    // objective after round 1 equals the final objective.
    assert!(
        (bcd_out.stats.curve[1].f - fb).abs() <= 1e-3 * fb.abs().max(1.0),
        "one exact Newton block step: curve[1] {} vs final {fb}",
        bcd_out.stats.curve[1].f
    );

    // Majorized case: sqhinge, multiple blocks — same minimum, looser band
    // (BCD's damped steps converge linearly, not in one shot).
    let mut st = settings(64, 4, ExecutorChoice::Serial, SolverChoice::Tron);
    st.tol = 1e-5;
    st.max_iters = 200;
    let tron_out = train(&st, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let mut sb = settings(64, 4, ExecutorChoice::Serial, SolverChoice::Bcd { block: 16 });
    sb.tol = 1e-4;
    sb.max_iters = 600;
    let bcd_out = train(&sb, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let (ft, fb) = (tron_out.stats.final_f, bcd_out.stats.final_f);
    assert!(
        (fb - ft) <= 0.02 * ft.abs().max(1.0),
        "sqhinge: tron {ft} vs bcd {fb}"
    );
    // The curve BCD reports is (weakly) monotone: majorization means every
    // block step decreases f — allow f32-rounding slack only.
    for w in bcd_out.stats.curve.windows(2) {
        assert!(
            w[1].f <= w[0].f * (1.0 + 1e-5) + 1e-6,
            "bcd curve increased: {} -> {}",
            w[0].f,
            w[1].f
        );
    }
}

/// The BCD metering acceptance criterion, pinned as a delta between two
/// runs that differ only in round count (setup and final-flush phases are
/// per-solve constants and cancel): each extra outer round costs exactly
/// ONE barrier and ONE AllReduce round-trip on the fused path, and one
/// f/g-style evaluation — never an Hd.
#[test]
fn bcd_metering_one_barrier_one_round_per_round_serial_exec() {
    let (tr, _) = data(900, 120, 29);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let lat = CostModel {
        latency_s: 0.01,
        per_byte_s: 0.0,
    };
    let run = |rounds: usize| {
        let mut s = settings(96, 6, ExecutorChoice::Serial, SolverChoice::Bcd { block: 32 });
        // tol 0 never converges (a sweep-gradient of exactly zero would be
        // required), so the solver runs exactly `max_iters` rounds.
        s.tol = 0.0;
        s.max_iters = rounds;
        train(&s, &tr, Arc::clone(&backend), lat).unwrap()
    };
    let (r1, r2) = (8usize, 20usize);
    let a = run(r1);
    let b = run(r2);
    assert_eq!(a.stats.iterations, r1);
    assert_eq!(b.stats.iterations, r2);
    assert!(!a.stats.converged && !b.stats.converged);
    let extra = (r2 - r1) as u64;
    assert_eq!(
        b.sim.comm_rounds() - a.sim.comm_rounds(),
        extra,
        "one AllReduce round-trip per outer round"
    );
    assert_eq!(
        b.sim.barriers() - a.sim.barriers(),
        extra,
        "one barrier per outer round"
    );
    assert_eq!(b.fg_evals - a.fg_evals, r2 - r1, "one evaluation per round");
    assert_eq!(a.hd_evals, 0);
    assert_eq!(b.hd_evals, 0);
    // The wall-clock metrics mirror the sim ledger counters.
    assert_eq!(a.wall.comm_rounds(), a.sim.comm_rounds());
    assert_eq!(a.wall.barriers(), a.sim.barriers());
    // Each round's AllReduce carries block+2 floats (up + down passes of
    // the tree) and its delta broadcast block floats (down pass) —
    // strictly fewer bytes per round than TRON's m-vector rounds; pin the
    // per-round byte delta exactly against the ledger's pricing model.
    let per_round_bytes = (b.sim.comm_bytes() - a.sim.comm_bytes()) / extra;
    let depth = dkm::cluster::Tree::new(6, 2).depth();
    let block = 32usize;
    let want = 2 * depth * (block + 2) * 4 + depth * block * 4;
    assert_eq!(per_round_bytes as usize, want, "per-round byte volume");
}
