//! Executor equivalence: the tentpole contract that training on the
//! threaded execution layer — spawn-per-phase threads AND the persistent
//! worker pool — is BIT-IDENTICAL to the serial reference: same β bits,
//! same evaluation counts, same TRON trajectory, and every collective
//! reduces in the same deterministic order under every executor. Plus the
//! pool-specific behaviors: worker-panic propagation (with pool survival)
//! and worker reuse across many small phases (the streaming shape).
//!
//! The training runs here drive TRON through the FUSED evaluation
//! pipeline (the default): every full-training / multi-tile-m /
//! stage-wise bit-identity assertion below is therefore also a
//! `run_reduce` bit-identity assertion across serial, threads and pool.
//! The raw fused-phase primitive and its failure modes are covered at the
//! bottom; fused-vs-split equivalence lives in `rust/tests/fused_eval.rs`.

use std::sync::Arc;

use dkm::cluster::{Cluster, CostModel, Executor};
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings,
};
use dkm::coordinator::trainer::train_stagewise;
use dkm::coordinator::train;
use dkm::data::{synth, Dataset};
use dkm::metrics::Step;
use dkm::rng::Rng;
use dkm::runtime::make_backend;

fn settings(m: usize, nodes: usize, executor: ExecutorChoice) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: CStorage::Materialized,
        eval_pipeline: EvalPipeline::Fused,
        c_memory_budget: 256 << 20,
        max_iters: 60,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

/// The acceptance-criterion test: serial, spawn-per-phase threaded and
/// persistent-pool training on covtype_like produce bit-identical β and
/// identical fg/hd eval counts.
#[test]
fn threaded_and_pooled_training_are_bit_identical_to_serial() {
    let (tr, _) = data(1600, 200, 7);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let serial = train(
        &settings(96, 8, ExecutorChoice::Serial),
        &tr,
        Arc::clone(&backend),
        CostModel::hadoop_crude(),
    )
    .unwrap();
    for exec in [
        ExecutorChoice::Threads { cap: 2 },
        ExecutorChoice::Threads { cap: 8 },
        ExecutorChoice::Pool { cap: 2 },
        ExecutorChoice::Pool { cap: 8 },
    ] {
        let name = exec.name();
        let other = train(
            &settings(96, 8, exec),
            &tr,
            Arc::clone(&backend),
            CostModel::hadoop_crude(),
        )
        .unwrap();
        assert_eq!(
            serial.model.beta.len(),
            other.model.beta.len(),
            "exec={name}"
        );
        for (i, (a, b)) in serial
            .model
            .beta
            .iter()
            .zip(&other.model.beta)
            .enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "exec={name} beta[{i}]: {a} vs {b}");
        }
        assert_eq!(serial.fg_evals, other.fg_evals, "exec={name}");
        assert_eq!(serial.hd_evals, other.hd_evals, "exec={name}");
        assert_eq!(
            serial.stats.iterations, other.stats.iterations,
            "exec={name}"
        );
        assert_eq!(
            serial.stats.final_f.to_bits(),
            other.stats.final_f.to_bits(),
            "exec={name}"
        );
        // The communication ledger is executor-independent too.
        assert_eq!(
            serial.sim.comm_bytes(),
            other.sim.comm_bytes(),
            "exec={name}"
        );
    }
}

/// Multi-tile m (two basis column tiles) exercises the unfused
/// matvec/matvec_t partials; equivalence must hold there too.
#[test]
fn threaded_training_multi_tile_m_is_bit_identical() {
    let (tr, _) = data(1400, 200, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mut runs = Vec::new();
    for exec in [
        ExecutorChoice::Serial,
        ExecutorChoice::Threads { cap: 4 },
        ExecutorChoice::Pool { cap: 4 },
    ] {
        let mut s = settings(300, 5, exec);
        s.max_iters = 25;
        runs.push(train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap());
    }
    for other in &runs[1..] {
        for (a, b) in runs[0].model.beta.iter().zip(&other.model.beta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

/// K-means basis selection (explicit W shares, the distributed Lloyd loop)
/// also rides the executor; its output must be executor-independent.
#[test]
fn kmeans_basis_training_is_bit_identical_across_executors() {
    let (tr, _) = data(900, 150, 13);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mut runs = Vec::new();
    for exec in [
        ExecutorChoice::Serial,
        ExecutorChoice::Threads { cap: 3 },
        ExecutorChoice::Pool { cap: 3 },
    ] {
        let mut s = settings(24, 3, exec);
        s.basis = BasisSelection::KMeans;
        runs.push(train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap());
    }
    for other in &runs[1..] {
        for (a, b) in runs[0].model.beta.iter().zip(&other.model.beta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // The basis itself (K-means centers) must match exactly, too.
        assert_eq!(runs[0].model.basis, other.model.basis);
    }
}

/// The stage-wise path (basis growth, dirty-column recompute, warm-started
/// β) rides the executor too; its per-stage output must be bit-identical
/// between the serial loop and real worker threads.
#[test]
fn stagewise_training_is_bit_identical_across_executors() {
    let (tr, _) = data(1300, 150, 17);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let stages = [32usize, 96, 192];
    let mut s = settings(32, 4, ExecutorChoice::Serial);
    s.max_iters = 30;
    let serial = train_stagewise(&s, &tr, Arc::clone(&backend), CostModel::free(), &stages)
        .unwrap();
    for exec in [
        ExecutorChoice::Threads { cap: 4 },
        ExecutorChoice::Pool { cap: 4 },
    ] {
        let name = exec.name();
        let mut st = settings(32, 4, exec);
        st.max_iters = 30;
        let other = train_stagewise(&st, &tr, Arc::clone(&backend), CostModel::free(), &stages)
            .unwrap();
        assert_eq!(serial.len(), other.len());
        for (stage, (a, b)) in serial.iter().zip(&other).enumerate() {
            assert_eq!(a.m, b.m, "{name} stage {stage}");
            assert_eq!(a.stats.iterations, b.stats.iterations, "{name} stage {stage}");
            assert_eq!(
                a.stats.final_f.to_bits(),
                b.stats.final_f.to_bits(),
                "{name} stage {stage}"
            );
            assert_eq!(a.model.beta.len(), b.model.beta.len(), "{name} stage {stage}");
            for (i, (x, y)) in a.model.beta.iter().zip(&b.model.beta).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{name} stage {stage} beta[{i}]");
            }
        }
    }
}

/// AllReduce determinism under every executor, for vectors and scalars.
#[test]
fn allreduce_bit_identical_under_all_executors() {
    for p in [1usize, 3, 8, 20] {
        let mut rng = Rng::new(p as u64);
        let partials: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..33).map(|_| rng.normal_f32()).collect())
            .collect();
        let scalars: Vec<f32> = partials.iter().map(|v| v[7.min(v.len() - 1)]).collect();
        let mut serial = Cluster::new(vec![(); p], 2, CostModel::free());
        let a = serial.allreduce_sum(Step::Tron, partials.clone());
        let sa = serial.allreduce_scalar(Step::Tron, scalars.clone());
        for exec in [Executor::threaded(4), Executor::pooled(4)] {
            let name = exec.name();
            let mut other = Cluster::new(vec![(); p], 2, CostModel::free()).with_executor(exec);
            let b = other.allreduce_sum(Step::Tron, partials.clone());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "p={p} exec={name}");
            }
            let sb = other.allreduce_scalar(Step::Tron, scalars.clone());
            assert_eq!(sa.to_bits(), sb.to_bits(), "p={p} exec={name}");
        }
    }
}

/// The fused compute+reduce phase is bit-identical to compute-then-reduce
/// under every executor, for any node count (including p cut mid-chunk).
#[test]
fn fused_phase_bit_identical_across_executors() {
    for p in [1usize, 3, 8, 20] {
        let mut rng = Rng::new(40 + p as u64);
        let data: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..33).map(|_| rng.normal_f32()).collect())
            .collect();
        // Reference: the split path on the serial executor.
        let mut split = Cluster::new(data.clone(), 2, CostModel::free());
        let parts = split.par_compute(Step::Tron, |_, n: &mut Vec<f32>| n.clone());
        let want = split.allreduce_sum(Step::Tron, parts);
        for exec in [Executor::serial(), Executor::threaded(4), Executor::pooled(4)] {
            let name = exec.name();
            let mut fused =
                Cluster::new(data.clone(), 2, CostModel::free()).with_executor(exec);
            let got = fused.par_compute_reduce(Step::Tron, |_, n: &mut Vec<f32>| n.clone());
            assert_eq!(got.len(), want.len());
            for (x, y) in got.iter().zip(&want) {
                assert_eq!(x.to_bits(), y.to_bits(), "p={p} exec={name}");
            }
        }
    }
}

/// A worker PANICKING mid-fused-phase (after some partials are already
/// recorded) must propagate to the coordinator — and the pool must keep
/// serving later fused phases of the same cluster.
#[test]
fn fused_phase_worker_panic_propagates_and_pool_survives() {
    let mut cl =
        Cluster::new(vec![0u32; 6], 2, CostModel::free()).with_executor(Executor::pooled(3));
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cl.par_compute_reduce(Step::Tron, |j, _| {
            if j == 4 {
                panic!("worker died mid-fused-phase on node 4");
            }
            vec![j as f32; 8]
        });
    }));
    assert!(caught.is_err(), "mid-fused-phase panic must reach the caller");
    // Same cluster, same pool: the next fused phase completes and reduces.
    let out = cl.par_compute_reduce(Step::Tron, |j, n| {
        *n = j as u32 + 1;
        vec![1.0f32]
    });
    assert_eq!(out, vec![6.0]);
    assert_eq!(cl.node(5), &6);
}

/// Structured node failures inside a fused phase surface the same
/// node-ordered error as try_par_compute, on every executor.
#[test]
fn fused_phase_node_failure_is_reported_in_node_order() {
    for exec in [Executor::serial(), Executor::threaded(6), Executor::pooled(6)] {
        let name = exec.name();
        let mut cl = Cluster::new(vec![(); 6], 2, CostModel::free()).with_executor(exec);
        let err = cl
            .try_par_compute_reduce(Step::Tron, |j, _| {
                if j >= 3 {
                    anyhow::bail!("partial {j} corrupt")
                }
                Ok(vec![j as f32])
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 3"), "{name}: {msg}");
        assert!(msg.contains("partial 3 corrupt"), "{name}: {msg}");
    }
}

/// The simulated ledger stays max-over-nodes on the threaded executor:
/// a phase's simulated time is one slow node, not the sum of all nodes.
#[test]
fn threaded_metering_is_max_over_nodes() {
    let p = 4;
    let mut cl =
        Cluster::new(vec![(); p], 2, CostModel::free()).with_executor(Executor::threaded(p));
    cl.par_compute(Step::Kernel, |_, _| {
        std::thread::sleep(std::time::Duration::from_millis(20));
    });
    let secs = cl.clock.compute_secs(Step::Kernel);
    assert!(secs >= 0.018, "phase under-metered: {secs}");
    // Sum-over-nodes would be >= 80ms; max-over-nodes stays well below
    // (generous bound for scheduling noise on loaded CI hosts).
    assert!(secs < 0.060, "phase looks sum-metered: {secs}");
}

/// Node failures under the threaded executor surface the same structured
/// error, naming the first failing node in node order.
#[test]
fn threaded_node_failure_is_reported_in_node_order() {
    for exec in [Executor::threaded(6), Executor::pooled(6)] {
        let name = exec.name();
        let mut cl = Cluster::new(vec![(); 6], 2, CostModel::free()).with_executor(exec);
        let err = cl
            .try_par_compute(Step::Kernel, |j, _| {
                if j >= 3 {
                    anyhow::bail!("shard {j} corrupt")
                }
                Ok(j)
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 3"), "{name}: {msg}");
        assert!(msg.contains("shard 3 corrupt"), "{name}: {msg}");
    }
}

/// A PANICKING worker (not a structured error) must propagate the panic to
/// the dispatching thread — and the pool must survive it: its parked
/// workers keep serving later phases of the same cluster.
#[test]
fn pool_worker_panic_propagates_and_pool_stays_usable() {
    let mut cl =
        Cluster::new(vec![0u32; 6], 2, CostModel::free()).with_executor(Executor::pooled(3));
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cl.par_compute(Step::Kernel, |j, _| {
            if j == 4 {
                panic!("worker died on node 4");
            }
        });
    }));
    assert!(caught.is_err(), "worker panic must reach the caller");
    // Same cluster, same pool: the next phase runs to completion.
    let out = cl.par_compute(Step::Kernel, |j, n| {
        *n = j as u32 + 1;
        j
    });
    assert_eq!(out, vec![0, 1, 2, 3, 4, 5]);
    assert_eq!(cl.node(5), &6);
}

/// The streaming-dispatch shape: many small phases against one persistent
/// pool. Every phase must reuse the SAME parked workers (no per-phase
/// spawn) and keep results in node order.
#[test]
fn pool_reuse_across_many_small_phases() {
    use std::collections::HashSet;
    use std::sync::Mutex;
    let p = 8;
    let mut cl =
        Cluster::new(vec![0u64; p], 2, CostModel::free()).with_executor(Executor::pooled(4));
    let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
    for phase in 0..300u64 {
        let out = cl.par_compute(Step::Tron, |j, n| {
            *n += 1;
            ids.lock().unwrap().insert(std::thread::current().id());
            (phase, j)
        });
        assert_eq!(out, (0..p).map(|j| (phase, j)).collect::<Vec<_>>());
    }
    for j in 0..p {
        assert_eq!(cl.node(j), &300, "node {j} missed a phase");
    }
    let ids = ids.into_inner().unwrap();
    // 300 phases, but only the pool's fixed worker set ever ran them —
    // spawn-per-phase would have minted hundreds of distinct thread ids.
    assert!(ids.len() > 1, "expected real parallelism");
    assert!(ids.len() <= 4, "worker ids exceed pool size: {}", ids.len());
}
