//! Executor equivalence: the tentpole contract that training on the
//! threaded execution layer is BIT-IDENTICAL to the serial reference —
//! same β bits, same evaluation counts, same TRON trajectory — and that
//! every collective reduces in the same deterministic order under both.

use std::sync::Arc;

use dkm::cluster::{Cluster, CostModel, Executor};
use dkm::config::settings::{Backend, BasisSelection, CStorage, ExecutorChoice, Loss, Settings};
use dkm::coordinator::trainer::train_stagewise;
use dkm::coordinator::train;
use dkm::data::{synth, Dataset};
use dkm::metrics::Step;
use dkm::rng::Rng;
use dkm::runtime::make_backend;

fn settings(m: usize, nodes: usize, executor: ExecutorChoice) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: CStorage::Materialized,
        c_memory_budget: 256 << 20,
        max_iters: 60,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

/// The acceptance-criterion test: serial and threaded training on
/// covtype_like produce bit-identical β and identical fg/hd eval counts.
#[test]
fn threaded_training_is_bit_identical_to_serial() {
    let (tr, _) = data(1600, 200, 7);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let serial = train(
        &settings(96, 8, ExecutorChoice::Serial),
        &tr,
        Arc::clone(&backend),
        CostModel::hadoop_crude(),
    )
    .unwrap();
    for cap in [2usize, 8] {
        let threaded = train(
            &settings(96, 8, ExecutorChoice::Threads { cap }),
            &tr,
            Arc::clone(&backend),
            CostModel::hadoop_crude(),
        )
        .unwrap();
        assert_eq!(
            serial.model.beta.len(),
            threaded.model.beta.len(),
            "cap={cap}"
        );
        for (i, (a, b)) in serial
            .model
            .beta
            .iter()
            .zip(&threaded.model.beta)
            .enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "cap={cap} beta[{i}]: {a} vs {b}");
        }
        assert_eq!(serial.fg_evals, threaded.fg_evals, "cap={cap}");
        assert_eq!(serial.hd_evals, threaded.hd_evals, "cap={cap}");
        assert_eq!(
            serial.stats.iterations, threaded.stats.iterations,
            "cap={cap}"
        );
        assert_eq!(
            serial.stats.final_f.to_bits(),
            threaded.stats.final_f.to_bits(),
            "cap={cap}"
        );
    }
}

/// Multi-tile m (two basis column tiles) exercises the unfused
/// matvec/matvec_t partials; equivalence must hold there too.
#[test]
fn threaded_training_multi_tile_m_is_bit_identical() {
    let (tr, _) = data(1400, 200, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mut runs = Vec::new();
    for exec in [ExecutorChoice::Serial, ExecutorChoice::Threads { cap: 4 }] {
        let mut s = settings(300, 5, exec);
        s.max_iters = 25;
        runs.push(train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap());
    }
    for (a, b) in runs[0].model.beta.iter().zip(&runs[1].model.beta) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// K-means basis selection (explicit W shares, the distributed Lloyd loop)
/// also rides the executor; its output must be executor-independent.
#[test]
fn kmeans_basis_training_is_bit_identical_across_executors() {
    let (tr, _) = data(900, 150, 13);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mut runs = Vec::new();
    for exec in [ExecutorChoice::Serial, ExecutorChoice::Threads { cap: 3 }] {
        let mut s = settings(24, 3, exec);
        s.basis = BasisSelection::KMeans;
        runs.push(train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap());
    }
    for (a, b) in runs[0].model.beta.iter().zip(&runs[1].model.beta) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // The basis itself (K-means centers) must match exactly, too.
    assert_eq!(runs[0].model.basis, runs[1].model.basis);
}

/// The stage-wise path (basis growth, dirty-column recompute, warm-started
/// β) rides the executor too; its per-stage output must be bit-identical
/// between the serial loop and real worker threads.
#[test]
fn stagewise_training_is_bit_identical_across_executors() {
    let (tr, _) = data(1300, 150, 17);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let stages = [32usize, 96, 192];
    let mut s = settings(32, 4, ExecutorChoice::Serial);
    s.max_iters = 30;
    let serial = train_stagewise(&s, &tr, Arc::clone(&backend), CostModel::free(), &stages)
        .unwrap();
    let mut st = settings(32, 4, ExecutorChoice::Threads { cap: 4 });
    st.max_iters = 30;
    let threaded = train_stagewise(&st, &tr, Arc::clone(&backend), CostModel::free(), &stages)
        .unwrap();
    assert_eq!(serial.len(), threaded.len());
    for (stage, (a, b)) in serial.iter().zip(&threaded).enumerate() {
        assert_eq!(a.m, b.m, "stage {stage}");
        assert_eq!(a.stats.iterations, b.stats.iterations, "stage {stage}");
        assert_eq!(
            a.stats.final_f.to_bits(),
            b.stats.final_f.to_bits(),
            "stage {stage}"
        );
        assert_eq!(a.model.beta.len(), b.model.beta.len(), "stage {stage}");
        for (i, (x, y)) in a.model.beta.iter().zip(&b.model.beta).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "stage {stage} beta[{i}]");
        }
    }
}

/// AllReduce determinism under both executors, for vectors and scalars.
#[test]
fn allreduce_bit_identical_under_both_executors() {
    for p in [1usize, 3, 8, 20] {
        let mut rng = Rng::new(p as u64);
        let partials: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..33).map(|_| rng.normal_f32()).collect())
            .collect();
        let scalars: Vec<f32> = partials.iter().map(|v| v[7.min(v.len() - 1)]).collect();
        let mut serial = Cluster::new(vec![(); p], 2, CostModel::free());
        let mut threaded =
            Cluster::new(vec![(); p], 2, CostModel::free()).with_executor(Executor::threaded(4));
        let a = serial.allreduce_sum(Step::Tron, partials.clone());
        let b = threaded.allreduce_sum(Step::Tron, partials);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "p={p}");
        }
        let sa = serial.allreduce_scalar(Step::Tron, scalars.clone());
        let sb = threaded.allreduce_scalar(Step::Tron, scalars);
        assert_eq!(sa.to_bits(), sb.to_bits(), "p={p}");
    }
}

/// The simulated ledger stays max-over-nodes on the threaded executor:
/// a phase's simulated time is one slow node, not the sum of all nodes.
#[test]
fn threaded_metering_is_max_over_nodes() {
    let p = 4;
    let mut cl =
        Cluster::new(vec![(); p], 2, CostModel::free()).with_executor(Executor::threaded(p));
    cl.par_compute(Step::Kernel, |_, _| {
        std::thread::sleep(std::time::Duration::from_millis(20));
    });
    let secs = cl.clock.compute_secs(Step::Kernel);
    assert!(secs >= 0.018, "phase under-metered: {secs}");
    // Sum-over-nodes would be >= 80ms; max-over-nodes stays well below
    // (generous bound for scheduling noise on loaded CI hosts).
    assert!(secs < 0.060, "phase looks sum-metered: {secs}");
}

/// Node failures under the threaded executor surface the same structured
/// error, naming the first failing node in node order.
#[test]
fn threaded_node_failure_is_reported_in_node_order() {
    let mut cl =
        Cluster::new(vec![(); 6], 2, CostModel::free()).with_executor(Executor::threaded(6));
    let err = cl
        .try_par_compute(Step::Kernel, |j, _| {
            if j >= 3 {
                anyhow::bail!("shard {j} corrupt")
            }
            Ok(j)
        })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("node 3"), "{msg}");
    assert!(msg.contains("shard 3 corrupt"), "{msg}");
}
