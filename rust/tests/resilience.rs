//! Resilience subsystem acceptance: injected phase faults + bounded
//! retries, mid-training checkpoint/resume, and the phase trace
//! recorder/replayer — all bit-identical by construction.
//!
//! The contract under test, per `ROADMAP.md` item 5(b):
//!
//! * A training run that loses node tasks to injected faults and
//!   recovers them through retries produces the SAME β bits, the same
//!   TRON/BCD trajectory and the same communication ledger as a clean
//!   run — only the fault/retry counters and the simulated backoff
//!   seconds move. This must hold on every execution layer (tests are
//!   prefixed `serial_exec_` / `threads_exec_` / `pool_exec_` so CI can
//!   run each group in isolation).
//! * An interrupted run resumed from a `--checkpoint-every` snapshot
//!   finishes bitwise identical to the uninterrupted run — β, objective
//!   curve and ledger counters — even when the resumed process picks a
//!   different executor or scheduler.
//! * A recorded phase trace replays onto a fresh simulated ledger and
//!   lands exactly on the live clock's frozen snapshot.

use std::sync::Arc;

use dkm::cluster::{CostModel, FaultPlan, Sched, SimClock};
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings, SolverChoice,
};
use dkm::coordinator::{Session, Solve};
use dkm::data::{synth, Dataset};
use dkm::runtime::make_backend;
use dkm::trace::Record;

fn settings(solver: SolverChoice, exec: ExecutorChoice, c_storage: CStorage) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m: 48,
        nodes: 4,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor: exec,
        c_storage,
        eval_pipeline: EvalPipeline::Fused,
        max_iters: 15,
        tol: 1e-3,
        seed: 42,
        solver,
        ..Settings::default()
    }
}

fn data() -> Dataset {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = 800;
    spec.n_test = 10;
    synth::generate(&spec, 7).0
}

fn run(s: &Settings, tr: &Dataset) -> (Vec<f32>, Solve, SimClock) {
    let backend = make_backend(s.backend, &s.artifacts_dir).unwrap();
    let mut session = Session::build(s, tr, backend, CostModel::hadoop_crude()).unwrap();
    let solve = session.solve().unwrap();
    (session.beta().to_vec(), solve, session.sim())
}

/// Two fixed task deaths early on (phases 3 and 6 run during any build +
/// solve of this shape) plus a low-rate seeded random trigger sprayed
/// over the whole run; the default retry budget recovers everything.
fn plan() -> FaultPlan {
    FaultPlan::parse("node=1@phase=3,node=0@phase=6,rand:0.08:77").unwrap()
}

/// The fault-recovery matrix on one executor: {TRON, BCD} × {materialized,
/// streaming C}, faulty-vs-clean on the same executor.
fn fault_recovery_is_bit_identical(exec: ExecutorChoice) {
    let tr = data();
    for solver in [SolverChoice::Tron, SolverChoice::Bcd { block: 16 }] {
        for c_storage in [CStorage::Materialized, CStorage::Streaming] {
            let tag = format!("{exec:?}/{solver:?}/{c_storage:?}");
            let clean = settings(solver, exec, c_storage);
            let mut faulty = clean.clone();
            faulty.faults = plan();
            faulty.retries = 4;
            faulty.retry_backoff = 0.05;
            let (beta_c, solve_c, sim_c) = run(&clean, &tr);
            let (beta_f, solve_f, sim_f) = run(&faulty, &tr);
            assert_eq!(sim_c.faults(), 0, "{tag}: clean run must not fault");
            assert!(sim_f.faults() >= 2, "{tag}: the fixed triggers must fire");
            assert_eq!(
                sim_f.faults(),
                sim_f.retries(),
                "{tag}: every death recovered (no exhaustion)"
            );
            for (i, (a, b)) in beta_c.iter().zip(&beta_f).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: beta[{i}] {a} vs {b}");
            }
            assert_eq!(solve_c.stats.iterations, solve_f.stats.iterations, "{tag}");
            assert_eq!(
                solve_c.stats.final_f.to_bits(),
                solve_f.stats.final_f.to_bits(),
                "{tag}"
            );
            assert_eq!(solve_c.fg_evals, solve_f.fg_evals, "{tag}");
            assert_eq!(solve_c.hd_evals, solve_f.hd_evals, "{tag}");
            // Recovery is invisible to the communication story: the same
            // barriers, round-trips and bytes as the clean run.
            assert_eq!(sim_c.barriers(), sim_f.barriers(), "{tag}");
            assert_eq!(sim_c.comm_rounds(), sim_f.comm_rounds(), "{tag}");
            assert_eq!(sim_c.comm_bytes(), sim_f.comm_bytes(), "{tag}");
            // The re-launch backoff is the only compute-side signature.
            assert!(
                sim_f.total_secs() > sim_c.total_secs(),
                "{tag}: backoff seconds must land on the ledger"
            );
        }
    }
}

#[test]
fn serial_exec_fault_recovery_is_bit_identical() {
    fault_recovery_is_bit_identical(ExecutorChoice::Serial);
}

#[test]
fn threads_exec_fault_recovery_is_bit_identical() {
    fault_recovery_is_bit_identical(ExecutorChoice::Threads { cap: 4 });
}

#[test]
fn pool_exec_fault_recovery_is_bit_identical() {
    fault_recovery_is_bit_identical(ExecutorChoice::Pool { cap: 4 });
}

/// An exhausted retry budget aborts the run with the first lost node in
/// node order and the phase named in the error chain.
#[test]
fn serial_exec_exhausted_retries_abort_with_phase_context() {
    let tr = data();
    let mut s = settings(SolverChoice::Tron, ExecutorChoice::Serial, CStorage::Materialized);
    s.faults = FaultPlan::parse("rand:1:3").unwrap(); // every attempt dies
    s.retries = 1;
    s.retry_backoff = 0.0;
    let backend = make_backend(s.backend, &s.artifacts_dir).unwrap();
    let err = match Session::build(&s, &tr, backend, CostModel::free()) {
        Err(e) => e,
        Ok(mut session) => session.solve().expect_err("every task dies — the run must abort"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("retries exhausted"), "{msg}");
    assert!(msg.contains("node 0"), "first lost node in node order: {msg}");
}

/// Kill-and-resume on the threaded executor: a run checkpointed every
/// round, then resumed from the second checkpoint, lands bitwise on the
/// uninterrupted run — β, curve, eval counts and ledger counters.
#[test]
fn threads_exec_checkpoint_resume_is_bit_identical() {
    let tr = data();
    let exec = ExecutorChoice::Threads { cap: 4 };
    for solver in [SolverChoice::Tron, SolverChoice::Bcd { block: 16 }] {
        let tag = format!("{solver:?}");
        let full = settings(solver, exec, CStorage::Materialized);
        let (beta_full, solve_full, sim_full) = run(&full, &tr);

        let path = std::env::temp_dir().join(format!("dkm_resilience_{tag}.ckpt"));
        let mut first = full.clone();
        first.checkpoint_every = 1;
        first.checkpoint_path = path.to_str().unwrap().to_string();
        // A build-phase fault (phase 0 is always during build) exercises
        // recovery on BOTH sides of the kill without desynchronizing the
        // fault counters between the full and the resumed timelines.
        first.faults = FaultPlan::parse("node=2@phase=0").unwrap();
        let backend = make_backend(first.backend, &first.artifacts_dir).unwrap();
        let mut interrupted =
            Session::build(&first, &tr, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap();
        interrupted.solve().unwrap();
        assert!(path.exists(), "{tag}: no checkpoint was written");

        let mut full_faulty = full.clone();
        full_faulty.faults = first.faults.clone();
        let (beta_want, solve_want, sim_want) = run(&full_faulty, &tr);
        // The build-phase fault itself must not move β.
        for (a, b) in beta_full.iter().zip(&beta_want) {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}");
        }
        assert_eq!(solve_full.stats.iterations, solve_want.stats.iterations);
        assert_eq!(sim_full.comm_bytes(), sim_want.comm_bytes());

        let mut resumed = Session::resume_from(
            &first,
            &tr,
            Arc::clone(&backend),
            CostModel::hadoop_crude(),
            &path,
        )
        .unwrap();
        let solve_res = resumed.solve().unwrap();
        for (i, (a, b)) in beta_want.iter().zip(resumed.beta()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: beta[{i}]");
        }
        assert_eq!(solve_want.stats.iterations, solve_res.stats.iterations, "{tag}");
        assert_eq!(
            solve_want.stats.final_f.to_bits(),
            solve_res.stats.final_f.to_bits(),
            "{tag}"
        );
        let sim_res = resumed.sim();
        assert_eq!(sim_want.barriers(), sim_res.barriers(), "{tag}");
        assert_eq!(sim_want.comm_rounds(), sim_res.comm_rounds(), "{tag}");
        assert_eq!(sim_want.comm_bytes(), sim_res.comm_bytes(), "{tag}");
        assert_eq!(sim_want.dispatches(), sim_res.dispatches(), "{tag}");
        assert_eq!(sim_want.faults(), sim_res.faults(), "{tag}");
        assert_eq!(sim_want.retries(), sim_res.retries(), "{tag}");
        std::fs::remove_file(&path).ok();
    }
}

/// The checkpoint deliberately excludes `--exec` and `--sched` from its
/// config fingerprint: a resumed process may land on different hardware.
/// Resuming a serial/static run on the pooled executor with work-stealing
/// still reproduces the uninterrupted run bit-for-bit.
#[test]
fn pool_exec_resume_crosses_executor_and_sched() {
    let tr = data();
    let original = settings(SolverChoice::Tron, ExecutorChoice::Serial, CStorage::Materialized);
    let (beta_want, solve_want, _) = run(&original, &tr);

    let path = std::env::temp_dir().join("dkm_resilience_crossexec.ckpt");
    let mut first = original.clone();
    first.checkpoint_every = 1;
    first.checkpoint_path = path.to_str().unwrap().to_string();
    let backend = make_backend(first.backend, &first.artifacts_dir).unwrap();
    let mut interrupted =
        Session::build(&first, &tr, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap();
    interrupted.solve().unwrap();
    assert!(path.exists());

    let mut moved = first.clone();
    moved.executor = ExecutorChoice::Pool { cap: 4 };
    moved.sched = Sched::Steal { grain: 2 };
    let mut resumed = Session::resume_from(
        &moved,
        &tr,
        Arc::clone(&backend),
        CostModel::hadoop_crude(),
        &path,
    )
    .unwrap();
    let solve_res = resumed.solve().unwrap();
    for (i, (a, b)) in beta_want.iter().zip(resumed.beta()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{i}] after exec/sched move");
    }
    assert_eq!(solve_want.stats.iterations, solve_res.stats.iterations);
    assert_eq!(
        solve_want.stats.final_f.to_bits(),
        solve_res.stats.final_f.to_bits()
    );
    std::fs::remove_file(&path).ok();
}

/// Record a faulty training run end-to-end (the trace starts at cluster
/// birth, before the simulated data-ingest charge) and replay it onto a
/// fresh ledger: every counter and every f64 must land exactly, and the
/// manifest must round-trip through its wire format.
fn trace_replays_bitwise(exec: ExecutorChoice) {
    let tr = data();
    let mut s = settings(SolverChoice::Tron, exec, CStorage::Materialized);
    s.trace = true;
    s.faults = plan();
    s.retries = 4;
    let backend = make_backend(s.backend, &s.artifacts_dir).unwrap();
    let mut session = Session::build(&s, &tr, backend, CostModel::hadoop_crude()).unwrap();
    session.solve().unwrap();
    let sim = session.sim();
    let trace = session.take_trace().expect("tracing was on");
    assert!(!session.tracing(), "take_trace ends the recording");

    let replayed = trace.replay_verified().expect("replay must match the live ledger");
    assert_eq!(replayed.barriers(), sim.barriers());
    assert_eq!(replayed.faults(), sim.faults());
    assert!(replayed.faults() >= 2, "the recorded run really faulted");
    // The build-time ingest charge made it into the record stream — the
    // reason a whole-session trace can verify at all.
    assert!(
        trace.records.iter().any(|r| matches!(r, Record::Compute { .. })),
        "expected the build's compute charge in the trace"
    );
    // Wire round-trip preserves replayability.
    let back = dkm::trace::Trace::from_bytes(&trace.to_bytes()).unwrap();
    assert_eq!(back, trace);
    back.replay_verified().unwrap();
}

#[test]
fn serial_exec_trace_record_replays_bitwise() {
    trace_replays_bitwise(ExecutorChoice::Serial);
}

#[test]
fn threads_exec_trace_record_replays_bitwise() {
    trace_replays_bitwise(ExecutorChoice::Threads { cap: 4 });
}

#[test]
fn pool_exec_trace_record_replays_bitwise() {
    trace_replays_bitwise(ExecutorChoice::Pool { cap: 4 });
}
