//! Serving-pipeline contract: a prediction-only [`ServingSession`] and the
//! multi-slot concurrent dispatch must be BIT-IDENTICAL to the serial
//! scoring loop (`predict.rs::predict`) and to the training session's
//! one-phase-per-batch `Session::predict`, per batch, across storage ×
//! executor — concurrency may reorder work between batches but never the
//! accumulation inside one. Edge shapes (empty batch, single row, fewer
//! rows than nodes) go through every path; a β hot-swap tracks a
//! re-trained model bit-for-bit; the serving ledger pays ONE barrier per
//! dispatch (however many batches it carries) and never an AllReduce
//! round-trip; and the closed-loop `dkm serve` queue answers every
//! request with the serial score.
//!
//! Test names end in `serial_exec` / `threads_exec` / `pool_exec`; CI runs
//! each group explicitly next to the c_storage / fused_eval / session
//! matrices.

use std::sync::Arc;

use dkm::cluster::{CostModel, Executor};
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings,
};
use dkm::coordinator::{Session, ServingSession};
use dkm::data::{synth, Dataset};
use dkm::linalg::Mat;
use dkm::metrics::Step;
use dkm::runtime::make_backend;
use dkm::runtime::Compute;
use dkm::serve::{run as serve_run, ServeConfig};

fn settings(
    m: usize,
    nodes: usize,
    storage: CStorage,
    executor: ExecutorChoice,
) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: storage,
        eval_pipeline: EvalPipeline::Fused,
        c_memory_budget: 256 << 20,
        max_iters: 40,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

fn backend() -> Arc<dyn Compute> {
    make_backend(Backend::Native, "artifacts").unwrap()
}

/// Copy rows `[r0, r1)` of `x` into a standalone batch.
fn slice_rows(x: &Mat, r0: usize, r1: usize) -> Mat {
    Mat::from_vec(r1 - r0, x.cols(), x.row_panel(r0, r1).to_vec())
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), w.to_bits(), "{what}: score[{i}] {a} vs {w}");
    }
}

/// The core parity matrix: for each storage mode, train once, then score
/// batches of edge-case sizes (1 row, fewer rows than p, a mid-size
/// batch, the ragged rest) through FOUR paths — serial `predict.rs` loop,
/// `Session::predict` (one phase per batch), `ServingSession::
/// predict_batch` (one slot), and `ServingSession::predict_many` (every
/// batch one slot of a single concurrent dispatch) — and require the same
/// bits from all of them.
fn serving_bit_identical(executor: ExecutorChoice) {
    let (train_ds, test_ds) = data(1000, 257, 7);
    let be = backend();
    let p = 4usize;
    for storage in [CStorage::Materialized, CStorage::Streaming] {
        // m = 300 spans a TM tile boundary.
        let s = settings(300, p, storage, executor);
        let what = format!("{} exec={}", storage.name(), executor.name());
        let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
        sess.solve().unwrap();
        let model = sess.model();
        let serial = model.predict(be.as_ref(), &test_ds.x).unwrap();

        let serving = ServingSession::load(
            &model,
            Arc::clone(&be),
            p,
            executor.to_executor(),
            CostModel::free(),
        )
        .unwrap();
        assert_eq!(serving.p(), p);
        assert_eq!(serving.m(), 300);

        // 1 row | 3 rows (< p) | 64 | the ragged rest (189, not ÷ p).
        let mut batches = Vec::new();
        let mut at = 0usize;
        for sz in [1usize, 3, 64] {
            batches.push(slice_rows(&test_ds.x, at, at + sz));
            at += sz;
        }
        batches.push(slice_rows(&test_ds.x, at, test_ds.n()));
        let refs: Vec<&Mat> = batches.iter().collect();

        let grouped = serving.predict_many(&refs).unwrap();
        assert_eq!(grouped.len(), refs.len(), "{what}");
        let mut at = 0usize;
        for (b, x) in refs.iter().enumerate() {
            let want = &serial[at..at + x.rows()];
            at += x.rows();
            let via_session = sess.predict(x).unwrap();
            let via_slot = serving.predict_batch(x).unwrap();
            assert_bits(&via_session, want, &format!("{what} batch {b} Session::predict"));
            assert_bits(&via_slot, want, &format!("{what} batch {b} predict_batch"));
            assert_bits(&grouped[b], want, &format!("{what} batch {b} predict_many"));
        }
        assert_eq!(at, test_ds.n(), "{what}: batches cover the test set");
        assert_eq!(serving.rows_served() as usize, 2 * test_ds.n(), "{what}");
    }
}

#[test]
fn serving_bit_identical_serial_exec() {
    serving_bit_identical(ExecutorChoice::Serial);
}

#[test]
fn serving_bit_identical_threads_exec() {
    serving_bit_identical(ExecutorChoice::Threads { cap: 4 });
}

#[test]
fn serving_bit_identical_pool_exec() {
    serving_bit_identical(ExecutorChoice::Pool { cap: 4 });
}

/// Degenerate batch shapes through every entry point: an empty dispatch,
/// an empty batch (0 rows is a valid request), and single-row requests —
/// all on p = 4 so every shard is ragged or empty.
fn predict_edge_cases(executor: ExecutorChoice) {
    let (train_ds, test_ds) = data(900, 64, 5);
    let be = backend();
    let s = settings(96, 4, CStorage::Materialized, executor);
    let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
    sess.solve().unwrap();
    let model = sess.model();
    let serial = model.predict(be.as_ref(), &test_ds.x).unwrap();
    let serving = ServingSession::load(
        &model,
        Arc::clone(&be),
        4,
        executor.to_executor(),
        CostModel::free(),
    )
    .unwrap();
    let what = format!("exec={}", executor.name());

    // Empty dispatch and empty batch.
    assert!(serving.predict_many(&[]).unwrap().is_empty(), "{what}");
    let empty = Mat::from_vec(0, test_ds.x.cols(), Vec::new());
    assert!(sess.predict(&empty).unwrap().is_empty(), "{what}");
    assert!(serving.predict_batch(&empty).unwrap().is_empty(), "{what}");

    // Single-row requests, one per path, plus a 3-row batch (< p) mixed
    // into one concurrent dispatch with them.
    let one_a = slice_rows(&test_ds.x, 10, 11);
    let one_b = slice_rows(&test_ds.x, 63, 64);
    let under_p = slice_rows(&test_ds.x, 20, 23);
    assert_bits(&sess.predict(&one_a).unwrap(), &serial[10..11], &format!("{what} 1-row session"));
    assert_bits(&serving.predict_batch(&one_a).unwrap(), &serial[10..11], &format!("{what} 1-row slot"));
    assert_bits(&sess.predict(&under_p).unwrap(), &serial[20..23], &format!("{what} 3<p session"));
    let grouped = serving.predict_many(&[&one_a, &under_p, &one_b]).unwrap();
    assert_bits(&grouped[0], &serial[10..11], &format!("{what} mixed[0]"));
    assert_bits(&grouped[1], &serial[20..23], &format!("{what} mixed[1]"));
    assert_bits(&grouped[2], &serial[63..64], &format!("{what} mixed[2]"));
}

#[test]
fn predict_edge_cases_serial_exec() {
    predict_edge_cases(ExecutorChoice::Serial);
}

#[test]
fn predict_edge_cases_pool_exec() {
    predict_edge_cases(ExecutorChoice::Pool { cap: 4 });
}

/// β hot-swap: `set_beta` with a re-trained session's coefficients makes
/// the serving scores bit-identical to the NEW model's serial loop — the
/// basis stays resident, only β ships.
#[test]
fn set_beta_tracks_retrained_model_threads_exec() {
    let (train_ds, test_ds) = data(900, 100, 3);
    let be = backend();
    let s = settings(96, 3, CStorage::Materialized, ExecutorChoice::Threads { cap: 4 });
    let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
    sess.solve().unwrap();
    let serving = ServingSession::load(
        &sess.model(),
        Arc::clone(&be),
        3,
        Executor::threaded(4),
        CostModel::free(),
    )
    .unwrap();
    let before = sess.model().predict(be.as_ref(), &test_ds.x).unwrap();
    assert_bits(&serving.predict_batch(&test_ds.x).unwrap(), &before, "before swap");

    // Re-train at a different λ and ship only β.
    sess.set_lambda(0.002).unwrap();
    sess.reset_beta();
    sess.solve().unwrap();
    serving.set_beta(sess.beta()).unwrap();
    let after = sess.model().predict(be.as_ref(), &test_ds.x).unwrap();
    assert_bits(&serving.predict_batch(&test_ds.x).unwrap(), &after, "after swap");
    // The swap really changed something (different λ ⇒ different β).
    assert!(
        before.iter().zip(&after).any(|(a, b)| a.to_bits() != b.to_bits()),
        "re-solve at a different λ should move the scores"
    );
}

/// The serving ledger's shape: one barrier per DISPATCH (however many
/// batches it carries), scatter/compute/gather priced under
/// `Step::Predict`, the model broadcast under `Step::BasisBcast`, and —
/// unlike training — never an AllReduce round-trip. The wall-side barrier
/// counter mirrors the sim ledger.
#[test]
fn serving_meters_one_barrier_per_dispatch_pool_exec() {
    let (train_ds, test_ds) = data(900, 96, 9);
    let be = backend();
    let s = settings(96, 4, CStorage::Materialized, ExecutorChoice::Pool { cap: 4 });
    let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
    sess.solve().unwrap();
    let serving = ServingSession::load(
        &sess.model(),
        Arc::clone(&be),
        4,
        Executor::pooled(4),
        CostModel::hadoop_crude(),
    )
    .unwrap();
    // Model shipping was priced as a tree broadcast at load.
    assert!(serving.sim().comm_secs(Step::BasisBcast) > 0.0);
    assert_eq!(serving.sim().barriers(), 0);

    let batches: Vec<Mat> = (0..3).map(|b| slice_rows(&test_ds.x, b * 32, (b + 1) * 32)).collect();
    let refs: Vec<&Mat> = batches.iter().collect();
    serving.predict_many(&refs).unwrap();
    // ONE barrier for the 3-batch dispatch…
    assert_eq!(serving.sim().barriers(), 1);
    assert_eq!(serving.batches_served(), 3);
    for x in &refs {
        serving.predict_batch(x).unwrap();
    }
    // …and one each on the lockstep path.
    assert_eq!(serving.sim().barriers(), 4);
    assert_eq!(serving.wall().barriers(), serving.sim().barriers());
    // Per-batch comm (row scatter + score gather) was priced on p > 1…
    assert!(serving.sim().comm_secs(Step::Predict) > 0.0);
    // …but serving never pays an AllReduce round-trip — prediction is
    // scatter/gather only.
    assert_eq!(serving.sim().comm_rounds(), 0);
    // β swap is priced as a broadcast, not a barrier.
    let bcast = serving.sim().comm_secs(Step::BasisBcast);
    serving.set_beta(&vec![0.0; serving.m()]).unwrap();
    assert!(serving.sim().comm_secs(Step::BasisBcast) > bcast);
    assert_eq!(serving.sim().barriers(), 4);
}

/// The whole `dkm serve` loop, in-process: closed-loop clients through
/// the bounded micro-batching queue on the pool executor — every reply
/// bit-identical to the serial reference, never more than one barrier per
/// micro-batch.
#[test]
fn serve_closed_loop_bit_identical_pool_exec() {
    let (train_ds, test_ds) = data(900, 128, 11);
    let be = backend();
    let s = settings(96, 4, CStorage::Materialized, ExecutorChoice::Pool { cap: 4 });
    let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
    sess.solve().unwrap();
    let model = sess.model();
    let expected = model.predict(be.as_ref(), &test_ds.x).unwrap();
    let serving = ServingSession::load(
        &model,
        Arc::clone(&be),
        4,
        Executor::pooled(4),
        CostModel::free(),
    )
    .unwrap();
    let cfg = ServeConfig {
        clients: 4,
        requests_per_client: 8,
        mean_think_ms: 0.0,
        max_batch: 8,
        max_delay_ms: 0.5,
        slots: 3,
        queue_cap: 64,
        seed: 5,
    };
    let report = serve_run(&serving, &test_ds.x, Some(&expected), &cfg).unwrap();
    assert_eq!(report.requests, 32);
    assert_eq!(report.mismatches, 0, "served replies diverged from serial");
    assert!(report.batches >= 1);
    assert!(report.barriers <= report.batches);
    assert!(report.barriers_per_batch <= 1.0 + 1e-12);
    assert!(report.p99_ms >= report.p50_ms);
}
