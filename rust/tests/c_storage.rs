//! C-storage equivalence: the tentpole contract that training with
//! `--c-storage streaming` (no stored C; kernel tiles recomputed per
//! dispatch), `--c-storage streaming:rowbuf` (streaming with a
//! row-tile-scoped tile scratch that halves the recompute for m > TM) and
//! `--c-storage auto` (budgeted mix) is BIT-IDENTICAL to the materialized
//! reference — same β bits, same TRON trajectory, same evaluation counts —
//! across executors, basis modes, and the stage-wise path, while streaming
//! holds only O(1 tile) (rowbuf: O(col_tiles) tiles) of C per node.
//!
//! Test names end in `serial_exec` / `threads_exec` / `pool_exec`; CI runs
//! each group explicitly so storage×executor equivalence is enforced on
//! every push.

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings,
};
use dkm::coordinator::trainer::train_stagewise;
use dkm::coordinator::{train, CBlockStore, TrainOutput, WorkerNode};
use dkm::data::{synth, Dataset};
use dkm::runtime::tiles::{TB, TM};
use dkm::runtime::make_backend;

fn settings(
    m: usize,
    nodes: usize,
    storage: CStorage,
    executor: ExecutorChoice,
) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: storage,
        eval_pipeline: EvalPipeline::Fused,
        c_memory_budget: 256 << 20,
        max_iters: 40,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

fn assert_bit_identical(a: &TrainOutput, b: &TrainOutput, what: &str) {
    assert_eq!(a.model.beta.len(), b.model.beta.len(), "{what}");
    for (i, (x, y)) in a.model.beta.iter().zip(&b.model.beta).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: beta[{i}] {x} vs {y}");
    }
    assert_eq!(a.fg_evals, b.fg_evals, "{what}");
    assert_eq!(a.hd_evals, b.hd_evals, "{what}");
    assert_eq!(a.stats.iterations, b.stats.iterations, "{what}");
    assert_eq!(
        a.stats.final_f.to_bits(),
        b.stats.final_f.to_bits(),
        "{what}"
    );
}

/// The acceptance criterion: streaming, streaming:rowbuf and auto train
/// bit-identically to materialized, for single-tile AND multi-tile m, on
/// the serial executor — streaming's peak per-node C-block footprint is
/// exactly one tile (rowbuf: col_tiles tiles), and for m > TM the rowbuf
/// scratch performs about HALF the kernel-tile recomputes of plain
/// streaming.
#[test]
fn storage_modes_bit_identical_serial_exec() {
    let (tr, _) = data(1600, 200, 7);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    for m in [96usize, 300] {
        let ct = m.div_ceil(TM).max(1);
        let reference = train(
            &settings(m, 4, CStorage::Materialized, ExecutorChoice::Serial),
            &tr,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        assert_eq!(reference.recomputed_tiles, 0);
        assert_eq!(reference.sim.recompute_flops(), 0);
        // Native shares each host tile with its prepared copy: the
        // materialized peak is EXACTLY the tile grid, held once
        // (400 rows/node = 2 row tiles).
        assert_eq!(reference.peak_c_bytes, 2 * ct * TB * TM * 4, "m={m}");

        let streaming = train(
            &settings(m, 4, CStorage::Streaming, ExecutorChoice::Serial),
            &tr,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        assert_bit_identical(&reference, &streaming, &format!("streaming m={m}"));
        // O(1 tile) of C held per node, recompute charged to the ledger.
        assert_eq!(streaming.peak_c_bytes, TB * TM * 4, "m={m}");
        assert!(reference.peak_c_bytes > streaming.peak_c_bytes, "m={m}");
        // Random basis: streaming caches its W-share rows (reported apart
        // from the C block); materialized reads them from C directly.
        assert!(streaming.peak_w_cache_bytes > 0, "m={m}");
        assert_eq!(reference.peak_w_cache_bytes, 0, "m={m}");
        assert!(streaming.recomputed_tiles > 0, "m={m}");
        assert!(streaming.sim.recompute_flops() > 0, "m={m}");

        let rowbuf = train(
            &settings(m, 4, CStorage::StreamingRowbuf, ExecutorChoice::Serial),
            &tr,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        assert_bit_identical(&reference, &rowbuf, &format!("rowbuf m={m}"));
        // Bounded scratch: O(col_tiles) tiles per node, nothing more.
        assert_eq!(rowbuf.peak_c_bytes, ct * TB * TM * 4, "m={m}");
        assert!(rowbuf.recomputed_tiles > 0, "m={m}");
        if m > TM {
            // Multi-tile evaluations touch every tile twice (matvec +
            // matvec_t); the scratch serves the second touch, so rowbuf
            // performs about half the recomputes (the remainder over an
            // exact half is the shared one-time W-cache build).
            assert!(
                rowbuf.recomputed_tiles * 100 < streaming.recomputed_tiles * 55,
                "m={m}: rowbuf {} not ~half of streaming {}",
                rowbuf.recomputed_tiles,
                streaming.recomputed_tiles
            );
            assert!(
                rowbuf.recomputed_tiles * 2 >= streaming.recomputed_tiles / 2,
                "m={m}: rowbuf {} suspiciously low vs streaming {}",
                rowbuf.recomputed_tiles,
                streaming.recomputed_tiles
            );
        } else {
            // Single-tile m uses the fused dispatches: one tile compute
            // per dispatch either way for multi-row-tile shards (exactly
            // equal here — 400 rows/node = 2 row tiles); a single-row-tile
            // shard could only do BETTER (its scratch survives across
            // dispatches), hence <=.
            assert!(
                rowbuf.recomputed_tiles <= streaming.recomputed_tiles,
                "m={m}: rowbuf {} vs streaming {}",
                rowbuf.recomputed_tiles,
                streaming.recomputed_tiles
            );
        }

        // Auto with a budget for exactly one materialized row of tiles per
        // node: a genuine mix (400 rows/node = 2 row tiles). One row costs
        // ct tiles on native (host/prepared buffer shared).
        let mut s = settings(m, 4, CStorage::Auto, ExecutorChoice::Serial);
        s.c_memory_budget = ct * TB * TM * 4;
        let auto = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
        assert_bit_identical(&reference, &auto, &format!("auto m={m}"));
        // Exactly one materialized row of tiles plus the transient tile.
        assert_eq!(auto.peak_c_bytes, (ct + 1) * TB * TM * 4, "m={m}");
        assert!(auto.peak_c_bytes < reference.peak_c_bytes, "m={m}");
        assert!(auto.recomputed_tiles > 0, "m={m}");
        assert!(
            auto.recomputed_tiles < streaming.recomputed_tiles,
            "m={m}: auto {} vs streaming {}",
            auto.recomputed_tiles,
            streaming.recomputed_tiles
        );
    }
}

/// K-means basis (explicit W shares — no W-row cache involved) must also be
/// storage-independent.
#[test]
fn kmeans_basis_storage_modes_bit_identical_serial_exec() {
    let (tr, _) = data(900, 150, 13);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mut runs = Vec::new();
    for storage in [CStorage::Materialized, CStorage::Streaming] {
        let mut s = settings(24, 3, storage, ExecutorChoice::Serial);
        s.basis = BasisSelection::KMeans;
        runs.push(train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap());
    }
    assert_bit_identical(&runs[0], &runs[1], "kmeans streaming");
    assert_eq!(runs[0].model.basis, runs[1].model.basis);
    // Explicit W shares live outside the store: no W-row cache either way.
    assert_eq!(runs[1].peak_w_cache_bytes, 0);
}

/// Stage-wise growth (dirty-column recompute, W-row cache extension,
/// warm-started β, rowbuf scratch invalidation) is bit-identical between
/// materialized and both streaming variants. The schedule crosses the
/// TM=256 column-tile boundary twice so the partial-tile incremental
/// recompute/re-prepare path runs end-to-end.
#[test]
fn stagewise_storage_modes_bit_identical_serial_exec() {
    let (tr, _) = data(1300, 150, 19);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let stages = [200usize, 400, 600];
    let mut s = settings(32, 4, CStorage::Materialized, ExecutorChoice::Serial);
    s.max_iters = 30;
    let mat = train_stagewise(&s, &tr, Arc::clone(&backend), CostModel::free(), &stages)
        .unwrap();
    for storage in [CStorage::Streaming, CStorage::StreamingRowbuf] {
        let mut s = settings(32, 4, storage, ExecutorChoice::Serial);
        s.max_iters = 30;
        let st = train_stagewise(&s, &tr, Arc::clone(&backend), CostModel::free(), &stages)
            .unwrap();
        assert_eq!(mat.len(), st.len());
        let mut prev_recomputed = 0u64;
        for (stage, (a, b)) in mat.iter().zip(&st).enumerate() {
            let what = format!("{} stage {stage}", storage.name());
            assert_eq!(a.m, b.m, "{what}");
            assert_eq!(a.stats.iterations, b.stats.iterations, "{what}");
            for (i, (x, y)) in a.model.beta.iter().zip(&b.model.beta).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what} beta[{i}]");
            }
            assert_eq!(a.recomputed_tiles, 0, "materialized never recomputes");
            assert!(
                b.recomputed_tiles > prev_recomputed,
                "{what}: streaming recompute must grow"
            );
            prev_recomputed = b.recomputed_tiles;
        }
    }
}

/// Storage × executor: streaming (both variants) under real worker threads
/// is bit-identical to materialized under the serial loop — the full
/// cross-product contract.
#[test]
fn storage_modes_bit_identical_threads_exec() {
    let (tr, _) = data(1400, 150, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    for m in [96usize, 300] {
        let mut reference = None;
        for storage in [
            CStorage::Materialized,
            CStorage::Streaming,
            CStorage::StreamingRowbuf,
            CStorage::Auto,
        ] {
            for exec in [
                ExecutorChoice::Serial,
                ExecutorChoice::Threads { cap: 4 },
            ] {
                let mut s = settings(m, 5, storage, exec);
                s.max_iters = 25;
                if storage == CStorage::Auto {
                    let ct = m.div_ceil(TM).max(1);
                    s.c_memory_budget = ct * TB * TM * 4;
                }
                let out = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
                match &reference {
                    None => reference = Some(out),
                    Some(want) => assert_bit_identical(
                        want,
                        &out,
                        &format!("m={m} {}/{}", s.c_storage.name(), s.executor.name()),
                    ),
                }
            }
        }
    }
}

/// Storage × the persistent-pool executor: every storage mode under the
/// pool is bit-identical to materialized under the serial loop. Streaming
/// is the pool's motivating workload (many small dispatches per phase), so
/// this cell of the matrix is enforced explicitly in CI.
#[test]
fn storage_modes_bit_identical_pool_exec() {
    let (tr, _) = data(1400, 150, 11);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    for m in [96usize, 300] {
        let mut s = settings(m, 5, CStorage::Materialized, ExecutorChoice::Serial);
        s.max_iters = 25;
        let reference = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
        for storage in [
            CStorage::Materialized,
            CStorage::Streaming,
            CStorage::StreamingRowbuf,
            CStorage::Auto,
        ] {
            let mut s = settings(m, 5, storage, ExecutorChoice::Pool { cap: 4 });
            s.max_iters = 25;
            if storage == CStorage::Auto {
                let ct = m.div_ceil(TM).max(1);
                s.c_memory_budget = ct * TB * TM * 4;
            }
            let out = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
            assert_bit_identical(
                &reference,
                &out,
                &format!("m={m} {}/pool", s.c_storage.name()),
            );
        }
    }
}

/// Satellite regression: shrinking m used to re-zero C but recompute only
/// the caller's `dirty_cols`, leaving stale zero columns. The store must
/// force a full recompute on any shrink.
#[test]
fn shrink_path_forces_full_recompute_serial_exec() {
    let (tr, _) = data(400, 50, 23);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let dpad = backend.pad_d(tr.d()).unwrap();
    let basis_big = tr.x.gather_rows(&(0..300).collect::<Vec<_>>());
    let basis_small = tr.x.gather_rows(&(0..100).collect::<Vec<_>>());
    let zt_big = dkm::coordinator::basis::tiles_of(&basis_big, dpad);
    let zt_small = dkm::coordinator::basis::tiles_of(&basis_small, dpad);

    let mut node = WorkerNode::new(tr.x.clone(), tr.y.clone(), dpad);
    node.compute_c_block(backend.as_ref(), &zt_big, 300, 0.125, 0..2)
        .unwrap();
    assert_eq!(node.cstore.col_tiles(), 2);
    // Shrink with a deliberately stale (empty) dirty range.
    node.compute_c_block(backend.as_ref(), &zt_small, 100, 0.125, 1..1)
        .unwrap();
    assert_eq!(node.cstore.col_tiles(), 1);

    let mut fresh = WorkerNode::new(tr.x.clone(), tr.y.clone(), dpad);
    fresh
        .compute_c_block(backend.as_ref(), &zt_small, 100, 0.125, 0..1)
        .unwrap();

    let v: Vec<f32> = (0..TM).map(|i| (i as f32 * 0.01).sin()).collect();
    for i in 0..node.row_tiles() {
        let a = node
            .cstore
            .matvec_tile(backend.as_ref(), i, 0, &v)
            .unwrap();
        let b = fresh
            .cstore
            .matvec_tile(backend.as_ref(), i, 0, &v)
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "row tile {i}");
        }
    }
}
