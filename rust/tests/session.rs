//! Session API contract: the stateful `Session` handle is the ONE code
//! path behind `train()` / `train_stagewise()`, so driving it by hand must
//! be bit-identical to the wrappers across storage × executor; growing a
//! live session matches the stage-wise wrapper stage by stage; a re-solve
//! on a live session (λ or loss changed, β reset) is bit-identical to a
//! cold `train()` at those settings (the kernel state does not depend on
//! them); warm re-solves reach the same solution quality; distributed
//! `predict` is bit-identical to the serial coordinator loop and is
//! metered as its own `predict` step (one executor phase per batch); and a
//! saved/loaded model predicts bit-identically.
//!
//! Test names end in `serial_exec` / `threads_exec` / `pool_exec`; CI runs
//! each group explicitly next to the c_storage / fused_eval matrices.

use std::sync::Arc;

use dkm::cluster::CostModel;
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings,
};
use dkm::coordinator::{train, train_stagewise, Session, TrainOutput};
use dkm::data::{synth, Dataset};
use dkm::metrics::Step;
use dkm::runtime::make_backend;
use dkm::runtime::Compute;

fn settings(
    m: usize,
    nodes: usize,
    storage: CStorage,
    executor: ExecutorChoice,
) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor,
        c_storage: storage,
        eval_pipeline: EvalPipeline::Fused,
        c_memory_budget: 256 << 20,
        max_iters: 40,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

fn backend() -> Arc<dyn Compute> {
    make_backend(Backend::Native, "artifacts").unwrap()
}

fn assert_beta_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: beta length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: beta[{i}] {x} vs {y}");
    }
}

/// Manual build+solve on a Session vs the `train()` wrapper: same β bits,
/// same evaluation counts, same final objective — for single-tile and
/// multi-tile m, across storage modes, on the given executor.
fn session_matches_train(executor: ExecutorChoice) {
    let (train_ds, test_ds) = data(1200, 320, 3);
    let be = backend();
    for storage in [
        CStorage::Materialized,
        CStorage::Streaming,
        CStorage::StreamingRowbuf,
        CStorage::Auto,
    ] {
        for m in [96usize, 300] {
            let s = settings(m, 4, storage, executor);
            let what = format!("{} m={m} exec={}", storage.name(), executor.name());
            let wrapped: TrainOutput =
                train(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
            let mut sess =
                Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
            let solve = sess.solve().unwrap();
            assert_beta_bits(sess.beta(), &wrapped.model.beta, &what);
            assert_eq!(solve.fg_evals, wrapped.fg_evals, "{what}");
            assert_eq!(solve.hd_evals, wrapped.hd_evals, "{what}");
            assert_eq!(
                solve.stats.final_f.to_bits(),
                wrapped.stats.final_f.to_bits(),
                "{what}"
            );
            assert_eq!(solve.peak_c_bytes, wrapped.peak_c_bytes, "{what}");
            assert_eq!(solve.recomputed_tiles, wrapped.recomputed_tiles, "{what}");
            // The session's model snapshot ships the same predictions.
            let snap = sess.model();
            let a = snap.predict(be.as_ref(), &test_ds.x).unwrap();
            let b = wrapped.model.predict(be.as_ref(), &test_ds.x).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: prediction");
            }
        }
    }
}

#[test]
fn session_matches_train_serial_exec() {
    session_matches_train(ExecutorChoice::Serial);
}

#[test]
fn session_matches_train_threads_exec() {
    session_matches_train(ExecutorChoice::Threads { cap: 4 });
}

#[test]
fn session_matches_train_pool_exec() {
    session_matches_train(ExecutorChoice::Pool { cap: 4 });
}

/// Growing a live session stage by stage is bit-identical to the
/// `train_stagewise` wrapper (and crosses a TM tile boundary).
fn grow_matches_stagewise(executor: ExecutorChoice, storage: CStorage) {
    let (train_ds, _) = data(1100, 200, 9);
    let be = backend();
    let stages = [48usize, 160, 288];
    let s = settings(48, 3, storage, executor);
    let what = format!("{} exec={}", storage.name(), executor.name());
    let wrapped = train_stagewise(
        &s,
        &train_ds,
        Arc::clone(&be),
        CostModel::free(),
        &stages,
    )
    .unwrap();
    let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
    for (i, &m) in stages.iter().enumerate() {
        if i > 0 {
            sess.grow_basis(m).unwrap();
        }
        let solve = sess.solve().unwrap();
        assert_eq!(sess.m(), m, "{what}");
        assert_beta_bits(sess.beta(), &wrapped[i].model.beta, &format!("{what} stage {m}"));
        assert_eq!(
            solve.stats.final_f.to_bits(),
            wrapped[i].stats.final_f.to_bits(),
            "{what} stage {m}"
        );
    }
}

#[test]
fn grow_basis_matches_stagewise_serial_exec() {
    grow_matches_stagewise(ExecutorChoice::Serial, CStorage::Materialized);
}

#[test]
fn grow_basis_matches_stagewise_streaming_pool_exec() {
    grow_matches_stagewise(ExecutorChoice::Pool { cap: 4 }, CStorage::StreamingRowbuf);
}

/// λ / loss re-solves on a live session: with β reset, the re-solve is
/// BIT-IDENTICAL to a cold `train()` at those settings (basis selection
/// and C do not depend on λ or the loss); without the reset, the warm
/// re-solve reaches the same solution quality.
#[test]
fn lambda_and_loss_resolve_match_cold_train_serial_exec() {
    let (train_ds, test_ds) = data(1200, 320, 3);
    let be = backend();
    // Let TRON run to convergence: the warm-vs-cold quality comparison
    // below is only meaningful when neither path hits the iteration cap.
    let s = Settings {
        max_iters: 120,
        ..settings(96, 4, CStorage::Materialized, ExecutorChoice::Serial)
    };
    let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
    sess.solve().unwrap();

    // Cold λ re-solve == cold train at λ2.
    let lambda2 = 0.002f32;
    sess.set_lambda(lambda2).unwrap();
    sess.reset_beta();
    let re = sess.solve().unwrap();
    let cold = train(
        &Settings {
            lambda: lambda2,
            ..s.clone()
        },
        &train_ds,
        Arc::clone(&be),
        CostModel::free(),
    )
    .unwrap();
    assert_beta_bits(sess.beta(), &cold.model.beta, "cold λ re-solve");
    assert_eq!(re.fg_evals, cold.fg_evals);
    assert_eq!(re.stats.final_f.to_bits(), cold.stats.final_f.to_bits());

    // Warm λ re-solve (no reset) reaches the same quality.
    sess.set_lambda(s.lambda).unwrap();
    sess.reset_beta();
    sess.solve().unwrap(); // back at λ1's solution
    sess.set_lambda(lambda2).unwrap();
    let warm = sess.solve().unwrap();
    let rel = (warm.stats.final_f - cold.stats.final_f).abs() / cold.stats.final_f.abs();
    assert!(
        rel < 1e-2,
        "warm {} vs cold {} (rel {rel})",
        warm.stats.final_f,
        cold.stats.final_f
    );
    let acc_warm = sess.accuracy(&test_ds).unwrap();
    let acc_cold = cold.model.accuracy(be.as_ref(), &test_ds).unwrap();
    assert!(
        (acc_warm - acc_cold).abs() < 0.03,
        "warm {acc_warm} vs cold {acc_cold}"
    );

    // Cold loss re-solve == cold train at that loss.
    sess.set_loss(Loss::Squared);
    sess.reset_beta();
    sess.set_lambda(s.lambda).unwrap();
    sess.solve().unwrap();
    let cold_sq = train(
        &Settings {
            loss: Loss::Squared,
            ..s.clone()
        },
        &train_ds,
        Arc::clone(&be),
        CostModel::free(),
    )
    .unwrap();
    assert_beta_bits(sess.beta(), &cold_sq.model.beta, "cold loss re-solve");
}

/// Distributed predict over the live cluster is bit-identical to the
/// serial coordinator loop, for any p (including p > 1 with a ragged last
/// shard and more nodes than score tiles), and is metered as ONE executor
/// phase per batch under `Step::Predict` on both ledgers.
fn predict_bit_identical(executor: ExecutorChoice) {
    let (train_ds, test_ds) = data(1000, 333, 7);
    let be = backend();
    for p in [1usize, 3, 8] {
        let s = settings(300, p, CStorage::Materialized, executor);
        let what = format!("p={p} exec={}", executor.name());
        // A priced cost model so the predict comm metering is observable.
        let mut sess =
            Session::build(&s, &train_ds, Arc::clone(&be), CostModel::hadoop_crude()).unwrap();
        sess.solve().unwrap();
        let serial = sess.model().predict(be.as_ref(), &test_ds.x).unwrap();

        let barriers0 = sess.sim().barriers();
        let rounds0 = sess.sim().comm_rounds();
        let distributed = sess.predict(&test_ds.x).unwrap();
        assert_eq!(distributed.len(), serial.len(), "{what}");
        for (i, (a, b)) in distributed.iter().zip(&serial).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{what}: score[{i}] {a} vs {b}");
        }
        // One metered executor phase (barrier) per batch; the gather is
        // one-way so no AllReduce round-trip is added.
        assert_eq!(sess.sim().barriers(), barriers0 + 1, "{what}");
        assert_eq!(sess.sim().comm_rounds(), rounds0, "{what}");
        assert!(sess.wall().wall_secs(Step::Predict) > 0.0, "{what}");
        assert!(sess.sim().step_secs(Step::Predict) > 0.0, "{what}");
        // Sim comm was metered too (β broadcast + score gather) on p > 1.
        if p > 1 {
            assert!(sess.sim().comm_secs(Step::Predict) > 0.0, "{what}");
        }
        // Each batch is its own phase.
        sess.predict(&test_ds.x).unwrap();
        assert_eq!(sess.sim().barriers(), barriers0 + 2, "{what}");
    }
}

#[test]
fn predict_bit_identical_serial_exec() {
    predict_bit_identical(ExecutorChoice::Serial);
}

#[test]
fn predict_bit_identical_threads_exec() {
    predict_bit_identical(ExecutorChoice::Threads { cap: 4 });
}

#[test]
fn predict_bit_identical_pool_exec() {
    predict_bit_identical(ExecutorChoice::Pool { cap: 4 });
}

/// Save → load → predict is bit-identical to the live session's model, so
/// a session's snapshot can be shipped to a serving process.
#[test]
fn saved_model_round_trips_and_predicts_bit_identically_serial_exec() {
    let (train_ds, test_ds) = data(900, 250, 5);
    let be = backend();
    let s = settings(96, 3, CStorage::Materialized, ExecutorChoice::Serial);
    let mut sess = Session::build(&s, &train_ds, Arc::clone(&be), CostModel::free()).unwrap();
    sess.solve().unwrap();
    let live = sess.predict(&test_ds.x).unwrap();

    let dir = std::env::temp_dir().join("dkm_session_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.dkm");
    sess.model().save(&path).unwrap();
    let shipped = dkm::coordinator::TrainedModel::load(&path).unwrap();
    let served = shipped.predict(be.as_ref(), &test_ds.x).unwrap();
    assert_eq!(served.len(), live.len());
    for (a, b) in served.iter().zip(&live) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_file(path).ok();
}
