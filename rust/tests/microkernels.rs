//! Property tests for the register-blocked SIMD microkernels: every
//! blocked kernel must be BITWISE equal to its unblocked per-element
//! reference built from `linalg::mat::dot`/`axpy` (the accumulation-order
//! contract of `linalg::simd`), across edge feature widths (not multiples
//! of the lane width), zero-padded tails, and exact-zero inputs.
//!
//! These tests run unchanged under `--features scalar-fallback`: both
//! builds must match the same scalar reference bitwise, which proves the
//! vectorized and fallback builds bit-identical to each other.

use dkm::linalg::mat::{axpy, dot};
use dkm::rng::Rng;
use dkm::runtime::native;
use dkm::runtime::tiles::{TB, TM};

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// Feature widths that exercise every tail path of the lane-blocked dot:
/// below one lane, exactly one lane, between one and two lanes, exactly
/// the unrolled width, and past it with a scalar tail.
const EDGE_D: [usize; 6] = [5, 8, 13, 16, 20, 37];

#[test]
fn kernel_block_matches_per_element_dot_reference_bitwise() {
    let mut rng = Rng::new(101);
    for d in EDGE_D {
        let x = rand_vec(&mut rng, TB * d);
        let z = rand_vec(&mut rng, TM * d);
        let gamma = 0.37f32;
        let got = native::kernel_block(&x, &z, d, gamma);
        for i in (0..TB).step_by(41) {
            let xi = &x[i * d..(i + 1) * d];
            let xsq = dot(xi, xi);
            for k in (0..TM).step_by(23) {
                let zk = &z[k * d..(k + 1) * d];
                let d2 = (xsq + dot(zk, zk) - 2.0 * dot(xi, zk)).max(0.0);
                let want = (-gamma * d2).exp();
                assert_eq!(
                    got[i * TM + k].to_bits(),
                    want.to_bits(),
                    "d={d} i={i} k={k}"
                );
            }
        }
    }
}

#[test]
fn dist2_block_is_kernel_block_exponent_bitwise() {
    let mut rng = Rng::new(103);
    for d in [13usize, 32] {
        let x = rand_vec(&mut rng, TB * d);
        let z = rand_vec(&mut rng, TM * d);
        let gamma = 0.5f32;
        let d2 = native::dist2_block(&x, &z, d);
        let k = native::kernel_block(&x, &z, d, gamma);
        for (i, (kv, dv)) in k.iter().zip(&d2).enumerate() {
            assert_eq!(
                kv.to_bits(),
                (-gamma * dv).exp().to_bits(),
                "d={d} flat={i}"
            );
        }
    }
}

#[test]
fn matvec_matches_row_dot_bitwise() {
    let mut rng = Rng::new(107);
    let c = rand_vec(&mut rng, TB * TM);
    let v = rand_vec(&mut rng, TM);
    let got = native::matvec(&c, &v);
    for i in 0..TB {
        let want = dot(&c[i * TM..(i + 1) * TM], &v);
        assert_eq!(got[i].to_bits(), want.to_bits(), "row {i}");
    }
}

#[test]
fn matvec_t_matches_guarded_axpy_reference_bitwise() {
    let mut rng = Rng::new(109);
    let c = rand_vec(&mut rng, TB * TM);
    // Residual with exact zeros AND a negative zero: the sparsity guard
    // must skip both (−0.0 == 0.0), exactly like the reference.
    let mut r = rand_vec(&mut rng, TB);
    for i in (0..TB).step_by(3) {
        r[i] = 0.0;
    }
    r[7] = -0.0;
    let got = native::matvec_t(&c, &r);
    let mut want = vec![0.0f32; TM];
    for i in 0..TB {
        if r[i] != 0.0 {
            axpy(r[i], &c[i * TM..(i + 1) * TM], &mut want);
        }
    }
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "col {j}");
    }
}

/// Zero-padded row tail (a node's last row tile): residual entries past
/// the live rows are exact zeros, so the blocked matvec_t must produce
/// bitwise the same output as accumulating the live rows alone.
#[test]
fn matvec_t_zero_padded_row_tail_matches_live_prefix_bitwise() {
    let mut rng = Rng::new(113);
    let live = 100usize;
    let c = rand_vec(&mut rng, TB * TM);
    let mut r = vec![0.0f32; TB];
    for ri in r.iter_mut().take(live) {
        *ri = rng.normal_f32();
    }
    let got = native::matvec_t(&c, &r);
    let mut want = vec![0.0f32; TM];
    for i in 0..live {
        if r[i] != 0.0 {
            axpy(r[i], &c[i * TM..(i + 1) * TM], &mut want);
        }
    }
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "col {j}");
    }
}

/// Zero-padded basis tail (m < TM): v entries past the live columns are
/// exact zeros, so zeroing the corresponding C columns must not change a
/// single bit of the matvec (0·c and 0·0 are both exactly +0.0 for finite
/// c, accumulated in identical chunk positions).
#[test]
fn matvec_zero_padded_v_tail_ignores_dead_columns_bitwise() {
    let mut rng = Rng::new(127);
    let live = 200usize;
    let c = rand_vec(&mut rng, TB * TM);
    let mut v = vec![0.0f32; TM];
    for vi in v.iter_mut().take(live) {
        *vi = rng.normal_f32();
    }
    let mut c_dead = c.clone();
    for i in 0..TB {
        for k in live..TM {
            c_dead[i * TM + k] = 0.0;
        }
    }
    let a = native::matvec(&c, &v);
    let b = native::matvec(&c_dead, &v);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "row {i}");
    }
}

/// The streaming fused ops must stay bitwise equal to "kernel tile, then
/// the plain op" — the property the C-storage bit-identity contract
/// rests on — including at edge feature widths.
#[test]
fn from_x_ops_match_kernel_then_op_bitwise() {
    let mut rng = Rng::new(131);
    for d in [13usize, 32] {
        let x = rand_vec(&mut rng, TB * d);
        let z = rand_vec(&mut rng, TM * d);
        let v = rand_vec(&mut rng, TM);
        let r = rand_vec(&mut rng, TB);
        let gamma = 0.25f32;
        let c = native::kernel_block(&x, &z, d, gamma);
        let mv = native::matvec_from_x(&x, &z, d, gamma, &v);
        let mvt = native::matvec_t_from_x(&x, &z, d, gamma, &r);
        let want_mv = native::matvec(&c, &v);
        let want_mvt = native::matvec_t(&c, &r);
        for (i, (a, b)) in mv.iter().zip(&want_mv).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "matvec_from_x d={d} row {i}");
        }
        for (j, (a, b)) in mvt.iter().zip(&want_mvt).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "matvec_t_from_x d={d} col {j}");
        }
    }
}
