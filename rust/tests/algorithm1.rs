//! Cross-module integration + property tests for Algorithm 1 (native
//! backend — fast; the PJRT differential suite lives in runtime_pjrt.rs).

use std::sync::Arc;

use dkm::baselines::{train_linearized, train_ppacksvm, PPackOptions};
use dkm::cluster::CostModel;
use dkm::config::settings::{
    Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice, Loss, Settings,
};
use dkm::coordinator::dist::DistProblem;
use dkm::coordinator::trainer::{build_cluster, train_stagewise};
use dkm::coordinator::solver::Objective;
use dkm::coordinator::{basis, train};
use dkm::data::{synth, Dataset};
use dkm::metrics::Step;
use dkm::rng::Rng;
use dkm::runtime::make_backend;

fn settings(m: usize, nodes: usize) -> Settings {
    Settings {
        dataset: "covtype_like".into(),
        m,
        nodes,
        lambda: 0.01,
        sigma: 2.0,
        loss: Loss::SqHinge,
        basis: BasisSelection::Random,
        backend: Backend::Native,
        executor: ExecutorChoice::Serial,
        c_storage: CStorage::Materialized,
        eval_pipeline: EvalPipeline::Fused,
        c_memory_budget: 256 << 20,
        max_iters: 60,
        tol: 1e-3,
        seed: 42,
        kmeans_iters: 2,
        kmeans_max_m: 512,
        artifacts_dir: "artifacts".into(),
        solver: dkm::config::settings::SolverChoice::Tron,
        ..Settings::default()
    }
}

fn data(n: usize, ntest: usize, seed: u64) -> (Dataset, Dataset) {
    let mut spec = synth::spec("covtype_like");
    spec.n_train = n;
    spec.n_test = ntest;
    synth::generate(&spec, seed)
}

/// Property: the distributed gradient matches central finite differences
/// for every loss, across random seeds and node counts.
#[test]
fn property_distributed_gradient_matches_fd() {
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    for (seed, p, loss) in [
        (1u64, 1usize, Loss::SqHinge),
        (2, 3, Loss::Logistic),
        (3, 4, Loss::Squared),
        (4, 2, Loss::SqHinge),
    ] {
        let (tr, _) = data(400, 100, seed);
        let dpad = backend.pad_d(tr.d()).unwrap();
        let mut cluster = build_cluster(&tr, p, dpad, CostModel::free());
        let b = basis::select_random(&mut cluster, 24, tr.d(), dpad, seed).unwrap();
        basis::install_w_shares(&mut cluster, &backend, &b, 0.125, dpad).unwrap();
        let zt = b.z_tiles.clone();
        let be = Arc::clone(&backend);
        cluster
            .try_par_compute(Step::Kernel, |_, n| {
                n.compute_c_block(be.as_ref(), &zt, 24, 0.125, 0..1)?;
                n.prepare_hot(be.as_ref())
            })
            .unwrap();
        let mut prob = DistProblem::new(&mut cluster, Arc::clone(&backend), 24, 0.05, loss);
        let mut rng = Rng::new(seed);
        let beta: Vec<f32> = (0..24).map(|_| 0.2 * rng.normal_f32()).collect();
        let (_, g) = prob.eval_fg(&beta).unwrap();
        let eps = 1e-2f32;
        for i in [0usize, 11, 23] {
            let mut bp = beta.clone();
            bp[i] += eps;
            let (fp, _) = prob.eval_fg(&bp).unwrap();
            let mut bm = beta.clone();
            bm[i] -= eps;
            let (fm, _) = prob.eval_fg(&bm).unwrap();
            let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[i]).abs() < 3e-2 * g[i].abs().max(1.0),
                "seed={seed} p={p} {}: i={i} fd {fd} vs g {}",
                loss.name(),
                g[i]
            );
        }
    }
}

/// Property: Hd matches the Gauss-Newton quadratic form and is PSD.
#[test]
fn property_hd_is_psd_quadratic() {
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    for seed in [5u64, 6, 7] {
        let (tr, _) = data(300, 80, seed);
        let dpad = backend.pad_d(tr.d()).unwrap();
        let mut cluster = build_cluster(&tr, 2, dpad, CostModel::free());
        let b = basis::select_random(&mut cluster, 16, tr.d(), dpad, seed).unwrap();
        basis::install_w_shares(&mut cluster, &backend, &b, 0.125, dpad).unwrap();
        let zt = b.z_tiles.clone();
        let be = Arc::clone(&backend);
        cluster
            .try_par_compute(Step::Kernel, |_, n| {
                n.compute_c_block(be.as_ref(), &zt, 16, 0.125, 0..1)?;
                n.prepare_hot(be.as_ref())
            })
            .unwrap();
        let mut prob =
            DistProblem::new(&mut cluster, Arc::clone(&backend), 16, 0.05, Loss::SqHinge);
        let mut rng = Rng::new(seed ^ 99);
        let beta: Vec<f32> = (0..16).map(|_| 0.2 * rng.normal_f32()).collect();
        prob.eval_fg(&beta).unwrap(); // refresh dcoef cache
        for _ in 0..5 {
            let d: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
            let hd = prob.eval_hd(&d).unwrap();
            let quad: f64 = d.iter().zip(&hd).map(|(a, b)| (*a * *b) as f64).sum();
            assert!(quad > -1e-4, "seed {seed}: d'Hd = {quad}");
        }
    }
}

/// Formulations (3) and (4) are the same model: with the same basis-size
/// they must reach comparable accuracy.
#[test]
fn formulations_3_and_4_agree() {
    let (tr, te) = data(900, 300, 11);
    let s = settings(96, 1);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let f4 = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let f3 = train_linearized(&s, &tr).unwrap();
    let a4 = f4.model.accuracy(backend.as_ref(), &te).unwrap();
    let a3 = f3.accuracy(&te);
    assert!((a3 - a4).abs() < 0.05, "(3): {a3} (4): {a4}");
}

/// Stage-wise warm starting: each later stage starts from a better
/// objective than a cold start at the same m would.
#[test]
fn stagewise_warm_start_reduces_initial_objective() {
    let (tr, _) = data(800, 200, 13);
    let s = settings(0, 3);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let stages =
        train_stagewise(&s, &tr, Arc::clone(&backend), CostModel::free(), &[32, 128]).unwrap();
    // Cold start at m=128 begins at f(0) = L(0, y) = n/2 for sqhinge.
    let cold_f0 = tr.n() as f64 / 2.0;
    let warm_f0 = stages[1].stats.curve[0].f;
    assert!(
        warm_f0 < cold_f0 * 0.95,
        "warm f0 {warm_f0} vs cold {cold_f0}"
    );
}

/// Failure injection: a node erroring mid-kernel-computation surfaces as a
/// structured coordinator error naming the node.
#[test]
fn node_failure_is_reported() {
    let (tr, _) = data(300, 80, 17);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let dpad = backend.pad_d(tr.d()).unwrap();
    let mut cluster = build_cluster(&tr, 4, dpad, CostModel::free());
    let err = cluster
        .try_par_compute(Step::Kernel, |j, _| {
            if j == 3 {
                anyhow::bail!("simulated node crash")
            }
            Ok(())
        })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("node 3") && msg.contains("simulated node crash"), "{msg}");
}

/// The m > n guard fires before any compute happens.
#[test]
fn basis_larger_than_data_is_rejected() {
    let (tr, _) = data(100, 30, 19);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let s = settings(500, 2);
    let err = match train(&s, &tr, backend, CostModel::free()) {
        Ok(_) => panic!("expected m > n to be rejected"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
}

/// LibSVM round-trip: a model trained from a LibSVM file of synthetic data
/// matches training on the in-memory dataset.
#[test]
fn libsvm_ingestion_trains_identically() {
    let (tr, te) = data(400, 100, 23);
    let dir = std::env::temp_dir().join("dkm_it_libsvm");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("train.libsvm");
    dkm::data::libsvm::write_file(&tr, &path).unwrap();
    let tr2 = dkm::data::libsvm::read_file(&path, tr.d()).unwrap();
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let s = settings(48, 2);
    let out1 = train(&s, &tr, Arc::clone(&backend), CostModel::free()).unwrap();
    let out2 = train(&s, &tr2, Arc::clone(&backend), CostModel::free()).unwrap();
    let a1 = out1.model.accuracy(backend.as_ref(), &te).unwrap();
    let a2 = out2.model.accuracy(backend.as_ref(), &te).unwrap();
    // Text serialization rounds floats; accuracies must be very close.
    assert!((a1 - a2).abs() < 0.02, "{a1} vs {a2}");
    std::fs::remove_file(path).ok();
}

/// P-packSVM on the same substrate: sane accuracy and O(n/r) rounds.
#[test]
fn ppacksvm_trains_on_substrate() {
    let mut spec = synth::spec("mnist8m_like");
    spec.n_train = 600;
    spec.n_test = 150;
    let (tr, te) = synth::generate(&spec, 29);
    let opts = PPackOptions {
        pack: 60,
        epochs: 1,
        lambda: 1e-4,
        seed: 5,
        nodes: 4,
    };
    let gamma = 1.0 / (2.0 * 18.0f32 * 18.0);
    let out = train_ppacksvm(&tr, gamma, &opts, CostModel::hadoop_crude()).unwrap();
    assert_eq!(out.rounds, 10);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let acc = out.model.accuracy(backend.as_ref(), &te).unwrap();
    assert!(acc > 0.75, "accuracy {acc}");
    // Every pack costs at least one latency on the crude-Hadoop ledger.
    assert!(out.sim.comm_secs(Step::Tron) >= 10.0 * 0.03);
}

/// Simulated speed-up sanity: more nodes → less simulated kernel compute
/// time; TRON comm time does NOT shrink (the Fig-2 mechanism).
#[test]
fn sim_ledger_reproduces_fig2_mechanism() {
    let (tr, _) = data(2000, 200, 31);
    let backend = make_backend(Backend::Native, "artifacts").unwrap();
    let mut kernel_secs = Vec::new();
    let mut tron_comm = Vec::new();
    for p in [2usize, 8] {
        let s = settings(128, p);
        let out = train(&s, &tr, Arc::clone(&backend), CostModel::hadoop_crude()).unwrap();
        kernel_secs.push(out.sim.compute_secs(Step::Kernel));
        tron_comm.push(out.sim.comm_secs(Step::Tron));
    }
    assert!(
        kernel_secs[1] < kernel_secs[0] * 0.55,
        "kernel compute did not scale: {kernel_secs:?}"
    );
    // Comm accumulates per-instance latency; with more nodes the tree is
    // deeper, so it must not decrease.
    assert!(
        tron_comm[1] >= tron_comm[0] * 0.9,
        "tron comm unexpectedly shrank: {tron_comm:?}"
    );
}
