//! Distributed K-means (Lloyd iterations over the cluster substrate) —
//! the basis-selection substrate of paper §3.2: "Cluster centers obtained
//! via K-means clustering form good basis functions when Gaussian kernel is
//! used. ... We use a (distributed) K-means algorithm when m is not too
//! large."
//!
//! Per iteration: centroids are broadcast down the tree; every node assigns
//! its rows with the `kmeans_assign` tile module (k ≤ TM) or with `dist2`
//! tiles merged across centroid tiles (k > TM); per-centroid (count, sum)
//! accumulators are AllReduce-summed; the master recomputes centroids.
//! The cost per iteration is one C-sized kernel-distance pass — the paper's
//! footnote 4: "nearly N_kmeans times the cost of computing C".

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::coordinator::WorkerNode;
use crate::linalg::Mat;
use crate::metrics::Step;
use crate::rng::Rng;
use crate::runtime::tiles::{TB, TM};
use crate::runtime::Compute;
use crate::Result;

#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// k × d centroid matrix (unpadded width).
    pub centroids: Mat,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
    pub iterations: usize,
}

/// Run `iters` Lloyd iterations for `k` centroids over the sharded data.
pub fn distributed_kmeans(
    cluster: &mut Cluster<WorkerNode>,
    backend: &Arc<dyn Compute>,
    k: usize,
    iters: usize,
    d: usize,
    dpad: usize,
    seed: u64,
) -> Result<KMeansResult> {
    assert!(k > 0);
    let mut rng = Rng::new(seed);

    // --- Init: sample k distinct rows proportionally across nodes. ---
    let shard_sizes: Vec<usize> = (0..cluster.p()).map(|j| cluster.node(j).n_local()).collect();
    let total: usize = shard_sizes.iter().sum();
    assert!(k <= total, "k={k} exceeds n={total}");
    let picks = sample_across_shards(&shard_sizes, k, &mut rng);
    let mut centroids = Mat::zeros(k, d);
    {
        let mut row = 0;
        for (j, locals) in picks.iter().enumerate() {
            for &local in locals {
                centroids
                    .row_mut(row)
                    .copy_from_slice(cluster.node(j).x.row(local));
                row += 1;
            }
        }
    }
    // Init gather costs one tree pass of k·d floats.
    cluster.gather_meter(Step::KMeans, k * d * 4 / cluster.p().max(1));

    let cent_tiles_count = k.div_ceil(TM);
    let mut inertia = f64::INFINITY;
    let mut done = 0;
    for _ in 0..iters {
        // Broadcast centroids.
        cluster.broadcast_meter(Step::KMeans, k * dpad * 4);
        let (cent_tiles, cmasks) = pad_centroid_tiles(&centroids, dpad);

        // Assignment + local accumulation on every node.
        let backend2 = Arc::clone(backend);
        let partials = cluster.try_par_compute(Step::KMeans, |_, node| {
            node_accumulate(node, backend2.as_ref(), &cent_tiles, &cmasks, k, d, dpad)
        })?;

        // AllReduce [counts (k), sums (k*d), inertia (1)].
        let flat: Vec<Vec<f32>> = partials
            .into_iter()
            .map(|(counts, sums, inr)| {
                let mut v = counts;
                v.extend(sums);
                v.push(inr);
                v
            })
            .collect();
        let reduced = cluster.allreduce_sum(Step::KMeans, flat);
        let (counts, rest) = reduced.split_at(k);
        let (sums, inr) = rest.split_at(k * d);
        inertia = inr[0] as f64;

        // Master: recompute centroids (empty clusters keep their position).
        for c in 0..k {
            if counts[c] > 0.0 {
                for j in 0..d {
                    *centroids.at_mut(c, j) = sums[c * d + j] / counts[c];
                }
            }
        }
        done += 1;
    }
    let _ = cent_tiles_count;
    Ok(KMeansResult {
        centroids,
        inertia,
        iterations: done,
    })
}

/// Sample `k` distinct rows spread across shards (proportional shares).
fn sample_across_shards(sizes: &[usize], k: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let total: usize = sizes.iter().sum();
    let mut shares: Vec<usize> = sizes.iter().map(|&s| k * s / total).collect();
    let mut assigned: usize = shares.iter().sum();
    // Distribute the rounding remainder to the largest shards.
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by_key(|&j| std::cmp::Reverse(sizes[j]));
    let mut oi = 0;
    while assigned < k {
        let j = order[oi % order.len()];
        if shares[j] < sizes[j] {
            shares[j] += 1;
            assigned += 1;
        }
        oi += 1;
    }
    sizes
        .iter()
        .zip(&shares)
        .map(|(&n, &share)| rng.sample_indices(n, share.min(n)))
        .collect()
}

/// Pad a k × d centroid matrix into TM × dpad tiles + per-tile masks.
pub fn pad_centroid_tiles(centroids: &Mat, dpad: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let k = centroids.rows();
    let d = centroids.cols();
    let nt = k.div_ceil(TM).max(1);
    let mut tiles = Vec::with_capacity(nt);
    let mut masks = Vec::with_capacity(nt);
    for t in 0..nt {
        let mut tile = vec![0.0f32; TM * dpad];
        let mut mask = vec![0.0f32; TM];
        let live = (k - t * TM).min(TM);
        for r in 0..live {
            tile[r * dpad..r * dpad + d].copy_from_slice(centroids.row(t * TM + r));
            mask[r] = 1.0;
        }
        tiles.push(tile);
        masks.push(mask);
    }
    (tiles, masks)
}

/// One node's assignment pass: returns (counts k, sums k*d, inertia).
fn node_accumulate(
    node: &WorkerNode,
    backend: &dyn Compute,
    cent_tiles: &[Vec<f32>],
    cmasks: &[Vec<f32>],
    k: usize,
    d: usize,
    dpad: usize,
) -> Result<(Vec<f32>, Vec<f32>, f32)> {
    let mut counts = vec![0.0f32; k];
    let mut sums = vec![0.0f32; k * d];
    let mut inertia = 0.0f32;
    let single_tile = cent_tiles.len() == 1;
    for (i, x_tile) in node.x_tiles.iter().enumerate() {
        let rmask = &node.masks[i];
        if single_tile {
            // Fast path: the fused assignment module.
            let a = backend.kmeans_assign(x_tile, &cent_tiles[0], &cmasks[0], rmask, dpad)?;
            for c in 0..k {
                counts[c] += a.counts[c];
                for j in 0..d {
                    sums[c * d + j] += a.sums[c * dpad + j];
                }
            }
            inertia += a.inertia;
        } else {
            // Multi-tile: dist2 tiles, merge argmin across centroid tiles.
            let mut best = vec![f32::INFINITY; TB];
            let mut best_idx = vec![0usize; TB];
            for (t, cent_tile) in cent_tiles.iter().enumerate() {
                let d2 = backend.dist2_block(x_tile, cent_tile, dpad)?;
                let cmask = &cmasks[t];
                for r in 0..TB {
                    for c in 0..TM {
                        if cmask[c] > 0.0 {
                            let v = d2[r * TM + c];
                            if v < best[r] {
                                best[r] = v;
                                best_idx[r] = t * TM + c;
                            }
                        }
                    }
                }
            }
            for r in 0..TB {
                if rmask[r] > 0.0 {
                    let c = best_idx[r];
                    counts[c] += 1.0;
                    let xr = &x_tile[r * dpad..r * dpad + d];
                    crate::linalg::mat::axpy(1.0, xr, &mut sums[c * d..(c + 1) * d]);
                    inertia += best[r];
                }
            }
        }
    }
    Ok((counts, sums, inertia))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::shard_rows;

    fn build_cluster(x: Mat, y: Vec<f32>, p: usize, dpad: usize) -> Cluster<WorkerNode> {
        let shards = shard_rows(x.rows(), p);
        let nodes: Vec<WorkerNode> = shards
            .iter()
            .map(|r| {
                let idx: Vec<usize> = r.clone().collect();
                WorkerNode::new(x.gather_rows(&idx), y[r.clone()].to_vec(), dpad)
            })
            .collect();
        Cluster::new(nodes, 2, CostModel::free())
    }

    fn blob_data(n: usize, seed: u64) -> Mat {
        // 3 well-separated blobs in 8-d.
        let mut rng = Rng::new(seed);
        let centers = [[0.0f32; 8], [10.0; 8], [-10.0; 8]];
        Mat::from_fn(n, 8, |i, j| centers[i % 3][j] + 0.3 * rng.normal_f32())
    }

    #[test]
    fn finds_separated_blobs() {
        let x = blob_data(600, 1);
        let y = vec![1.0f32; 600];
        let backend: Arc<dyn Compute> =
            Arc::new(crate::runtime::backend::NativeCompute::new());
        let mut cl = build_cluster(x, y, 4, 32);
        let res = distributed_kmeans(&mut cl, &backend, 3, 5, 8, 32, 7).unwrap();
        // Each centroid should be near one blob center (coordinates all
        // ~0, ~10 or ~-10).
        for c in 0..3 {
            let v = res.centroids.at(c, 0);
            assert!(
                (v.abs() < 1.0) || ((v - 10.0).abs() < 1.0) || ((v + 10.0).abs() < 1.0),
                "centroid {c} coord {v}"
            );
        }
        assert!(res.inertia < 600.0 * 8.0 * 0.5, "inertia {}", res.inertia);
    }

    #[test]
    fn inertia_decreases_monotonically() {
        let x = blob_data(300, 2);
        let y = vec![1.0f32; 300];
        let backend: Arc<dyn Compute> =
            Arc::new(crate::runtime::backend::NativeCompute::new());
        let mut prev = f64::INFINITY;
        for iters in [1, 2, 4] {
            let mut cl = build_cluster(x.clone(), y.clone(), 3, 32);
            let res = distributed_kmeans(&mut cl, &backend, 5, iters, 8, 32, 3).unwrap();
            assert!(res.inertia <= prev + 1e-3, "iters={iters}: {} > {prev}", res.inertia);
            prev = res.inertia;
        }
    }

    #[test]
    fn multi_tile_centroids_work() {
        // k > TM exercises the dist2 merge path.
        let x = blob_data(1200, 3);
        let y = vec![1.0f32; 1200];
        let backend: Arc<dyn Compute> =
            Arc::new(crate::runtime::backend::NativeCompute::new());
        let mut cl = build_cluster(x, y, 2, 32);
        let res = distributed_kmeans(&mut cl, &backend, 300, 2, 8, 32, 5).unwrap();
        assert_eq!(res.centroids.rows(), 300);
        assert!(res.inertia.is_finite());
    }

    #[test]
    fn kmeans_invariant_to_node_count() {
        let x = blob_data(400, 4);
        let y = vec![1.0f32; 400];
        let backend: Arc<dyn Compute> =
            Arc::new(crate::runtime::backend::NativeCompute::new());
        // Same seed, different p: init picks differ (sharding changes), so
        // compare inertia magnitude only — both must cluster the blobs.
        for p in [1, 4] {
            let mut cl = build_cluster(x.clone(), y.clone(), p, 32);
            let res = distributed_kmeans(&mut cl, &backend, 3, 6, 8, 32, 11).unwrap();
            assert!(res.inertia < 400.0 * 8.0 * 0.5, "p={p}: {}", res.inertia);
        }
    }

    #[test]
    fn sample_across_shards_respects_sizes() {
        let mut rng = Rng::new(1);
        let picks = sample_across_shards(&[10, 5, 1], 8, &mut rng);
        let total: usize = picks.iter().map(|v| v.len()).sum();
        assert_eq!(total, 8);
        for (j, p) in picks.iter().enumerate() {
            let size = [10, 5, 1][j];
            assert!(p.len() <= size);
            assert!(p.iter().all(|&i| i < size));
        }
    }
}
