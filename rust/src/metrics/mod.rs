//! Metrics: per-step timers keyed by Algorithm-1 step, accuracy, and the
//! fixed-width table printer the benches use to regenerate the paper's
//! tables.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// The steps of Algorithm 1 (plus prediction), used as timer keys so
/// Table 4's "cost slicing" falls straight out of any run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Step {
    /// Step 1: data loading / sharding.
    Load,
    /// Step 3.2 extra: K-means basis selection (when enabled).
    KMeans,
    /// Step 2: communication of basis points.
    BasisBcast,
    /// Step 3: kernel (C row block) computation.
    Kernel,
    /// Step 4: TRON optimization.
    Tron,
    /// Test-set prediction (not an Algorithm-1 step; reported separately).
    Predict,
}

impl Step {
    pub fn name(&self) -> &'static str {
        match self {
            Step::Load => "load",
            Step::KMeans => "kmeans",
            Step::BasisBcast => "basis_bcast",
            Step::Kernel => "kernel",
            Step::Tron => "tron",
            Step::Predict => "predict",
        }
    }

    pub fn all() -> [Step; 6] {
        [
            Step::Load,
            Step::KMeans,
            Step::BasisBcast,
            Step::Kernel,
            Step::Tron,
            Step::Predict,
        ]
    }

    /// Stable binary tag for serialized ledgers (checkpoints, phase
    /// traces): the position in [`Step::all`]. New steps must be APPENDED
    /// to `all()` so existing tags keep their meaning on disk.
    pub fn tag(&self) -> u8 {
        Step::all()
            .iter()
            .position(|s| s == self)
            .expect("every step is in Step::all()") as u8
    }

    /// Inverse of [`Step::tag`]; `None` for tags from a newer format.
    pub fn from_tag(tag: u8) -> Option<Step> {
        Step::all().get(tag as usize).copied()
    }

    /// True for the steps of Algorithm 1 proper — prediction is reported
    /// separately and never belongs to a training-time series.
    pub fn is_algorithm1(&self) -> bool {
        !matches!(self, Step::Predict)
    }

    /// True for the Fig-2 "Other time" series: the Algorithm-1 steps minus
    /// TRON. Shared by the wall-clock and simulated ledgers so the two
    /// series can never diverge in what they count.
    pub fn is_other(&self) -> bool {
        self.is_algorithm1() && !matches!(self, Step::Tron)
    }
}

/// Wall-clock timers per step + free-form counters.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    wall: BTreeMap<Step, Duration>,
    counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a step key (accumulating).
    pub fn time<T>(&mut self, step: Step, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        *self.wall.entry(step).or_default() += start.elapsed();
        out
    }

    pub fn add_wall(&mut self, step: Step, d: Duration) {
        *self.wall.entry(step).or_default() += d;
    }

    pub fn wall(&self, step: Step) -> Duration {
        self.wall.get(&step).copied().unwrap_or_default()
    }

    pub fn wall_secs(&self, step: Step) -> f64 {
        self.wall(step).as_secs_f64()
    }

    pub fn total_secs(&self) -> f64 {
        self.wall.values().map(|d| d.as_secs_f64()).sum()
    }

    /// The paper's "Other time" series in Fig 2: every Algorithm-1 step
    /// except TRON (see [`Step::is_other`]). `Predict` is documented as
    /// NOT an Algorithm-1 step (reported separately), so it is excluded —
    /// `total - tron` would silently fold test-set prediction into the
    /// training-time series.
    pub fn other_secs(&self) -> f64 {
        self.wall
            .iter()
            .filter(|(s, _)| s.is_other())
            .map(|(_, d)| d.as_secs_f64())
            .sum()
    }

    pub fn bump(&mut self, key: &str, by: u64) {
        *self.counters.entry(key.to_string()).or_default() += by;
    }

    /// Global synchronization points of the run (compute phases +
    /// collectives) — mirrored from the cluster ledger by the trainer so
    /// wall-clock reports can show rounds next to seconds.
    pub fn barriers(&self) -> u64 {
        self.counter("barriers")
    }

    /// AllReduce round-trips of the run (an up+down tree pass counts as
    /// one) — mirrored from the cluster ledger by the trainer.
    pub fn comm_rounds(&self) -> u64 {
        self.counter("comm_rounds")
    }

    /// Backend dispatches issued inside TRON evaluation phases — mirrored
    /// from the cluster ledger by the trainer. One per node per evaluation
    /// with the whole-node block ops on the native backend.
    pub fn dispatches(&self) -> u64 {
        self.counter("dispatches")
    }

    /// Slowest-node compute seconds (the straggler bound every barrier
    /// waits on) — mirrored from the cluster ledger as integer
    /// microseconds so the free-form counter map can carry it.
    pub fn max_node_secs(&self) -> f64 {
        self.counter("max_node_us") as f64 / 1e6
    }

    /// Summed per-node compute seconds (total fleet work) — mirrored from
    /// the cluster ledger as integer microseconds.
    pub fn sum_node_secs(&self) -> f64 {
        self.counter("sum_node_us") as f64 / 1e6
    }

    /// Straggler ratio `max·p / sum`: how much longer the slowest-node
    /// bound is than perfectly balanced work (1.0 = balanced fleet).
    pub fn straggler_ratio(&self, p: usize) -> f64 {
        let sum = self.sum_node_secs();
        if sum <= 0.0 || p == 0 {
            return 1.0;
        }
        self.max_node_secs() * p as f64 / sum
    }

    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    pub fn counters(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    pub fn merge(&mut self, other: &Metrics) {
        for (s, d) in &other.wall {
            *self.wall.entry(*s).or_default() += *d;
        }
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_default() += v;
        }
    }
}

/// Binary-classification accuracy from decision values.
pub fn accuracy(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    if scores.is_empty() {
        return 0.0;
    }
    let correct = scores
        .iter()
        .zip(labels)
        .filter(|(s, y)| (**s >= 0.0) == (**y > 0.0))
        .count();
    correct as f64 / scores.len() as f64
}

/// Fixed-width console table (the benches print paper-style tables).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:>w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&line(&self.header));
        out.push('\n');
        let sep: String = format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate() {
        let mut m = Metrics::new();
        m.time(Step::Kernel, || std::thread::sleep(Duration::from_millis(5)));
        m.time(Step::Kernel, || std::thread::sleep(Duration::from_millis(5)));
        assert!(m.wall_secs(Step::Kernel) >= 0.009);
        assert_eq!(m.wall_secs(Step::Tron), 0.0);
    }

    #[test]
    fn other_excludes_tron_and_predict() {
        let mut m = Metrics::new();
        m.add_wall(Step::Tron, Duration::from_secs(3));
        m.add_wall(Step::Kernel, Duration::from_secs(2));
        assert!((m.other_secs() - 2.0).abs() < 1e-9);
        assert!((m.total_secs() - 5.0).abs() < 1e-9);
        // Predict is not an Algorithm-1 step: it counts toward the total
        // but must NOT leak into the Fig-2 "Other time" series.
        m.add_wall(Step::Predict, Duration::from_secs(7));
        assert!((m.other_secs() - 2.0).abs() < 1e-9, "{}", m.other_secs());
        assert!((m.total_secs() - 12.0).abs() < 1e-9);
    }

    #[test]
    fn merge_sums_both() {
        let mut a = Metrics::new();
        a.add_wall(Step::Load, Duration::from_secs(1));
        a.bump("calls", 2);
        let mut b = Metrics::new();
        b.add_wall(Step::Load, Duration::from_secs(2));
        b.bump("calls", 3);
        a.merge(&b);
        assert!((a.wall_secs(Step::Load) - 3.0).abs() < 1e-9);
        assert_eq!(a.counter("calls"), 5);
    }

    #[test]
    fn straggler_mirror_reads_back_in_seconds() {
        let mut m = Metrics::new();
        assert_eq!(m.straggler_ratio(8), 1.0, "no work yet = balanced");
        // 4s slowest node over 11s total work at p=8 (microsecond counters).
        m.bump("max_node_us", 4_000_000);
        m.bump("sum_node_us", 11_000_000);
        assert!((m.max_node_secs() - 4.0).abs() < 1e-9);
        assert!((m.sum_node_secs() - 11.0).abs() < 1e-9);
        assert!((m.straggler_ratio(8) - 32.0 / 11.0).abs() < 1e-9);
        assert_eq!(m.straggler_ratio(0), 1.0);
    }

    #[test]
    fn step_tags_round_trip_and_stay_dense() {
        for (i, s) in Step::all().iter().enumerate() {
            assert_eq!(s.tag() as usize, i);
            assert_eq!(Step::from_tag(s.tag()), Some(*s));
        }
        assert_eq!(Step::from_tag(Step::all().len() as u8), None);
    }

    #[test]
    fn accuracy_counts_sign_agreement() {
        let acc = accuracy(&[1.0, -0.5, 0.2, -2.0], &[1.0, 1.0, 1.0, -1.0]);
        assert!((acc - 0.75).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["m", "acc"]);
        t.row(&["100".into(), "0.81".into()]);
        t.row(&["51200".into(), "0.9493".into()]);
        let s = t.render();
        assert!(s.contains("| 51200 | 0.9493 |"));
        assert!(s.lines().count() == 4);
    }
}
