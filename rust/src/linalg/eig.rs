//! Symmetric eigensolver: Householder tridiagonalization (tred2) + implicit
//! shift QL with eigenvectors (tql2), in f64 — the EISPACK pair.
//!
//! This is the O(m³) step the paper's formulation (4) exists to AVOID: the
//! linearization baseline (formulation (3), `baselines::linearized`) needs
//! the eigen-decomposition W = U Λ Uᵀ to form A = C U Λ^{-1/2}. It lives in
//! the substrate so Table 1 can measure exactly how badly it scales with m.

/// Eigen-decomposition of a symmetric matrix given as a dense row-major
/// `n x n` slice (only the symmetric part is used).
///
/// Returns (eigenvalues ascending, eigenvectors as columns of a row-major
/// `n x n` matrix: `vecs[i*n + j]` = component i of eigenvector j).
pub fn sym_eig(a: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n, "matrix must be n*n");
    let mut v = a.to_vec();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut v, n, &mut d, &mut e);
    tql2(&mut v, n, &mut d, &mut e);
    (d, v)
}

/// Householder reduction to tridiagonal form. On exit `v` holds the
/// accumulated orthogonal transform Q, `d` the diagonal, `e` the
/// subdiagonal (e[0] unused).
fn tred2(v: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) {
    for j in 0..n {
        d[j] = v[(n - 1) * n + j];
    }
    for i in (1..n).rev() {
        // Scale to avoid under/overflow.
        let mut scale = 0.0;
        let mut h = 0.0;
        for item in d.iter().take(i) {
            scale += item.abs();
        }
        if scale == 0.0 {
            e[i] = d[i - 1];
            for j in 0..i {
                d[j] = v[(i - 1) * n + j];
                v[i * n + j] = 0.0;
                v[j * n + i] = 0.0;
            }
        } else {
            for item in d.iter_mut().take(i) {
                *item /= scale;
            }
            for item in d.iter().take(i) {
                h += item * item;
            }
            let mut f = d[i - 1];
            let mut g = h.sqrt();
            if f > 0.0 {
                g = -g;
            }
            e[i] = scale * g;
            h -= f * g;
            d[i - 1] = f - g;
            for j in 0..i {
                e[j] = 0.0;
            }
            // Apply similarity transformation to remaining columns.
            for j in 0..i {
                f = d[j];
                v[j * n + i] = f;
                g = e[j] + v[j * n + j] * f;
                for k in (j + 1)..i {
                    g += v[k * n + j] * d[k];
                    e[k] += v[k * n + j] * f;
                }
                e[j] = g;
            }
            f = 0.0;
            for j in 0..i {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..i {
                e[j] -= hh * d[j];
            }
            for j in 0..i {
                f = d[j];
                g = e[j];
                for k in j..i {
                    v[k * n + j] -= f * e[k] + g * d[k];
                }
                d[j] = v[(i - 1) * n + j];
                v[i * n + j] = 0.0;
            }
        }
        d[i] = h;
    }
    // Accumulate transformations.
    for i in 0..(n - 1) {
        v[(n - 1) * n + i] = v[i * n + i];
        v[i * n + i] = 1.0;
        let h = d[i + 1];
        if h != 0.0 {
            for k in 0..=i {
                d[k] = v[k * n + (i + 1)] / h;
            }
            for j in 0..=i {
                let mut g = 0.0;
                for k in 0..=i {
                    g += v[k * n + (i + 1)] * v[k * n + j];
                }
                for k in 0..=i {
                    v[k * n + j] -= g * d[k];
                }
            }
        }
        for k in 0..=i {
            v[k * n + (i + 1)] = 0.0;
        }
    }
    for j in 0..n {
        d[j] = v[(n - 1) * n + j];
        v[(n - 1) * n + j] = 0.0;
    }
    v[(n - 1) * n + (n - 1)] = 1.0;
    e[0] = 0.0;
}

/// Implicit-shift QL for symmetric tridiagonal; accumulates eigenvectors
/// into `v`. Eigenvalues are sorted ascending on exit (with vectors).
fn tql2(v: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        // Find small subdiagonal element.
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m == n {
            m = n - 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                assert!(iter <= 50, "tql2: no convergence after 50 iterations");
                // Compute implicit shift.
                let mut g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = (p * p + 1.0).sqrt();
                if p < 0.0 {
                    r = -r;
                }
                d[l] = e[l] / (p + r);
                d[l + 1] = e[l] * (p + r);
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for item in d.iter_mut().take(n).skip(l + 2) {
                    *item -= h;
                }
                f += h;
                // Implicit QL transformation.
                p = d[m];
                let mut c = 1.0f64;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0f64;
                let mut s2 = 0.0f64;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    g = c * e[i];
                    h = c * p;
                    r = (p * p + e[i] * e[i]).sqrt();
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    // Accumulate eigenvectors.
                    for k in 0..n {
                        h = v[k * n + (i + 1)];
                        v[k * n + (i + 1)] = s * v[k * n + i] + c * h;
                        v[k * n + i] = c * v[k * n + i] - s * h;
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }

    // Sort eigenvalues ascending, permuting vectors along.
    for i in 0..n.saturating_sub(1) {
        let mut k = i;
        let mut p = d[i];
        for j in (i + 1)..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d[k] = d[i];
            d[i] = p;
            for r in 0..n {
                v.swap(r * n + i, r * n + k);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        a
    }

    fn check_decomposition(a: &[f64], n: usize, tol: f64) {
        let (d, v) = sym_eig(a, n);
        // A v_j == d_j v_j for every eigenpair.
        for j in 0..n {
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[i * n + k] * v[k * n + j];
                }
                let want = d[j] * v[i * n + j];
                assert!(
                    (av - want).abs() < tol,
                    "eigenpair {j}: row {i}: {av} vs {want}"
                );
            }
        }
        // Orthonormal columns.
        for j1 in 0..n {
            for j2 in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += v[k * n + j1] * v[k * n + j2];
                }
                let want = if j1 == j2 { 1.0 } else { 0.0 };
                assert!((s - want).abs() < tol, "orthonormality ({j1},{j2}): {s}");
            }
        }
        // Ascending order.
        for j in 1..n {
            assert!(d[j] >= d[j - 1] - 1e-12);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let (d, _) = sym_eig(&[2.0, 1.0, 1.0, 2.0], 2);
        assert!((d[0] - 1.0).abs() < 1e-12);
        assert!((d[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_matrix() {
        let a = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0];
        let (d, _) = sym_eig(&a, 3);
        assert!((d[0] - 1.0).abs() < 1e-12);
        assert!((d[1] - 2.0).abs() < 1e-12);
        assert!((d[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn random_matrices_reconstruct() {
        for (n, seed) in [(5, 1), (16, 2), (33, 3), (64, 4)] {
            let a = random_symmetric(n, seed);
            check_decomposition(&a, n, 1e-8);
        }
    }

    #[test]
    fn gram_matrix_is_psd() {
        // W = G Gᵀ must have non-negative eigenvalues.
        let mut rng = Rng::new(9);
        let n = 24;
        let g: Vec<f64> = (0..n * 8).map(|_| rng.normal()).collect();
        let mut w = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..8 {
                    s += g[i * 8 + k] * g[j * 8 + k];
                }
                w[i * n + j] = s;
            }
        }
        let (d, _) = sym_eig(&w, n);
        assert!(d[0] > -1e-9, "smallest eigenvalue {}", d[0]);
    }

    #[test]
    fn repeated_eigenvalues() {
        // Identity: all eigenvalues 1, any orthonormal basis is fine.
        let n = 8;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        check_decomposition(&a, n, 1e-10);
    }
}
