//! O(m) vector kernels: everything TRON does on the master between the
//! distributed matrix-vector products ("all other computations in TRON
//! require only O(m) effort" — paper §3.1).

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    super::mat::dot(x, x).sqrt()
}

/// Dot product (re-exported from the unrolled mat kernel).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    super::mat::dot(a, b)
}

/// y += alpha x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    super::mat::axpy(alpha, x, y)
}

/// x *= alpha.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = a + alpha b (allocating).
pub fn add_scaled(a: &[f32], alpha: f32, b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + alpha * y).collect()
}

/// Elementwise product, in place: y *= x.
#[inline]
pub fn hadamard(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi *= xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_of_unit_axes() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert_eq!(norm2(&[]), 0.0);
    }

    #[test]
    fn add_scaled_works() {
        assert_eq!(add_scaled(&[1.0, 2.0], 2.0, &[3.0, -1.0]), vec![7.0, 0.0]);
    }

    #[test]
    fn scale_and_hadamard() {
        let mut x = vec![1.0, -2.0, 3.0];
        scale(2.0, &mut x);
        assert_eq!(x, vec![2.0, -4.0, 6.0]);
        let mut y = vec![1.0, 2.0, 3.0];
        hadamard(&[0.0, 1.0, 2.0], &mut y);
        assert_eq!(y, vec![0.0, 2.0, 6.0]);
    }
}
