//! Cholesky factorization + solve (f64), for ridge solves in tests and the
//! exact small-m reference solutions the integration tests compare against.

/// In-place lower Cholesky of a row-major symmetric positive-definite
/// `n x n` matrix. Returns the lower factor L (row-major, upper part zero).
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None; // not positive definite
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve A x = b for SPD A via Cholesky. Returns None if not SPD.
pub fn cholesky_solve(a: &[f64], n: usize, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(b.len(), n);
    let l = cholesky(a, n)?;
    Some(cholesky_solve_factored(&l, n, b))
}

/// Solve A x = b given A's lower Cholesky factor L (from [`cholesky`]) —
/// factor once, solve many times (the BCD block-step path).
pub fn cholesky_solve_factored(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    assert_eq!(l.len(), n * n);
    assert_eq!(b.len(), n);
    // Forward solve L y = b.
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    // Back solve Lᵀ x = y.
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn factor_reconstructs() {
        let a = [4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2).unwrap();
        // L = [[2,0],[1,sqrt(2)]]
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn solve_recovers_known_x() {
        let mut rng = Rng::new(21);
        let n = 20;
        // SPD: A = G Gᵀ + n I
        let g: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { n as f64 } else { 0.0 };
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64) - 10.0).collect();
        let mut b = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a[i * n + j] * x_true[j];
            }
        }
        let x = cholesky_solve(&a, n, &b).unwrap();
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "{} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn factored_solve_matches_direct() {
        let a = [4.0, 2.0, 2.0, 3.0];
        let b = [1.0, -2.0];
        let l = cholesky(&a, 2).unwrap();
        let x = cholesky_solve_factored(&l, 2, &b);
        let direct = cholesky_solve(&a, 2, &b).unwrap();
        assert_eq!(x, direct);
        // Residual check: A x = b.
        assert!((4.0 * x[0] + 2.0 * x[1] - 1.0).abs() < 1e-12);
        assert!((2.0 * x[0] + 3.0 * x[1] + 2.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }
}
