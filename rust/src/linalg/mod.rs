//! Dense linear algebra substrate (no external BLAS/LAPACK offline).
//!
//! * [`mat`] — row-major `Mat<f32>` + matvec / gemm kernels used by the
//!   native compute backend and the baselines.
//! * [`eig`] — Householder tridiagonalization + implicit-shift QL symmetric
//!   eigensolver (f64), needed *only* by the formulation-(3) baseline: the
//!   whole point of the paper's formulation (4) is to avoid it.
//! * [`chol`] — Cholesky factorization (diagnostics, ridge solves in tests).
//! * [`vecops`] — the O(m) vector kernels TRON runs on the master.
//! * [`simd`] — the portable fixed-lane vector shim (with its
//!   `scalar-fallback` feature gate) behind every microkernel, and the
//!   accumulation-order contract they all share.

pub mod chol;
pub mod eig;
pub mod mat;
pub mod simd;
pub mod vecops;

pub use chol::cholesky_solve;
pub use eig::sym_eig;
pub use mat::Mat;
