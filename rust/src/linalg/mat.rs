//! Row-major dense matrix + the matvec/gemm kernels of the native backend.
//!
//! The layout contract (row-major, contiguous) is shared with
//! `runtime::tiles`, which reinterprets row panels of a `Mat` as PJRT tile
//! inputs without copying rows around.

use std::fmt;

/// Row-major dense `rows x cols` matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from a row-producing closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Contiguous row panel `[r0, r1)` — the zero-copy tile view.
    #[inline]
    pub fn row_panel(&self, r0: usize, r1: usize) -> &[f32] {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        &self.data[r0 * self.cols..r1 * self.cols]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Gather the given rows into a new matrix (basis sub-matrix extraction).
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// y = A x. Panics on dimension mismatch.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        // 4-wide unrolled dot per row: the compiler autovectorizes this form.
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }

    /// y = Aᵀ x.
    pub fn matvec_t(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        // axpy per row keeps the inner loop unit-stride over the row-major
        // layout (a column-wise loop would stride by `cols`).
        for i in 0..self.rows {
            let xi = x[i];
            // The sparsity guard pays for itself here: the linearized
            // baseline's TRON feeds sq-hinge residuals through this path,
            // and those are EXACTLY zero for every margin-inactive example
            // (most of the set near convergence) — each skip saves a full
            // `cols`-wide axpy for one predictable branch. See the
            // `matvec_t guard` section of `cargo bench --bench micro`.
            if xi != 0.0 {
                axpy(xi, self.row(i), y);
            }
        }
    }

    /// C = A Bᵀ where B is given row-major (i.e. C_ik = <A_i, B_k>).
    /// This is the natural product for kernel blocks (both operands are
    /// row-major example matrices).
    pub fn gemm_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "inner dims");
        let mut out = Mat::zeros(self.rows, b.rows);
        for i in 0..self.rows {
            let ai = self.row(i);
            let orow = out.row_mut(i);
            for k in 0..b.rows {
                orow[k] = dot(ai, b.row(k));
            }
        }
        out
    }

    /// C = A B (B row-major `self.cols x n`).
    pub fn gemm_nn(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "inner dims");
        let n = b.cols;
        let mut out = Mat::zeros(self.rows, n);
        for i in 0..self.rows {
            let ai = self.row(i);
            let orow = out.row_mut(i);
            // No sparsity guard: every caller feeds dense operands (A is an
            // RBF kernel matrix in the linearized baseline — entries are
            // exp(−γd²), never exactly zero), so a per-element branch is
            // pure overhead in the innermost loop. Measured in the
            // `matvec_t guard` section of `cargo bench --bench micro`.
            for (k, &aik) in ai.iter().enumerate() {
                axpy(aik, b.row(k), orow);
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *out.at_mut(j, i) = self.at(i, j);
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

/// Unit-stride dot product; written so LLVM autovectorizes (4 accumulators).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// y += alpha * x, unit stride.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 2];
        a.matvec(&[1., 1., 1.], &mut y);
        assert_eq!(y, vec![6.0, 15.0]);
    }

    #[test]
    fn matvec_t_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 3];
        a.matvec_t(&[1., 2.], &mut y);
        assert_eq!(y, vec![9.0, 12.0, 15.0]);
    }

    #[test]
    fn matvec_adjoint_identity() {
        // <A x, r> == <x, Aᵀ r>
        let mut rng = crate::rng::Rng::new(3);
        let a = Mat::from_fn(17, 29, |_, _| rng.normal_f32());
        let x: Vec<f32> = (0..29).map(|_| rng.normal_f32()).collect();
        let r: Vec<f32> = (0..17).map(|_| rng.normal_f32()).collect();
        let mut ax = vec![0.0; 17];
        a.matvec(&x, &mut ax);
        let mut atr = vec![0.0; 29];
        a.matvec_t(&r, &mut atr);
        let lhs = dot(&ax, &r);
        let rhs = dot(&x, &atr);
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
    }

    #[test]
    fn gemm_nt_matches_manual() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let c = a.gemm_nt(&b);
        assert_eq!(c.as_slice(), &[1., 2., 3., 3., 4., 7.]);
    }

    #[test]
    fn gemm_nn_matches_gemm_nt_with_transpose() {
        let mut rng = crate::rng::Rng::new(5);
        let a = Mat::from_fn(7, 11, |_, _| rng.normal_f32());
        let b = Mat::from_fn(11, 5, |_, _| rng.normal_f32());
        let c1 = a.gemm_nn(&b);
        let c2 = a.gemm_nt(&b.transpose());
        for (x, y) in c1.as_slice().iter().zip(c2.as_slice()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gather_rows_picks_rows() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_rows(&[2, 0]);
        assert_eq!(g.as_slice(), &[5., 6., 1., 2.]);
    }

    #[test]
    fn dot_handles_tails() {
        for n in [0, 1, 7, 8, 9, 31, 64] {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![2.0f32; n];
            let want: f32 = (0..n).map(|i| 2.0 * i as f32).sum();
            assert_eq!(dot(&a, &b), want, "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_rejects_bad_shape() {
        Mat::from_vec(2, 2, vec![1.0; 5]);
    }
}
