//! Row-major dense matrix + the matvec/gemm kernels of the native backend.
//!
//! The layout contract (row-major, contiguous) is shared with
//! `runtime::tiles`, which reinterprets row panels of a `Mat` as PJRT tile
//! inputs without copying rows around.

use std::fmt;

use super::simd::{F32x, LANES};

/// Row-major dense `rows x cols` matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from a row-producing closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Contiguous row panel `[r0, r1)` — the zero-copy tile view.
    #[inline]
    pub fn row_panel(&self, r0: usize, r1: usize) -> &[f32] {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        &self.data[r0 * self.cols..r1 * self.cols]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Gather the given rows into a new matrix (basis sub-matrix extraction).
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// y = A x. Panics on dimension mismatch.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        // 4-wide unrolled dot per row: the compiler autovectorizes this form.
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }

    /// y = Aᵀ x.
    pub fn matvec_t(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        // axpy per row keeps the inner loop unit-stride over the row-major
        // layout (a column-wise loop would stride by `cols`).
        for i in 0..self.rows {
            let xi = x[i];
            // The sparsity guard pays for itself here: the linearized
            // baseline's TRON feeds sq-hinge residuals through this path,
            // and those are EXACTLY zero for every margin-inactive example
            // (most of the set near convergence) — each skip saves a full
            // `cols`-wide axpy for one predictable branch. See the
            // `matvec_t guard` section of `cargo bench --bench micro`.
            if xi != 0.0 {
                axpy(xi, self.row(i), y);
            }
        }
    }

    /// C = A Bᵀ where B is given row-major (i.e. C_ik = <A_i, B_k>).
    /// This is the natural product for kernel blocks (both operands are
    /// row-major example matrices).
    pub fn gemm_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "inner dims");
        let mut out = Mat::zeros(self.rows, b.rows);
        for i in 0..self.rows {
            let ai = self.row(i);
            let orow = out.row_mut(i);
            for k in 0..b.rows {
                orow[k] = dot(ai, b.row(k));
            }
        }
        out
    }

    /// C = A B (B row-major `self.cols x n`).
    ///
    /// Register-blocked over the output row: two `F32x` output chunks stay
    /// in registers across the whole k loop, so each 16-wide output block
    /// costs one pass over A's row and B's column panel instead of k
    /// read-modify-write sweeps of the output row. Per output element the
    /// accumulation is still `Σ_k a_ik·b_kj` in ascending k from 0.0 —
    /// bitwise identical to the unblocked axpy-per-k formulation.
    pub fn gemm_nn(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "inner dims");
        let n = b.cols;
        let mut out = Mat::zeros(self.rows, n);
        for i in 0..self.rows {
            let ai = self.row(i);
            let orow = out.row_mut(i);
            // No sparsity guard: every caller feeds dense operands (A is an
            // RBF kernel matrix in the linearized baseline — entries are
            // exp(−γd²), never exactly zero), so a per-element branch is
            // pure overhead in the innermost loop. Measured in the
            // `matvec_t guard` section of `cargo bench --bench micro`.
            let mut j = 0;
            while j + 2 * LANES <= n {
                let mut acc0 = F32x::zero();
                let mut acc1 = F32x::zero();
                for (k, &aik) in ai.iter().enumerate() {
                    let brow = b.row(k);
                    let s = F32x::splat(aik);
                    acc0 = acc0.add(s.mul(F32x::load(&brow[j..])));
                    acc1 = acc1.add(s.mul(F32x::load(&brow[j + LANES..])));
                }
                acc0.store(&mut orow[j..]);
                acc1.store(&mut orow[j + LANES..]);
                j += 2 * LANES;
            }
            while j + LANES <= n {
                let mut acc = F32x::zero();
                for (k, &aik) in ai.iter().enumerate() {
                    acc = acc.add(F32x::splat(aik).mul(F32x::load(&b.row(k)[j..])));
                }
                acc.store(&mut orow[j..]);
                j += LANES;
            }
            if j < n {
                for (k, &aik) in ai.iter().enumerate() {
                    let brow = b.row(k);
                    for jj in j..n {
                        orow[jj] += aik * brow[jj];
                    }
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *out.at_mut(j, i) = self.at(i, j);
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

/// Unit-stride dot product — THE reduction of the accumulation-order
/// contract (see [`crate::linalg::simd`]): two `F32x` accumulators over
/// `2·LANES`-wide chunk pairs, one trailing `LANES` chunk into acc0,
/// pairwise lane reduction, scalar tail in index order. Every blocked
/// microkernel reproduces this order per element, so "blocked" never
/// means "different bits".
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc0 = F32x::zero();
    let mut acc1 = F32x::zero();
    let mut i = 0;
    while i + 2 * LANES <= n {
        acc0 = acc0.add(F32x::load(&a[i..]).mul(F32x::load(&b[i..])));
        acc1 = acc1.add(F32x::load(&a[i + LANES..]).mul(F32x::load(&b[i + LANES..])));
        i += 2 * LANES;
    }
    if i + LANES <= n {
        acc0 = acc0.add(F32x::load(&a[i..]).mul(F32x::load(&b[i..])));
        i += LANES;
    }
    let mut s = acc0.add(acc1).hsum();
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Four simultaneous dot products against one shared right-hand side —
/// the register-blocked core of the tile `matvec` and `kernel_block`.
/// Each lane of the result is BITWISE equal to `dot(r_i, v)`: the per-row
/// accumulator structure is `dot`'s exactly; blocking only shares the `v`
/// loads across the four rows.
#[inline]
pub fn dot4(r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32], v: &[f32]) -> [f32; 4] {
    let n = v.len();
    debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
    let mut a00 = F32x::zero();
    let mut a01 = F32x::zero();
    let mut a10 = F32x::zero();
    let mut a11 = F32x::zero();
    let mut a20 = F32x::zero();
    let mut a21 = F32x::zero();
    let mut a30 = F32x::zero();
    let mut a31 = F32x::zero();
    let mut i = 0;
    while i + 2 * LANES <= n {
        let v0 = F32x::load(&v[i..]);
        let v1 = F32x::load(&v[i + LANES..]);
        a00 = a00.add(F32x::load(&r0[i..]).mul(v0));
        a01 = a01.add(F32x::load(&r0[i + LANES..]).mul(v1));
        a10 = a10.add(F32x::load(&r1[i..]).mul(v0));
        a11 = a11.add(F32x::load(&r1[i + LANES..]).mul(v1));
        a20 = a20.add(F32x::load(&r2[i..]).mul(v0));
        a21 = a21.add(F32x::load(&r2[i + LANES..]).mul(v1));
        a30 = a30.add(F32x::load(&r3[i..]).mul(v0));
        a31 = a31.add(F32x::load(&r3[i + LANES..]).mul(v1));
        i += 2 * LANES;
    }
    if i + LANES <= n {
        let v0 = F32x::load(&v[i..]);
        a00 = a00.add(F32x::load(&r0[i..]).mul(v0));
        a10 = a10.add(F32x::load(&r1[i..]).mul(v0));
        a20 = a20.add(F32x::load(&r2[i..]).mul(v0));
        a30 = a30.add(F32x::load(&r3[i..]).mul(v0));
        i += LANES;
    }
    let mut s = [
        a00.add(a01).hsum(),
        a10.add(a11).hsum(),
        a20.add(a21).hsum(),
        a30.add(a31).hsum(),
    ];
    while i < n {
        s[0] += r0[i] * v[i];
        s[1] += r1[i] * v[i];
        s[2] += r2[i] * v[i];
        s[3] += r3[i] * v[i];
        i += 1;
    }
    s
}

/// y += alpha * x, unit stride, vectorized. Element-wise, so bitwise equal
/// to the plain scalar loop for any length.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let a = F32x::splat(alpha);
    let mut i = 0;
    while i + LANES <= n {
        let r = F32x::load(&y[i..]).add(a.mul(F32x::load(&x[i..])));
        r.store(&mut y[i..]);
        i += LANES;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 2];
        a.matvec(&[1., 1., 1.], &mut y);
        assert_eq!(y, vec![6.0, 15.0]);
    }

    #[test]
    fn matvec_t_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 3];
        a.matvec_t(&[1., 2.], &mut y);
        assert_eq!(y, vec![9.0, 12.0, 15.0]);
    }

    #[test]
    fn matvec_adjoint_identity() {
        // <A x, r> == <x, Aᵀ r>
        let mut rng = crate::rng::Rng::new(3);
        let a = Mat::from_fn(17, 29, |_, _| rng.normal_f32());
        let x: Vec<f32> = (0..29).map(|_| rng.normal_f32()).collect();
        let r: Vec<f32> = (0..17).map(|_| rng.normal_f32()).collect();
        let mut ax = vec![0.0; 17];
        a.matvec(&x, &mut ax);
        let mut atr = vec![0.0; 29];
        a.matvec_t(&r, &mut atr);
        let lhs = dot(&ax, &r);
        let rhs = dot(&x, &atr);
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
    }

    #[test]
    fn gemm_nt_matches_manual() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let c = a.gemm_nt(&b);
        assert_eq!(c.as_slice(), &[1., 2., 3., 3., 4., 7.]);
    }

    #[test]
    fn gemm_nn_matches_gemm_nt_with_transpose() {
        let mut rng = crate::rng::Rng::new(5);
        let a = Mat::from_fn(7, 11, |_, _| rng.normal_f32());
        let b = Mat::from_fn(11, 5, |_, _| rng.normal_f32());
        let c1 = a.gemm_nn(&b);
        let c2 = a.gemm_nt(&b.transpose());
        for (x, y) in c1.as_slice().iter().zip(c2.as_slice()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gather_rows_picks_rows() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_rows(&[2, 0]);
        assert_eq!(g.as_slice(), &[5., 6., 1., 2.]);
    }

    #[test]
    fn dot_handles_tails() {
        for n in [0, 1, 7, 8, 9, 31, 64] {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![2.0f32; n];
            let want: f32 = (0..n).map(|i| 2.0 * i as f32).sum();
            assert_eq!(dot(&a, &b), want, "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_rejects_bad_shape() {
        Mat::from_vec(2, 2, vec![1.0; 5]);
    }

    /// Scalar re-statement of the documented accumulation order (lane
    /// arrays instead of `F32x`); `dot` must match it BITWISE for every
    /// shape — in both the vectorized and scalar-fallback builds, which
    /// proves the two builds bit-identical transitively.
    fn dot_contract_ref(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut acc0 = [0.0f32; 8];
        let mut acc1 = [0.0f32; 8];
        let mut i = 0;
        while i + 16 <= n {
            for l in 0..8 {
                acc0[l] += a[i + l] * b[i + l];
                acc1[l] += a[i + 8 + l] * b[i + 8 + l];
            }
            i += 16;
        }
        if i + 8 <= n {
            for l in 0..8 {
                acc0[l] += a[i + l] * b[i + l];
            }
            i += 8;
        }
        let c: Vec<f32> = (0..8).map(|l| acc0[l] + acc1[l]).collect();
        let mut s = ((c[0] + c[1]) + (c[2] + c[3])) + ((c[4] + c[5]) + (c[6] + c[7]));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[test]
    fn dot_matches_contract_reference_bitwise() {
        let mut rng = crate::rng::Rng::new(17);
        for n in [0usize, 1, 5, 7, 8, 9, 15, 16, 17, 23, 31, 32, 100, 256, 784] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_contract_ref(&a, &b).to_bits(),
                "n={n}"
            );
        }
    }

    #[test]
    fn dot4_matches_dot_bitwise() {
        let mut rng = crate::rng::Rng::new(19);
        for n in [0usize, 3, 8, 13, 16, 20, 64, 100, 784] {
            let rows: Vec<Vec<f32>> = (0..4)
                .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
                .collect();
            let v: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let got = dot4(&rows[0], &rows[1], &rows[2], &rows[3], &v);
            for r in 0..4 {
                assert_eq!(
                    got[r].to_bits(),
                    dot(&rows[r], &v).to_bits(),
                    "n={n} row={r}"
                );
            }
        }
    }

    #[test]
    fn axpy_matches_scalar_loop_bitwise() {
        let mut rng = crate::rng::Rng::new(23);
        for n in [0usize, 1, 7, 8, 9, 16, 31, 100] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let y0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let alpha = rng.normal_f32();
            let mut got = y0.clone();
            axpy(alpha, &x, &mut got);
            for i in 0..n {
                let want = y0[i] + alpha * x[i];
                assert_eq!(got[i].to_bits(), want.to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn gemm_nn_matches_axpy_reference_bitwise() {
        let mut rng = crate::rng::Rng::new(29);
        // Odd shapes: output widths hitting the 16-wide, 8-wide and scalar
        // tails of the blocked kernel.
        for (rows, kk, n) in [(3usize, 11usize, 5usize), (4, 7, 16), (2, 9, 21), (5, 16, 40)] {
            let a = Mat::from_fn(rows, kk, |_, _| rng.normal_f32());
            let b = Mat::from_fn(kk, n, |_, _| rng.normal_f32());
            let got = a.gemm_nn(&b);
            let mut want = Mat::zeros(rows, n);
            for i in 0..rows {
                let ai = a.row(i);
                let orow = want.row_mut(i);
                for (k, &aik) in ai.iter().enumerate() {
                    for (yi, xi) in orow.iter_mut().zip(b.row(k)) {
                        *yi += aik * xi;
                    }
                }
            }
            for (x, y) in got.as_slice().iter().zip(want.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{rows}x{kk}x{n}");
            }
        }
    }
}
