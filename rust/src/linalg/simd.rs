//! Portable SIMD shim for the tile microkernels.
//!
//! Stable Rust has no `std::simd`, so the "vector type" here is a fixed
//! `[f32; LANES]` wrapper whose lane-wise ops are written in the shape LLVM
//! reliably turns into packed vector instructions at opt-level 2+. The
//! `scalar-fallback` cargo feature swaps the lane-wise ops for plain indexed
//! loops — same per-lane operations in the same order, so both builds are
//! bit-identical by construction (CI runs the full tier-1 suite under both).
//!
//! ## Accumulation-order contract
//!
//! Every length-n reduction in the microkernels (`dot`, the inner products
//! of `kernel_block`/`dist2_block`, the per-row dots of `matvec`) uses ONE
//! order, defined by [`crate::linalg::mat::dot`]:
//!
//! 1. two `F32x` accumulators walk `2·LANES`-wide chunks in index order
//!    (acc0 takes the even chunk of each pair, acc1 the odd);
//! 2. one trailing `LANES`-wide chunk, if present, folds into acc0;
//! 3. `(acc0 + acc1).hsum()` reduces lanes pairwise
//!    (`((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`);
//! 4. the scalar tail is added in index order.
//!
//! Register-blocked kernels may interleave several such reductions (sharing
//! operand loads across rows), but each individual reduction follows the
//! contract exactly, so a blocked kernel is bitwise equal to calling `dot`
//! per element. Element-wise ops (`axpy`, `gemm_nn`'s k-accumulation) have
//! no reduction and are bit-identical to their scalar forms trivially.
//! No FMA anywhere: `a + b * c` must round twice, like the scalar code.

/// Lane count of the portable vector type (256-bit f32 vectors).
pub const LANES: usize = 8;

/// Portable `f32 x LANES` vector. Plain data; all ops are by value.
#[derive(Clone, Copy, Debug)]
pub struct F32x(pub [f32; LANES]);

impl F32x {
    /// All lanes zero.
    #[inline(always)]
    pub fn zero() -> Self {
        F32x([0.0; LANES])
    }

    /// All lanes `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        F32x([v; LANES])
    }

    /// Load the first `LANES` elements of `s` (panics if shorter).
    #[inline(always)]
    pub fn load(s: &[f32]) -> Self {
        let mut a = [0.0f32; LANES];
        a.copy_from_slice(&s[..LANES]);
        F32x(a)
    }

    /// Store into the first `LANES` elements of `out`.
    #[inline(always)]
    pub fn store(self, out: &mut [f32]) {
        out[..LANES].copy_from_slice(&self.0);
    }

    /// Lane-wise addition.
    #[cfg(not(feature = "scalar-fallback"))]
    #[inline(always)]
    pub fn add(self, o: F32x) -> F32x {
        F32x(core::array::from_fn(|l| self.0[l] + o.0[l]))
    }

    /// Lane-wise addition (scalar reference path).
    #[cfg(feature = "scalar-fallback")]
    #[inline(always)]
    pub fn add(self, o: F32x) -> F32x {
        let mut r = [0.0f32; LANES];
        let mut l = 0;
        while l < LANES {
            r[l] = self.0[l] + o.0[l];
            l += 1;
        }
        F32x(r)
    }

    /// Lane-wise multiplication.
    #[cfg(not(feature = "scalar-fallback"))]
    #[inline(always)]
    pub fn mul(self, o: F32x) -> F32x {
        F32x(core::array::from_fn(|l| self.0[l] * o.0[l]))
    }

    /// Lane-wise multiplication (scalar reference path).
    #[cfg(feature = "scalar-fallback")]
    #[inline(always)]
    pub fn mul(self, o: F32x) -> F32x {
        let mut r = [0.0f32; LANES];
        let mut l = 0;
        while l < LANES {
            r[l] = self.0[l] * o.0[l];
            l += 1;
        }
        F32x(r)
    }

    /// Horizontal sum with a FIXED pairwise order (part of the accumulation
    /// contract — do not replace with a sequential fold).
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let a = self.0;
        ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_is_eight() {
        // hsum is written for 8 lanes; this pins the two together.
        assert_eq!(LANES, 8);
    }

    #[test]
    fn ops_are_lane_wise() {
        let a = F32x([1., 2., 3., 4., 5., 6., 7., 8.]);
        let b = F32x::splat(2.0);
        assert_eq!(a.add(b).0, [3., 4., 5., 6., 7., 8., 9., 10.]);
        assert_eq!(a.mul(b).0, [2., 4., 6., 8., 10., 12., 14., 16.]);
        assert_eq!(a.hsum(), 36.0);
    }

    #[test]
    fn load_store_round_trip() {
        let src = [1.0f32, 2., 3., 4., 5., 6., 7., 8., 99.];
        let v = F32x::load(&src);
        let mut dst = [0.0f32; 9];
        v.store(&mut dst);
        assert_eq!(&dst[..8], &src[..8]);
        assert_eq!(dst[8], 0.0);
    }

    #[test]
    fn hsum_is_pairwise_not_sequential() {
        // A vector crafted so pairwise and sequential summation round
        // differently in f32 — pins the documented reduction order.
        let v = F32x([1e8, 1.0, -1e8, 1.0, 0.5, 0.5, -0.25, -0.25]);
        let pairwise = ((1e8f32 + 1.0) + (-1e8 + 1.0)) + ((0.5 + 0.5) + (-0.25 + -0.25));
        assert_eq!(v.hsum().to_bits(), pairwise.to_bits());
    }
}
