//! # DKM — Distributed Kernel Machines
//!
//! A reproduction of *"A Distributed Algorithm for Training Nonlinear Kernel
//! Machines"* (Mahajan, Keerthi, Sundararajan, 2014) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper trains a nonlinear kernel machine through the Nyström
//! formulation
//!
//! ```text
//! min_β  f(β) = λ/2 βᵀWβ + L(Cβ, y)          (formulation (4))
//! ```
//!
//! solved with TRON (trust-region Newton), where the function / gradient /
//! Hessian-vector products are row-block matrix-vector products distributed
//! over `p` nodes and summed with an AllReduce tree.
//!
//! Layer map:
//! * [`cluster`] — the Hadoop-AllReduce substitute: worker nodes, a binary
//!   AllReduce tree, the `C + D·B` communication cost model of §4.4, and
//!   the pluggable **execution layer** ([`cluster::exec`]): node-local
//!   phases run on the deterministic serial loop, on OS worker threads
//!   spawned per phase (`--exec threads[:N]`), or on a persistent worker
//!   pool parked across phases (`--exec pool[:N]`), with bit-identical
//!   results.
//! * [`runtime`] — the `Send + Sync` tile-compute backends: pure-Rust
//!   native math (always built) and, behind the off-by-default `pjrt`
//!   cargo feature, the PJRT engine loading AOT artifacts (HLO text
//!   lowered from JAX+Pallas at build time).
//! * [`coordinator`] — the paper's contribution: Algorithm 1, TRON, losses,
//!   basis selection (random / distributed K-means), stage-wise growth —
//!   driven through the **stateful Session API**
//!   ([`coordinator::session`]): one `Session` owns the sharded cluster,
//!   backend, basis, β and metrics across calls (`solve`, `grow_basis`,
//!   `set_lambda`/`set_loss` re-solves, distributed metered `predict`,
//!   `model` snapshots with save/load persistence); the one-shot
//!   `train()`/`train_stagewise()` entry points are thin wrappers over it.
//!   Includes the **memory-bounded kernel-operator layer**
//!   ([`coordinator::cstore`]): each node's C row block lives behind a
//!   `CBlockStore` (`--c-storage materialized|streaming|streaming:rowbuf|
//!   auto`) that stores the kernel tiles (held once on native — prepared
//!   operands alias the host tiles), recomputes them per dispatch from the
//!   prepared feature/basis tiles (O(1 tile) of C per node; `rowbuf` adds
//!   a row-scoped scratch that halves the recompute for m > TM), or mixes
//!   the two under a byte budget — with bit-identical training output.
//! * [`baselines`] — formulation (3) (Zhang et al. linearization) and
//!   P-packSVM (Zhu et al.), the paper's comparators.
//! * [`serve`] — the serving loop: a bounded request queue with adaptive
//!   micro-batching (flush on max-batch or max-delay) in front of a
//!   prediction-only [`coordinator::serving::ServingSession`], driven by
//!   closed-loop clients and reported as qps + latency percentiles on
//!   both the wall clock and the simulated ledger.
//! * [`cluster::fault`] + [`trace`] + [`coordinator::checkpoint`] — the
//!   **resilience subsystem**: seeded deterministic phase-fault injection
//!   with bounded, ledger-charged retries (`--faults`/`--retries`);
//!   bit-identical mid-training checkpoint/resume of a whole `Session`
//!   (`--checkpoint-every`/`--resume`); and a phase trace
//!   recorder/replayer (`--trace`, `dkm trace`) that re-drives the
//!   simulated ledger exactly from a compact binary manifest.
//! * [`linalg`], [`rng`], [`data`], [`config`], [`metrics`] — substrates.

// Numeric tile code indexes several parallel buffers per loop and threads
// wide argument bundles through the hot path; these pedantic lints fight
// that idiom without making it clearer.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::type_complexity)]

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kmeans;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod trace;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
