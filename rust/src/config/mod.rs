//! Configuration substrate: a minimal JSON parser (for the artifact
//! manifest), a flat `key = value` config-file format for experiments, and
//! a CLI argument parser (no serde/clap offline).

pub mod args;
pub mod json;
pub mod settings;

pub use args::Args;
pub use json::Json;
pub use settings::Settings;
