//! Tiny CLI argument parser: `--key value`, `--key=value` and `--flag`
//! forms, with typed accessors and "unknown flag" validation against a
//! declared set (no clap offline).

use std::collections::BTreeMap;

use crate::Result;

#[derive(Debug, Default)]
pub struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminator: rest is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error if any flag is not in the allowed set (catches typos).
    pub fn validate(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                anyhow::bail!(
                    "unknown flag --{k}; allowed: {}",
                    allowed
                        .iter()
                        .map(|a| format!("--{a}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        Ok(())
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true" | "1" | "yes"))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_forms() {
        let a = args(&["--m", "1600", "--dataset=covtype_like", "--verbose"]);
        assert_eq!(a.usize_or("m", 0).unwrap(), 1600);
        assert_eq!(a.str_or("dataset", ""), "covtype_like");
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn positional_and_terminator() {
        let a = args(&["train", "--m", "8", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["train", "--not-a-flag"]);
        assert_eq!(a.usize_or("m", 0).unwrap(), 8);
    }

    #[test]
    fn validate_catches_typos() {
        let a = args(&["--mm", "1600"]);
        assert!(a.validate(&["m"]).is_err());
        assert!(a.validate(&["mm"]).is_ok());
    }

    #[test]
    fn typed_parse_errors() {
        let a = args(&["--m", "abc"]);
        assert!(a.usize_or("m", 0).is_err());
    }

    #[test]
    fn defaults_used_when_absent() {
        let a = args(&[]);
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
        assert_eq!(a.f32_or("lambda", 0.5).unwrap(), 0.5);
    }
}
