//! Minimal recursive-descent JSON parser — just enough for the artifact
//! manifest written by `python/compile/aot.py` (objects, arrays, strings,
//! numbers, booleans, null; UTF-8 input, `\uXXXX` escapes supported).

use std::collections::BTreeMap;
use std::fmt;

use crate::Result;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{s:?}"),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors (error with the key path for debuggability) ----

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing key {key:?}")),
            _ => anyhow::bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => anyhow::bail!("expected array, got {other}"),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => anyhow::bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow::anyhow!("bad codepoint {code}"))?,
                        );
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                },
                c if c < 0x20 => anyhow::bail!("raw control char in string"),
                c => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            anyhow::bail!("truncated UTF-8");
                        }
                        out.push_str(
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|e| anyhow::anyhow!("bad UTF-8: {e}"))?,
                        );
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow::anyhow!("bad number {text:?}: {e}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
 "version": 1, "tb": 256, "tm": 256, "ds": [32, 64],
 "modules": [{"name": "matvec", "file": "matvec.hlo.txt",
   "inputs": [{"shape": [256, 256], "dtype": "f32"}],
   "outputs": [{"shape": [256], "dtype": "f32"}]}]
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("tb").unwrap().as_usize().unwrap(), 256);
        let mods = v.get("modules").unwrap().as_arr().unwrap();
        assert_eq!(mods[0].get("name").unwrap().as_str().unwrap(), "matvec");
        let shape = mods[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 256);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"x", "{\"a\" 1}", "01x", "{} extra", "[1 2]"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
        assert_eq!(Json::parse("\"π\"").unwrap(), Json::Str("π".into()));
    }

    #[test]
    fn accessor_errors_are_descriptive() {
        let v = Json::parse("{}").unwrap();
        let err = v.get("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
        let err = Json::Num(1.5).as_usize().unwrap_err().to_string();
        assert!(err.contains("integer"));
    }
}
