//! Experiment settings: a typed bundle of everything a training run needs,
//! loadable from a flat `key = value` file (TOML-subset) and overridable
//! from CLI flags. This is the single config object threaded through the
//! launcher, trainer, and benches.

use std::collections::BTreeMap;
use std::path::Path;

use crate::cluster::{FaultPlan, RetryPolicy, Sched, Skew};
use crate::Result;

/// Which loss / kernel machine to train (paper §2: SVM, KLR, KRR).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// Squared hinge (L2-SVM) — the paper's running example.
    SqHinge,
    /// Logistic (kernel logistic regression).
    Logistic,
    /// Squared (kernel ridge regression).
    Squared,
}

impl Loss {
    pub fn parse(s: &str) -> Result<Loss> {
        match s {
            "sqhinge" => Ok(Loss::SqHinge),
            "logistic" => Ok(Loss::Logistic),
            "squared" => Ok(Loss::Squared),
            other => anyhow::bail!("unknown loss {other:?} (sqhinge|logistic|squared)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Loss::SqHinge => "sqhinge",
            Loss::Logistic => "logistic",
            Loss::Squared => "squared",
        }
    }
}

/// Basis selection policy (paper §3.2: K-means when m small, random else).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BasisSelection {
    Random,
    KMeans,
    /// The paper's adaptive policy: K-means below the threshold, random above.
    Auto,
}

impl BasisSelection {
    pub fn parse(s: &str) -> Result<BasisSelection> {
        match s {
            "random" => Ok(BasisSelection::Random),
            "kmeans" => Ok(BasisSelection::KMeans),
            "auto" => Ok(BasisSelection::Auto),
            other => anyhow::bail!("unknown basis selection {other:?} (random|kmeans|auto)"),
        }
    }
}

/// Execution layer for the simulated cluster's node-local phases
/// (see [`crate::cluster::exec`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorChoice {
    /// Deterministic single-thread loop (the metering reference).
    Serial,
    /// Scoped OS worker threads spawned per phase, one per logical node up
    /// to `cap` (`cap = 0` means "one per available core").
    Threads { cap: usize },
    /// Persistent worker pool: the same worker model as `Threads`, but the
    /// threads are parked once per cluster lifetime and reused by every
    /// phase — no per-phase spawn/join cost.
    Pool { cap: usize },
}

impl ExecutorChoice {
    pub fn parse(s: &str) -> Result<ExecutorChoice> {
        fn cap_of(n: &str) -> Result<usize> {
            let cap: usize = n
                .parse()
                .map_err(|e| anyhow::anyhow!("executor thread cap {n:?}: {e}"))?;
            if cap == 0 {
                anyhow::bail!("executor thread cap must be > 0");
            }
            Ok(cap)
        }
        match s {
            "serial" => Ok(ExecutorChoice::Serial),
            "threads" => Ok(ExecutorChoice::Threads { cap: 0 }),
            "pool" => Ok(ExecutorChoice::Pool { cap: 0 }),
            other => {
                if let Some(n) = other.strip_prefix("threads:") {
                    Ok(ExecutorChoice::Threads { cap: cap_of(n)? })
                } else if let Some(n) = other.strip_prefix("pool:") {
                    Ok(ExecutorChoice::Pool { cap: cap_of(n)? })
                } else {
                    anyhow::bail!(
                        "unknown executor {other:?} (serial|threads[:N]|pool[:N])"
                    )
                }
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            ExecutorChoice::Serial => "serial".to_string(),
            ExecutorChoice::Threads { cap: 0 } => "threads".to_string(),
            ExecutorChoice::Threads { cap } => format!("threads:{cap}"),
            ExecutorChoice::Pool { cap: 0 } => "pool".to_string(),
            ExecutorChoice::Pool { cap } => format!("pool:{cap}"),
        }
    }

    /// Resolve to a concrete cluster executor (`cap = 0` → core count).
    /// For `Pool` this spawns the persistent workers right here — once per
    /// cluster lifetime, not per phase.
    pub fn to_executor(self) -> crate::cluster::Executor {
        fn resolved(cap: usize) -> usize {
            if cap == 0 {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            } else {
                cap
            }
        }
        match self {
            ExecutorChoice::Serial => crate::cluster::Executor::serial(),
            ExecutorChoice::Threads { cap } => {
                crate::cluster::Executor::threaded(resolved(cap))
            }
            ExecutorChoice::Pool { cap } => crate::cluster::Executor::pooled(resolved(cap)),
        }
    }
}

/// How the TRON evaluations of step 4 drive the cluster (the
/// [`crate::coordinator::dist`] layer). Both pipelines are bit-identical;
/// only the barrier/round-trip count — and hence the simulated (and real)
/// latency — changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalPipeline {
    /// One fused phase per evaluation: node partials (scalars + gradient
    /// tiles packed into one flat buffer) are computed and tree-reduced
    /// inside a single dispatch — one barrier, one AllReduce round-trip.
    Fused,
    /// The paper's literal 4a/4b/4c call structure: a compute barrier,
    /// then separate scalar and m-vector AllReduces. Kept as the metering
    /// reference and for before/after comparisons.
    Split,
}

impl EvalPipeline {
    pub fn parse(s: &str) -> Result<EvalPipeline> {
        match s {
            "fused" => Ok(EvalPipeline::Fused),
            "split" => Ok(EvalPipeline::Split),
            other => anyhow::bail!("unknown eval pipeline {other:?} (fused|split)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvalPipeline::Fused => "fused",
            EvalPipeline::Split => "split",
        }
    }
}

/// Default BCD block size when `--solver bcd` is given without `:N`.
pub const BCD_DEFAULT_BLOCK: usize = 64;

/// Which master-side solver minimizes formulation (4) (the
/// [`crate::coordinator::solver`] layer). Both run on the same cluster
/// substrate and sim ledger; they trade communication rounds against
/// per-round progress differently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverChoice {
    /// Trust-region Newton on the master (the paper's Algorithm 1): one
    /// global step per round, full-β broadcast + m-vector AllReduce per
    /// evaluation.
    Tron,
    /// Distributed parallel block minimization (Hsieh et al.
    /// arXiv:1608.02010): one β column block of `block` coordinates per
    /// round, O(block)-float broadcast + AllReduce per round.
    Bcd { block: usize },
}

impl SolverChoice {
    pub fn parse(s: &str) -> Result<SolverChoice> {
        match s {
            "tron" => Ok(SolverChoice::Tron),
            "bcd" => Ok(SolverChoice::Bcd {
                block: BCD_DEFAULT_BLOCK,
            }),
            other => {
                if let Some(n) = other.strip_prefix("bcd:") {
                    let block: usize = n
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bcd block size {n:?}: {e}"))?;
                    if block == 0 {
                        anyhow::bail!("bcd block size must be > 0");
                    }
                    Ok(SolverChoice::Bcd { block })
                } else {
                    anyhow::bail!("unknown solver {other:?} (tron|bcd[:block])")
                }
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            SolverChoice::Tron => "tron".to_string(),
            SolverChoice::Bcd { block } => format!("bcd:{block}"),
        }
    }
}

/// How each node stores its kernel row block C_j (the
/// [`crate::coordinator::cstore`] layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CStorage {
    /// Fully materialized tiled C + prepared operands (fastest; O(n_j·m)
    /// bytes per node).
    Materialized,
    /// No stored C: every f/g/Hd dispatch recomputes its kernel tile from
    /// the prepared feature/basis tiles (O(1 tile) bytes per node).
    Streaming,
    /// Streaming plus a row-tile-scoped scratch of O(col_tiles) tiles: the
    /// tile recomputed for the matvec half of an evaluation is kept until
    /// the matvec_t half of the same evaluation consumes it, halving the
    /// streamed recompute for m > TM at bounded extra memory.
    StreamingRowbuf,
    /// Materialize row tiles while they fit `c_memory_budget`, stream the
    /// rest — memory becomes a dial instead of a cap.
    Auto,
}

impl CStorage {
    pub fn parse(s: &str) -> Result<CStorage> {
        match s {
            "materialized" => Ok(CStorage::Materialized),
            "streaming" => Ok(CStorage::Streaming),
            "streaming:rowbuf" => Ok(CStorage::StreamingRowbuf),
            "auto" => Ok(CStorage::Auto),
            other => {
                anyhow::bail!(
                    "unknown C storage {other:?} (materialized|streaming|streaming:rowbuf|auto)"
                )
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CStorage::Materialized => "materialized",
            CStorage::Streaming => "streaming",
            CStorage::StreamingRowbuf => "streaming:rowbuf",
            CStorage::Auto => "auto",
        }
    }
}

/// Parse a byte count with an optional k/m/g suffix ("512m", "64k", "2g").
pub fn parse_bytes(s: &str) -> Result<usize> {
    let t = s.trim().to_ascii_lowercase();
    let (body, mult) = if let Some(b) = t.strip_suffix('g') {
        (b, 1usize << 30)
    } else if let Some(b) = t.strip_suffix('m') {
        (b, 1usize << 20)
    } else if let Some(b) = t.strip_suffix('k') {
        (b, 1usize << 10)
    } else {
        (t.as_str(), 1usize)
    };
    let n: usize = body
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("byte count {s:?}: {e}"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte count {s:?} overflows"))
}

/// Compute backend for node-local block math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// PJRT: load AOT artifacts (JAX+Pallas lowered HLO) — the paper stack.
    Pjrt,
    /// Pure-Rust reference math; differential-tested against Pjrt.
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "pjrt" => Ok(Backend::Pjrt),
            "native" => Ok(Backend::Native),
            other => anyhow::bail!("unknown backend {other:?} (pjrt|native)"),
        }
    }
}

/// Full training-run settings.
#[derive(Clone, Debug)]
pub struct Settings {
    pub dataset: String,
    /// Number of basis points m.
    pub m: usize,
    /// Number of nodes p.
    pub nodes: usize,
    pub lambda: f32,
    pub sigma: f32,
    pub loss: Loss,
    pub basis: BasisSelection,
    pub backend: Backend,
    /// How node-local phases execute: serial loop or real worker threads.
    pub executor: ExecutorChoice,
    /// How phases are scheduled onto executor workers: static contiguous
    /// chunks (the metering reference) or work stealing via a shared claim
    /// cursor (`steal[:grain]`, where grain shapes only the simulated
    /// makespan model).
    pub sched: Sched,
    /// Simulated fleet heterogeneity: deterministic per-node speed
    /// multipliers applied by the ledger (`none`, `0=4,3=2`, `rand:max[:seed]`).
    pub skew: Skew,
    /// How each node stores its kernel row block C_j.
    pub c_storage: CStorage,
    /// Fused (one barrier + one AllReduce per TRON evaluation) or split
    /// (the paper's literal compute + 2-reduce sequence) evaluation
    /// pipeline — bit-identical results either way.
    pub eval_pipeline: EvalPipeline,
    /// Per-node byte budget for `CStorage::Auto` (materialize C row tiles
    /// while they fit, stream the rest).
    pub c_memory_budget: usize,
    /// Which master-side solver minimizes formulation (4).
    pub solver: SolverChoice,
    /// Solver-scoped outer-round cap: TRON iterations (paper: "typically
    /// around 300") or BCD block rounds.
    pub max_iters: usize,
    /// Solver-scoped relative stopping tolerance on the monitored gradient
    /// norm (TRON: ‖∇f‖; BCD: the sweep-aggregated block-gradient norm).
    pub tol: f32,
    pub seed: u64,
    /// K-means iterations for basis selection (paper Table 2 used 3).
    pub kmeans_iters: usize,
    /// m threshold below which Auto picks K-means.
    pub kmeans_max_m: usize,
    pub artifacts_dir: String,
    /// Injected phase faults (`none`, `node=J@phase=K,…`, or
    /// `rand:p[:seed]`) — the resilience subsystem's deterministic,
    /// seeded failure source (see [`crate::cluster::fault`]).
    pub faults: FaultPlan,
    /// Bounded retries per failed node task before the phase aborts.
    pub retries: u32,
    /// Simulated seconds charged to the phase's ledger step per retry
    /// (the relaunch cost a real cluster would pay).
    pub retry_backoff: f64,
    /// Write a resumable mid-training checkpoint every N solver rounds
    /// (0 = off). Each write atomically overwrites `checkpoint_path`.
    pub checkpoint_every: usize,
    /// Where the latest checkpoint lands.
    pub checkpoint_path: String,
    /// Record a phase trace from cluster birth (see [`crate::trace`]):
    /// every ledger-visible event becomes a replayable record. The CLI's
    /// `--trace PATH` / `dkm trace record` turn this on and save the
    /// manifest after the solve.
    pub trace: bool,
}

impl Default for Settings {
    fn default() -> Self {
        Settings {
            dataset: "covtype_like".into(),
            m: 400,
            nodes: 4,
            lambda: 0.005,
            sigma: 0.7,
            loss: Loss::SqHinge,
            basis: BasisSelection::Random,
            // The paper stack when compiled in; pure-Rust math otherwise
            // (a `--backend pjrt` request still errors helpfully).
            backend: if cfg!(feature = "pjrt") {
                Backend::Pjrt
            } else {
                Backend::Native
            },
            executor: ExecutorChoice::Serial,
            sched: Sched::Static,
            skew: Skew::None,
            c_storage: CStorage::Materialized,
            eval_pipeline: EvalPipeline::Fused,
            c_memory_budget: 256 << 20,
            solver: SolverChoice::Tron,
            max_iters: 300,
            tol: 1e-3,
            seed: 42,
            kmeans_iters: 3,
            kmeans_max_m: 2048,
            artifacts_dir: "artifacts".into(),
            faults: FaultPlan::none(),
            retries: RetryPolicy::default().max_retries,
            retry_backoff: RetryPolicy::default().backoff_secs,
            checkpoint_every: 0,
            checkpoint_path: "dkm.ckpt".into(),
            trace: false,
        }
    }
}

impl Settings {
    pub fn gamma(&self) -> f32 {
        1.0 / (2.0 * self.sigma * self.sigma)
    }

    /// Parse a flat `key = value` file (`#` comments, blank lines ok).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Settings> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.as_ref().display()))?;
        let mut kv = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            kv.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        let mut s = Settings::default();
        s.apply(&kv)?;
        Ok(s)
    }

    /// Apply string key/values (from file or CLI) onto the settings.
    pub fn apply(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            match k.as_str() {
                "dataset" => self.dataset = v.clone(),
                "m" => self.m = v.parse().map_err(|e| anyhow::anyhow!("m: {e}"))?,
                "nodes" => self.nodes = v.parse().map_err(|e| anyhow::anyhow!("nodes: {e}"))?,
                "lambda" => self.lambda = v.parse().map_err(|e| anyhow::anyhow!("lambda: {e}"))?,
                "sigma" => self.sigma = v.parse().map_err(|e| anyhow::anyhow!("sigma: {e}"))?,
                "loss" => self.loss = Loss::parse(v)?,
                "basis" => self.basis = BasisSelection::parse(v)?,
                "backend" => self.backend = Backend::parse(v)?,
                "executor" => self.executor = ExecutorChoice::parse(v)?,
                "sched" => self.sched = Sched::parse(v)?,
                "skew" => self.skew = Skew::parse(v)?,
                "c_storage" => self.c_storage = CStorage::parse(v)?,
                "eval_pipeline" => self.eval_pipeline = EvalPipeline::parse(v)?,
                "c_memory_budget" => self.c_memory_budget = parse_bytes(v)?,
                "solver" => self.solver = SolverChoice::parse(v)?,
                // "max_iters"/"tol" are the historical TRON-era spellings,
                // kept as aliases of the solver-scoped keys.
                "max_iters" | "solver_max_iters" => {
                    self.max_iters = v.parse().map_err(|e| anyhow::anyhow!("{k}: {e}"))?
                }
                "tol" | "solver_tol" => {
                    self.tol = v.parse().map_err(|e| anyhow::anyhow!("{k}: {e}"))?
                }
                "seed" => self.seed = v.parse().map_err(|e| anyhow::anyhow!("seed: {e}"))?,
                "kmeans_iters" => {
                    self.kmeans_iters =
                        v.parse().map_err(|e| anyhow::anyhow!("kmeans_iters: {e}"))?
                }
                "kmeans_max_m" => {
                    self.kmeans_max_m =
                        v.parse().map_err(|e| anyhow::anyhow!("kmeans_max_m: {e}"))?
                }
                "artifacts_dir" => self.artifacts_dir = v.clone(),
                "faults" => self.faults = FaultPlan::parse(v)?,
                "retries" => {
                    self.retries = v.parse().map_err(|e| anyhow::anyhow!("retries: {e}"))?
                }
                "retry_backoff" => {
                    self.retry_backoff =
                        v.parse().map_err(|e| anyhow::anyhow!("retry_backoff: {e}"))?
                }
                "checkpoint_every" => {
                    self.checkpoint_every =
                        v.parse().map_err(|e| anyhow::anyhow!("checkpoint_every: {e}"))?
                }
                "checkpoint_path" => self.checkpoint_path = v.clone(),
                "trace" => {
                    self.trace = v.parse().map_err(|e| anyhow::anyhow!("trace: {e}"))?
                }
                other => anyhow::bail!("unknown setting {other:?}"),
            }
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.m == 0 {
            anyhow::bail!("m must be > 0");
        }
        if self.nodes == 0 {
            anyhow::bail!("nodes must be > 0");
        }
        if self.lambda <= 0.0 {
            anyhow::bail!("lambda must be > 0");
        }
        if self.sigma <= 0.0 {
            anyhow::bail!("sigma must be > 0");
        }
        if !(self.retry_backoff >= 0.0) {
            anyhow::bail!("retry_backoff must be >= 0");
        }
        if self.checkpoint_every > 0 && self.checkpoint_path.is_empty() {
            anyhow::bail!("checkpoint_every needs a checkpoint_path");
        }
        Ok(())
    }

    /// The retry policy the fault-injection settings resolve to.
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy {
            max_retries: self.retries,
            backoff_secs: self.retry_backoff,
        }
    }

    /// Load the per-dataset hyper-parameters from the Table-3 specs.
    pub fn with_dataset_defaults(mut self, name: &str) -> Settings {
        let spec = crate::data::synth::spec(name);
        self.dataset = name.to_string();
        self.lambda = spec.lambda;
        self.sigma = spec.sigma;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        Settings::default().validate().unwrap();
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("dkm_settings_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.conf");
        std::fs::write(
            &path,
            "# experiment\ndataset = vehicle_like\nm = 1600\nloss = logistic\nbackend = native\nsigma = 2.0\n",
        )
        .unwrap();
        let s = Settings::from_file(&path).unwrap();
        assert_eq!(s.dataset, "vehicle_like");
        assert_eq!(s.m, 1600);
        assert_eq!(s.loss, Loss::Logistic);
        assert_eq!(s.backend, Backend::Native);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("bogus".to_string(), "1".to_string());
        assert!(s.apply(&kv).is_err());
        let mut kv = BTreeMap::new();
        kv.insert("m".to_string(), "zero".to_string());
        assert!(s.apply(&kv).is_err());
        let mut kv = BTreeMap::new();
        kv.insert("m".to_string(), "0".to_string());
        assert!(s.apply(&kv).is_err());
    }

    #[test]
    fn executor_parse_forms() {
        assert_eq!(
            ExecutorChoice::parse("serial").unwrap(),
            ExecutorChoice::Serial
        );
        assert_eq!(
            ExecutorChoice::parse("threads").unwrap(),
            ExecutorChoice::Threads { cap: 0 }
        );
        assert_eq!(
            ExecutorChoice::parse("threads:8").unwrap(),
            ExecutorChoice::Threads { cap: 8 }
        );
        assert!(ExecutorChoice::parse("threads:0").is_err());
        assert!(ExecutorChoice::parse("threads:x").is_err());
        assert!(ExecutorChoice::parse("fibers").is_err());
        assert_eq!(ExecutorChoice::Threads { cap: 8 }.name(), "threads:8");
        assert_eq!(ExecutorChoice::Threads { cap: 0 }.name(), "threads");
    }

    #[test]
    fn pool_executor_parse_forms() {
        assert_eq!(
            ExecutorChoice::parse("pool").unwrap(),
            ExecutorChoice::Pool { cap: 0 }
        );
        assert_eq!(
            ExecutorChoice::parse("pool:6").unwrap(),
            ExecutorChoice::Pool { cap: 6 }
        );
        assert!(ExecutorChoice::parse("pool:0").is_err());
        assert!(ExecutorChoice::parse("pool:x").is_err());
        assert_eq!(ExecutorChoice::Pool { cap: 6 }.name(), "pool:6");
        assert_eq!(ExecutorChoice::Pool { cap: 0 }.name(), "pool");
        assert_eq!(ExecutorChoice::Pool { cap: 3 }.to_executor().name(), "pool:3");
    }

    #[test]
    fn executor_setting_applies_from_kv() {
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("executor".to_string(), "threads:4".to_string());
        s.apply(&kv).unwrap();
        assert_eq!(s.executor, ExecutorChoice::Threads { cap: 4 });
        let mut kv = BTreeMap::new();
        kv.insert("executor".to_string(), "coroutines".to_string());
        assert!(s.apply(&kv).is_err());
    }

    #[test]
    fn sched_and_skew_settings_apply_from_kv() {
        let s = Settings::default();
        assert_eq!(s.sched, Sched::Static);
        assert_eq!(s.skew, Skew::None);
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("sched".to_string(), "steal:2".to_string());
        kv.insert("skew".to_string(), "0=4".to_string());
        s.apply(&kv).unwrap();
        assert_eq!(s.sched, Sched::Steal { grain: 2 });
        assert_eq!(s.skew.multiplier(0), 4.0);
        assert_eq!(s.skew.multiplier(1), 1.0);
        let mut kv = BTreeMap::new();
        kv.insert("sched".to_string(), "fifo".to_string());
        assert!(s.apply(&kv).is_err());
        let mut kv = BTreeMap::new();
        kv.insert("skew".to_string(), "0=0.25".to_string());
        assert!(s.apply(&kv).is_err());
    }

    #[test]
    fn c_storage_parse_and_apply() {
        assert_eq!(
            CStorage::parse("materialized").unwrap(),
            CStorage::Materialized
        );
        assert_eq!(CStorage::parse("streaming").unwrap(), CStorage::Streaming);
        assert_eq!(
            CStorage::parse("streaming:rowbuf").unwrap(),
            CStorage::StreamingRowbuf
        );
        assert_eq!(CStorage::parse("auto").unwrap(), CStorage::Auto);
        assert!(CStorage::parse("mmap").is_err());
        assert!(CStorage::parse("streaming:colbuf").is_err());
        assert_eq!(CStorage::Streaming.name(), "streaming");
        assert_eq!(CStorage::StreamingRowbuf.name(), "streaming:rowbuf");
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("c_storage".to_string(), "streaming".to_string());
        kv.insert("c_memory_budget".to_string(), "64m".to_string());
        s.apply(&kv).unwrap();
        assert_eq!(s.c_storage, CStorage::Streaming);
        assert_eq!(s.c_memory_budget, 64 << 20);
    }

    #[test]
    fn eval_pipeline_parse_and_apply() {
        assert_eq!(EvalPipeline::parse("fused").unwrap(), EvalPipeline::Fused);
        assert_eq!(EvalPipeline::parse("split").unwrap(), EvalPipeline::Split);
        assert!(EvalPipeline::parse("turbo").is_err());
        assert_eq!(EvalPipeline::Fused.name(), "fused");
        assert_eq!(Settings::default().eval_pipeline, EvalPipeline::Fused);
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("eval_pipeline".to_string(), "split".to_string());
        s.apply(&kv).unwrap();
        assert_eq!(s.eval_pipeline, EvalPipeline::Split);
    }

    #[test]
    fn solver_parse_and_apply() {
        assert_eq!(SolverChoice::parse("tron").unwrap(), SolverChoice::Tron);
        assert_eq!(
            SolverChoice::parse("bcd").unwrap(),
            SolverChoice::Bcd {
                block: BCD_DEFAULT_BLOCK
            }
        );
        assert_eq!(
            SolverChoice::parse("bcd:32").unwrap(),
            SolverChoice::Bcd { block: 32 }
        );
        assert!(SolverChoice::parse("bcd:0").is_err());
        assert!(SolverChoice::parse("bcd:x").is_err());
        assert!(SolverChoice::parse("lbfgs").is_err());
        assert_eq!(SolverChoice::Tron.name(), "tron");
        assert_eq!(SolverChoice::Bcd { block: 32 }.name(), "bcd:32");
        assert_eq!(Settings::default().solver, SolverChoice::Tron);
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("solver".to_string(), "bcd:16".to_string());
        s.apply(&kv).unwrap();
        assert_eq!(s.solver, SolverChoice::Bcd { block: 16 });
    }

    #[test]
    fn solver_scoped_knobs_alias_old_spellings() {
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("solver_max_iters".to_string(), "77".to_string());
        kv.insert("solver_tol".to_string(), "0.05".to_string());
        s.apply(&kv).unwrap();
        assert_eq!(s.max_iters, 77);
        assert_eq!(s.tol, 0.05);
        // Old spellings still land on the same fields.
        let mut kv = BTreeMap::new();
        kv.insert("max_iters".to_string(), "11".to_string());
        kv.insert("tol".to_string(), "0.5".to_string());
        s.apply(&kv).unwrap();
        assert_eq!(s.max_iters, 11);
        assert_eq!(s.tol, 0.5);
    }

    #[test]
    fn byte_counts_parse_with_suffixes() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("8k").unwrap(), 8 << 10);
        assert_eq!(parse_bytes("512M").unwrap(), 512 << 20);
        assert_eq!(parse_bytes("2g").unwrap(), 2 << 30);
        assert!(parse_bytes("lots").is_err());
        // Parses as a number but overflows usize once the suffix applies.
        assert!(parse_bytes("99999999999g").is_err());
    }

    #[test]
    fn resilience_settings_apply_from_kv() {
        let s = Settings::default();
        assert!(s.faults.is_empty());
        assert_eq!(s.checkpoint_every, 0);
        let mut s = Settings::default();
        let mut kv = BTreeMap::new();
        kv.insert("faults".to_string(), "node=1@phase=3".to_string());
        kv.insert("retries".to_string(), "5".to_string());
        kv.insert("retry_backoff".to_string(), "0.25".to_string());
        kv.insert("checkpoint_every".to_string(), "4".to_string());
        kv.insert("checkpoint_path".to_string(), "run.ckpt".to_string());
        s.apply(&kv).unwrap();
        assert!(!s.faults.is_empty());
        assert_eq!(s.retry_policy().max_retries, 5);
        assert_eq!(s.retry_policy().backoff_secs, 0.25);
        assert_eq!(s.checkpoint_every, 4);
        assert_eq!(s.checkpoint_path, "run.ckpt");
        let mut kv = BTreeMap::new();
        kv.insert("faults".to_string(), "node=@".to_string());
        assert!(s.apply(&kv).is_err());
        let mut kv = BTreeMap::new();
        kv.insert("retry_backoff".to_string(), "-1.0".to_string());
        assert!(s.apply(&kv).is_err());
    }

    #[test]
    fn gamma_matches_sigma() {
        let s = Settings {
            sigma: 2.0,
            ..Settings::default()
        };
        assert!((s.gamma() - 0.125).abs() < 1e-7);
    }

    #[test]
    fn dataset_defaults_pull_spec() {
        let s = Settings::default().with_dataset_defaults("vehicle_like");
        assert_eq!(s.lambda, 8.0);
        assert_eq!(s.sigma, 2.0);
    }
}
