//! Simulated-time ledger: per-step compute and communication seconds.
//!
//! `compute` entries are MEASURED single-node wall times (max over nodes per
//! phase — the synchronous bulk model); `comm` entries come from the
//! `C + D·B` cost model. Their sum is the simulated end-to-end time a run
//! would take on a real p-node cluster with those link parameters, which is
//! what the Fig-2 speed-up plots sweep.

use std::collections::BTreeMap;

use super::cost::CostModel;
use super::tree::Tree;
use crate::metrics::Step;

#[derive(Clone, Debug, PartialEq)]
pub struct SimClock {
    cost: CostModel,
    compute: BTreeMap<Step, f64>,
    comm: BTreeMap<Step, f64>,
    comm_instances: u64,
    comm_bytes: u64,
    recompute_flops: u64,
    barriers: u64,
    reduce_round_trips: u64,
    dispatches: u64,
    /// Injected task deaths observed (every fault-plan fire, including
    /// the ones a retry later recovered).
    faults: u64,
    /// Task re-launches after injected deaths; each one charged
    /// `RetryPolicy::backoff_secs` of simulated wall to its phase.
    retries: u64,
    /// Σ over phases of the slowest node's (skew-scaled) compute seconds —
    /// the barrier-synchronized wall a static schedule pays.
    max_node_secs: f64,
    /// Σ over phases of ALL nodes' (skew-scaled) compute seconds — the
    /// total useful work; `max·p / sum` is the straggler ratio.
    sum_node_secs: f64,
}

/// A plain-data image of a [`SimClock`] — every counter and the per-step
/// second series with f64 bits preserved — so a checkpoint can freeze a
/// mid-training ledger and resume restores it exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct ClockSnapshot {
    pub cost: CostModel,
    pub compute: Vec<(Step, f64)>,
    pub comm: Vec<(Step, f64)>,
    pub comm_instances: u64,
    pub comm_bytes: u64,
    pub recompute_flops: u64,
    pub barriers: u64,
    pub reduce_round_trips: u64,
    pub dispatches: u64,
    pub faults: u64,
    pub retries: u64,
    pub max_node_secs: f64,
    pub sum_node_secs: f64,
}

impl SimClock {
    pub fn new(cost: CostModel) -> Self {
        SimClock {
            cost,
            compute: BTreeMap::new(),
            comm: BTreeMap::new(),
            comm_instances: 0,
            comm_bytes: 0,
            recompute_flops: 0,
            barriers: 0,
            reduce_round_trips: 0,
            dispatches: 0,
            faults: 0,
            retries: 0,
            max_node_secs: 0.0,
            sum_node_secs: 0.0,
        }
    }

    pub fn cost(&self) -> CostModel {
        self.cost
    }

    /// Freeze the whole ledger into plain data (f64 bits preserved).
    pub fn snapshot(&self) -> ClockSnapshot {
        ClockSnapshot {
            cost: self.cost,
            compute: self.compute.iter().map(|(s, v)| (*s, *v)).collect(),
            comm: self.comm.iter().map(|(s, v)| (*s, *v)).collect(),
            comm_instances: self.comm_instances,
            comm_bytes: self.comm_bytes,
            recompute_flops: self.recompute_flops,
            barriers: self.barriers,
            reduce_round_trips: self.reduce_round_trips,
            dispatches: self.dispatches,
            faults: self.faults,
            retries: self.retries,
            max_node_secs: self.max_node_secs,
            sum_node_secs: self.sum_node_secs,
        }
    }

    /// Rebuild a clock from a [`ClockSnapshot`] — the bitwise inverse of
    /// [`SimClock::snapshot`] (checkpoint resume's ledger restore).
    pub fn from_snapshot(s: &ClockSnapshot) -> SimClock {
        SimClock {
            cost: s.cost,
            compute: s.compute.iter().cloned().collect(),
            comm: s.comm.iter().cloned().collect(),
            comm_instances: s.comm_instances,
            comm_bytes: s.comm_bytes,
            recompute_flops: s.recompute_flops,
            barriers: s.barriers,
            reduce_round_trips: s.reduce_round_trips,
            dispatches: s.dispatches,
            faults: s.faults,
            retries: s.retries,
            max_node_secs: s.max_node_secs,
            sum_node_secs: s.sum_node_secs,
        }
    }

    pub fn add_compute(&mut self, step: Step, secs: f64) {
        *self.compute.entry(step).or_default() += secs;
    }

    /// `rounds` sequential tree levels, each one communication instance of
    /// `bytes` (edges within a level run in parallel). This is the
    /// low-level one-way meter (broadcast/gather legs) and feeds
    /// [`SimClock::comm_instances`] — NOT [`SimClock::comm_rounds`], which
    /// counts whole collectives. Price a reduce through
    /// [`SimClock::add_reduce`] so it is counted as a round-trip.
    pub fn add_comm_rounds(&mut self, step: Step, rounds: usize, bytes: usize) {
        let secs = rounds as f64 * self.cost.instance(bytes);
        *self.comm.entry(step).or_default() += secs;
        self.comm_instances += rounds as u64;
        self.comm_bytes += (rounds * bytes) as u64;
    }

    /// Broadcast `bytes` from the root down `tree` (one instance per
    /// level; edges within a level run in parallel).
    pub fn meter_broadcast(&mut self, step: Step, tree: &Tree, bytes: usize) {
        self.add_comm_rounds(step, tree.depth(), bytes);
    }

    /// Gather `bytes_per_node` up `tree`. A level-l edge carries its
    /// sender's whole gathered subtree, and edges within a level run in
    /// parallel — so each level is priced as ONE instance of the LARGEST
    /// subtree transiting it, not the full p-node concatenation. A scatter
    /// (root shipping each node its own shard, e.g. a serving batch's rows
    /// fanning out) transits the same per-level volumes in the opposite
    /// direction, so it is priced through this same meter.
    pub fn meter_gather(&mut self, step: Step, tree: &Tree, bytes_per_node: usize) {
        for level in 1..=tree.depth() {
            let bytes = bytes_per_node * tree.max_subtree_at_level(level);
            self.add_comm_rounds(step, 1, bytes);
        }
    }

    /// Fold another ledger into this one: per-step compute/comm series and
    /// every counter are summed. Used to combine a session's training
    /// ledger with its interior-mutable predict meter into one cumulative
    /// view; the cost model stays `self`'s (both sides of such a fold are
    /// built from the same model).
    pub fn merge(&mut self, other: &SimClock) {
        for (s, v) in &other.compute {
            *self.compute.entry(*s).or_default() += v;
        }
        for (s, v) in &other.comm {
            *self.comm.entry(*s).or_default() += v;
        }
        self.comm_instances += other.comm_instances;
        self.comm_bytes += other.comm_bytes;
        self.recompute_flops += other.recompute_flops;
        self.barriers += other.barriers;
        self.reduce_round_trips += other.reduce_round_trips;
        self.dispatches += other.dispatches;
        self.faults += other.faults;
        self.retries += other.retries;
        self.max_node_secs += other.max_node_secs;
        self.sum_node_secs += other.sum_node_secs;
    }

    pub fn compute_secs(&self, step: Step) -> f64 {
        self.compute.get(&step).copied().unwrap_or(0.0)
    }

    pub fn comm_secs(&self, step: Step) -> f64 {
        self.comm.get(&step).copied().unwrap_or(0.0)
    }

    pub fn step_secs(&self, step: Step) -> f64 {
        self.compute_secs(step) + self.comm_secs(step)
    }

    pub fn total_secs(&self) -> f64 {
        Step::all().iter().map(|s| self.step_secs(*s)).sum()
    }

    /// The paper's "Other time" (Fig 2): every Algorithm-1 step except
    /// TRON (the shared [`Step::is_other`] predicate, so this can never
    /// diverge from the wall-clock series). `Predict` is not an
    /// Algorithm-1 step (it is reported separately), so it is excluded
    /// rather than silently folded in by a `total - tron` subtraction.
    pub fn other_secs(&self) -> f64 {
        Step::all()
            .iter()
            .filter(|s| s.is_other())
            .map(|s| self.step_secs(*s))
            .sum()
    }

    pub fn comm_instances(&self) -> u64 {
        self.comm_instances
    }

    pub fn comm_bytes(&self) -> u64 {
        self.comm_bytes
    }

    /// Count one global synchronization point: a dispatched compute phase
    /// or a collective. The fused compute+reduce path is one barrier where
    /// the split path is a compute barrier plus one per reduction — this
    /// counter is what makes that saving observable.
    pub fn add_barrier(&mut self) {
        self.barriers += 1;
    }

    /// Global synchronization points so far (phases + collectives).
    pub fn barriers(&self) -> u64 {
        self.barriers
    }

    /// Meter one full tree-reduce round-trip (`rounds` sequential levels —
    /// up pass + down pass — of a `bytes` buffer) and count it toward
    /// [`SimClock::comm_rounds`]. One-way broadcast/gather metering goes
    /// through [`SimClock::add_comm_rounds`] directly and is NOT a
    /// round-trip.
    pub fn add_reduce(&mut self, step: Step, rounds: usize, bytes: usize) {
        self.add_comm_rounds(step, rounds, bytes);
        self.reduce_round_trips += 1;
    }

    /// AllReduce round-trips issued (an up+down tree pass counts as ONE;
    /// a zero-depth single-node tree still counts its collective). The
    /// fused evaluation pipeline drops this from 2 to 1 per f/g
    /// evaluation.
    pub fn comm_rounds(&self) -> u64 {
        self.reduce_round_trips
    }

    /// Count backend dispatches issued inside TRON evaluation phases (the
    /// `Compute` call-count delta around each f/g and Hd phase). With the
    /// whole-node block ops this is exactly ONE per node per evaluation on
    /// the native backend, independent of how many (row × column) tiles
    /// the node holds.
    pub fn add_dispatches(&mut self, n: u64) {
        self.dispatches += n;
    }

    /// Backend dispatches issued inside TRON evaluation phases so far.
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Record injected task deaths (fault-plan fires), recovered or not.
    pub fn add_faults(&mut self, n: u64) {
        self.faults += n;
    }

    /// Injected task deaths observed so far.
    pub fn faults(&self) -> u64 {
        self.faults
    }

    /// Record task re-launches after injected deaths. The backoff seconds
    /// those re-launches cost are charged separately through
    /// [`SimClock::add_compute`] on the phase's step, so the ledger's
    /// time and this count stay independently auditable.
    pub fn add_retries(&mut self, n: u64) {
        self.retries += n;
    }

    /// Task re-launches after injected deaths so far.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Charge extra FLOPs spent recomputing kernel tiles (the streaming
    /// C-storage tradeoff). The *time* of those FLOPs is already inside the
    /// measured per-phase compute; this keeps the count visible so benches
    /// can show memory-vs-compute honestly.
    pub fn add_recompute_flops(&mut self, flops: u64) {
        self.recompute_flops += flops;
    }

    pub fn recompute_flops(&self) -> u64 {
        self.recompute_flops
    }

    /// Record one compute phase's straggler observables: the slowest
    /// node's (skew-scaled) seconds and the sum over all nodes. These are
    /// accumulated separately from the charged wall so the ledger can show
    /// both what the straggler bound cost and how much of it a scheduler
    /// recovered (see `cost::phase_wall`).
    pub fn add_straggler(&mut self, max_node: f64, sum_nodes: f64) {
        self.max_node_secs += max_node;
        self.sum_node_secs += sum_nodes;
    }

    /// Σ over phases of the slowest node's compute seconds (the static
    /// straggler bound).
    pub fn max_node_secs(&self) -> f64 {
        self.max_node_secs
    }

    /// Σ over phases of all nodes' compute seconds (total useful work).
    pub fn sum_node_secs(&self) -> f64 {
        self.sum_node_secs
    }

    /// Straggler ratio on a `p`-node fleet: slowest-node bound over the
    /// perfectly-balanced wall (`max·p / sum`). 1.0 = no idle time; a 4×
    /// single-node skew at p=8 yields ≈ 2.9. Returns 1.0 before any
    /// compute has been recorded.
    pub fn straggler_ratio(&self, p: usize) -> f64 {
        if self.sum_node_secs <= 0.0 || p == 0 {
            return 1.0;
        }
        self.max_node_secs * p as f64 / self.sum_node_secs
    }

    /// Render a per-step breakdown (Table-4 style).
    pub fn report(&self) -> String {
        let mut t = crate::metrics::Table::new(&["step", "compute_s", "comm_s", "total_s"]);
        for s in Step::all() {
            if self.step_secs(s) > 0.0 {
                t.row(&[
                    s.name().to_string(),
                    format!("{:.4}", self.compute_secs(s)),
                    format!("{:.4}", self.comm_secs(s)),
                    format!("{:.4}", self.step_secs(s)),
                ]);
            }
        }
        let mut out = t.render();
        if self.recompute_flops > 0 {
            out.push_str(&format!(
                "streaming C recompute: {:.3} GFLOP (inside the compute column)\n",
                self.recompute_flops as f64 / 1e9
            ));
        }
        if self.sum_node_secs > 0.0 {
            out.push_str(&format!(
                "straggler bound: {:.4}s slowest-node wall over {:.4}s total node work\n",
                self.max_node_secs, self.sum_node_secs
            ));
        }
        if self.faults > 0 {
            out.push_str(&format!(
                "resilience: {} injected task deaths, {} re-launches (backoff inside the compute column)\n",
                self.faults, self.retries
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_by_step() {
        let mut c = SimClock::new(CostModel {
            latency_s: 0.5,
            per_byte_s: 0.0,
        });
        c.add_compute(Step::Kernel, 2.0);
        c.add_compute(Step::Kernel, 1.0);
        c.add_comm_rounds(Step::Tron, 4, 100);
        assert!((c.compute_secs(Step::Kernel) - 3.0).abs() < 1e-12);
        assert!((c.comm_secs(Step::Tron) - 2.0).abs() < 1e-12);
        assert!((c.total_secs() - 5.0).abs() < 1e-12);
        assert!((c.other_secs() - 3.0).abs() < 1e-12);
        assert_eq!(c.comm_instances(), 4);
        assert_eq!(c.comm_bytes(), 400);
    }

    #[test]
    fn other_secs_excludes_predict() {
        let mut c = SimClock::new(CostModel::free());
        c.add_compute(Step::Kernel, 2.0);
        c.add_compute(Step::Tron, 3.0);
        c.add_compute(Step::Predict, 7.0);
        assert!((c.other_secs() - 2.0).abs() < 1e-12, "{}", c.other_secs());
        assert!((c.total_secs() - 12.0).abs() < 1e-12);
    }

    #[test]
    fn report_lists_active_steps() {
        let mut c = SimClock::new(CostModel::free());
        c.add_compute(Step::Load, 1.0);
        let r = c.report();
        assert!(r.contains("load"));
        assert!(!r.contains("predict"));
        assert!(!r.contains("recompute"));
    }

    #[test]
    fn barriers_and_reduce_round_trips_count_separately() {
        let mut c = SimClock::new(CostModel::free());
        assert_eq!(c.barriers(), 0);
        assert_eq!(c.comm_rounds(), 0);
        c.add_barrier();
        c.add_reduce(Step::Tron, 4, 64);
        c.add_comm_rounds(Step::Tron, 2, 8); // one-way: no round-trip
        assert_eq!(c.barriers(), 1);
        assert_eq!(c.comm_rounds(), 1);
        assert_eq!(c.comm_instances(), 6);
        assert_eq!(c.comm_bytes(), 4 * 64 + 2 * 8);
    }

    #[test]
    fn dispatches_accumulate() {
        let mut c = SimClock::new(CostModel::free());
        assert_eq!(c.dispatches(), 0);
        c.add_dispatches(3);
        c.add_dispatches(2);
        assert_eq!(c.dispatches(), 5);
    }

    #[test]
    fn merge_folds_series_and_counters() {
        let mut a = SimClock::new(CostModel::free());
        a.add_compute(Step::Tron, 2.0);
        a.add_barrier();
        a.add_dispatches(3);
        let mut b = SimClock::new(CostModel {
            latency_s: 1.0,
            per_byte_s: 0.0,
        });
        b.add_compute(Step::Tron, 1.0);
        b.add_compute(Step::Predict, 4.0);
        b.add_comm_rounds(Step::Predict, 2, 8);
        b.add_reduce(Step::Tron, 1, 4);
        b.add_barrier();
        b.add_barrier();
        b.add_recompute_flops(10);
        a.merge(&b);
        assert!((a.compute_secs(Step::Tron) - 3.0).abs() < 1e-12);
        assert!((a.compute_secs(Step::Predict) - 4.0).abs() < 1e-12);
        assert!((a.comm_secs(Step::Predict) - 2.0).abs() < 1e-12);
        assert_eq!(a.barriers(), 3);
        assert_eq!(a.comm_rounds(), 1);
        assert_eq!(a.comm_instances(), 3);
        assert_eq!(a.comm_bytes(), 2 * 8 + 4);
        assert_eq!(a.dispatches(), 3);
        assert_eq!(a.recompute_flops(), 10);
    }

    #[test]
    fn tree_meters_match_cluster_pricing() {
        // Same p=4 binary-tree shape as the Cluster::gather_meter test:
        // levels carry max-subtrees of 2 then 1 nodes.
        let tree = Tree::new(4, 2);
        let cost = CostModel {
            latency_s: 0.5,
            per_byte_s: 1e-2,
        };
        let mut c = SimClock::new(cost);
        c.meter_gather(Step::Predict, &tree, 100);
        let want = (0.5 + 200.0 * 1e-2) + (0.5 + 100.0 * 1e-2);
        assert!((c.comm_secs(Step::Predict) - want).abs() < 1e-12);
        let mut b = SimClock::new(cost);
        b.meter_broadcast(Step::Predict, &tree, 100);
        assert_eq!(b.comm_instances(), tree.depth() as u64);
        assert_eq!(b.comm_bytes(), 100 * tree.depth() as u64);
    }

    #[test]
    fn straggler_observables_accumulate_merge_and_ratio() {
        let mut c = SimClock::new(CostModel::free());
        assert_eq!(c.straggler_ratio(8), 1.0, "no compute yet");
        // Two phases on p=8 with a 4× single-node skew: max 4c, sum 11c.
        c.add_straggler(4.0, 11.0);
        c.add_straggler(4.0, 11.0);
        assert!((c.max_node_secs() - 8.0).abs() < 1e-12);
        assert!((c.sum_node_secs() - 22.0).abs() < 1e-12);
        assert!((c.straggler_ratio(8) - 8.0 * 8.0 / 22.0).abs() < 1e-12);
        let mut d = SimClock::new(CostModel::free());
        d.add_straggler(1.0, 8.0);
        c.merge(&d);
        assert!((c.max_node_secs() - 9.0).abs() < 1e-12);
        assert!((c.sum_node_secs() - 30.0).abs() < 1e-12);
        assert!(c.report().contains("straggler bound"));
    }

    #[test]
    fn resilience_counters_accumulate_merge_and_report() {
        let mut c = SimClock::new(CostModel::free());
        assert_eq!(c.faults(), 0);
        assert_eq!(c.retries(), 0);
        assert!(!c.report().contains("resilience"));
        c.add_faults(3);
        c.add_retries(2);
        let mut d = SimClock::new(CostModel::free());
        d.add_faults(1);
        d.add_retries(1);
        c.merge(&d);
        assert_eq!(c.faults(), 4);
        assert_eq!(c.retries(), 3);
        assert!(c.report().contains("4 injected task deaths"), "{}", c.report());
    }

    #[test]
    fn snapshot_round_trips_bitwise() {
        let mut c = SimClock::new(CostModel {
            latency_s: 0.01,
            per_byte_s: 1e-8,
        });
        c.add_compute(Step::Kernel, 1.0 / 3.0);
        c.add_compute(Step::Tron, 0.1234567891234);
        c.add_reduce(Step::Tron, 4, 640);
        c.add_comm_rounds(Step::KMeans, 2, 32);
        c.add_barrier();
        c.add_dispatches(7);
        c.add_recompute_flops(99);
        c.add_faults(2);
        c.add_retries(1);
        c.add_straggler(0.5, 1.75);
        let restored = SimClock::from_snapshot(&c.snapshot());
        assert_eq!(c, restored);
        assert_eq!(
            c.compute_secs(Step::Tron).to_bits(),
            restored.compute_secs(Step::Tron).to_bits()
        );
        assert_eq!(
            c.comm_secs(Step::Tron).to_bits(),
            restored.comm_secs(Step::Tron).to_bits()
        );
    }

    #[test]
    fn recompute_flops_accumulate_and_report() {
        let mut c = SimClock::new(CostModel::free());
        c.add_recompute_flops(1_500_000_000);
        c.add_recompute_flops(500_000_000);
        assert_eq!(c.recompute_flops(), 2_000_000_000);
        assert!(c.report().contains("2.000 GFLOP"));
    }
}
