//! The paper's communication cost model (§4.4): "Let us write one instance
//! communication cost in the form C + DB where C is communication latency,
//! D is the cost of communication per byte after leaving out latency, and B
//! is the number of bytes transferred." — plus the fleet-heterogeneity half
//! of the straggler model: deterministic per-node speed multipliers
//! ([`Skew`], the `--skew` spec) and the work-stealing makespan the ledger
//! charges under `--sched steal` ([`steal_makespan`] / [`phase_wall`]).

use super::exec::Sched;
use crate::Result;

/// Per-instance communication cost parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// C: latency per communication instance, seconds.
    pub latency_s: f64,
    /// D: per-byte transfer cost, seconds/byte.
    pub per_byte_s: f64,
}

impl CostModel {
    /// One communication instance of `bytes` bytes: C + D·B.
    pub fn instance(&self, bytes: usize) -> f64 {
        self.latency_s + self.per_byte_s * bytes as f64
    }

    /// The paper's *crude* Hadoop AllReduce: high per-call latency — the
    /// regime where "the term 5NC dominates" and Covtype speed-up collapses
    /// (Fig 2 left).
    pub fn hadoop_crude() -> CostModel {
        CostModel {
            latency_s: 30e-3,     // ~30 ms per hop-round on the crude tree
            per_byte_s: 1.0 / 100e6, // ~100 MB/s commodity network
        }
    }

    /// A professional MPI cluster (what P-packSVM ran on): "negligible
    /// latency" per the paper.
    pub fn mpi() -> CostModel {
        CostModel {
            latency_s: 50e-6,     // ~50 µs
            per_byte_s: 1.0 / 1e9, // ~1 GB/s
        }
    }

    /// Zero-cost model (pure-algorithm runs / unit tests).
    pub fn free() -> CostModel {
        CostModel {
            latency_s: 0.0,
            per_byte_s: 0.0,
        }
    }
}

/// Deterministic per-node speed multipliers (≥ 1 means SLOWER): the
/// simulated fleet's heterogeneity. A node's measured compute seconds are
/// scaled by `multiplier(j)` before the phase wall is charged, so a single
/// skewed node models exactly the straggler that stalls every AllReduce
/// barrier in the paper's synchronous design.
#[derive(Clone, Debug, PartialEq)]
pub enum Skew {
    /// Homogeneous fleet: every node at 1× (the default; charging is
    /// bit-identical to a ledger with no skew model at all).
    None,
    /// Explicit `node=factor` overrides; unlisted nodes run at 1×.
    Explicit(Vec<(usize, f64)>),
    /// Seeded per-node draw, uniform in [1, max]: the same spec always
    /// yields the same fleet (splitmix64 of seed and node index — no
    /// global RNG state, so replays are exact).
    Random { max: f64, seed: u64 },
}

impl Skew {
    pub fn none() -> Skew {
        Skew::None
    }

    pub fn is_none(&self) -> bool {
        matches!(self, Skew::None)
    }

    /// Parse a `--skew` spec: `none`, a `node=factor[,node=factor...]`
    /// list (e.g. `0=4` slows node 0 by 4×), or `rand:<max>[:<seed>]`.
    pub fn parse(s: &str) -> Result<Skew> {
        if s == "none" {
            return Ok(Skew::None);
        }
        if let Some(rest) = s.strip_prefix("rand:") {
            let mut it = rest.splitn(2, ':');
            let max: f64 = it
                .next()
                .unwrap_or("")
                .parse()
                .map_err(|_| anyhow::anyhow!("bad skew max in '{s}' (want rand:<max>[:<seed>])"))?;
            anyhow::ensure!(max >= 1.0, "skew max must be >= 1, got {max}");
            let seed: u64 = match it.next() {
                Some(sd) => sd
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad skew seed in '{s}'"))?,
                None => 17,
            };
            return Ok(Skew::Random { max, seed });
        }
        let mut pairs = Vec::new();
        for part in s.split(',') {
            let (j, f) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!(
                    "bad skew spec '{s}' (valid: none, <node>=<factor>[,...], rand:<max>[:<seed>])"
                )
            })?;
            let j: usize = j
                .parse()
                .map_err(|_| anyhow::anyhow!("bad node index '{j}' in skew spec '{s}'"))?;
            let f: f64 = f
                .parse()
                .map_err(|_| anyhow::anyhow!("bad factor '{f}' in skew spec '{s}'"))?;
            anyhow::ensure!(f >= 1.0, "skew factor must be >= 1, got {f} for node {j}");
            pairs.push((j, f));
        }
        Ok(Skew::Explicit(pairs))
    }

    /// Round-trippable spec string (`Skew::parse(&skew.name())` is `skew`).
    pub fn name(&self) -> String {
        match self {
            Skew::None => "none".to_string(),
            Skew::Explicit(pairs) => pairs
                .iter()
                .map(|(j, f)| format!("{j}={f}"))
                .collect::<Vec<_>>()
                .join(","),
            Skew::Random { max, seed } => format!("rand:{max}:{seed}"),
        }
    }

    /// Speed multiplier of node `j` (1.0 = full speed).
    pub fn multiplier(&self, j: usize) -> f64 {
        match self {
            Skew::None => 1.0,
            Skew::Explicit(pairs) => pairs
                .iter()
                .find(|(node, _)| *node == j)
                .map(|(_, f)| *f)
                .unwrap_or(1.0),
            Skew::Random { max, seed } => {
                let mut z = seed
                    .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(j as u64 + 1));
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
                z ^= z >> 31;
                let frac = (z >> 11) as f64 / (1u64 << 53) as f64;
                1.0 + frac * (max - 1.0)
            }
        }
    }
}

/// Simulated wall of one phase under work stealing: each node's (already
/// skew-scaled) cost is oversplit into `grain` equal items and the p
/// simulated workers claim items in flattened node order, each next item
/// going to the earliest-free worker — the same dynamics as the executors'
/// claim cursor. Returns the latest finish time. With `grain` = 1 and one
/// item per worker this degrades to the static max, as it must.
pub fn steal_makespan(node_secs: &[f64], grain: usize) -> f64 {
    let p = node_secs.len();
    if p == 0 {
        return 0.0;
    }
    let g = grain.max(1);
    let mut free = vec![0.0f64; p];
    for &t in node_secs {
        let item = t / g as f64;
        for _ in 0..g {
            // Earliest-free worker claims the next item (first index wins
            // ties — fully deterministic).
            let w = (0..p)
                .min_by(|&a, &b| free[a].total_cmp(&free[b]).then(a.cmp(&b)))
                .unwrap();
            free[w] += item;
        }
    }
    free.iter().fold(0.0f64, |a, &b| a.max(b))
}

/// Fold one phase's measured per-node seconds into what the ledger
/// charges: `(charged wall, max node seconds, summed node seconds)`, all
/// after skew scaling. Static charges the max (the barrier waits for the
/// slowest node — bit-identical to the pre-skew ledger when `skew` is
/// `None`); stealing charges the [`steal_makespan`]. The max/sum pair is
/// the straggler observable: `max·p / sum` is how much longer the
/// slowest-node bound is than perfectly balanced work.
pub fn phase_wall(sched: Sched, skew: &Skew, node_secs: &[f64]) -> (f64, f64, f64) {
    let scaled: Vec<f64> = node_secs
        .iter()
        .enumerate()
        .map(|(j, s)| s * skew.multiplier(j))
        .collect();
    let max = scaled.iter().fold(0.0f64, |a, &b| a.max(b));
    let sum: f64 = scaled.iter().sum();
    let wall = match sched {
        Sched::Static => max,
        Sched::Steal { grain } => steal_makespan(&scaled, grain),
    };
    (wall, max, sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instance_is_affine_in_bytes() {
        let c = CostModel {
            latency_s: 0.01,
            per_byte_s: 1e-6,
        };
        assert!((c.instance(0) - 0.01).abs() < 1e-12);
        assert!((c.instance(1000) - 0.011).abs() < 1e-12);
    }

    #[test]
    fn hadoop_latency_dominates_small_messages() {
        let h = CostModel::hadoop_crude();
        // A beta broadcast of m=1600 floats is latency-bound on crude Hadoop.
        let bytes = 1600 * 4;
        assert!(h.latency_s > h.per_byte_s * bytes as f64);
        // ...but not on MPI.
        let m = CostModel::mpi();
        assert!(m.instance(bytes) < h.instance(bytes) / 100.0);
    }

    #[test]
    fn skew_parses_round_trips_and_scales() {
        assert_eq!(Skew::parse("none").unwrap(), Skew::None);
        let e = Skew::parse("0=4,3=2").unwrap();
        assert_eq!(e.multiplier(0), 4.0);
        assert_eq!(e.multiplier(1), 1.0);
        assert_eq!(e.multiplier(3), 2.0);
        let r = Skew::parse("rand:3:7").unwrap();
        assert_eq!(r, Skew::Random { max: 3.0, seed: 7 });
        // Seeded draws are deterministic, within range, and not constant.
        let ms: Vec<f64> = (0..16).map(|j| r.multiplier(j)).collect();
        assert!(ms.iter().all(|&m| (1.0..=3.0).contains(&m)));
        assert!(ms.iter().any(|&m| (m - ms[0]).abs() > 1e-6));
        assert_eq!(ms, (0..16).map(|j| r.multiplier(j)).collect::<Vec<_>>());
        for s in ["none", "0=4,3=2", "rand:3:7"] {
            let k = Skew::parse(s).unwrap();
            assert_eq!(Skew::parse(&k.name()).unwrap(), k);
        }
        assert!(Skew::parse("0=0.5").is_err(), "speedups are not skew");
        assert!(Skew::parse("rand:0.5").is_err());
        assert!(Skew::parse("garbage").is_err());
    }

    #[test]
    fn steal_makespan_recovers_straggler_idle_time() {
        // p=8, node 0 skewed 4×: static pays 4c; stealing with grain 4
        // spreads node 0's items so the wall lands well under 4c.
        let mut secs = vec![1.0f64; 8];
        secs[0] = 4.0;
        let static_wall = secs.iter().fold(0.0f64, |a, &b| a.max(b));
        let steal_wall = steal_makespan(&secs, 4);
        assert!(steal_wall < static_wall * 0.6, "{steal_wall} vs {static_wall}");
        // Never below the perfectly-balanced bound.
        assert!(steal_wall >= secs.iter().sum::<f64>() / 8.0 - 1e-12);
        // Uniform work with one item per worker degrades to the max.
        let even = vec![2.0f64; 8];
        assert!((steal_makespan(&even, 1) - 2.0).abs() < 1e-12);
        assert_eq!(steal_makespan(&[], 4), 0.0);
    }

    #[test]
    fn phase_wall_static_no_skew_is_plain_max() {
        let secs = [0.5f64, 0.25, 1.5, 0.75];
        let (wall, max, sum) = phase_wall(Sched::Static, &Skew::None, &secs);
        assert_eq!(wall.to_bits(), 1.5f64.to_bits());
        assert_eq!(max.to_bits(), 1.5f64.to_bits());
        assert!((sum - 3.0).abs() < 1e-12);
        // Skew scales before the fold; stealing charges the makespan.
        let skew = Skew::parse("2=4").unwrap();
        let (w2, m2, s2) = phase_wall(Sched::Static, &skew, &secs);
        assert!((w2 - 6.0).abs() < 1e-12);
        assert!((m2 - 6.0).abs() < 1e-12);
        assert!((s2 - 7.5).abs() < 1e-12);
        let (w3, m3, _) = phase_wall(Sched::Steal { grain: 4 }, &skew, &secs);
        assert!((m3 - 6.0).abs() < 1e-12);
        assert!(w3 < w2, "steal {w3} must beat static {w2} under skew");
    }
}
