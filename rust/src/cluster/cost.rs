//! The paper's communication cost model (§4.4): "Let us write one instance
//! communication cost in the form C + DB where C is communication latency,
//! D is the cost of communication per byte after leaving out latency, and B
//! is the number of bytes transferred."

/// Per-instance communication cost parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// C: latency per communication instance, seconds.
    pub latency_s: f64,
    /// D: per-byte transfer cost, seconds/byte.
    pub per_byte_s: f64,
}

impl CostModel {
    /// One communication instance of `bytes` bytes: C + D·B.
    pub fn instance(&self, bytes: usize) -> f64 {
        self.latency_s + self.per_byte_s * bytes as f64
    }

    /// The paper's *crude* Hadoop AllReduce: high per-call latency — the
    /// regime where "the term 5NC dominates" and Covtype speed-up collapses
    /// (Fig 2 left).
    pub fn hadoop_crude() -> CostModel {
        CostModel {
            latency_s: 30e-3,     // ~30 ms per hop-round on the crude tree
            per_byte_s: 1.0 / 100e6, // ~100 MB/s commodity network
        }
    }

    /// A professional MPI cluster (what P-packSVM ran on): "negligible
    /// latency" per the paper.
    pub fn mpi() -> CostModel {
        CostModel {
            latency_s: 50e-6,     // ~50 µs
            per_byte_s: 1.0 / 1e9, // ~1 GB/s
        }
    }

    /// Zero-cost model (pure-algorithm runs / unit tests).
    pub fn free() -> CostModel {
        CostModel {
            latency_s: 0.0,
            per_byte_s: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instance_is_affine_in_bytes() {
        let c = CostModel {
            latency_s: 0.01,
            per_byte_s: 1e-6,
        };
        assert!((c.instance(0) - 0.01).abs() < 1e-12);
        assert!((c.instance(1000) - 0.011).abs() < 1e-12);
    }

    #[test]
    fn hadoop_latency_dominates_small_messages() {
        let h = CostModel::hadoop_crude();
        // A beta broadcast of m=1600 floats is latency-bound on crude Hadoop.
        let bytes = 1600 * 4;
        assert!(h.latency_s > h.per_byte_s * bytes as f64);
        // ...but not on MPI.
        let m = CostModel::mpi();
        assert!(m.instance(bytes) < h.instance(bytes) / 100.0);
    }
}
