//! Arity-k reduction tree over node ids 0..p (heap numbering: node 0 is
//! the root/master; parent(j) = (j-1)/k). Used by every collective.

/// Tree topology.
#[derive(Clone, Debug)]
pub struct Tree {
    p: usize,
    arity: usize,
    depth: usize,
    bottom_up: Vec<usize>,
    subtree: Vec<usize>,
}

impl Tree {
    pub fn new(p: usize, arity: usize) -> Self {
        assert!(p > 0, "empty tree");
        assert!(arity >= 2, "tree arity must be >= 2");
        // depth = number of edge levels = max over nodes of level(j).
        let mut depth = 0;
        for j in 0..p {
            depth = depth.max(Self::level_of(j, arity));
        }
        // Heap numbering gives parent(j) < j, so descending id order is a
        // valid bottom-up (children-before-parents) schedule.
        let bottom_up: Vec<usize> = (1..p).rev().collect();
        // Subtree sizes (node included), folded children-before-parents —
        // what a gather edge from node j actually carries.
        let mut subtree = vec![1usize; p];
        for &j in &bottom_up {
            subtree[(j - 1) / arity] += subtree[j];
        }
        Tree {
            p,
            arity,
            depth,
            bottom_up,
            subtree,
        }
    }

    fn level_of(mut j: usize, arity: usize) -> usize {
        let mut level = 0;
        while j > 0 {
            j = (j - 1) / arity;
            level += 1;
        }
        level
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Number of edge levels (0 for a single node).
    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn parent(&self, j: usize) -> Option<usize> {
        if j == 0 {
            None
        } else {
            Some((j - 1) / self.arity)
        }
    }

    pub fn children(&self, j: usize) -> Vec<usize> {
        (0..self.arity)
            .map(|c| j * self.arity + 1 + c)
            .filter(|&c| c < self.p)
            .collect()
    }

    /// Node ids in children-before-parents order (root excluded).
    pub fn bottom_up_order(&self) -> &[usize] {
        &self.bottom_up
    }

    /// Level (distance from root) of node j.
    pub fn level(&self, j: usize) -> usize {
        Self::level_of(j, self.arity)
    }

    /// Number of nodes in j's subtree, j included (1 for a leaf). In a
    /// gather, the edge j→parent carries exactly this many per-node
    /// payloads.
    pub fn subtree_size(&self, j: usize) -> usize {
        self.subtree[j]
    }

    /// Largest subtree hanging from any node at `level` — the volume of
    /// the busiest edge of that gather level (edges within a level run in
    /// parallel, so this is what prices the level). Zero when the level is
    /// past the tree's depth.
    pub fn max_subtree_at_level(&self, level: usize) -> usize {
        (0..self.p)
            .filter(|&j| self.level(j) == level)
            .map(|j| self.subtree[j])
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_tree_structure() {
        let t = Tree::new(7, 2);
        assert_eq!(t.parent(0), None);
        assert_eq!(t.parent(1), Some(0));
        assert_eq!(t.parent(2), Some(0));
        assert_eq!(t.parent(5), Some(2));
        assert_eq!(t.children(0), vec![1, 2]);
        assert_eq!(t.children(2), vec![5, 6]);
        assert_eq!(t.depth(), 2);
    }

    #[test]
    fn depth_is_logarithmic() {
        assert_eq!(Tree::new(1, 2).depth(), 0);
        assert_eq!(Tree::new(2, 2).depth(), 1);
        assert_eq!(Tree::new(4, 2).depth(), 2);
        assert_eq!(Tree::new(200, 2).depth(), 7);
        assert_eq!(Tree::new(200, 4).depth(), 4);
    }

    #[test]
    fn every_non_root_has_parent_below_it() {
        let t = Tree::new(33, 3);
        for j in 1..33 {
            assert!(t.parent(j).unwrap() < j);
        }
    }

    #[test]
    fn children_parent_consistency() {
        let t = Tree::new(20, 3);
        for j in 0..20 {
            for c in t.children(j) {
                assert_eq!(t.parent(c), Some(j));
            }
        }
    }

    #[test]
    fn bottom_up_visits_children_first() {
        let t = Tree::new(15, 2);
        let order = t.bottom_up_order();
        assert_eq!(order.len(), 14);
        for (pos, &j) in order.iter().enumerate() {
            if let Some(parent) = t.parent(j) {
                if parent != 0 {
                    let ppos = order.iter().position(|&x| x == parent).unwrap();
                    assert!(ppos > pos, "parent {parent} before child {j}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn rejects_unary_tree() {
        Tree::new(4, 1);
    }

    #[test]
    fn subtree_sizes_partition_the_tree() {
        for (p, arity) in [(1usize, 2usize), (4, 2), (7, 2), (20, 3), (33, 4)] {
            let t = Tree::new(p, arity);
            assert_eq!(t.subtree_size(0), p, "root subtree is the whole tree");
            for j in 0..p {
                let child_sum: usize = t.children(j).iter().map(|&c| t.subtree_size(c)).sum();
                assert_eq!(t.subtree_size(j), 1 + child_sum, "p={p} node {j}");
            }
        }
    }

    #[test]
    fn max_subtree_per_level_binary_four_nodes() {
        // p=4, arity 2: node 1 owns {1,3}, node 2 owns {2}, node 3 is a leaf.
        let t = Tree::new(4, 2);
        assert_eq!(t.max_subtree_at_level(0), 4);
        assert_eq!(t.max_subtree_at_level(1), 2);
        assert_eq!(t.max_subtree_at_level(2), 1);
        assert_eq!(t.max_subtree_at_level(3), 0, "past the depth");
    }
}
