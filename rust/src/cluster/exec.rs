//! The pluggable execution layer: *how* a "parallel" phase actually runs.
//!
//! Every node-local phase of Algorithm 1 (kernel blocks, TRON f/g/Hd
//! partials, K-means assignment, W-share computation) is expressed as
//! "apply `f(j, &mut node_j)` to every node". An [`Executor`] decides how
//! those applications are scheduled:
//!
//! * [`SerialExecutor`] — the original metered loop: nodes run one after
//!   another on the calling thread. Deterministic, zero threading overhead,
//!   and the reference semantics for the simulated `C + D·B` ledger.
//! * [`ThreadedExecutor`] — real OS worker threads (scoped, so node state
//!   is borrowed, not moved): one thread per logical node up to a
//!   configurable cap. This is what makes the row-block parallelism of the
//!   paper *actually* parallel on a multi-core host.
//!
//! Both executors preserve the contract the rest of the system relies on:
//!
//! 1. **Results are collected in node order** — `run` returns `out[j]` from
//!    node j regardless of which thread computed it or when it finished.
//! 2. **Reductions walk the same tree in the same order** — [`Executor::
//!    reduce`] uses one shared bottom-up walk, so floating-point sums are
//!    bit-identical across executors (fp addition order never changes).
//! 3. **Metering is per-node** — each node's wall time is measured around
//!    its own `f` invocation (inside the worker thread for the threaded
//!    executor) and the phase is charged the MAX across nodes, the
//!    synchronous bulk-parallel semantics of the paper.
//!
//! Together 1–3 give the headline guarantee: training output is
//! bit-identical between executors (verified in `rust/tests/executor.rs`),
//! and so is the simulated *communication* ledger (bytes and rounds are
//! deterministic). The simulated *compute* ledger is MEASURED, so it is
//! most faithful on the serial executor: under the threaded executor each
//! node's wall time can include cross-worker contention (time-slicing when
//! workers exceed cores, shared memory bandwidth). Use `serial` for
//! Fig-2/Table-4-grade ledger experiments, `threads` for real wall-clock.

use super::tree::Tree;

/// Runs every node one after another on the calling thread.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SerialExecutor;

impl SerialExecutor {
    pub fn run<N, T, F>(&self, nodes: &mut [N], f: &F) -> (Vec<T>, f64)
    where
        F: Fn(usize, &mut N) -> T,
    {
        let mut out = Vec::with_capacity(nodes.len());
        let mut max_secs = 0.0f64;
        for (j, node) in nodes.iter_mut().enumerate() {
            let start = std::time::Instant::now();
            out.push(f(j, node));
            max_secs = max_secs.max(start.elapsed().as_secs_f64());
        }
        (out, max_secs)
    }
}

/// Runs nodes on scoped OS worker threads: one thread per logical node, up
/// to the `threads` cap (nodes are split into contiguous chunks when the
/// cap is below the node count).
///
/// Threads are spawned per phase (scoped, so node state is borrowed with
/// no `'static` gymnastics) rather than parked in a persistent pool. That
/// costs one spawn+join per worker per phase — tens of microseconds —
/// which is noise against real per-node phase work (kernel tiles, TRON
/// partials are ms-scale per node) but can mute the speedup on toy-scale
/// runs. A persistent pool (no external deps allowed here, so it would
/// need hand-rolled unsafe lifetime erasure) is the designated next
/// optimization if profiling ever shows spawn overhead on a real workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThreadedExecutor {
    /// Maximum number of worker threads (>= 1).
    pub threads: usize,
}

impl ThreadedExecutor {
    pub fn new(threads: usize) -> Self {
        ThreadedExecutor {
            threads: threads.max(1),
        }
    }

    pub fn run<N, T, F>(&self, nodes: &mut [N], f: &F) -> (Vec<T>, f64)
    where
        N: Send,
        T: Send,
        F: Fn(usize, &mut N) -> T + Sync,
    {
        let p = nodes.len();
        let workers = self.threads.min(p).max(1);
        if workers <= 1 {
            return SerialExecutor.run(nodes, f);
        }
        // Result slots are pre-allocated in node order; each worker fills
        // the slots of its own contiguous chunk, so no ordering is lost.
        let mut slots: Vec<Option<(T, f64)>> = Vec::with_capacity(p);
        slots.resize_with(p, || None);
        // Contiguous chunks of ceil(p/workers) nodes => at most `workers`
        // worker threads, one chunk each.
        let chunk = p.div_ceil(workers);
        std::thread::scope(|scope| {
            for (w, (node_chunk, slot_chunk)) in nodes
                .chunks_mut(chunk)
                .zip(slots.chunks_mut(chunk))
                .enumerate()
            {
                let first = w * chunk;
                scope.spawn(move || {
                    for (i, (node, slot)) in
                        node_chunk.iter_mut().zip(slot_chunk.iter_mut()).enumerate()
                    {
                        // Per-node wall time is measured inside the worker
                        // thread; the coordinator takes the max afterwards.
                        let start = std::time::Instant::now();
                        let out = f(first + i, node);
                        *slot = Some((out, start.elapsed().as_secs_f64()));
                    }
                });
            }
        });
        let mut max_secs = 0.0f64;
        let out = slots
            .into_iter()
            .map(|s| {
                let (v, secs) = s.expect("worker thread filled every slot");
                max_secs = max_secs.max(secs);
                v
            })
            .collect();
        (out, max_secs)
    }
}

/// The configured execution strategy for a [`super::Cluster`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Executor {
    Serial(SerialExecutor),
    Threaded(ThreadedExecutor),
}

impl Default for Executor {
    fn default() -> Self {
        Executor::serial()
    }
}

impl Executor {
    pub fn serial() -> Executor {
        Executor::Serial(SerialExecutor)
    }

    pub fn threaded(threads: usize) -> Executor {
        Executor::Threaded(ThreadedExecutor::new(threads))
    }

    /// Human-readable name for reports ("serial" / "threads:N").
    pub fn name(&self) -> String {
        match self {
            Executor::Serial(_) => "serial".to_string(),
            Executor::Threaded(t) => format!("threads:{}", t.threads),
        }
    }

    /// Apply `f` to every node; returns the per-node results in node order
    /// plus the MAX single-node wall time (the simulated phase duration).
    pub fn run<N, T, F>(&self, nodes: &mut [N], f: &F) -> (Vec<T>, f64)
    where
        N: Send,
        T: Send,
        F: Fn(usize, &mut N) -> T + Sync,
    {
        match self {
            Executor::Serial(e) => e.run(nodes, f),
            Executor::Threaded(e) => e.run(nodes, f),
        }
    }

    /// Tree-sum per-node vector partials. BOTH executors use the identical
    /// bottom-up walk: reduction order is part of the determinism contract
    /// (bit-identical results across executors), and the walk is O(p·len)
    /// on tiny m-vectors — never the bottleneck worth parallelizing.
    pub fn reduce(&self, tree: &Tree, partials: Vec<Vec<f32>>) -> Vec<f32> {
        reduce_sum_tree(tree, partials)
    }

    /// Tree-sum per-node scalars (no per-node Vec allocations; same
    /// deterministic order as [`Executor::reduce`] on length-1 vectors).
    pub fn reduce_scalar(&self, tree: &Tree, partials: Vec<f32>) -> f32 {
        reduce_scalar_tree(tree, partials)
    }
}

/// Bottom-up tree reduction of vector accumulators: each non-root node's
/// accumulator is added into its parent, children before parents, in the
/// tree's fixed order.
fn reduce_sum_tree(tree: &Tree, mut acc: Vec<Vec<f32>>) -> Vec<f32> {
    for &j in tree.bottom_up_order() {
        if let Some(parent) = tree.parent(j) {
            let child = std::mem::take(&mut acc[j]);
            let dst = &mut acc[parent];
            for (p, c) in dst.iter_mut().zip(child.iter()) {
                *p += c;
            }
        }
    }
    acc.swap_remove(0)
}

/// Scalar twin of [`reduce_sum_tree`] — same additions in the same order,
/// without boxing every scalar in a one-element `Vec`.
fn reduce_scalar_tree(tree: &Tree, mut acc: Vec<f32>) -> f32 {
    for &j in tree.bottom_up_order() {
        if let Some(parent) = tree.parent(j) {
            let child = acc[j];
            acc[parent] += child;
        }
    }
    acc[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_threaded_return_same_results_in_node_order() {
        let f = |j: usize, n: &mut u64| {
            *n += 1;
            (j * 10) as u64 + *n
        };
        let mut a = vec![5u64; 13];
        let mut b = vec![5u64; 13];
        let (ra, _) = SerialExecutor.run(&mut a, &f);
        let (rb, _) = ThreadedExecutor::new(4).run(&mut b, &f);
        assert_eq!(ra, rb);
        assert_eq!(a, b);
        assert_eq!(ra[3], 36);
    }

    #[test]
    fn threaded_mutates_every_node_exactly_once() {
        for threads in [1usize, 2, 3, 7, 64] {
            let mut nodes: Vec<u32> = vec![0; 7];
            let (out, _) = ThreadedExecutor::new(threads).run(&mut nodes, &|j, n| {
                *n += 1;
                j
            });
            assert_eq!(out, (0..7).collect::<Vec<_>>(), "threads={threads}");
            assert!(nodes.iter().all(|&n| n == 1), "threads={threads}");
        }
    }

    #[test]
    fn threaded_actually_uses_multiple_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        let mut nodes = vec![(); 8];
        ThreadedExecutor::new(8).run(&mut nodes, &|_, _| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(ids.lock().unwrap().len() > 1, "expected >1 worker thread");
    }

    #[test]
    fn thread_cap_bounds_concurrency() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let mut nodes = vec![(); 12];
        ThreadedExecutor::new(2).run(&mut nodes, &|_, _| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(2));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn reductions_are_bit_identical_across_executors() {
        let tree = Tree::new(9, 2);
        let partials: Vec<Vec<f32>> = (0..9)
            .map(|j| (0..17).map(|i| ((j * 31 + i) as f32).sin()).collect())
            .collect();
        let scalars: Vec<f32> = partials.iter().map(|v| v[0]).collect();
        let a = Executor::serial().reduce(&tree, partials.clone());
        let b = Executor::threaded(4).reduce(&tree, partials.clone());
        assert_eq!(a, b, "vector reduce must be bit-identical");
        let sa = Executor::serial().reduce_scalar(&tree, scalars.clone());
        let sb = Executor::threaded(4).reduce_scalar(&tree, scalars);
        assert_eq!(sa.to_bits(), sb.to_bits());
        // The scalar path reduces in the same order as a length-1 vector.
        let singleton: Vec<Vec<f32>> = partials.iter().map(|v| vec![v[0]]).collect();
        let sv = Executor::serial().reduce(&tree, singleton);
        assert_eq!(sa.to_bits(), sv[0].to_bits());
    }

    #[test]
    fn names_describe_the_variant() {
        assert_eq!(Executor::serial().name(), "serial");
        assert_eq!(Executor::threaded(6).name(), "threads:6");
        assert_eq!(Executor::threaded(0).name(), "threads:1");
    }
}
