//! The pluggable execution layer: *how* a "parallel" phase actually runs.
//!
//! Every node-local phase of Algorithm 1 (kernel blocks, TRON f/g/Hd
//! partials, K-means assignment, W-share computation) is expressed as
//! "apply `f(j, &mut node_j)` to every node". An [`Executor`] decides how
//! those applications are scheduled:
//!
//! * [`SerialExecutor`] — the original metered loop: nodes run one after
//!   another on the calling thread. Deterministic, zero threading overhead,
//!   and the reference semantics for the simulated `C + D·B` ledger.
//! * [`ThreadedExecutor`] — real OS worker threads (scoped, so node state
//!   is borrowed, not moved): one thread per logical node up to a
//!   configurable cap, spawned fresh for every phase. This is what makes
//!   the row-block parallelism of the paper *actually* parallel on a
//!   multi-core host.
//! * [`PooledExecutor`] — the same worker model behind a **persistent
//!   pool**: threads are spawned once (when the executor is built, i.e.
//!   once per `Cluster` lifetime) and parked between phases; each phase is
//!   dispatched to them as a borrowed closure through a hand-rolled scoped
//!   lifetime erasure (no external deps). This kills the per-phase
//!   spawn+join cost, which matters once streaming C storage turns every
//!   TRON evaluation into many small dispatches.
//!
//! All executors preserve the contract the rest of the system relies on:
//!
//! 1. **Results are collected in node order** — `run` returns `out[j]` from
//!    node j regardless of which thread computed it or when it finished.
//! 2. **Reductions walk the same tree in the same order** — [`Executor::
//!    reduce`] uses one shared bottom-up walk, so floating-point sums are
//!    bit-identical across executors (fp addition order never changes).
//!    [`Executor::run_reduce`] fuses compute and reduction into ONE phase
//!    (the last worker to finish folds the partials before anyone parks)
//!    using that same walk, so fused and two-step results are bit-identical
//!    too — only the number of barriers changes.
//! 3. **Metering is per-node** — each node's wall time is measured around
//!    its own `f` invocation (inside the worker thread for the threaded
//!    executor) and returned per node; the cluster charges the phase the
//!    MAX across nodes (the synchronous bulk-parallel semantics of the
//!    paper) or, under `--sched steal`, the work-stealing makespan model
//!    in [`super::cost`].
//!
//! Together 1–3 give the headline guarantee: training output is
//! bit-identical between executors (verified in `rust/tests/executor.rs`),
//! and so is the simulated *communication* ledger (bytes and rounds are
//! deterministic). The simulated *compute* ledger is MEASURED, so it is
//! most faithful on the serial executor: under the threaded executor each
//! node's wall time can include cross-worker contention (time-slicing when
//! workers exceed cores, shared memory bandwidth); the pooled executor has
//! the same caveat. Use `serial` for Fig-2/Table-4-grade ledger
//! experiments, `pool` (or `threads`) for real wall-clock.
//!
//! **Scheduling** ([`Sched`]): both parallel executors claim per-node work
//! through one shared [`NodeQueue`] seam. `static` (the reference) carves
//! nodes into contiguous chunks of `ceil(p/workers)` exactly as before;
//! `steal[:grain]` replaces the chunks with a single atomic-cursor claim —
//! the idiom `run_concurrent` already proves out — so a worker that
//! finishes early keeps pulling nodes instead of parking behind a
//! straggler. Results still land in node order, errors still report the
//! first failing node in node order, and panics still propagate, so β is
//! bit-identical across schedulers (locked by `rust/tests/scheduling.rs`).
//! The `grain` only parameterizes the simulated makespan model (a node's
//! closure is indivisible on a real host); real stealing is node-granular.
//!
//! **Multi-slot phases** ([`Executor::run_concurrent`]) extend the model
//! from lockstep training to overlapping serving work: a phase carries
//! SEVERAL independent slots (one per prediction batch), each with its own
//! independent work items (one per shard), and workers PULL items from any
//! in-flight slot through one global cursor — batch B+1 computes while
//! batch B's last shards drain, inside a single dispatch. The collection
//! contract is unchanged: results land in per-slot item order, and every
//! item is a pure function of its own inputs, so each slot's outputs are
//! bit-identical to running the slots one serial phase at a time.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::tree::Tree;
use crate::Result;

/// Outcome of a fused compute+reduce phase: the tree-summed vector, or the
/// FIRST failing node in node order with its error (the same reporting
/// contract as `Cluster::try_par_compute`).
pub type ReduceOutcome = std::result::Result<Vec<f32>, (usize, anyhow::Error)>;

/// Default oversplit factor of `--sched steal` (items per node in the
/// simulated makespan model).
pub const DEFAULT_STEAL_GRAIN: usize = 4;

/// How a phase's per-node work is handed to the workers (`--sched`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sched {
    /// Contiguous chunks of `ceil(p/workers)` nodes, one per worker — the
    /// reference schedule (and the only one the serial executor has).
    Static,
    /// Workers race one atomic cursor over the node list, so an early
    /// finisher keeps claiming nodes instead of idling behind a straggler.
    /// `grain` oversplits each node into that many equal items in the
    /// simulated makespan model (see `cost::steal_makespan`); the real
    /// executors steal whole nodes (a node closure is indivisible).
    Steal { grain: usize },
}

impl Default for Sched {
    fn default() -> Self {
        Sched::Static
    }
}

impl Sched {
    /// Parse a `--sched` spec: `static`, `steal`, or `steal:<grain>`.
    pub fn parse(s: &str) -> Result<Sched> {
        match s {
            "static" => Ok(Sched::Static),
            "steal" => Ok(Sched::Steal {
                grain: DEFAULT_STEAL_GRAIN,
            }),
            _ => {
                if let Some(g) = s.strip_prefix("steal:") {
                    let grain: usize = g
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad steal grain '{g}' (want an integer)"))?;
                    anyhow::ensure!(grain >= 1, "steal grain must be >= 1, got {grain}");
                    Ok(Sched::Steal { grain })
                } else {
                    anyhow::bail!(
                        "unknown scheduler '{s}' (valid: static, steal, steal:<grain>)"
                    )
                }
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            Sched::Static => "static".to_string(),
            Sched::Steal { grain } => format!("steal:{grain}"),
        }
    }
}

/// Shared state of one fused compute+reduce phase: per-node result slots,
/// the countdown of workers still computing, and the finished outcome. The
/// LAST worker to finish its chunk performs the tree fold right there —
/// still inside the phase, so the pool never re-parks between the compute
/// half and the reduction, and the threaded executor never bounces back to
/// the coordinator thread between them.
struct FusedPhase<'t> {
    tree: &'t Tree,
    /// One slot per node: (node partial or error, node compute seconds).
    /// Workers only touch their own chunk's slots, so every lock is
    /// uncontended; the mutexes exist to hand the slots to whichever
    /// worker finishes last.
    slots: Vec<Mutex<Option<(Result<Vec<f32>>, f64)>>>,
    /// Workers that have not finished their chunk yet.
    pending: AtomicUsize,
    /// Set exactly once, by the finishing worker.
    out: Mutex<Option<(ReduceOutcome, Vec<f64>)>>,
}

impl<'t> FusedPhase<'t> {
    fn new(tree: &'t Tree, p: usize, workers: usize) -> Self {
        let mut slots = Vec::with_capacity(p);
        slots.resize_with(p, || Mutex::new(None));
        FusedPhase {
            tree,
            slots,
            pending: AtomicUsize::new(workers),
            out: Mutex::new(None),
        }
    }

    fn record(&self, j: usize, r: Result<Vec<f32>>, secs: f64) {
        *self.slots[j].lock().unwrap() = Some((r, secs));
    }

    /// Called by each worker after its chunk; the last one runs the fold.
    fn worker_done(&self) {
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.finish();
        }
    }

    /// Collect every slot in node order and tree-fold the partials with
    /// the SAME deterministic bottom-up walk as the two-step AllReduce —
    /// that shared walk is what makes the fused path bit-identical to
    /// compute-then-reduce. The fold itself is O(p·len) on small vectors
    /// and deliberately NOT part of the metered compute time (the split
    /// path's reduction is priced as communication, never compute).
    fn finish(&self) {
        let mut partials = Vec::with_capacity(self.slots.len());
        let mut first_err: Option<(usize, anyhow::Error)> = None;
        let mut node_secs = Vec::with_capacity(self.slots.len());
        for (j, slot) in self.slots.iter().enumerate() {
            let (r, secs) = slot
                .lock()
                .unwrap()
                .take()
                .expect("fused phase filled every slot");
            node_secs.push(secs);
            match r {
                Ok(v) => partials.push(v),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some((j, e));
                    }
                }
            }
        }
        let outcome = match first_err {
            Some(err) => Err(err),
            None => {
                let len = partials[0].len();
                for v in &partials {
                    assert_eq!(v.len(), len, "fused reduce length mismatch");
                }
                Ok(reduce_sum_tree(self.tree, partials))
            }
        };
        *self.out.lock().unwrap() = Some((outcome, node_secs));
    }

    fn take(self) -> (ReduceOutcome, Vec<f64>) {
        self.out
            .into_inner()
            .unwrap()
            .expect("fused phase completed without an outcome")
    }
}

/// One slot of a multi-slot concurrent phase: `items` independent work
/// units (for serving, one per shard of one prediction batch) evaluated by
/// `run(i)`. Items of one slot must be independent of each other AND of
/// every other slot — that independence is what lets workers interleave
/// slots freely without breaking bit-identity.
pub struct SlotWork<'a, T> {
    /// Number of independent work items in this slot.
    pub items: usize,
    /// Evaluate item `i` (0-based within the slot).
    pub run: &'a (dyn Fn(usize) -> T + Sync),
}

/// Per-slot outcome of [`Executor::run_concurrent`].
pub struct SlotResult<T> {
    /// Item outputs in item order — the same deterministic collection
    /// contract as [`Executor::run`]'s node order.
    pub items: Vec<T>,
    /// Each item's measured wall seconds, in item order (for serving, one
    /// per shard — what the skewed-fleet model scales per node).
    pub item_secs: Vec<f64>,
    /// MAX single-item seconds: the slot's metered phase duration under
    /// the synchronous bulk model (comparable to a serial one-slot phase).
    pub max_item_secs: f64,
    /// Offsets (seconds from dispatch start) of the slot's first item
    /// beginning and last item finishing. Two slots whose windows overlap
    /// were in flight simultaneously — the observable the serving bench
    /// uses to demonstrate >1 batch in flight.
    pub started_at: f64,
    pub finished_at: f64,
}

/// Shared state of one multi-slot phase: the flattened (slot, item) work
/// list claimed through one atomic cursor, per-item result cells, and
/// per-slot work-window bounds. The flattened list keeps slot order —
/// FIFO across batches — so workers finish slot s before starting s+1
/// unless s's tail is still draining, which is exactly when overlap pays.
struct ConcurrentPhase<T> {
    flat: Vec<(usize, usize)>,
    next: AtomicUsize,
    /// `out[s][i]`: (item output, item seconds). Each cell is written by
    /// exactly one worker (the cursor hands every flat index out once),
    /// so the locks are uncontended.
    out: Vec<Vec<Mutex<Option<(T, f64)>>>>,
    /// `spans[s]`: (first start, last end) offsets of slot s's items.
    spans: Vec<Mutex<Option<(f64, f64)>>>,
}

impl<T: Send> ConcurrentPhase<T> {
    fn new<'a>(slots: &[SlotWork<'a, T>]) -> Self {
        let mut flat = Vec::with_capacity(slots.iter().map(|s| s.items).sum());
        for (s, slot) in slots.iter().enumerate() {
            flat.extend((0..slot.items).map(|i| (s, i)));
        }
        let out = slots
            .iter()
            .map(|slot| {
                let mut v = Vec::with_capacity(slot.items);
                v.resize_with(slot.items, || Mutex::new(None));
                v
            })
            .collect();
        let mut spans = Vec::with_capacity(slots.len());
        spans.resize_with(slots.len(), || Mutex::new(None));
        ConcurrentPhase {
            flat,
            next: AtomicUsize::new(0),
            out,
            spans,
        }
    }

    /// Worker loop: claim flattened items through the cursor until none
    /// remain. Runs identically on the calling thread (serial), scoped
    /// threads, and parked pool workers.
    fn drain<'a>(&self, slots: &[SlotWork<'a, T>], t0: std::time::Instant) {
        loop {
            let k = self.next.fetch_add(1, Ordering::Relaxed);
            let Some(&(s, i)) = self.flat.get(k) else {
                return;
            };
            let begin = t0.elapsed().as_secs_f64();
            let start = std::time::Instant::now();
            let v = (slots[s].run)(i);
            let secs = start.elapsed().as_secs_f64();
            let end = begin + secs;
            *self.out[s][i].lock().unwrap() = Some((v, secs));
            let mut span = self.spans[s].lock().unwrap();
            *span = Some(match *span {
                None => (begin, end),
                Some((a, b)) => (a.min(begin), b.max(end)),
            });
        }
    }

    fn collect(self) -> Vec<SlotResult<T>> {
        self.out
            .into_iter()
            .zip(self.spans)
            .map(|(cells, span)| {
                let mut max_item_secs = 0.0f64;
                let mut item_secs = Vec::with_capacity(cells.len());
                let items = cells
                    .into_iter()
                    .map(|c| {
                        let (v, secs) = c
                            .into_inner()
                            .unwrap()
                            .expect("concurrent phase filled every item");
                        max_item_secs = max_item_secs.max(secs);
                        item_secs.push(secs);
                        v
                    })
                    .collect();
                let (started_at, finished_at) = span.into_inner().unwrap().unwrap_or((0.0, 0.0));
                SlotResult {
                    items,
                    item_secs,
                    max_item_secs,
                    started_at,
                    finished_at,
                }
            })
            .collect()
    }
}

/// Maximum number of slots simultaneously in flight, from their work
/// windows (empty slots never fly). Windows that merely touch (one ends
/// exactly where another starts) do not overlap.
pub fn max_slots_in_flight<T>(results: &[SlotResult<T>]) -> usize {
    let mut events: Vec<(f64, i32)> = Vec::with_capacity(2 * results.len());
    for r in results {
        if !r.items.is_empty() {
            events.push((r.started_at, 1));
            events.push((r.finished_at, -1));
        }
    }
    // Process ends before starts at equal times so touching ≠ overlapping.
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let (mut live, mut peak) = (0i32, 0i32);
    for (_, delta) in events {
        live += delta;
        peak = peak.max(live);
    }
    peak.max(0) as usize
}

/// The ONE work-claiming seam shared by `run`/`run_reduce` on both
/// parallel executors (this replaces the contiguous-chunking boilerplate
/// that used to be repeated four times): one cell per node hands each
/// `&mut N` to exactly one worker, drained either as the classic static
/// chunks of `ceil(p/workers)` or through a single atomic cursor every
/// worker races (work stealing). Each cell is taken at most once, so the
/// locks are uncontended except for the cursor race itself.
struct NodeQueue<'a, N> {
    cells: Vec<Mutex<Option<&'a mut N>>>,
    next: AtomicUsize,
    sched: Sched,
    /// Requested worker count (the static chunk divisor).
    workers: usize,
}

impl<'a, N: Send> NodeQueue<'a, N> {
    fn new(nodes: &'a mut [N], workers: usize, sched: Sched) -> Self {
        NodeQueue {
            cells: nodes.iter_mut().map(|n| Mutex::new(Some(n))).collect(),
            next: AtomicUsize::new(0),
            sched,
            workers,
        }
    }

    fn p(&self) -> usize {
        self.cells.len()
    }

    /// Number of workers that actually receive work (static chunking can
    /// leave trailing workers with empty chunks; stealing never does).
    fn spawned(&self) -> usize {
        match self.sched {
            Sched::Static => {
                let chunk = self.p().div_ceil(self.workers);
                self.p().div_ceil(chunk)
            }
            Sched::Steal { .. } => self.workers.min(self.p()),
        }
    }

    /// Drain worker `w`'s share of the nodes: its contiguous chunk under
    /// static scheduling, or whatever the shared cursor hands it under
    /// stealing. `sink(j, node)` runs each claimed node exactly once.
    fn drain(&self, w: usize, sink: &impl Fn(usize, &mut N)) {
        match self.sched {
            Sched::Static => {
                let chunk = self.p().div_ceil(self.workers);
                let first = w * chunk;
                for j in first..self.p().min(first + chunk) {
                    self.claim(j, sink);
                }
            }
            Sched::Steal { .. } => loop {
                let j = self.next.fetch_add(1, Ordering::Relaxed);
                if j >= self.p() {
                    return;
                }
                self.claim(j, sink);
            },
        }
    }

    fn claim(&self, j: usize, sink: &impl Fn(usize, &mut N)) {
        let node = self.cells[j]
            .lock()
            .unwrap()
            .take()
            .expect("node claimed exactly once per phase");
        sink(j, node);
    }
}

/// Collect per-node `(value, seconds)` cells (in node order) into the
/// `(outputs, per-node seconds)` pair `run` returns.
fn collect_cells<T>(cells: Vec<Mutex<Option<(T, f64)>>>) -> (Vec<T>, Vec<f64>) {
    let mut out = Vec::with_capacity(cells.len());
    let mut secs = Vec::with_capacity(cells.len());
    for c in cells {
        let (v, s) = c
            .into_inner()
            .unwrap()
            .expect("worker filled every result cell");
        out.push(v);
        secs.push(s);
    }
    (out, secs)
}

/// Runs every node one after another on the calling thread.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SerialExecutor;

impl SerialExecutor {
    pub fn run<N, T, F>(&self, nodes: &mut [N], f: &F) -> (Vec<T>, Vec<f64>)
    where
        F: Fn(usize, &mut N) -> T,
    {
        let mut out = Vec::with_capacity(nodes.len());
        let mut secs = Vec::with_capacity(nodes.len());
        for (j, node) in nodes.iter_mut().enumerate() {
            let start = std::time::Instant::now();
            out.push(f(j, node));
            secs.push(start.elapsed().as_secs_f64());
        }
        (out, secs)
    }

    /// Fused compute+reduce, serial reference: every node's flat partial
    /// is computed (and metered) in node order, then tree-folded in place.
    /// One "phase" — the reference semantics the parallel executors must
    /// reproduce bit for bit.
    pub fn run_reduce<N, F>(&self, tree: &Tree, nodes: &mut [N], f: &F) -> (ReduceOutcome, Vec<f64>)
    where
        F: Fn(usize, &mut N) -> Result<Vec<f32>>,
    {
        let phase = FusedPhase::new(tree, nodes.len(), 1);
        for (j, node) in nodes.iter_mut().enumerate() {
            let start = std::time::Instant::now();
            let r = f(j, node);
            phase.record(j, r, start.elapsed().as_secs_f64());
        }
        phase.worker_done();
        phase.take()
    }

    /// Multi-slot phase, serial reference: items run on the calling thread
    /// in flattened (slot, item) order — the zero-overlap semantics the
    /// parallel executors must match bit for bit per slot.
    pub fn run_concurrent<'a, T: Send>(&self, slots: &[SlotWork<'a, T>]) -> Vec<SlotResult<T>> {
        let phase = ConcurrentPhase::new(slots);
        phase.drain(slots, std::time::Instant::now());
        phase.collect()
    }
}

/// Runs nodes on scoped OS worker threads: one thread per logical node, up
/// to the `threads` cap (nodes are split into contiguous chunks when the
/// cap is below the node count).
///
/// Threads are spawned per phase (scoped, so node state is borrowed with
/// no `'static` gymnastics) rather than parked in a persistent pool. That
/// costs one spawn+join per worker per phase — tens of microseconds —
/// which is noise against ms-scale per-node phase work but adds up once
/// streaming C storage issues many small dispatches per phase. Use
/// [`PooledExecutor`] (`--exec pool`) to amortize the spawn cost; this
/// spawn-per-phase variant stays as the zero-state baseline the
/// `exec_speedup` bench compares against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThreadedExecutor {
    /// Maximum number of worker threads (>= 1).
    pub threads: usize,
    /// How workers claim per-node work (see [`Sched`]).
    pub sched: Sched,
}

impl ThreadedExecutor {
    pub fn new(threads: usize) -> Self {
        ThreadedExecutor {
            threads: threads.max(1),
            sched: Sched::Static,
        }
    }

    pub fn with_sched(mut self, sched: Sched) -> Self {
        self.sched = sched;
        self
    }

    pub fn run<N, T, F>(&self, nodes: &mut [N], f: &F) -> (Vec<T>, Vec<f64>)
    where
        N: Send,
        T: Send,
        F: Fn(usize, &mut N) -> T + Sync,
    {
        let p = nodes.len();
        let workers = self.threads.min(p).max(1);
        if workers <= 1 {
            return SerialExecutor.run(nodes, f);
        }
        // Result cells are pre-allocated in node order; whichever worker
        // claims node j fills cell j, so no ordering is lost.
        let queue = NodeQueue::new(nodes, workers, self.sched);
        let out: Vec<Mutex<Option<(T, f64)>>> = (0..p).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for w in 0..queue.spawned() {
                let queue = &queue;
                let out = &out;
                scope.spawn(move || {
                    queue.drain(w, &|j, node| {
                        // Per-node wall time is measured inside the worker
                        // thread; the cluster charges max (or makespan).
                        let start = std::time::Instant::now();
                        let v = f(j, node);
                        *out[j].lock().unwrap() = Some((v, start.elapsed().as_secs_f64()));
                    });
                });
            }
        });
        collect_cells(out)
    }

    /// Fused compute+reduce on scoped worker threads: same claim seam as
    /// [`ThreadedExecutor::run`], but the LAST worker to finish folds all
    /// partials down the tree before the scope joins — compute and
    /// reduction share one spawn/join cycle.
    pub fn run_reduce<N, F>(&self, tree: &Tree, nodes: &mut [N], f: &F) -> (ReduceOutcome, Vec<f64>)
    where
        N: Send,
        F: Fn(usize, &mut N) -> Result<Vec<f32>> + Sync,
    {
        let p = nodes.len();
        let workers = self.threads.min(p).max(1);
        if workers <= 1 {
            return SerialExecutor.run_reduce(tree, nodes, f);
        }
        let queue = NodeQueue::new(nodes, workers, self.sched);
        let phase = FusedPhase::new(tree, p, queue.spawned());
        std::thread::scope(|scope| {
            for w in 0..queue.spawned() {
                let queue = &queue;
                let phase = &phase;
                scope.spawn(move || {
                    queue.drain(w, &|j, node| {
                        let start = std::time::Instant::now();
                        let r = f(j, node);
                        phase.record(j, r, start.elapsed().as_secs_f64());
                    });
                    phase.worker_done();
                });
            }
        });
        phase.take()
    }

    /// Multi-slot phase on scoped worker threads: up to `threads` workers
    /// pull flattened (slot, item) work through the shared cursor, so a
    /// worker idling past one slot's items flows straight into the next
    /// slot's — overlap with no extra dispatch.
    pub fn run_concurrent<'a, T: Send>(&self, slots: &[SlotWork<'a, T>]) -> Vec<SlotResult<T>> {
        let total: usize = slots.iter().map(|s| s.items).sum();
        let workers = self.threads.min(total).max(1);
        if workers <= 1 {
            return SerialExecutor.run_concurrent(slots);
        }
        let phase = ConcurrentPhase::new(slots);
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let phase = &phase;
                scope.spawn(move || phase.drain(slots, t0));
            }
        });
        phase.collect()
    }
}

/// A phase handed to the pool: the borrowed task, lifetime-erased to a
/// RAW fat pointer (a raw pointer may dangle harmlessly, so a worker that
/// copies a job it does not participate in owes no validity to it), plus
/// the number of participating workers. The erasure is sound because only
/// participants (index < `workers`) ever dereference `task`, and
/// [`PooledExecutor::run_phase`] blocks until every participant has
/// finished before the pointee goes out of scope (the job is cleared,
/// under the same lock, the moment the phase completes).
#[derive(Clone, Copy)]
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    workers: usize,
}

// SAFETY: the pointer is only dereferenced by phase participants while the
// dispatching thread keeps the pointee alive; `run`'s `F: Sync` bound is
// what makes sharing the closure itself across workers sound.
unsafe impl Send for Job {}

/// Pool state guarded by one mutex: the current phase (epoch-stamped so a
/// parked worker runs each phase exactly once), the completion countdown,
/// and the first panic payload captured from a worker.
struct PoolState {
    epoch: u64,
    job: Option<Job>,
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between phases.
    work: Condvar,
    /// The dispatching thread parks here until `remaining` hits zero.
    done: Condvar,
}

impl PoolShared {
    // Wakeup audit (shared-cursor scheduling relies on this): `run_phase`
    // installs the job, bumps the epoch, and notifies all under the SAME
    // state mutex this loop waits on, so a worker is either already
    // waiting (woken by the notify) or about to re-check the epoch before
    // it can wait — a missed wakeup is impossible. Spurious wakes only
    // re-run the epoch/participation check. A worker that slept through
    // entire phases compares against the CURRENT epoch and job, never a
    // stale one, so it can neither run a finished phase (the job is
    // cleared under the lock before its epoch is observable as stale) nor
    // double-run one (`seen` is updated before the job is taken). Locked
    // by `rapid_phase_alternation_under_stealing_pool_exec` in
    // rust/tests/scheduling.rs.
    fn worker_loop(&self, index: usize) {
        let mut seen = 0u64;
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.epoch != seen {
                        seen = st.epoch;
                        // Break out only with a phase this worker actually
                        // participates in. Anything else is benign: the
                        // phase may already be over (completion gates on
                        // its participants only, so an idle worker can
                        // wake after `run_phase` cleared the job — a
                        // worker that late is never a participant, since
                        // participants hold the phase open), or this
                        // worker may simply not be among the phase's
                        // chunks. Either way, keep waiting without
                        // blocking the tiny phase on idle threads.
                        match st.job {
                            Some(job) if index < job.workers => break job,
                            _ => {}
                        }
                    }
                    st = self.work.wait(st).unwrap();
                }
            };
            // Contain a panicking task so the pool survives it; the
            // payload is re-thrown on the dispatching thread.
            let result = {
                // SAFETY: this worker is a participant of the phase `job`
                // belongs to (checked under the lock above), so run_phase
                // is still blocked on the `remaining` decrement below —
                // the borrowed closure behind the pointer is alive. The
                // reference is scoped to this block: it is gone before the
                // decrement that lets run_phase return.
                let task = unsafe { &*job.task };
                catch_unwind(AssertUnwindSafe(|| task(index)))
            };
            let mut st = self.state.lock().unwrap();
            if let Err(payload) = result {
                if st.panic.is_none() {
                    st.panic = Some(payload);
                }
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                self.done.notify_all();
            }
        }
    }
}

/// Owns the worker handles; dropped only by the last executor clone (the
/// workers themselves never hold one), so its `Drop` can join them.
struct PoolHandle {
    shared: Arc<PoolShared>,
    /// Serializes phases from cloned executor handles sharing this pool.
    dispatch: Mutex<()>,
    threads: usize,
    /// Only touched here (set once) and in `Drop` (`&mut self`) — no lock.
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Drop for PoolHandle {
    fn drop(&mut self) {
        {
            // Tolerate a poisoned state mutex: shutdown must still reach
            // the workers (and a second panic during unwind would abort).
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }
}

/// Runs nodes on a **persistent** worker pool: `threads` OS threads are
/// spawned once when the executor is built and parked on a condvar between
/// phases. Dispatching a phase costs one lock + wakeup instead of a
/// spawn+join per worker, so the executor stays cheap when a phase is
/// small — the many-small-dispatch shape streaming C storage produces.
///
/// Scheduling is otherwise identical to [`ThreadedExecutor`] (same
/// [`Sched`]-driven claim seam, same in-worker metering, same node-order
/// result collection), so training output is bit-identical across all
/// executors — and across schedulers.
/// Worker panics are caught in the worker (the pool survives), and the
/// first payload in completion order is re-thrown on the dispatching
/// thread once the phase has fully drained.
#[derive(Clone)]
pub struct PooledExecutor {
    pool: Arc<PoolHandle>,
    /// How workers claim per-node work (per executor handle, not per
    /// pool: clones share the workers but may schedule differently).
    pub sched: Sched,
}

impl std::fmt::Debug for PooledExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledExecutor")
            .field("threads", &self.pool.threads)
            .field("sched", &self.sched)
            .finish()
    }
}

impl PooledExecutor {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        // A 1-thread pool never dispatches (run() serves single-worker
        // phases on the calling thread, like the other executors), so
        // don't park an OS thread that no phase will ever reach.
        let handles = if threads >= 2 {
            (0..threads)
                .map(|i| {
                    let shared = Arc::clone(&shared);
                    std::thread::Builder::new()
                        .name(format!("dkm-pool-{i}"))
                        .spawn(move || shared.worker_loop(i))
                        .expect("spawn pool worker")
                })
                .collect()
        } else {
            Vec::new()
        };
        PooledExecutor {
            pool: Arc::new(PoolHandle {
                shared,
                dispatch: Mutex::new(()),
                threads,
                handles,
            }),
            sched: Sched::Static,
        }
    }

    pub fn with_sched(mut self, sched: Sched) -> Self {
        self.sched = sched;
        self
    }

    pub fn threads(&self) -> usize {
        self.pool.threads
    }

    /// Dispatch one phase to the parked workers and block until every
    /// PARTICIPATING worker (index < `workers`) has finished it. The
    /// borrowed `task` is lifetime-erased for the trip through the pool;
    /// blocking here — and clearing the job under the lock before
    /// returning — is what makes that sound: no worker can reach the
    /// erased borrow after this returns.
    fn run_phase(&self, workers: usize, task: &(dyn Fn(usize) + Sync)) {
        // A prior phase that re-threw a worker panic unwound while holding
        // this lock, poisoning it — but only after its phase fully drained
        // (remaining == 0, job cleared), so the state is consistent and
        // the poison flag can be dismissed.
        let _phase = self
            .pool
            .dispatch
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let shared = &self.pool.shared;
        // SAFETY: only the lifetime is erased (the fat-pointer layout is
        // unchanged); participants dereference the pointer solely while
        // this call keeps the phase open, and the job is cleared under the
        // lock before this function returns.
        let task: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(task)
        };
        let mut st = shared.state.lock().unwrap();
        debug_assert_eq!(st.remaining, 0, "phase dispatched while one is in flight");
        st.job = Some(Job { task, workers });
        // Completion is gated on the participating workers only; idle pool
        // threads beyond `workers` observe the epoch at their leisure.
        st.remaining = workers;
        st.epoch += 1;
        shared.work.notify_all();
        while st.remaining > 0 {
            st = shared.done.wait(st).unwrap();
        }
        st.job = None;
        let panic = st.panic.take();
        drop(st);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }

    pub fn run<N, T, F>(&self, nodes: &mut [N], f: &F) -> (Vec<T>, Vec<f64>)
    where
        N: Send,
        T: Send,
        F: Fn(usize, &mut N) -> T + Sync,
    {
        let p = nodes.len();
        let workers = self.pool.threads.min(p).max(1);
        if workers <= 1 {
            return SerialExecutor.run(nodes, f);
        }
        // Same claim seam as ThreadedExecutor: per-node cells handed out
        // through the queue (one uncontended lock per node per phase),
        // results landing in node-order cells.
        let queue = NodeQueue::new(nodes, workers, self.sched);
        let out: Vec<Mutex<Option<(T, f64)>>> = (0..p).map(|_| Mutex::new(None)).collect();
        {
            let queue = &queue;
            let out = &out;
            let task = move |w: usize| {
                queue.drain(w, &|j, node| {
                    // Per-node wall time is measured inside the worker
                    // thread; the cluster charges max (or makespan).
                    let start = std::time::Instant::now();
                    let v = f(j, node);
                    *out[j].lock().unwrap() = Some((v, start.elapsed().as_secs_f64()));
                });
            };
            self.run_phase(queue.spawned(), &task);
        }
        collect_cells(out)
    }

    /// Fused compute+reduce on the persistent pool: ONE dispatch wakes the
    /// workers, each computes its chunk's partials, and the last to finish
    /// folds them down the tree — all before anyone re-parks. This is the
    /// primitive that turns a TRON evaluation into a single barrier
    /// instead of a compute phase plus separate reductions.
    pub fn run_reduce<N, F>(&self, tree: &Tree, nodes: &mut [N], f: &F) -> (ReduceOutcome, Vec<f64>)
    where
        N: Send,
        F: Fn(usize, &mut N) -> Result<Vec<f32>> + Sync,
    {
        let p = nodes.len();
        let workers = self.pool.threads.min(p).max(1);
        if workers <= 1 {
            return SerialExecutor.run_reduce(tree, nodes, f);
        }
        let queue = NodeQueue::new(nodes, workers, self.sched);
        let spawned = queue.spawned();
        let phase = FusedPhase::new(tree, p, spawned);
        {
            let queue = &queue;
            let phase = &phase;
            let task = move |w: usize| {
                queue.drain(w, &|j, node| {
                    let start = std::time::Instant::now();
                    let r = f(j, node);
                    phase.record(j, r, start.elapsed().as_secs_f64());
                });
                phase.worker_done();
            };
            self.run_phase(spawned, &task);
        }
        phase.take()
    }

    /// Multi-slot phase on the persistent pool: ONE dispatch wakes up to
    /// `threads` parked workers, each of which pulls flattened (slot, item)
    /// work through the shared cursor until every slot is drained. This is
    /// the serving primitive: k prediction batches cost one barrier, and
    /// batch B+1's shards compute while batch B's last shard drains.
    pub fn run_concurrent<'a, T: Send>(&self, slots: &[SlotWork<'a, T>]) -> Vec<SlotResult<T>> {
        let total: usize = slots.iter().map(|s| s.items).sum();
        let workers = self.pool.threads.min(total).max(1);
        if workers <= 1 {
            return SerialExecutor.run_concurrent(slots);
        }
        let phase = ConcurrentPhase::new(slots);
        {
            let phase = &phase;
            let t0 = std::time::Instant::now();
            let task = move |_w: usize| phase.drain(slots, t0);
            self.run_phase(workers, &task);
        }
        phase.collect()
    }
}

/// The configured execution strategy for a [`super::Cluster`].
///
/// `Clone` on the pooled variant shares the underlying pool (the workers
/// are joined when the last clone drops).
#[derive(Clone, Debug)]
pub enum Executor {
    Serial(SerialExecutor),
    Threaded(ThreadedExecutor),
    Pooled(PooledExecutor),
}

impl Default for Executor {
    fn default() -> Self {
        Executor::serial()
    }
}

impl Executor {
    pub fn serial() -> Executor {
        Executor::Serial(SerialExecutor)
    }

    pub fn threaded(threads: usize) -> Executor {
        Executor::Threaded(ThreadedExecutor::new(threads))
    }

    /// Spawns the persistent pool immediately: workers are parked once per
    /// executor (in practice once per `Cluster` lifetime) and reused by
    /// every subsequent phase.
    pub fn pooled(threads: usize) -> Executor {
        Executor::Pooled(PooledExecutor::new(threads))
    }

    /// Human-readable name for reports ("serial" / "threads:N" / "pool:N").
    pub fn name(&self) -> String {
        match self {
            Executor::Serial(_) => "serial".to_string(),
            Executor::Threaded(t) => format!("threads:{}", t.threads),
            Executor::Pooled(p) => format!("pool:{}", p.threads()),
        }
    }

    /// Set how the parallel executors claim per-node work (no-op on the
    /// serial executor, which has nothing to schedule).
    pub fn with_sched(self, sched: Sched) -> Executor {
        match self {
            Executor::Serial(e) => Executor::Serial(e),
            Executor::Threaded(e) => Executor::Threaded(e.with_sched(sched)),
            Executor::Pooled(e) => Executor::Pooled(e.with_sched(sched)),
        }
    }

    pub fn sched(&self) -> Sched {
        match self {
            Executor::Serial(_) => Sched::Static,
            Executor::Threaded(e) => e.sched,
            Executor::Pooled(e) => e.sched,
        }
    }

    /// Apply `f` to every node; returns the per-node results in node order
    /// plus each node's measured wall seconds (index j = node j). The
    /// cluster folds these into the simulated phase duration — max under
    /// static scheduling, the steal makespan model otherwise.
    pub fn run<N, T, F>(&self, nodes: &mut [N], f: &F) -> (Vec<T>, Vec<f64>)
    where
        N: Send,
        T: Send,
        F: Fn(usize, &mut N) -> T + Sync,
    {
        match self {
            Executor::Serial(e) => e.run(nodes, f),
            Executor::Threaded(e) => e.run(nodes, f),
            Executor::Pooled(e) => e.run(nodes, f),
        }
    }

    /// Fused compute+reduce: apply `f` to every node AND tree-sum the flat
    /// f32 partials inside the SAME phase (for the pool: one dispatch, no
    /// re-park between compute and reduction). Returns the reduced vector
    /// — or the first failing node in node order — plus the per-node
    /// compute seconds (the fold is excluded, mirroring the split path
    /// where the reduction is priced as communication). The fold is the
    /// shared deterministic bottom-up walk, so the result is bit-identical
    /// to [`Executor::run`] followed by [`Executor::reduce`] on every
    /// executor.
    pub fn run_reduce<N, F>(&self, tree: &Tree, nodes: &mut [N], f: &F) -> (ReduceOutcome, Vec<f64>)
    where
        N: Send,
        F: Fn(usize, &mut N) -> Result<Vec<f32>> + Sync,
    {
        match self {
            Executor::Serial(e) => e.run_reduce(tree, nodes, f),
            Executor::Threaded(e) => e.run_reduce(tree, nodes, f),
            Executor::Pooled(e) => e.run_reduce(tree, nodes, f),
        }
    }

    /// Multi-slot concurrent phase: several independent slots of
    /// independent work items, drained in ONE dispatch (one barrier) by
    /// workers pulling from a shared cursor over the flattened
    /// (slot, item) list. Results come back per slot in item order with
    /// the slot's max item seconds (its synchronous metered duration) and
    /// its work window for overlap observation. On the serial executor the
    /// slots run strictly in order — the reference semantics; per-slot
    /// outputs are bit-identical across executors because every item is an
    /// independent pure computation.
    pub fn run_concurrent<'a, T: Send>(&self, slots: &[SlotWork<'a, T>]) -> Vec<SlotResult<T>> {
        match self {
            Executor::Serial(e) => e.run_concurrent(slots),
            Executor::Threaded(e) => e.run_concurrent(slots),
            Executor::Pooled(e) => e.run_concurrent(slots),
        }
    }

    /// Tree-sum per-node vector partials. BOTH executors use the identical
    /// bottom-up walk: reduction order is part of the determinism contract
    /// (bit-identical results across executors), and the walk is O(p·len)
    /// on tiny m-vectors — never the bottleneck worth parallelizing.
    pub fn reduce(&self, tree: &Tree, partials: Vec<Vec<f32>>) -> Vec<f32> {
        reduce_sum_tree(tree, partials)
    }

    /// Tree-sum per-node scalars (no per-node Vec allocations; same
    /// deterministic order as [`Executor::reduce`] on length-1 vectors).
    pub fn reduce_scalar(&self, tree: &Tree, partials: Vec<f32>) -> f32 {
        reduce_scalar_tree(tree, partials)
    }
}

/// Bottom-up tree reduction of vector accumulators: each non-root node's
/// accumulator is added into its parent, children before parents, in the
/// tree's fixed order.
fn reduce_sum_tree(tree: &Tree, mut acc: Vec<Vec<f32>>) -> Vec<f32> {
    for &j in tree.bottom_up_order() {
        if let Some(parent) = tree.parent(j) {
            let child = std::mem::take(&mut acc[j]);
            let dst = &mut acc[parent];
            for (p, c) in dst.iter_mut().zip(child.iter()) {
                *p += c;
            }
        }
    }
    acc.swap_remove(0)
}

/// Scalar twin of [`reduce_sum_tree`] — same additions in the same order,
/// without boxing every scalar in a one-element `Vec`.
fn reduce_scalar_tree(tree: &Tree, mut acc: Vec<f32>) -> f32 {
    for &j in tree.bottom_up_order() {
        if let Some(parent) = tree.parent(j) {
            let child = acc[j];
            acc[parent] += child;
        }
    }
    acc[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_threaded_return_same_results_in_node_order() {
        let f = |j: usize, n: &mut u64| {
            *n += 1;
            (j * 10) as u64 + *n
        };
        let mut a = vec![5u64; 13];
        let mut b = vec![5u64; 13];
        let (ra, _) = SerialExecutor.run(&mut a, &f);
        let (rb, _) = ThreadedExecutor::new(4).run(&mut b, &f);
        assert_eq!(ra, rb);
        assert_eq!(a, b);
        assert_eq!(ra[3], 36);
    }

    #[test]
    fn threaded_mutates_every_node_exactly_once() {
        for threads in [1usize, 2, 3, 7, 64] {
            let mut nodes: Vec<u32> = vec![0; 7];
            let (out, _) = ThreadedExecutor::new(threads).run(&mut nodes, &|j, n| {
                *n += 1;
                j
            });
            assert_eq!(out, (0..7).collect::<Vec<_>>(), "threads={threads}");
            assert!(nodes.iter().all(|&n| n == 1), "threads={threads}");
        }
    }

    #[test]
    fn threaded_actually_uses_multiple_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        let mut nodes = vec![(); 8];
        ThreadedExecutor::new(8).run(&mut nodes, &|_, _| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(ids.lock().unwrap().len() > 1, "expected >1 worker thread");
    }

    #[test]
    fn thread_cap_bounds_concurrency() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let mut nodes = vec![(); 12];
        ThreadedExecutor::new(2).run(&mut nodes, &|_, _| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(2));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn reductions_are_bit_identical_across_executors() {
        let tree = Tree::new(9, 2);
        let partials: Vec<Vec<f32>> = (0..9)
            .map(|j| (0..17).map(|i| ((j * 31 + i) as f32).sin()).collect())
            .collect();
        let scalars: Vec<f32> = partials.iter().map(|v| v[0]).collect();
        let a = Executor::serial().reduce(&tree, partials.clone());
        let b = Executor::threaded(4).reduce(&tree, partials.clone());
        assert_eq!(a, b, "vector reduce must be bit-identical");
        let sa = Executor::serial().reduce_scalar(&tree, scalars.clone());
        let sb = Executor::threaded(4).reduce_scalar(&tree, scalars);
        assert_eq!(sa.to_bits(), sb.to_bits());
        // The scalar path reduces in the same order as a length-1 vector.
        let singleton: Vec<Vec<f32>> = partials.iter().map(|v| vec![v[0]]).collect();
        let sv = Executor::serial().reduce(&tree, singleton);
        assert_eq!(sa.to_bits(), sv[0].to_bits());
    }

    #[test]
    fn names_describe_the_variant() {
        assert_eq!(Executor::serial().name(), "serial");
        assert_eq!(Executor::threaded(6).name(), "threads:6");
        assert_eq!(Executor::threaded(0).name(), "threads:1");
        assert_eq!(Executor::pooled(6).name(), "pool:6");
        assert_eq!(Executor::pooled(0).name(), "pool:1");
    }

    #[test]
    fn pool_matches_serial_and_threaded_results_in_node_order() {
        let f = |j: usize, n: &mut u64| {
            *n += 1;
            (j * 10) as u64 + *n
        };
        let mut a = vec![5u64; 13];
        let mut b = vec![5u64; 13];
        let (ra, _) = SerialExecutor.run(&mut a, &f);
        let (rb, _) = PooledExecutor::new(4).run(&mut b, &f);
        assert_eq!(ra, rb);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_mutates_every_node_exactly_once_any_cap() {
        for threads in [1usize, 2, 3, 7, 64] {
            let pool = PooledExecutor::new(threads);
            let mut nodes: Vec<u32> = vec![0; 7];
            let (out, _) = pool.run(&mut nodes, &|j, n| {
                *n += 1;
                j
            });
            assert_eq!(out, (0..7).collect::<Vec<_>>(), "threads={threads}");
            assert!(nodes.iter().all(|&n| n == 1), "threads={threads}");
        }
    }

    #[test]
    fn pool_reuses_the_same_parked_workers_across_phases() {
        use std::collections::HashSet;
        let pool = PooledExecutor::new(4);
        let mut per_phase: Vec<HashSet<std::thread::ThreadId>> = Vec::new();
        for _ in 0..50 {
            let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
            let mut nodes = vec![(); 8];
            pool.run(&mut nodes, &|_, _| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
            per_phase.push(ids.into_inner().unwrap());
        }
        // Persistent pool: every phase ran on a subset of ONE fixed set of
        // worker threads (spawn-per-phase would mint fresh ids each time).
        let all: HashSet<_> = per_phase.iter().flatten().copied().collect();
        assert!(all.len() > 1, "expected >1 pool worker");
        assert!(all.len() <= 4, "more distinct worker ids than pool threads");
    }

    #[test]
    fn pool_panic_propagates_and_pool_survives() {
        let pool = PooledExecutor::new(3);
        let mut nodes = vec![0u32; 6];
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&mut nodes, &|j, _: &mut u32| {
                if j == 4 {
                    panic!("node 4 exploded");
                }
            });
        }));
        let payload = caught.expect_err("worker panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(msg.contains("node 4 exploded"), "{msg}");
        // The pool survived the panic: the next phase runs normally.
        let mut nodes = vec![0u32; 6];
        let (out, _) = pool.run(&mut nodes, &|j, n| {
            *n = 1;
            j
        });
        assert_eq!(out, (0..6).collect::<Vec<_>>());
        assert!(nodes.iter().all(|&n| n == 1));
    }

    #[test]
    fn pool_single_worker_falls_back_to_serial_semantics() {
        let pool = PooledExecutor::new(1);
        let mut nodes = vec![0u32; 5];
        let (out, _) = pool.run(&mut nodes, &|j, n| {
            *n = j as u32;
            j * 2
        });
        assert_eq!(out, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn run_reduce_matches_run_plus_reduce_on_every_executor() {
        for p in [1usize, 2, 5, 8, 13] {
            let tree = Tree::new(p, 2);
            let partial = |j: usize| -> Vec<f32> {
                (0..9).map(|i| ((j * 17 + i) as f32).sin()).collect()
            };
            // Reference: two-step compute then tree fold.
            let two_step = {
                let mut nodes: Vec<usize> = (0..p).collect();
                let (parts, _) = SerialExecutor.run(&mut nodes, &|j, _n: &mut usize| partial(j));
                reduce_sum_tree(&tree, parts)
            };
            for exec in [Executor::serial(), Executor::threaded(4), Executor::pooled(4)] {
                let name = exec.name();
                let mut nodes: Vec<usize> = (0..p).collect();
                let (out, _) =
                    exec.run_reduce(&tree, &mut nodes, &|j, _n: &mut usize| Ok(partial(j)));
                let got = out.unwrap_or_else(|(j, e)| panic!("node {j}: {e}"));
                assert_eq!(got.len(), two_step.len(), "p={p} exec={name}");
                for (a, b) in got.iter().zip(&two_step) {
                    assert_eq!(a.to_bits(), b.to_bits(), "p={p} exec={name}");
                }
            }
        }
    }

    #[test]
    fn run_reduce_mutates_every_node_and_reports_first_error_in_node_order() {
        for exec in [Executor::serial(), Executor::threaded(3), Executor::pooled(3)] {
            let name = exec.name();
            let tree = Tree::new(7, 2);
            let mut nodes = vec![0u32; 7];
            let (out, _) = exec.run_reduce(&tree, &mut nodes, &|j, n: &mut u32| {
                *n += 1;
                if j >= 4 {
                    anyhow::bail!("node {j} bad");
                }
                Ok(vec![j as f32])
            });
            let (j, e) = out.expect_err("must fail");
            assert_eq!(j, 4, "{name}: first error must be node 4, got {j}: {e}");
            // A synchronous phase runs every node to completion regardless.
            assert!(nodes.iter().all(|&n| n == 1), "{name}");
        }
    }

    #[test]
    fn pool_run_reduce_panic_propagates_and_pool_survives() {
        let pool = PooledExecutor::new(3);
        let tree = Tree::new(6, 2);
        let mut nodes = vec![0u32; 6];
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run_reduce(&tree, &mut nodes, &|j, _: &mut u32| {
                if j == 2 {
                    panic!("fused phase worker died");
                }
                Ok(vec![1.0f32])
            });
        }));
        assert!(caught.is_err(), "mid-fused-phase panic must propagate");
        // The pool survived: the next fused phase completes normally.
        let mut nodes = vec![0u32; 6];
        let (out, _) = pool.run_reduce(&tree, &mut nodes, &|_, n: &mut u32| {
            *n = 1;
            Ok(vec![1.0f32])
        });
        assert_eq!(out.unwrap(), vec![6.0]);
        assert!(nodes.iter().all(|&n| n == 1));
    }

    #[test]
    fn cloned_pool_executors_share_workers_safely() {
        use std::collections::HashSet;
        let pool = PooledExecutor::new(2);
        let clone = pool.clone();
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        for p in [&pool, &clone] {
            let mut nodes = vec![(); 4];
            p.run(&mut nodes, &|_, _| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
        }
        assert!(ids.into_inner().unwrap().len() <= 2);
    }

    fn all_executors() -> [Executor; 3] {
        [Executor::serial(), Executor::threaded(4), Executor::pooled(4)]
    }

    #[test]
    fn run_concurrent_matches_serial_per_slot_on_every_executor() {
        let fns: Vec<Box<dyn Fn(usize) -> u64 + Sync>> = (0..5)
            .map(|s| {
                Box::new(move |i: usize| (s * 100 + i * 7 + 1) as u64) as Box<dyn Fn(usize) -> u64 + Sync>
            })
            .collect();
        let make_slots = || -> Vec<SlotWork<'_, u64>> {
            fns.iter()
                .enumerate()
                .map(|(s, f)| SlotWork {
                    items: 1 + s % 4, // mixed sizes, incl. single-item slots
                    run: f.as_ref(),
                })
                .collect()
        };
        let want: Vec<Vec<u64>> = SerialExecutor
            .run_concurrent(&make_slots())
            .into_iter()
            .map(|r| r.items)
            .collect();
        for exec in all_executors() {
            let got: Vec<Vec<u64>> = exec
                .run_concurrent(&make_slots())
                .into_iter()
                .map(|r| r.items)
                .collect();
            assert_eq!(got, want, "exec={}", exec.name());
        }
    }

    #[test]
    fn run_concurrent_runs_every_item_exactly_once() {
        use std::sync::atomic::AtomicU32;
        for exec in all_executors() {
            let counts: Vec<Vec<AtomicU32>> = (0..4)
                .map(|s| (0..(3 + s)).map(|_| AtomicU32::new(0)).collect())
                .collect();
            let fns: Vec<Box<dyn Fn(usize) -> usize + Sync>> = (0..4)
                .map(|s| {
                    let counts = &counts;
                    Box::new(move |i: usize| {
                        counts[s][i].fetch_add(1, Ordering::SeqCst);
                        i
                    }) as Box<dyn Fn(usize) -> usize + Sync>
                })
                .collect();
            let slots: Vec<SlotWork<'_, usize>> = fns
                .iter()
                .enumerate()
                .map(|(s, f)| SlotWork {
                    items: 3 + s,
                    run: f.as_ref(),
                })
                .collect();
            let results = exec.run_concurrent(&slots);
            assert_eq!(results.len(), 4, "exec={}", exec.name());
            for (s, slot) in counts.iter().enumerate() {
                assert_eq!(results[s].items, (0..(3 + s)).collect::<Vec<_>>());
                for (i, c) in slot.iter().enumerate() {
                    assert_eq!(c.load(Ordering::SeqCst), 1, "slot {s} item {i}");
                }
            }
        }
    }

    #[test]
    fn run_concurrent_handles_empty_slots_and_empty_phase() {
        for exec in all_executors() {
            let f = |i: usize| i as u64;
            let slots = [
                SlotWork { items: 0, run: &f },
                SlotWork { items: 2, run: &f },
                SlotWork { items: 0, run: &f },
            ];
            let r = exec.run_concurrent(&slots);
            assert_eq!(r[0].items, Vec::<u64>::new(), "exec={}", exec.name());
            assert_eq!(r[1].items, vec![0, 1]);
            assert!(r[2].items.is_empty());
            // An empty slot never flies: it cannot count toward occupancy.
            assert_eq!(max_slots_in_flight(&r), 1);
            let none: [SlotWork<'_, u64>; 0] = [];
            assert!(exec.run_concurrent(&none).is_empty());
        }
    }

    #[test]
    fn run_concurrent_overlaps_slots_on_pool_and_threads() {
        // Sleeping items overlap even on a single hardware core (sleep
        // yields the CPU), so this is robust on tiny CI hosts.
        for exec in [Executor::threaded(4), Executor::pooled(4)] {
            let f = |_i: usize| std::thread::sleep(std::time::Duration::from_millis(10));
            let slots = [
                SlotWork { items: 2, run: &f },
                SlotWork { items: 2, run: &f },
            ];
            let r = exec.run_concurrent(&slots);
            assert!(
                max_slots_in_flight(&r) >= 2,
                "exec={}: expected both slots in flight (spans {:?} and {:?})",
                exec.name(),
                (r[0].started_at, r[0].finished_at),
                (r[1].started_at, r[1].finished_at),
            );
        }
        // The serial reference never overlaps slots.
        let f = |_i: usize| std::thread::sleep(std::time::Duration::from_millis(1));
        let slots = [
            SlotWork { items: 2, run: &f },
            SlotWork { items: 2, run: &f },
        ];
        let r = Executor::serial().run_concurrent(&slots);
        assert_eq!(max_slots_in_flight(&r), 1);
    }

    #[test]
    fn run_concurrent_pool_panic_propagates_and_pool_survives() {
        let pool = PooledExecutor::new(3);
        let f = |i: usize| {
            if i == 3 {
                panic!("slot item exploded");
            }
            i
        };
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run_concurrent(&[SlotWork { items: 5, run: &f }]);
        }));
        assert!(caught.is_err(), "item panic must propagate");
        // Pool survives: the next multi-slot phase completes normally.
        let ok = |i: usize| i * 2;
        let r = pool.run_concurrent(&[
            SlotWork { items: 3, run: &ok },
            SlotWork { items: 1, run: &ok },
        ]);
        assert_eq!(r[0].items, vec![0, 2, 4]);
        assert_eq!(r[1].items, vec![0]);
    }

    #[test]
    fn sched_parses_and_names_round_trip() {
        assert_eq!(Sched::parse("static").unwrap(), Sched::Static);
        assert_eq!(
            Sched::parse("steal").unwrap(),
            Sched::Steal {
                grain: DEFAULT_STEAL_GRAIN
            }
        );
        assert_eq!(Sched::parse("steal:9").unwrap(), Sched::Steal { grain: 9 });
        for s in [Sched::Static, Sched::Steal { grain: 7 }] {
            assert_eq!(Sched::parse(&s.name()).unwrap(), s);
        }
        assert!(Sched::parse("steal:0").is_err());
        assert!(Sched::parse("steal:x").is_err());
        assert!(Sched::parse("lifo").is_err());
    }

    #[test]
    fn stealing_matches_static_results_and_mutations() {
        let f = |j: usize, n: &mut u64| {
            *n += 1;
            (j * 10) as u64 + *n
        };
        let steal = Sched::Steal { grain: 1 };
        for threads in [2usize, 3, 7, 64] {
            let mut a = vec![5u64; 13];
            let mut b = vec![5u64; 13];
            let mut c = vec![5u64; 13];
            let (ra, _) = SerialExecutor.run(&mut a, &f);
            let (rb, _) = ThreadedExecutor::new(threads).with_sched(steal).run(&mut b, &f);
            let (rc, _) = PooledExecutor::new(threads).with_sched(steal).run(&mut c, &f);
            assert_eq!(ra, rb, "threads={threads}");
            assert_eq!(ra, rc, "pool={threads}");
            assert_eq!(a, b);
            assert_eq!(a, c);
        }
    }

    #[test]
    fn stealing_run_reduce_is_bit_identical_and_orders_errors() {
        let steal = Sched::Steal { grain: 2 };
        for p in [2usize, 5, 8, 13] {
            let tree = Tree::new(p, 2);
            let partial =
                |j: usize| -> Vec<f32> { (0..9).map(|i| ((j * 17 + i) as f32).sin()).collect() };
            let two_step = {
                let mut nodes: Vec<usize> = (0..p).collect();
                let (parts, _) = SerialExecutor.run(&mut nodes, &|j, _n: &mut usize| partial(j));
                reduce_sum_tree(&tree, parts)
            };
            for exec in [
                Executor::threaded(4).with_sched(steal),
                Executor::pooled(4).with_sched(steal),
            ] {
                let mut nodes: Vec<usize> = (0..p).collect();
                let (out, secs) =
                    exec.run_reduce(&tree, &mut nodes, &|j, _n: &mut usize| Ok(partial(j)));
                let got = out.unwrap_or_else(|(j, e)| panic!("node {j}: {e}"));
                assert_eq!(secs.len(), p, "per-node secs, p={p}");
                for (a, b) in got.iter().zip(&two_step) {
                    assert_eq!(a.to_bits(), b.to_bits(), "p={p} exec={}", exec.name());
                }
            }
        }
        // First error in node order even when a later node fails "first".
        for exec in [
            Executor::threaded(3).with_sched(steal),
            Executor::pooled(3).with_sched(steal),
        ] {
            let tree = Tree::new(7, 2);
            let mut nodes = vec![0u32; 7];
            let (out, _) = exec.run_reduce(&tree, &mut nodes, &|j, n: &mut u32| {
                *n += 1;
                if j == 1 || j == 5 {
                    anyhow::bail!("node {j} bad");
                }
                Ok(vec![j as f32])
            });
            let (j, _) = out.expect_err("must fail");
            assert_eq!(j, 1, "{}: first error in node order", exec.name());
            assert!(nodes.iter().all(|&n| n == 1));
        }
    }

    #[test]
    fn stealing_pool_panic_propagates_and_pool_survives() {
        let pool = PooledExecutor::new(3).with_sched(Sched::Steal { grain: 4 });
        let mut nodes = vec![0u32; 6];
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&mut nodes, &|j, _: &mut u32| {
                if j == 4 {
                    panic!("stolen node exploded");
                }
            });
        }));
        assert!(caught.is_err(), "panic under stealing must propagate");
        let mut nodes = vec![0u32; 6];
        let (out, _) = pool.run(&mut nodes, &|j, n| {
            *n = 1;
            j
        });
        assert_eq!(out, (0..6).collect::<Vec<_>>());
        assert!(nodes.iter().all(|&n| n == 1));
    }

    #[test]
    fn per_node_secs_are_reported_for_every_node() {
        for exec in [
            Executor::serial(),
            Executor::threaded(4),
            Executor::pooled(4).with_sched(Sched::Steal { grain: 1 }),
        ] {
            let mut nodes = vec![(); 9];
            let (_, secs) = exec.run(&mut nodes, &|_, _| {
                std::thread::sleep(std::time::Duration::from_millis(1));
            });
            assert_eq!(secs.len(), 9, "exec={}", exec.name());
            assert!(secs.iter().all(|&s| s > 0.0), "exec={}", exec.name());
        }
    }

    #[test]
    fn max_slots_in_flight_counts_window_overlap() {
        let slot = |s: f64, e: f64| SlotResult {
            items: vec![0u8],
            item_secs: vec![e - s],
            max_item_secs: e - s,
            started_at: s,
            finished_at: e,
        };
        // Touching windows are sequential, not overlapping.
        assert_eq!(max_slots_in_flight(&[slot(0.0, 1.0), slot(1.0, 2.0)]), 1);
        assert_eq!(max_slots_in_flight(&[slot(0.0, 2.0), slot(1.0, 3.0)]), 2);
        assert_eq!(
            max_slots_in_flight(&[slot(0.0, 3.0), slot(1.0, 2.0), slot(1.5, 2.5)]),
            3
        );
        assert_eq!(max_slots_in_flight::<u8>(&[]), 0);
    }
}
