//! Deterministic phase-fault injection + the bounded retry policy.
//!
//! The source paper runs its AllReduce tree on Hadoop precisely because
//! MapReduce supplies fault tolerance around long iterative jobs. This
//! module is the simulated counterpart: a [`FaultPlan`] decides — purely
//! as a function of (phase index, node id, attempt) — whether a node's
//! task "dies" at dispatch, and a [`RetryPolicy`] bounds how many times
//! the cluster re-launches it (charging a simulated backoff to the
//! ledger) before the phase aborts with the usual
//! first-error-in-node-order report.
//!
//! Faults fire at task ENTRY, before the node closure touches any node
//! state. That single rule is what makes recovery bit-identical: a
//! retried task is indistinguishable from one that was dispatched late,
//! so β and every reduction are unchanged — only the resilience counters
//! and the backoff seconds on the ledger show that anything happened.
//!
//! Spec grammar (`--faults`, comma-separated; `none` = empty plan):
//!
//! ```text
//! node=J@phase=K      one fixed fault: node J's task dies on its first
//!                     attempt of injectable phase K (a single retry
//!                     always recovers it)
//! rand:P[:SEED]       every (phase, node, attempt) dies independently
//!                     with probability P — seeded, so the same plan
//!                     replays the same faults (default seed 0x5EED)
//! ```

use crate::Result;

/// One failure trigger of a [`FaultPlan`].
#[derive(Clone, Debug, PartialEq)]
enum Trigger {
    /// `node=J@phase=K`: fires on attempt 0 only, so one retry recovers.
    Fixed { node: usize, phase: u64 },
    /// `rand:P[:SEED]`: each (phase, node, attempt) fails independently
    /// with probability `p` — retries re-roll, so `rand:1` exhausts any
    /// retry budget (the graceful-abort path).
    Random { p: f64, seed: u64 },
}

const DEFAULT_RAND_SEED: u64 = 0x5EED;

/// A seeded, deterministic plan of injected phase faults.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    triggers: Vec<Trigger>,
}

impl FaultPlan {
    /// The empty plan: no faults, zero overhead on every phase.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.triggers.is_empty()
    }

    /// Parse a `--faults` spec. See the module docs for the grammar.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan::none());
        }
        let mut triggers = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if let Some(rest) = part.strip_prefix("rand:") {
                let mut it = rest.splitn(2, ':');
                let p: f64 = it
                    .next()
                    .unwrap_or("")
                    .parse()
                    .map_err(|e| anyhow::anyhow!("faults {part:?}: bad probability: {e}"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "faults {part:?}: probability must be in [0, 1]"
                );
                let seed = match it.next() {
                    Some(s) => s
                        .parse()
                        .map_err(|e| anyhow::anyhow!("faults {part:?}: bad seed: {e}"))?,
                    None => DEFAULT_RAND_SEED,
                };
                triggers.push(Trigger::Random { p, seed });
            } else if let Some(rest) = part.strip_prefix("node=") {
                let (node, phase) = rest.split_once("@phase=").ok_or_else(|| {
                    anyhow::anyhow!("faults {part:?}: expected node=J@phase=K")
                })?;
                triggers.push(Trigger::Fixed {
                    node: node
                        .parse()
                        .map_err(|e| anyhow::anyhow!("faults {part:?}: bad node: {e}"))?,
                    phase: phase
                        .parse()
                        .map_err(|e| anyhow::anyhow!("faults {part:?}: bad phase: {e}"))?,
                });
            } else {
                anyhow::bail!(
                    "unknown fault trigger {part:?} (node=J@phase=K | rand:P[:SEED] | none)"
                );
            }
        }
        Ok(FaultPlan { triggers })
    }

    /// Round-trippable display form (`FaultPlan::parse(plan.name())` is
    /// the same plan).
    pub fn name(&self) -> String {
        if self.triggers.is_empty() {
            return "none".into();
        }
        self.triggers
            .iter()
            .map(|t| match t {
                Trigger::Fixed { node, phase } => format!("node={node}@phase={phase}"),
                Trigger::Random { p, seed } => format!("rand:{p}:{seed}"),
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Does any trigger kill (`phase`, `node`)'s task on this `attempt`?
    /// Pure and deterministic: the same plan replays the same faults.
    pub fn fires(&self, phase: u64, node: usize, attempt: u32) -> bool {
        self.triggers.iter().any(|t| match t {
            Trigger::Fixed { node: n, phase: k } => {
                *n == node && *k == phase && attempt == 0
            }
            Trigger::Random { p, seed } => {
                fault_fraction(*seed, phase, node, attempt) < *p
            }
        })
    }
}

/// SplitMix-style hash of (seed, phase, node, attempt) to a uniform
/// fraction in [0, 1) — the same finalizer `Skew::Random` uses, so the
/// per-trial draws are decorrelated and stable across platforms.
fn fault_fraction(seed: u64, phase: u64, node: usize, attempt: u32) -> f64 {
    let mut z = seed
        .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(phase.wrapping_add(1)))
        .wrapping_add(0xBF58476D1CE4E5B9u64.wrapping_mul(node as u64 + 1))
        .wrapping_add(0x94D049BB133111EBu64.wrapping_mul(attempt as u64 + 1));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// How the cluster reacts to an injected task death: re-launch up to
/// `max_retries` times, charging `backoff_secs` of simulated wall per
/// re-launch to the phase's compute ledger, then give up and surface the
/// first exhausted node in node order (the coordinator abort path).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub backoff_secs: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 2,
            backoff_secs: 0.05,
        }
    }
}

/// The error a phase surfaces when a node's retry budget is exhausted.
/// Carried through anyhow so the existing first-error-in-node-order scan
/// reports it like any real node failure.
pub fn exhausted_error(phase: u64, node: usize, attempts: u32) -> anyhow::Error {
    anyhow::anyhow!(
        "injected fault: task died {attempts} times in phase {phase} (retries exhausted)"
    )
    .context(format!("node {node} lost after {attempts} attempts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_round_trips() {
        for spec in ["none", "node=2@phase=17", "rand:0.25:42", "node=0@phase=3,rand:0.5:7"] {
            let plan = FaultPlan::parse(spec).unwrap();
            let again = FaultPlan::parse(&plan.name()).unwrap();
            assert_eq!(plan, again, "{spec}");
        }
        assert_eq!(FaultPlan::parse("none").unwrap().name(), "none");
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        // Default seed fills in and round-trips explicitly.
        let plan = FaultPlan::parse("rand:0.1").unwrap();
        assert_eq!(plan.name(), format!("rand:0.1:{DEFAULT_RAND_SEED}"));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in ["node=2", "node=2@phase=x", "rand:1.5", "rand:-0.1", "chaos", "node=a@phase=1"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn fixed_trigger_fires_once_then_recovers() {
        let plan = FaultPlan::parse("node=2@phase=17").unwrap();
        assert!(plan.fires(17, 2, 0));
        assert!(!plan.fires(17, 2, 1), "one retry recovers a fixed fault");
        assert!(!plan.fires(16, 2, 0));
        assert!(!plan.fires(17, 1, 0));
    }

    #[test]
    fn random_trigger_is_deterministic_and_rate_roughly_p() {
        let plan = FaultPlan::parse("rand:0.25:9").unwrap();
        let again = FaultPlan::parse("rand:0.25:9").unwrap();
        let mut fires = 0usize;
        let mut total = 0usize;
        for phase in 0..200u64 {
            for node in 0..8usize {
                assert_eq!(plan.fires(phase, node, 0), again.fires(phase, node, 0));
                total += 1;
                if plan.fires(phase, node, 0) {
                    fires += 1;
                }
            }
        }
        let rate = fires as f64 / total as f64;
        assert!((0.18..=0.32).contains(&rate), "rate {rate}");
        // p=1 fires every attempt (the exhaustion path); p=0 never fires.
        let always = FaultPlan::parse("rand:1:3").unwrap();
        let never = FaultPlan::parse("rand:0:3").unwrap();
        for a in 0..5 {
            assert!(always.fires(7, 3, a));
            assert!(!never.fires(7, 3, a));
        }
    }

    #[test]
    fn retries_reroll_independently() {
        // With p=0.5 some (phase, node) pairs must recover on a later
        // attempt — i.e. attempt is genuinely part of the draw.
        let plan = FaultPlan::parse("rand:0.5:11").unwrap();
        let mut recovered = false;
        for phase in 0..50u64 {
            if plan.fires(phase, 0, 0) && !plan.fires(phase, 0, 1) {
                recovered = true;
            }
        }
        assert!(recovered);
    }
}
