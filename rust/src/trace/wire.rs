//! Little-endian byte helpers shared by the binary resilience formats
//! (phase traces here, training checkpoints in
//! `coordinator::checkpoint`). f64/f32 values travel as raw bit
//! patterns, so every round trip is bitwise exact.

use crate::cluster::{ClockSnapshot, CostModel};
use crate::metrics::Step;
use crate::Result;

/// Append-only buffer writer.
#[derive(Default)]
pub(crate) struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Writer::default()
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for x in xs {
            self.f32(*x);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked cursor over a byte buffer.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, off: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.off.checked_add(n).is_some_and(|end| end <= self.buf.len()),
            "truncated: wanted {n} bytes at offset {}, file has {}",
            self.off,
            self.buf.len()
        );
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    /// A u64 length prefix, sanity-bounded by the bytes actually left so
    /// a corrupt length can't drive a huge allocation.
    pub fn len_prefix(&mut self) -> Result<usize> {
        let n = self.u64()?;
        anyhow::ensure!(
            (n as usize) <= self.buf.len().saturating_sub(self.off),
            "corrupt length prefix: {n} items but only {} bytes remain",
            self.buf.len() - self.off
        );
        Ok(n as usize)
    }

    pub fn step(&mut self) -> Result<Step> {
        let tag = self.u8()?;
        Step::from_tag(tag).ok_or_else(|| anyhow::anyhow!("unknown step tag {tag}"))
    }

    pub fn done(&self) -> Result<()> {
        anyhow::ensure!(
            self.off == self.buf.len(),
            "{} trailing bytes after the last record",
            self.buf.len() - self.off
        );
        Ok(())
    }
}

pub(crate) fn put_clock(w: &mut Writer, s: &ClockSnapshot) {
    w.f64(s.cost.latency_s);
    w.f64(s.cost.per_byte_s);
    for series in [&s.compute, &s.comm] {
        w.u32(series.len() as u32);
        for (step, secs) in series {
            w.u8(step.tag());
            w.f64(*secs);
        }
    }
    w.u64(s.comm_instances);
    w.u64(s.comm_bytes);
    w.u64(s.recompute_flops);
    w.u64(s.barriers);
    w.u64(s.reduce_round_trips);
    w.u64(s.dispatches);
    w.u64(s.faults);
    w.u64(s.retries);
    w.f64(s.max_node_secs);
    w.f64(s.sum_node_secs);
}

pub(crate) fn read_clock(r: &mut Reader) -> Result<ClockSnapshot> {
    let cost = CostModel {
        latency_s: r.f64()?,
        per_byte_s: r.f64()?,
    };
    let mut series = [Vec::new(), Vec::new()];
    for s in &mut series {
        let n = r.u32()?;
        for _ in 0..n {
            let step = r.step()?;
            s.push((step, r.f64()?));
        }
    }
    let [compute, comm] = series;
    Ok(ClockSnapshot {
        cost,
        compute,
        comm,
        comm_instances: r.u64()?,
        comm_bytes: r.u64()?,
        recompute_flops: r.u64()?,
        barriers: r.u64()?,
        reduce_round_trips: r.u64()?,
        dispatches: r.u64()?,
        faults: r.u64()?,
        retries: r.u64()?,
        max_node_secs: r.f64()?,
        sum_node_secs: r.f64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SimClock;

    #[test]
    fn clock_wire_round_trips_bitwise() {
        let mut c = SimClock::new(CostModel {
            latency_s: 0.01,
            per_byte_s: 1e-8,
        });
        c.add_compute(Step::Tron, 1.0 / 7.0);
        c.add_reduce(Step::Tron, 4, 123);
        c.add_barrier();
        c.add_faults(1);
        c.add_retries(1);
        c.add_straggler(0.25, 0.75);
        let snap = c.snapshot();
        let mut w = Writer::new();
        put_clock(&mut w, &snap);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let back = read_clock(&mut r).unwrap();
        r.done().unwrap();
        assert_eq!(snap, back);
        assert_eq!(SimClock::from_snapshot(&back), c);
    }

    #[test]
    fn reader_rejects_truncation_and_bad_lengths() {
        let mut w = Writer::new();
        w.u64(10);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..4]);
        assert!(r.u64().is_err());
        let mut r = Reader::new(&bytes);
        assert!(r.len_prefix().is_err(), "10 items in 0 remaining bytes");
    }
}
