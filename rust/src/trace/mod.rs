//! Phase trace recorder / replayer: the post-mortem audit leg of the
//! resilience subsystem.
//!
//! While training runs, the cluster can record every ledger-visible
//! event — compute phases (step, per-node seconds, charged wall,
//! injected faults/retries, outcome, a cheap stable fingerprint of the
//! reduced payload), collectives, broadcast/gather metering, backend
//! dispatch counts and recompute-FLOP charges — into an in-memory
//! [`Recorder`]. [`Cluster::take_trace`](crate::cluster::Cluster) turns
//! that into a [`Trace`]: a compact binary manifest with the tree shape,
//! the cost model, the record stream, and a full snapshot of the live
//! ledger at capture time.
//!
//! [`Trace::replay`] re-drives a FRESH [`SimClock`] through the exact
//! same charging calls, in the same order, with the same f64 bits — so a
//! trace shipped off a production run reproduces its ledger exactly
//! (`replay_verified` checks it against the embedded snapshot). That
//! makes "what did this run actually pay, phase by phase?" answerable
//! offline, from a file, without the data or the model.
//!
//! CLI: `dkm trace record|inspect|replay` (see `dkm help`).

pub(crate) mod wire;

use crate::cluster::{ClockSnapshot, CostModel, SimClock, Tree};
use crate::metrics::Step;
use crate::Result;

use wire::{put_clock, read_clock, Reader, Writer};

const MAGIC: &[u8; 8] = b"DKMTRAC1";
const FORMAT_VERSION: u8 = 1;

/// Which executor phase kind a [`Record::Phase`] came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    /// `Executor::run` (split compute; any reductions follow separately).
    Run,
    /// `Executor::run_reduce` (fused compute + tree fold, one phase).
    FusedReduce,
}

impl PhaseKind {
    fn tag(self) -> u8 {
        match self {
            PhaseKind::Run => 0,
            PhaseKind::FusedReduce => 1,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        match t {
            0 => Ok(PhaseKind::Run),
            1 => Ok(PhaseKind::FusedReduce),
            _ => anyhow::bail!("unknown phase kind tag {t}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PhaseKind::Run => "run",
            PhaseKind::FusedReduce => "fused",
        }
    }
}

/// How a recorded phase ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Ok,
    /// First failing node in node order (real error or exhausted retries).
    Failed { node: u32 },
}

/// One ledger-visible event. Every variant replays as exactly the
/// charging calls the live path made, in the same order.
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    /// A dispatched compute phase: one barrier + the scheduled wall +
    /// straggler observables, plus any injected-fault accounting.
    Phase {
        step: Step,
        kind: PhaseKind,
        /// Charged phase wall (post skew + scheduler model), and the
        /// straggler observables that went with it.
        wall: f64,
        max_node: f64,
        sum_node: f64,
        /// Raw measured per-node seconds (audit only; the charges above
        /// are what replays).
        node_secs: Vec<f64>,
        /// Stable FNV-1a fingerprint of the phase's reduced f32 payload
        /// (0 when the phase's outputs aren't a flat f32 buffer).
        fingerprint: u64,
        outcome: Outcome,
        faults: u64,
        retries: u64,
        /// Total simulated backoff charged for those retries.
        backoff_secs: f64,
    },
    /// A tree reduction: AllReduce (`barrier: true` — its own sync
    /// point) or the tail of a fused phase (`barrier: false` — the
    /// barrier was the phase's).
    Collective {
        step: Step,
        barrier: bool,
        rounds: u32,
        bytes: u64,
        fingerprint: u64,
    },
    /// Metered one-way broadcast down the tree.
    Broadcast { step: Step, bytes: u64 },
    /// Metered gather up the tree (per-level subtree pricing).
    Gather { step: Step, bytes_per_node: u64 },
    /// Backend dispatches charged inside evaluation phases.
    Dispatches { n: u64 },
    /// Streaming-C recompute FLOPs charged.
    RecomputeFlops { n: u64 },
    /// Plain coordinator-side compute seconds charged outside a phase
    /// (e.g. the simulated per-node data ingest at build).
    Compute { step: Step, secs: f64 },
}

/// In-memory event sink the cluster writes to while tracing is on.
#[derive(Debug, Default)]
pub struct Recorder {
    records: Vec<Record>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    pub fn push(&mut self, rec: Record) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn into_records(self) -> Vec<Record> {
        self.records
    }
}

/// A recorded run: tree shape + cost model + the record stream + the
/// live ledger's snapshot at capture time (the replay oracle).
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub p: u32,
    pub arity: u32,
    pub cost: CostModel,
    pub records: Vec<Record>,
    /// The live [`SimClock`] frozen when the trace was taken; replay
    /// must reproduce it bitwise.
    pub expected: ClockSnapshot,
}

impl Trace {
    /// Re-drive a fresh ledger through every record, in order. Bitwise
    /// equal to the live clock by construction: each record carries the
    /// exact f64s the live path charged, and replay applies them through
    /// the same [`SimClock`] entry points in the same sequence.
    pub fn replay(&self) -> SimClock {
        let tree = Tree::new(self.p as usize, self.arity as usize);
        let mut clock = SimClock::new(self.cost);
        for rec in &self.records {
            match rec {
                Record::Phase {
                    step,
                    wall,
                    max_node,
                    sum_node,
                    faults,
                    retries,
                    backoff_secs,
                    ..
                } => {
                    clock.add_compute(*step, *wall);
                    clock.add_straggler(*max_node, *sum_node);
                    clock.add_barrier();
                    if *faults > 0 {
                        clock.add_faults(*faults);
                        clock.add_retries(*retries);
                        if *backoff_secs > 0.0 {
                            clock.add_compute(*step, *backoff_secs);
                        }
                    }
                }
                Record::Collective {
                    step,
                    barrier,
                    rounds,
                    bytes,
                    ..
                } => {
                    if *barrier {
                        clock.add_barrier();
                    }
                    clock.add_reduce(*step, *rounds as usize, *bytes as usize);
                }
                Record::Broadcast { step, bytes } => {
                    clock.meter_broadcast(*step, &tree, *bytes as usize);
                }
                Record::Gather {
                    step,
                    bytes_per_node,
                } => {
                    clock.meter_gather(*step, &tree, *bytes_per_node as usize);
                }
                Record::Dispatches { n } => clock.add_dispatches(*n),
                Record::RecomputeFlops { n } => clock.add_recompute_flops(*n),
                Record::Compute { step, secs } => clock.add_compute(*step, *secs),
            }
        }
        clock
    }

    /// Replay and check the result against the embedded live-ledger
    /// snapshot; errors name the first diverging counter.
    pub fn replay_verified(&self) -> Result<SimClock> {
        let got = self.replay();
        let want = SimClock::from_snapshot(&self.expected);
        anyhow::ensure!(
            got == want,
            "trace replay diverged from the recorded ledger:\n replay {got:?}\n   live {want:?}"
        );
        Ok(got)
    }

    // ---- persistence ----

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.bytes(MAGIC);
        w.u8(FORMAT_VERSION);
        w.u32(self.p);
        w.u32(self.arity);
        w.f64(self.cost.latency_s);
        w.f64(self.cost.per_byte_s);
        put_clock(&mut w, &self.expected);
        w.u64(self.records.len() as u64);
        for rec in &self.records {
            match rec {
                Record::Phase {
                    step,
                    kind,
                    wall,
                    max_node,
                    sum_node,
                    node_secs,
                    fingerprint,
                    outcome,
                    faults,
                    retries,
                    backoff_secs,
                } => {
                    w.u8(0);
                    w.u8(step.tag());
                    w.u8(kind.tag());
                    w.u32(match outcome {
                        Outcome::Ok => 0,
                        Outcome::Failed { node } => 1 + node,
                    });
                    w.f64(*wall);
                    w.f64(*max_node);
                    w.f64(*sum_node);
                    w.u64(*fingerprint);
                    w.u64(*faults);
                    w.u64(*retries);
                    w.f64(*backoff_secs);
                    w.u32(node_secs.len() as u32);
                    for s in node_secs {
                        w.f64(*s);
                    }
                }
                Record::Collective {
                    step,
                    barrier,
                    rounds,
                    bytes,
                    fingerprint,
                } => {
                    w.u8(1);
                    w.u8(step.tag());
                    w.u8(*barrier as u8);
                    w.u32(*rounds);
                    w.u64(*bytes);
                    w.u64(*fingerprint);
                }
                Record::Broadcast { step, bytes } => {
                    w.u8(2);
                    w.u8(step.tag());
                    w.u64(*bytes);
                }
                Record::Gather {
                    step,
                    bytes_per_node,
                } => {
                    w.u8(3);
                    w.u8(step.tag());
                    w.u64(*bytes_per_node);
                }
                Record::Dispatches { n } => {
                    w.u8(4);
                    w.u64(*n);
                }
                Record::RecomputeFlops { n } => {
                    w.u8(5);
                    w.u64(*n);
                }
                Record::Compute { step, secs } => {
                    w.u8(6);
                    w.u8(step.tag());
                    w.f64(*secs);
                }
            }
        }
        w.into_bytes()
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Trace> {
        let mut r = Reader::new(buf);
        let magic = r.take(MAGIC.len())?;
        anyhow::ensure!(magic == MAGIC, "not a dkm trace file (bad magic)");
        let version = r.u8()?;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "unsupported trace format version {version} (this build reads {FORMAT_VERSION})"
        );
        let p = r.u32()?;
        let arity = r.u32()?;
        anyhow::ensure!(p >= 1 && arity >= 2, "corrupt trace header: p={p} arity={arity}");
        let cost = CostModel {
            latency_s: r.f64()?,
            per_byte_s: r.f64()?,
        };
        let expected = read_clock(&mut r)?;
        let count = r.len_prefix()?;
        let mut records = Vec::with_capacity(count);
        for _ in 0..count {
            let tag = r.u8()?;
            records.push(match tag {
                0 => {
                    let step = r.step()?;
                    let kind = PhaseKind::from_tag(r.u8()?)?;
                    let out = r.u32()?;
                    let outcome = if out == 0 {
                        Outcome::Ok
                    } else {
                        Outcome::Failed { node: out - 1 }
                    };
                    let wall = r.f64()?;
                    let max_node = r.f64()?;
                    let sum_node = r.f64()?;
                    let fingerprint = r.u64()?;
                    let faults = r.u64()?;
                    let retries = r.u64()?;
                    let backoff_secs = r.f64()?;
                    let n = r.u32()? as usize;
                    anyhow::ensure!(n <= 1 << 24, "corrupt phase record: {n} nodes");
                    let mut node_secs = Vec::with_capacity(n);
                    for _ in 0..n {
                        node_secs.push(r.f64()?);
                    }
                    Record::Phase {
                        step,
                        kind,
                        wall,
                        max_node,
                        sum_node,
                        node_secs,
                        fingerprint,
                        outcome,
                        faults,
                        retries,
                        backoff_secs,
                    }
                }
                1 => Record::Collective {
                    step: r.step()?,
                    barrier: r.u8()? != 0,
                    rounds: r.u32()?,
                    bytes: r.u64()?,
                    fingerprint: r.u64()?,
                },
                2 => Record::Broadcast {
                    step: r.step()?,
                    bytes: r.u64()?,
                },
                3 => Record::Gather {
                    step: r.step()?,
                    bytes_per_node: r.u64()?,
                },
                4 => Record::Dispatches { n: r.u64()? },
                5 => Record::RecomputeFlops { n: r.u64()? },
                6 => Record::Compute {
                    step: r.step()?,
                    secs: r.f64()?,
                },
                _ => anyhow::bail!("unknown trace record tag {tag}"),
            });
        }
        r.done()?;
        Ok(Trace {
            p,
            arity,
            cost,
            records,
            expected,
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow::anyhow!("writing trace {path}: {e}"))
    }

    pub fn load(path: &str) -> Result<Trace> {
        let buf =
            std::fs::read(path).map_err(|e| anyhow::anyhow!("reading trace {path}: {e}"))?;
        Trace::from_bytes(&buf).map_err(|e| e.context(format!("loading trace {path}")))
    }

    // ---- inspection ----

    /// Human-readable manifest: header summary + up to `limit` records.
    pub fn render(&self, limit: usize) -> String {
        let mut phases = 0u64;
        let mut faults = 0u64;
        let mut retries = 0u64;
        for rec in &self.records {
            if let Record::Phase {
                faults: f,
                retries: rt,
                ..
            } = rec
            {
                phases += 1;
                faults += f;
                retries += rt;
            }
        }
        let mut out = format!(
            "trace: p={} arity={} cost C={:.3e} D={:.3e} | {} records ({} phases, {} faults, {} retries)\n",
            self.p,
            self.arity,
            self.cost.latency_s,
            self.cost.per_byte_s,
            self.records.len(),
            phases,
            faults,
            retries,
        );
        let mut t = crate::metrics::Table::new(&["#", "record", "step", "detail", "fingerprint"]);
        for (i, rec) in self.records.iter().take(limit).enumerate() {
            let (name, step, detail, fp) = match rec {
                Record::Phase {
                    step,
                    kind,
                    wall,
                    outcome,
                    faults,
                    retries,
                    fingerprint,
                    node_secs,
                    ..
                } => (
                    format!("phase:{}", kind.name()),
                    step.name(),
                    format!(
                        "{} nodes, wall {:.3e}s{}{}",
                        node_secs.len(),
                        wall,
                        if *faults > 0 {
                            format!(", {faults} faults/{retries} retries")
                        } else {
                            String::new()
                        },
                        match outcome {
                            Outcome::Ok => String::new(),
                            Outcome::Failed { node } => format!(", FAILED at node {node}"),
                        }
                    ),
                    *fingerprint,
                ),
                Record::Collective {
                    step,
                    barrier,
                    rounds,
                    bytes,
                    fingerprint,
                } => (
                    if *barrier { "allreduce" } else { "fused-reduce" }.to_string(),
                    step.name(),
                    format!("{rounds} rounds, {bytes} B"),
                    *fingerprint,
                ),
                Record::Broadcast { step, bytes } => {
                    ("broadcast".to_string(), step.name(), format!("{bytes} B"), 0)
                }
                Record::Gather {
                    step,
                    bytes_per_node,
                } => (
                    "gather".to_string(),
                    step.name(),
                    format!("{bytes_per_node} B/node"),
                    0,
                ),
                Record::Dispatches { n } => {
                    ("dispatches".to_string(), "-", format!("{n}"), 0)
                }
                Record::RecomputeFlops { n } => {
                    ("recompute".to_string(), "-", format!("{n} FLOP"), 0)
                }
                Record::Compute { step, secs } => {
                    ("compute".to_string(), step.name(), format!("{secs:.3e}s"), 0)
                }
            };
            t.row(&[
                i.to_string(),
                name,
                step.to_string(),
                detail,
                if fp == 0 {
                    "-".to_string()
                } else {
                    format!("{fp:016x}")
                },
            ]);
        }
        out.push_str(&t.render());
        if self.records.len() > limit {
            out.push_str(&format!("... {} more records\n", self.records.len() - limit));
        }
        out
    }
}

/// Cheap stable fingerprint of an f32 buffer: FNV-1a 64 over the
/// little-endian bit patterns. Platform-independent, order-sensitive —
/// two phases fingerprint equal iff their payloads are bitwise equal.
pub fn fingerprint_f32s(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        CostModel {
            latency_s: 0.01,
            per_byte_s: 1e-8,
        }
    }

    fn sample_trace() -> Trace {
        let mut rec = Recorder::new();
        rec.push(Record::Phase {
            step: Step::Kernel,
            kind: PhaseKind::Run,
            wall: 0.125,
            max_node: 0.125,
            sum_node: 0.5,
            node_secs: vec![0.1, 0.125, 0.05, 0.08],
            fingerprint: 0,
            outcome: Outcome::Ok,
            faults: 0,
            retries: 0,
            backoff_secs: 0.0,
        });
        rec.push(Record::Phase {
            step: Step::Tron,
            kind: PhaseKind::FusedReduce,
            wall: 1.0 / 3.0,
            max_node: 1.0 / 3.0,
            sum_node: 1.1,
            node_secs: vec![0.3, 1.0 / 3.0, 0.2, 0.25],
            fingerprint: fingerprint_f32s(&[1.5, -2.25]),
            outcome: Outcome::Ok,
            faults: 2,
            retries: 2,
            backoff_secs: 0.1,
        });
        rec.push(Record::Collective {
            step: Step::Tron,
            barrier: false,
            rounds: 4,
            bytes: 640,
            fingerprint: fingerprint_f32s(&[1.5, -2.25]),
        });
        rec.push(Record::Collective {
            step: Step::Tron,
            barrier: true,
            rounds: 4,
            bytes: 8,
            fingerprint: 0,
        });
        rec.push(Record::Broadcast {
            step: Step::BasisBcast,
            bytes: 4096,
        });
        rec.push(Record::Gather {
            step: Step::KMeans,
            bytes_per_node: 128,
        });
        rec.push(Record::Dispatches { n: 4 });
        rec.push(Record::RecomputeFlops { n: 1_000_000 });
        rec.push(Record::Compute {
            step: Step::Load,
            secs: 0.375,
        });
        // Build the oracle by replaying onto a fresh clock — exactly what
        // the live path would have charged.
        let partial = Trace {
            p: 4,
            arity: 2,
            cost: cost(),
            records: rec.records.clone(),
            expected: SimClock::new(cost()).snapshot(),
        };
        let live = partial.replay();
        Trace {
            expected: live.snapshot(),
            ..partial
        }
    }

    #[test]
    fn replay_matches_recorded_ledger_bitwise() {
        let trace = sample_trace();
        let clock = trace.replay_verified().unwrap();
        assert_eq!(clock.barriers(), 3, "two phases + one allreduce");
        assert_eq!(clock.comm_rounds(), 2);
        assert_eq!(clock.dispatches(), 4);
        assert_eq!(clock.faults(), 2);
        assert_eq!(clock.retries(), 2);
        assert_eq!(clock.recompute_flops(), 1_000_000);
    }

    #[test]
    fn file_round_trip_preserves_everything() {
        let trace = sample_trace();
        let bytes = trace.to_bytes();
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(trace, back);
        back.replay_verified().unwrap();
    }

    #[test]
    fn loader_rejects_corruption() {
        let trace = sample_trace();
        let bytes = trace.to_bytes();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(Trace::from_bytes(&bad).unwrap_err().to_string().contains("magic"));
        // Bad version.
        let mut bad = bytes.clone();
        bad[8] = 99;
        assert!(Trace::from_bytes(&bad)
            .unwrap_err()
            .to_string()
            .contains("version"));
        // Truncated.
        assert!(Trace::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage.
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(Trace::from_bytes(&bad).is_err());
    }

    #[test]
    fn replay_detects_a_tampered_ledger() {
        let mut trace = sample_trace();
        trace.expected.barriers += 1;
        assert!(trace.replay_verified().is_err());
    }

    #[test]
    fn fingerprint_is_stable_and_bit_sensitive() {
        let a = fingerprint_f32s(&[1.0, 2.0, 3.0]);
        assert_eq!(a, fingerprint_f32s(&[1.0, 2.0, 3.0]));
        assert_ne!(a, fingerprint_f32s(&[1.0, 2.0, 3.0000001]));
        assert_ne!(fingerprint_f32s(&[0.0]), fingerprint_f32s(&[-0.0]));
        assert_ne!(fingerprint_f32s(&[]), fingerprint_f32s(&[0.0]));
    }

    #[test]
    fn render_summarizes_faults_and_outcomes() {
        let mut trace = sample_trace();
        trace.records.push(Record::Phase {
            step: Step::Tron,
            kind: PhaseKind::Run,
            wall: 0.0,
            max_node: 0.0,
            sum_node: 0.0,
            node_secs: vec![0.0; 4],
            fingerprint: 0,
            outcome: Outcome::Failed { node: 2 },
            faults: 3,
            retries: 2,
            backoff_secs: 0.1,
        });
        let s = trace.render(100);
        assert!(s.contains("5 faults"), "{s}");
        assert!(s.contains("FAILED at node 2"), "{s}");
        assert!(s.contains("fused-reduce"), "{s}");
        let short = trace.render(2);
        assert!(short.contains("more records"), "{short}");
    }
}
