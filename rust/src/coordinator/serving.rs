//! Prediction-only sessions: the serving tier of the ROADMAP's
//! heavy-traffic story.
//!
//! A [`ServingSession`] is what a serving process loads a
//! [`TrainedModel`] into: basis tiles + β tiles sharded over a p-node
//! simulated cluster — NO training state (no data shards, no W shares,
//! no C blocks), so it is cheap to stand up and its memory footprint is
//! the model, not the training set. Three properties distinguish it from
//! [`super::session::Session::predict`]:
//!
//! * **`&self` everywhere.** `predict_batch` / `predict_many` /
//!   `set_beta` all take `&self`; serving threads share ONE session.
//!   Metering lands on an interior-mutability ledger locked briefly
//!   AFTER each compute phase.
//! * **Multi-slot dispatch.** `predict_many` submits every batch as one
//!   slot of a single [`Executor::run_concurrent`] phase: workers pull
//!   (batch, node-shard) work items from ANY in-flight batch, so batch
//!   B+1 computes while batch B's stragglers drain — the overlap the
//!   lockstep one-phase-per-batch path cannot express. Per-slot
//!   node-order collection keeps every batch's scores bit-identical to
//!   the serial [`super::predict::predict`] loop.
//! * **Double-buffered β.** The live β tiles sit behind an
//!   `Arc` swap: each dispatch snapshots the current `Arc` once, and
//!   [`ServingSession::set_beta`] installs a fresh one — a model refresh
//!   never stalls (or torn-reads) in-flight batches. The basis is
//!   immutable for the session's life (it shapes the resident tiles).
//!
//! Simulated-cost model: β updates and the one-time basis load are
//! priced as tree broadcasts; each batch pays its row scatter down the
//! tree, a per-batch compute term (max item seconds, the synchronous
//! per-batch pricing — comparable to the serial path; the concurrency
//! win shows up on the WALL clock and in barriers/batch), and a score
//! gather back up. One barrier per *dispatch*, however many batches it
//! carries — that is the ledger-visible saving.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cluster::{
    max_slots_in_flight, phase_wall, survive_faults, CostModel, Executor, FaultPlan, RetryPolicy,
    Sched, SimClock, Skew, SlotWork, Tree,
};
use crate::data::shard_rows;
use crate::linalg::Mat;
use crate::metrics::{Metrics, Step};
use crate::runtime::tiles::TM;
use crate::runtime::Compute;
use crate::Result;

use super::basis::tiles_of;
use super::node::pad_m_tiles;
use super::predict::score_rows;
use super::trainer::TrainedModel;

/// Serving-side ledgers (sim + wall), interior-mutable so every entry
/// point is `&self`.
struct ServeMeter {
    clock: SimClock,
    wall: Metrics,
}

/// A prediction-only cluster session over a loaded [`TrainedModel`].
pub struct ServingSession {
    backend: Arc<dyn Compute>,
    executor: Executor,
    tree: Tree,
    p: usize,
    /// Unpadded feature width of the basis (widest batch representable).
    d: usize,
    dpad: usize,
    gamma: f32,
    m: usize,
    col_tiles: usize,
    /// TM×dpad padded basis tiles, resident on every node for the
    /// session's life.
    z_tiles: Vec<Vec<f32>>,
    /// How the sim prices each batch's node shards: static slowest-shard
    /// max, or the work-stealing makespan model (`--sched steal[:grain]`).
    sched: Sched,
    /// Simulated per-node speed multipliers applied before pricing.
    skew: Skew,
    /// Injected phase faults (`--faults`), sharing the training cluster's
    /// plan grammar and task-entry semantics (see [`crate::cluster::fault`]).
    faults: FaultPlan,
    retry: RetryPolicy,
    /// Serving-side phase counter: one fault-plan draw index per
    /// `predict_many` dispatch (the dispatch IS the phase here).
    fault_seq: AtomicU64,
    /// Live TM-padded β tiles behind an Arc swap (see module docs).
    beta: Mutex<Arc<Vec<Vec<f32>>>>,
    meter: Mutex<ServeMeter>,
    batches: AtomicU64,
    rows: AtomicU64,
    /// Highest number of batches observed simultaneously in flight in any
    /// one dispatch (from per-slot execution spans).
    peak_slots: AtomicU64,
}

impl ServingSession {
    /// Stand up a p-node serving cluster around `model`: tile the basis
    /// once (broadcast-priced on the sim ledger with β, under
    /// [`Step::BasisBcast`]), install β, no training state at all.
    pub fn load(
        model: &TrainedModel,
        backend: Arc<dyn Compute>,
        nodes: usize,
        executor: Executor,
        cost: CostModel,
    ) -> Result<ServingSession> {
        anyhow::ensure!(nodes >= 1, "serving cluster needs at least one node");
        anyhow::ensure!(
            model.basis.rows() == model.beta.len(),
            "model is inconsistent: {} basis points but {} coefficients",
            model.basis.rows(),
            model.beta.len()
        );
        let t0 = Instant::now();
        let d = model.basis.cols();
        let dpad = backend.pad_d(d)?;
        let m = model.beta.len();
        let z_tiles = tiles_of(&model.basis, dpad);
        let col_tiles = m.div_ceil(TM).max(1);
        debug_assert_eq!(z_tiles.len(), col_tiles);
        let beta_tiles = Arc::new(pad_m_tiles(&model.beta, col_tiles));
        let tree = Tree::new(nodes, 2);
        let mut meter = ServeMeter {
            clock: SimClock::new(cost),
            wall: Metrics::new(),
        };
        // Model shipping: basis rows + β down the tree, once.
        let f32s = std::mem::size_of::<f32>();
        meter
            .clock
            .meter_broadcast(Step::BasisBcast, &tree, m * d * f32s + m * f32s);
        meter.wall.add_wall(Step::Load, t0.elapsed());
        Ok(ServingSession {
            backend,
            executor,
            tree,
            p: nodes,
            d,
            dpad,
            gamma: model.gamma,
            m,
            col_tiles,
            sched: Sched::Static,
            skew: Skew::None,
            faults: FaultPlan::none(),
            retry: RetryPolicy::default(),
            fault_seq: AtomicU64::new(0),
            z_tiles,
            beta: Mutex::new(beta_tiles),
            meter: Mutex::new(meter),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            peak_slots: AtomicU64::new(0),
        })
    }

    /// Builder: schedule batch node-shards by work stealing (the executor's
    /// claim cursor) and price each batch's compute with the stealing
    /// makespan model instead of the static slowest-shard max.
    pub fn with_sched(mut self, sched: Sched) -> ServingSession {
        self.sched = sched;
        self.executor = self.executor.with_sched(sched);
        self
    }

    /// Builder: simulated fleet heterogeneity (`--skew`) — node shard
    /// seconds are scaled by each node's multiplier before pricing.
    pub fn with_skew(mut self, skew: Skew) -> ServingSession {
        self.skew = skew;
        self
    }

    /// Builder: inject phase faults into serving dispatches. Each
    /// `predict_many` dispatch is one fault-plan phase; a fired task dies
    /// at entry (before scoring anything) and is re-launched under
    /// `retry`, so recovered replies stay bit-identical — only the
    /// ledger's fault/retry counters and the backoff seconds move.
    pub fn with_faults(mut self, plan: FaultPlan, retry: RetryPolicy) -> ServingSession {
        self.faults = plan;
        self.retry = retry;
        self
    }

    /// Score several independent batches in ONE multi-slot executor
    /// dispatch: batch b is slot b, its p node-shards are the slot's work
    /// items, and workers pull items from any unfinished batch. Returns
    /// per-batch score vectors in submission order, each bit-identical to
    /// the serial scoring loop (per-slot node-order collection + the fixed
    /// basis-tile accumulation order inside [`score_rows`]).
    pub fn predict_many(&self, batches: &[&Mat]) -> Result<Vec<Vec<f32>>> {
        if batches.is_empty() {
            return Ok(Vec::new());
        }
        for x in batches {
            anyhow::ensure!(
                x.cols() <= self.d,
                "predict: batch has {} features but the model was trained on {}",
                x.cols(),
                self.d
            );
        }
        let t0 = Instant::now();
        let p = self.p;
        // β double-buffer: ONE snapshot per dispatch. A concurrent
        // `set_beta` swaps the Arc for later dispatches; this one keeps
        // scoring the coefficients it started with.
        let beta = Arc::clone(&self.beta.lock().unwrap());
        let shards_per: Vec<Vec<std::ops::Range<usize>>> =
            batches.iter().map(|x| shard_rows(x.rows(), p)).collect();
        // Contiguous panel copy per (batch, node) — the in-process
        // stand-in for shipping the shard; skipped entirely on p == 1
        // where the lone shard is the batch itself.
        let panels: Vec<Vec<Mat>> = batches
            .iter()
            .zip(&shards_per)
            .map(|(x, shards)| {
                if p == 1 {
                    Vec::new()
                } else {
                    shards
                        .iter()
                        .map(|r| {
                            Mat::from_vec(r.len(), x.cols(), x.row_panel(r.start, r.end).to_vec())
                        })
                        .collect()
                }
            })
            .collect();
        // One fault-plan phase per dispatch: item j of any slot is node
        // j's task, so a fired (phase, node) draw kills that node's work
        // across the whole dispatch — the serving analogue of a node dying
        // mid-phase. Task deaths happen at entry, before scoring anything.
        let seq = self.fault_seq.fetch_add(1, Ordering::Relaxed);
        let fires_ctr = AtomicU64::new(0);
        let relaunches_ctr = AtomicU64::new(0);
        let closures: Vec<Box<dyn Fn(usize) -> Result<Vec<f32>> + Sync + '_>> = batches
            .iter()
            .enumerate()
            .map(|(b, x)| {
                let x: &Mat = x;
                let panels = &panels[b];
                let beta = &beta;
                let (fires, relaunches) = (&fires_ctr, &relaunches_ctr);
                Box::new(move |j: usize| {
                    survive_faults(&self.faults, self.retry, seq, j, fires, relaunches)?;
                    let shard = if p == 1 { x } else { &panels[j] };
                    score_rows(
                        self.backend.as_ref(),
                        shard,
                        &self.z_tiles,
                        beta.as_slice(),
                        self.gamma,
                        self.dpad,
                    )
                }) as Box<dyn Fn(usize) -> Result<Vec<f32>> + Sync + '_>
            })
            .collect();
        let slots: Vec<SlotWork<Result<Vec<f32>>>> = closures
            .iter()
            .map(|c| SlotWork {
                items: p,
                run: c.as_ref(),
            })
            .collect();
        let results = self.executor.run_concurrent(&slots);
        self.peak_slots
            .fetch_max(max_slots_in_flight(&results) as u64, Ordering::Relaxed);
        let fires = fires_ctr.load(Ordering::Relaxed);
        let relaunches = relaunches_ctr.load(Ordering::Relaxed);

        let f32s = std::mem::size_of::<f32>();
        let mut meter = self.meter.lock().unwrap();
        // ONE barrier for the whole dispatch, however many batches it
        // carried — vs one per batch on the lockstep path.
        meter.clock.add_barrier();
        meter.wall.bump("barriers", 1);
        // Resilience counters + simulated re-launch backoff, charged even
        // when a retry budget was exhausted (the deaths happened either
        // way) — same ordering as the training cluster's finish_phase.
        if fires > 0 {
            meter.clock.add_faults(fires);
            meter.clock.add_retries(relaunches);
            let backoff_secs = relaunches as f64 * self.retry.backoff_secs;
            if backoff_secs > 0.0 {
                meter.clock.add_compute(Step::Predict, backoff_secs);
            }
        }
        for (x, (shards, slot)) in batches.iter().zip(shards_per.iter().zip(&results)) {
            let max_shard = shards.iter().map(|r| r.len()).max().unwrap_or(0);
            // Rows scatter down the tree to their nodes (a scatter transits
            // the same per-level volumes as a gather, in reverse)...
            meter
                .clock
                .meter_gather(Step::Predict, &self.tree, max_shard * x.cols() * f32s);
            // ...the per-batch compute term: item j is node j's shard, so
            // the phase-wall model prices it exactly like a training phase
            // (static slowest-shard max, or the stealing makespan under
            // `--sched steal`, after skew scaling)...
            let (wall, max_node, sum_node) = phase_wall(self.sched, &self.skew, &slot.item_secs);
            meter.clock.add_compute(Step::Predict, wall);
            meter.clock.add_straggler(max_node, sum_node);
            meter.wall.bump("max_node_us", (max_node * 1e6) as u64);
            meter.wall.bump("sum_node_us", (sum_node * 1e6) as u64);
            // ...and the scores gather back up. β does NOT ship per batch:
            // it is resident from load/set_beta — that, plus the shared
            // barrier, is the serving path's whole comm story.
            meter
                .clock
                .meter_gather(Step::Predict, &self.tree, max_shard * f32s);
        }
        meter.wall.add_wall(Step::Predict, t0.elapsed());
        drop(meter);

        let mut out = Vec::with_capacity(batches.len());
        for (b, slot) in results.into_iter().enumerate() {
            let mut scores = Vec::with_capacity(batches[b].rows());
            for (j, item) in slot.items.into_iter().enumerate() {
                match item {
                    Ok(part) => scores.extend_from_slice(&part),
                    Err(e) => {
                        return Err(e.context(format!(
                            "batch {b} node {j} failed during serving predict"
                        )))
                    }
                }
            }
            self.rows.fetch_add(scores.len() as u64, Ordering::Relaxed);
            out.push(scores);
        }
        self.batches
            .fetch_add(batches.len() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Score one batch (a `predict_many` dispatch with a single slot).
    pub fn predict_batch(&self, x: &Mat) -> Result<Vec<f32>> {
        let mut out = self.predict_many(&[x])?;
        Ok(out.pop().expect("one slot in, one score vector out"))
    }

    /// Install fresh coefficients (same basis — e.g. a warm re-solve
    /// shipped from a training cluster). Priced as a β tree broadcast;
    /// in-flight batches finish on the snapshot they took, the NEXT
    /// dispatch sees the new β.
    pub fn set_beta(&self, beta: &[f32]) -> Result<()> {
        anyhow::ensure!(
            beta.len() == self.m,
            "set_beta: got {} coefficients for an m={} model",
            beta.len(),
            self.m
        );
        let tiles = Arc::new(pad_m_tiles(beta, self.col_tiles));
        let mut meter = self.meter.lock().unwrap();
        meter
            .clock
            .meter_broadcast(Step::BasisBcast, &self.tree, self.m * std::mem::size_of::<f32>());
        drop(meter);
        *self.beta.lock().unwrap() = tiles;
        Ok(())
    }

    // ---- introspection ----

    /// Simulated serving ledger (model broadcasts, per-batch scatter /
    /// compute / gather, one barrier per dispatch).
    pub fn sim(&self) -> SimClock {
        self.meter.lock().unwrap().clock.clone()
    }

    /// Wall clock (Load + Predict) and mirrored barrier count.
    pub fn wall(&self) -> Metrics {
        self.meter.lock().unwrap().wall.clone()
    }

    pub fn batches_served(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn rows_served(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    /// Highest number of batches simultaneously in flight in any single
    /// dispatch so far (1 on the serial executor; ≥2 shows real overlap).
    pub fn peak_slots_in_flight(&self) -> u64 {
        self.peak_slots.load(Ordering::Relaxed)
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn d(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::Loss;
    use crate::rng::Rng;
    use crate::runtime::backend::NativeCompute;

    fn tiny_model(m: usize, d: usize) -> TrainedModel {
        let mut rng = Rng::new(7);
        TrainedModel {
            basis: Mat::from_fn(m, d, |_, _| rng.normal_f32()),
            beta: (0..m).map(|_| 0.05 * rng.normal_f32()).collect(),
            gamma: 0.25,
            loss: Loss::SqHinge,
        }
    }

    fn serving(m: usize, d: usize, p: usize) -> ServingSession {
        ServingSession::load(
            &tiny_model(m, d),
            Arc::new(NativeCompute::new()),
            p,
            Executor::serial(),
            CostModel::free(),
        )
        .unwrap()
    }

    #[test]
    fn load_rejects_inconsistent_models_and_zero_nodes() {
        let mut model = tiny_model(32, 6);
        model.beta.pop();
        let err = ServingSession::load(
            &model,
            Arc::new(NativeCompute::new()),
            2,
            Executor::serial(),
            CostModel::free(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("inconsistent"), "{err:#}");
        let err = ServingSession::load(
            &tiny_model(32, 6),
            Arc::new(NativeCompute::new()),
            0,
            Executor::serial(),
            CostModel::free(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("at least one node"), "{err:#}");
    }

    #[test]
    fn empty_dispatch_and_wide_batch_edges() {
        let s = serving(32, 6, 2);
        assert!(s.predict_many(&[]).unwrap().is_empty());
        let wide = Mat::from_vec(1, 9, vec![0.0; 9]);
        let err = s.predict_batch(&wide).unwrap_err();
        assert!(format!("{err:#}").contains("9 features"), "{err:#}");
        assert_eq!(s.batches_served(), 0);
    }

    #[test]
    fn skewed_serving_keeps_scores_and_prices_stealing_below_the_straggler_bound() {
        let model = tiny_model(48, 5);
        let skew = Skew::parse("0=4").unwrap();
        let build = |sched: Sched| {
            ServingSession::load(
                &model,
                Arc::new(NativeCompute::new()),
                8,
                Executor::serial(),
                CostModel::free(),
            )
            .unwrap()
            .with_sched(sched)
            .with_skew(skew.clone())
        };
        let st = build(Sched::Static);
        let sl = build(Sched::Steal { grain: 4 });
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(96, 5, |_, _| rng.normal_f32());
        let a = st.predict_batch(&x).unwrap();
        let b = sl.predict_batch(&x).unwrap();
        assert_eq!(a, b, "scores are scheduling-invariant");
        // The comm story is untouched by the scheduler.
        assert_eq!(st.sim().barriers(), sl.sim().barriers());
        assert_eq!(st.sim().comm_bytes(), sl.sim().comm_bytes());
        // Static charges exactly the slowest (skew-scaled) shard...
        let st_sim = st.sim();
        assert_eq!(
            st_sim.step_secs(Step::Predict).to_bits(),
            st_sim.max_node_secs().to_bits()
        );
        // ...stealing recovers idle time below that straggler bound.
        let sl_sim = sl.sim();
        assert!(
            sl_sim.step_secs(Step::Predict) < 0.9 * sl_sim.max_node_secs(),
            "steal {} vs straggler bound {}",
            sl_sim.step_secs(Step::Predict),
            sl_sim.max_node_secs()
        );
        // Straggler observables recorded on the ledger and mirrored.
        assert!(st_sim.straggler_ratio(8) > 1.5, "{}", st_sim.straggler_ratio(8));
        assert!((st.wall().max_node_secs() - st_sim.max_node_secs()).abs() < 1e-4);
    }

    #[test]
    fn injected_faults_recover_bit_identically_or_abort_when_exhausted() {
        let model = tiny_model(48, 5);
        let mut rng = Rng::new(19);
        let x = Mat::from_fn(30, 5, |_, _| rng.normal_f32());
        let y = Mat::from_fn(12, 5, |_, _| rng.normal_f32());
        let clean = serving(48, 5, 3);
        let want = clean.predict_many(&[&x, &y]).unwrap();

        // Dispatch 0 is fault-plan phase 0: node 1's tasks die once and
        // are re-launched — replies must not move a bit.
        let faulty = ServingSession::load(
            &model,
            Arc::new(NativeCompute::new()),
            3,
            Executor::serial(),
            CostModel::free(),
        )
        .unwrap()
        .with_faults(
            FaultPlan::parse("node=1@phase=0").unwrap(),
            RetryPolicy {
                max_retries: 2,
                backoff_secs: 0.25,
            },
        );
        let got = faulty.predict_many(&[&x, &y]).unwrap();
        assert_eq!(got, want, "recovered replies are bit-identical");
        // Node 1 owned one item per slot: 2 deaths, 2 re-launches, and
        // the backoff seconds land under the predict step.
        let sim = faulty.sim();
        assert_eq!(sim.faults(), 2);
        assert_eq!(sim.retries(), 2);
        assert!(sim.step_secs(Step::Predict) >= 2.0 * 0.25);
        // Phase 1 is past the fixed trigger: clean dispatch, counters hold.
        assert_eq!(faulty.predict_batch(&x).unwrap(), want[0]);
        assert_eq!(faulty.sim().faults(), 2);

        // rand:1 dies on every attempt: the budget exhausts and the abort
        // names the batch and node like any real serving failure.
        let doomed = serving(48, 5, 3).with_faults(
            FaultPlan::parse("rand:1:7").unwrap(),
            RetryPolicy {
                max_retries: 1,
                backoff_secs: 0.0,
            },
        );
        let err = doomed.predict_many(&[&x]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("batch 0 node 0"), "{msg}");
        assert!(msg.contains("retries exhausted"), "{msg}");
        assert!(doomed.sim().faults() >= 2, "every attempt died");
    }

    #[test]
    fn set_beta_validates_length_and_applies_next_dispatch() {
        let model = tiny_model(48, 5);
        let s = serving(48, 5, 3);
        let mut rng = Rng::new(11);
        let x = Mat::from_fn(10, 5, |_, _| rng.normal_f32());
        let before = s.predict_batch(&x).unwrap();
        assert!(s.set_beta(&vec![0.0; 47]).is_err(), "wrong length");
        let doubled: Vec<f32> = model.beta.iter().map(|b| 2.0 * b).collect();
        s.set_beta(&doubled).unwrap();
        let after = s.predict_batch(&x).unwrap();
        for (a, b) in after.iter().zip(&before) {
            assert!((a - 2.0 * b).abs() <= 1e-5, "{a} vs 2·{b}");
        }
        assert_eq!(s.batches_served(), 2);
        assert_eq!(s.rows_served(), 20);
    }
}
