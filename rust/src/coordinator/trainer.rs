//! One-shot training entry points and the trained-model bundle.
//!
//! [`train`] and [`train_stagewise`] are thin wrappers over the stateful
//! [`Session`](super::session::Session) handle — build once, solve (and
//! grow) on the live cluster, snapshot the output. All the Algorithm-1
//! sequencing (sharding → basis → kernel → TRON) lives in
//! [`super::session`]; these wrappers only adapt it to the fire-and-forget
//! shape the benches and simple callers want.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{Cluster, CostModel, SimClock};
use crate::config::settings::{Loss, Settings};
use crate::data::{shard_rows, Dataset};
use crate::linalg::Mat;
use crate::metrics::Metrics;
use crate::runtime::Compute;
use crate::Result;

use super::node::WorkerNode;
use super::session::{growth_settings, Session};
use super::solver::SolveStats;

/// A trained formulation-(4) kernel machine.
#[derive(Clone)]
pub struct TrainedModel {
    /// m × d basis points z̄_k.
    pub basis: Mat,
    /// Expansion coefficients β.
    pub beta: Vec<f32>,
    /// Gaussian kernel 1/(2σ²).
    pub gamma: f32,
    pub loss: Loss,
}

impl TrainedModel {
    /// Decision values for a feature matrix (serial coordinator loop; use
    /// [`Session::predict`](super::session::Session::predict) for the
    /// distributed, metered path on a live cluster).
    pub fn predict(&self, backend: &dyn Compute, x: &Mat) -> Result<Vec<f32>> {
        super::predict::predict(backend, self, x)
    }

    /// Test accuracy.
    pub fn accuracy(&self, backend: &dyn Compute, test: &Dataset) -> Result<f64> {
        let scores = self.predict(backend, &test.x)?;
        Ok(crate::metrics::accuracy(&scores, &test.y))
    }

    /// Serialize to `path` (see [`super::model_io`] for the format); the
    /// loaded model predicts bit-identically.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        super::model_io::save(self, path)
    }

    /// Load a model previously written by [`TrainedModel::save`].
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<TrainedModel> {
        super::model_io::load(path)
    }
}

/// Everything a training run produces.
pub struct TrainOutput {
    pub model: TrainedModel,
    pub stats: SolveStats,
    /// Wall-clock per Algorithm-1 step (single-core reality).
    pub wall: Metrics,
    /// Simulated p-node ledger (compute max per phase + C + D·B comm).
    pub sim: SimClock,
    /// f/g and Hd evaluation counts (the 4a/4b/4c call counts).
    pub fg_evals: usize,
    pub hd_evals: usize,
    /// Peak C-block bytes held by any node (the `--c-storage` dial).
    pub peak_c_bytes: usize,
    /// Peak bytes of the streamed-row W-share cache on any node (streaming
    /// modes with a training-row basis; reported apart from the C block).
    pub peak_w_cache_bytes: usize,
    /// Kernel-tile recomputations across all nodes (streaming overhead;
    /// also charged to the sim ledger as FLOPs).
    pub recomputed_tiles: u64,
}

/// Step 1: shard the training set over p nodes. The cluster starts on the
/// serial executor; the session swaps in `Settings::executor` right after
/// (results are bit-identical either way — only wall-clock changes).
pub fn build_cluster(
    train: &Dataset,
    p: usize,
    dpad: usize,
    cost: CostModel,
) -> Cluster<WorkerNode> {
    let shards = shard_rows(train.n(), p);
    let nodes: Vec<WorkerNode> = shards
        .iter()
        .map(|r| {
            let idx: Vec<usize> = r.clone().collect();
            WorkerNode::new(train.x.gather_rows(&idx), train.y[r.clone()].to_vec(), dpad)
        })
        .collect();
    Cluster::new(nodes, 2, cost)
}

/// Full Algorithm-1 run: build a [`Session`], solve once, snapshot.
pub fn train(
    settings: &Settings,
    train_ds: &Dataset,
    backend: Arc<dyn Compute>,
    cost: CostModel,
) -> Result<TrainOutput> {
    let mut session = Session::build(settings, train_ds, backend, cost)?;
    let solve = session.solve()?;
    Ok(session.into_output(solve))
}

/// One stage of a stage-wise run.
pub struct StageOutput {
    pub m: usize,
    pub model: TrainedModel,
    pub stats: SolveStats,
    pub stage_wall_secs: f64,
    /// Cumulative kernel-tile recomputations across nodes at stage end
    /// (nonzero only for streaming storage).
    pub recomputed_tiles: u64,
}

/// Stage-wise basis addition (§3): train at stages[0], then repeatedly add
/// basis points and re-optimize with β warm-started by zero-extension —
/// "one can use the β obtained for a set of basis points to initialize a
/// good β when new basis points are added" — recomputing only the new
/// columns of C. The configured basis method is honored for the initial
/// stage; combinations growth cannot support (`--basis kmeans` with more
/// than one stage) are rejected with a clear error, and `auto` resolves
/// to the growth-capable random selection (see
/// [`growth_settings`](super::session::growth_settings)).
pub fn train_stagewise(
    settings: &Settings,
    train_ds: &Dataset,
    backend: Arc<dyn Compute>,
    cost: CostModel,
    stages: &[usize],
) -> Result<Vec<StageOutput>> {
    let staged = growth_settings(settings, stages)?;
    let t_build = Instant::now();
    let mut session = Session::build(&staged, train_ds, backend, cost)?;
    let build_secs = t_build.elapsed().as_secs_f64();

    let mut outputs = Vec::with_capacity(stages.len());
    for (i, &m) in stages.iter().enumerate() {
        let t0 = Instant::now();
        if i > 0 {
            session.grow_basis(m)?;
        }
        let solve = session.solve()?;
        let mut stage_wall_secs = t0.elapsed().as_secs_f64();
        if i == 0 {
            // The first stage pays the build (shard + basis + full C).
            stage_wall_secs += build_secs;
        }
        outputs.push(StageOutput {
            m,
            model: session.model(),
            stats: solve.stats,
            stage_wall_secs,
            recomputed_tiles: solve.recomputed_tiles,
        });
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::{Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice};
    use crate::data::synth;
    use crate::metrics::Step;
    use crate::runtime::make_backend;

    fn tiny_settings(m: usize, nodes: usize) -> Settings {
        Settings {
            dataset: "covtype_like".into(),
            m,
            nodes,
            lambda: 0.01,
            sigma: 2.0,
            loss: Loss::SqHinge,
            basis: BasisSelection::Random,
            backend: Backend::Native,
            executor: ExecutorChoice::Serial,
            c_storage: CStorage::Materialized,
            eval_pipeline: EvalPipeline::Fused,
            max_iters: 60,
            kmeans_iters: 2,
            kmeans_max_m: 512,
            ..Settings::default()
        }
    }

    fn tiny_data() -> (Dataset, Dataset) {
        let mut spec = synth::spec("covtype_like");
        spec.n_train = 1200;
        spec.n_test = 400;
        synth::generate(&spec, 5)
    }

    #[test]
    fn trains_above_chance_and_better_with_more_basis() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let small = train(
            &tiny_settings(16, 4),
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        let big = train(
            &tiny_settings(256, 4),
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        let acc_small = small.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        let acc_big = big.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(acc_small > 0.5, "small-m accuracy {acc_small}");
        assert!(acc_big > acc_small - 0.02, "{acc_big} vs {acc_small}");
        assert!(acc_big > 0.6, "big-m accuracy {acc_big}");
    }

    #[test]
    fn objective_decreases_and_counts_recorded() {
        let (train_ds, _) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let out = train(
            &tiny_settings(64, 3),
            &train_ds,
            backend,
            CostModel::free(),
        )
        .unwrap();
        assert!(out.stats.curve.len() >= 2);
        assert!(out.stats.final_f < out.stats.f0());
        assert!(out.fg_evals >= out.stats.iterations);
        assert!(out.hd_evals >= 1);
        assert!(out.wall.wall_secs(Step::Kernel) > 0.0);
    }

    #[test]
    fn node_count_does_not_change_the_model_much() {
        // The distributed objective is identical for any p. The random
        // basis SAMPLE differs across p (each node draws its own share), so
        // accuracies agree only statistically; reruns at the same p must be
        // bit-identical.
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut accs = Vec::new();
        for p in [1, 5, 5] {
            let out = train(
                &tiny_settings(96, p),
                &train_ds,
                Arc::clone(&backend),
                CostModel::free(),
            )
            .unwrap();
            accs.push(out.model.accuracy(backend.as_ref(), &test_ds).unwrap());
        }
        assert_eq!(accs[1], accs[2], "same p, same seed must reproduce");
        assert!(
            (accs[0] - accs[1]).abs() < 0.08,
            "p=1: {} vs p=5: {}",
            accs[0],
            accs[1]
        );
    }

    #[test]
    fn kmeans_basis_path_trains() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut s = tiny_settings(24, 3);
        s.basis = BasisSelection::KMeans;
        let out = train(&s, &train_ds, Arc::clone(&backend), CostModel::free()).unwrap();
        let acc = out.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(acc > 0.52, "kmeans-basis accuracy {acc}");
        assert!(out.sim.step_secs(Step::KMeans) > 0.0);
    }

    #[test]
    fn stagewise_warm_start_reaches_same_quality() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let s = tiny_settings(0, 4); // m overridden by stages
        let stages = train_stagewise(
            &s,
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
            &[32, 96, 192],
        )
        .unwrap();
        assert_eq!(stages.len(), 3);
        let cold = train(
            &tiny_settings(192, 4),
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        let acc_staged = stages[2]
            .model
            .accuracy(backend.as_ref(), &test_ds)
            .unwrap();
        let acc_cold = cold.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(
            (acc_staged - acc_cold).abs() < 0.05,
            "staged {acc_staged} vs cold {acc_cold}"
        );
        // Later stages should need no more iterations than a cold start
        // (warm start benefit) — allow slack for stochastic variation.
        assert!(stages[2].stats.iterations <= cold.stats.iterations + 20);
    }

    #[test]
    fn stagewise_kmeans_initial_stage_honored_and_growth_rejected() {
        let (train_ds, _) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut s = tiny_settings(0, 3);
        s.basis = BasisSelection::KMeans;
        // Single stage: the configured k-means method is honored (the old
        // path silently used random selection here).
        let one = train_stagewise(
            &s,
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
            &[24],
        )
        .unwrap();
        assert_eq!(one.len(), 1);
        // Multi-stage: rejected with a pointed error instead of silently
        // ignoring --basis kmeans.
        let err = train_stagewise(
            &s,
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
            &[24, 48],
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("kmeans"), "{err:#}");
        // Auto resolves to the growth-capable random policy.
        s.basis = BasisSelection::Auto;
        let staged = train_stagewise(
            &s,
            &train_ds,
            backend,
            CostModel::free(),
            &[24, 48],
        )
        .unwrap();
        assert_eq!(staged.len(), 2);
        assert_eq!(staged[1].model.beta.len(), 48);
    }

    #[test]
    fn all_losses_train() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        for loss in [Loss::SqHinge, Loss::Logistic, Loss::Squared] {
            let mut s = tiny_settings(64, 2);
            s.loss = loss;
            if loss == Loss::Logistic {
                s.lambda = 0.001;
            }
            let out = train(&s, &train_ds, Arc::clone(&backend), CostModel::free()).unwrap();
            let acc = out.model.accuracy(backend.as_ref(), &test_ds).unwrap();
            assert!(acc > 0.52, "{}: accuracy {acc}", loss.name());
        }
    }
}
