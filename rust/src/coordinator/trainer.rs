//! The Algorithm-1 driver: data loading → basis communication → kernel
//! computation → TRON optimization, with per-step wall timers and the
//! simulated cluster ledger. Also the stage-wise training mode of §3.

use std::sync::Arc;

use crate::cluster::{Cluster, CostModel, SimClock};
use crate::config::settings::{Loss, Settings};
use crate::data::{shard_rows, Dataset};
use crate::linalg::Mat;
use crate::metrics::{Metrics, Step};
use crate::runtime::Compute;
use crate::Result;

use super::basis::{self, Basis};
use super::cstore::CBlockStore;
use super::dist::DistProblem;
use super::node::WorkerNode;
use super::tron::{self, TronOptions, TronStats};

/// A trained formulation-(4) kernel machine.
#[derive(Clone)]
pub struct TrainedModel {
    /// m × d basis points z̄_k.
    pub basis: Mat,
    /// Expansion coefficients β.
    pub beta: Vec<f32>,
    /// Gaussian kernel 1/(2σ²).
    pub gamma: f32,
    pub loss: Loss,
}

impl TrainedModel {
    /// Decision values for a feature matrix.
    pub fn predict(&self, backend: &dyn Compute, x: &Mat) -> Result<Vec<f32>> {
        super::predict::predict(backend, self, x)
    }

    /// Test accuracy.
    pub fn accuracy(&self, backend: &dyn Compute, test: &Dataset) -> Result<f64> {
        let scores = self.predict(backend, &test.x)?;
        Ok(crate::metrics::accuracy(&scores, &test.y))
    }
}

/// Everything a training run produces.
pub struct TrainOutput {
    pub model: TrainedModel,
    pub stats: TronStats,
    /// Wall-clock per Algorithm-1 step (single-core reality).
    pub wall: Metrics,
    /// Simulated p-node ledger (compute max per phase + C + D·B comm).
    pub sim: SimClock,
    /// f/g and Hd evaluation counts (the 4a/4b/4c call counts).
    pub fg_evals: usize,
    pub hd_evals: usize,
    /// Peak C-block bytes held by any node (the `--c-storage` dial).
    pub peak_c_bytes: usize,
    /// Peak bytes of the streamed-row W-share cache on any node (streaming
    /// modes with a training-row basis; reported apart from the C block).
    pub peak_w_cache_bytes: usize,
    /// Kernel-tile recomputations across all nodes (streaming overhead;
    /// also charged to the sim ledger as FLOPs).
    pub recomputed_tiles: u64,
}

/// FLOPs of one RBF kernel-tile computation at padded width `dpad` (the
/// 2·TB·TM·D inner-product count the micro bench uses).
fn kernel_tile_flops(dpad: usize) -> u64 {
    2 * (crate::runtime::tiles::TB * crate::runtime::tiles::TM * dpad) as u64
}

/// Step 1: shard the training set over p nodes. The cluster starts on the
/// serial executor; the trainer swaps in `Settings::executor` right after
/// (results are bit-identical either way — only wall-clock changes).
pub fn build_cluster(
    train: &Dataset,
    p: usize,
    dpad: usize,
    cost: CostModel,
) -> Cluster<WorkerNode> {
    let shards = shard_rows(train.n(), p);
    let nodes: Vec<WorkerNode> = shards
        .iter()
        .map(|r| {
            let idx: Vec<usize> = r.clone().collect();
            WorkerNode::new(train.x.gather_rows(&idx), train.y[r.clone()].to_vec(), dpad)
        })
        .collect();
    Cluster::new(nodes, 2, cost)
}

/// Full Algorithm-1 run.
pub fn train(
    settings: &Settings,
    train_ds: &Dataset,
    backend: Arc<dyn Compute>,
    cost: CostModel,
) -> Result<TrainOutput> {
    settings.validate()?;
    let mut wall = Metrics::new();
    let dpad = backend.pad_d(train_ds.d())?;

    // Step 1: data loading / sharding.
    let mut cluster = wall.time(Step::Load, || {
        build_cluster(train_ds, settings.nodes, dpad, cost)
    });
    cluster.set_executor(settings.executor.to_executor());
    for node in cluster.nodes_mut() {
        node.set_c_storage(settings.c_storage, settings.c_memory_budget);
    }
    // Simulated: each node ingests its n/p shard (disk-bound in the paper;
    // we charge the measured shard-build time as the compute part).
    let load_wall = wall.wall_secs(Step::Load);
    cluster.clock.add_compute(Step::Load, load_wall / settings.nodes as f64);

    // Steps 2 (+ K-means when enabled): basis selection & broadcast.
    let basis_sel = wall.time(Step::BasisBcast, || {
        basis::select(&mut cluster, &backend, settings, train_ds.d(), dpad)
    })?;

    // Step 3: kernel computation (C row blocks; W shares).
    wall.time(Step::Kernel, || -> Result<()> {
        basis::install_w_shares(&mut cluster, &backend, &basis_sel, settings.gamma(), dpad)?;
        let m = basis_sel.m();
        let gamma = settings.gamma();
        // Prepare the basis tiles once; all nodes (and the streaming
        // stores, for the life of the run) share the same operands.
        let z_prep = Arc::new(
            basis_sel
                .z_tiles
                .iter()
                .map(|t| backend.prepare(t, &[crate::runtime::tiles::TM, dpad]))
                .collect::<Result<Vec<_>>>()?,
        );
        let backend2 = Arc::clone(&backend);
        let col_tiles = basis_sel.col_tiles();
        cluster.try_par_compute(Step::Kernel, |_, node| {
            node.compute_c_block_p(backend2.as_ref(), &z_prep, m, gamma, 0..col_tiles)?;
            node.prepare_hot(backend2.as_ref())
        })?;
        Ok(())
    })?;

    // Step 4: TRON on the master.
    let (beta, stats, fg, hd) = wall.time(Step::Tron, || -> Result<_> {
        let mut problem = DistProblem::new(
            &mut cluster,
            Arc::clone(&backend),
            basis_sel.m(),
            settings.lambda,
            settings.loss,
        )
        .with_pipeline(settings.eval_pipeline);
        let opts = TronOptions {
            tol: settings.tol,
            max_iters: settings.max_iters,
            ..TronOptions::default()
        };
        let beta0 = vec![0.0f32; basis_sel.m()];
        let (beta, stats) = tron::minimize(&mut problem, &beta0, &opts)?;
        Ok((beta, stats, problem.fg_evals, problem.hd_evals))
    })?;

    // Honest memory/compute accounting for the storage mode: peak C bytes
    // held per node, and the kernel-tile recompute charged to the ledger.
    let mut recomputed_tiles = 0u64;
    let mut peak_c_bytes = 0usize;
    let mut peak_w_cache_bytes = 0usize;
    for j in 0..cluster.p() {
        let store = &cluster.node(j).cstore;
        recomputed_tiles += store.recomputed_tiles();
        peak_c_bytes = peak_c_bytes.max(store.peak_c_bytes());
        peak_w_cache_bytes = peak_w_cache_bytes.max(store.w_cache_bytes());
    }
    cluster
        .clock
        .add_recompute_flops(recomputed_tiles * kernel_tile_flops(dpad));
    // Mirror the ledger's synchronization counters into the wall metrics
    // so both reports can show rounds next to seconds.
    wall.bump("barriers", cluster.clock.barriers());
    wall.bump("comm_rounds", cluster.clock.comm_rounds());

    Ok(TrainOutput {
        model: TrainedModel {
            basis: basis_sel.z,
            beta,
            gamma: settings.gamma(),
            loss: settings.loss,
        },
        stats,
        wall,
        sim: cluster.clock,
        fg_evals: fg,
        hd_evals: hd,
        peak_c_bytes,
        peak_w_cache_bytes,
        recomputed_tiles,
    })
}

/// One stage of a stage-wise run.
pub struct StageOutput {
    pub m: usize,
    pub model: TrainedModel,
    pub stats: TronStats,
    pub stage_wall_secs: f64,
    /// Cumulative kernel-tile recomputations across nodes at stage end
    /// (nonzero only for streaming storage).
    pub recomputed_tiles: u64,
}

/// Stage-wise basis addition (§3): train at stages[0], then repeatedly add
/// basis points and re-optimize with β warm-started by zero-extension —
/// "one can use the β obtained for a set of basis points to initialize a
/// good β when new basis points are added" — recomputing only the new
/// columns of C.
pub fn train_stagewise(
    settings: &Settings,
    train_ds: &Dataset,
    backend: Arc<dyn Compute>,
    cost: CostModel,
    stages: &[usize],
) -> Result<Vec<StageOutput>> {
    anyhow::ensure!(!stages.is_empty(), "need at least one stage");
    anyhow::ensure!(
        stages.windows(2).all(|w| w[1] > w[0]),
        "stages must be strictly increasing"
    );
    let dpad = backend.pad_d(train_ds.d())?;
    let mut cluster = build_cluster(train_ds, settings.nodes, dpad, cost);
    cluster.set_executor(settings.executor.to_executor());
    for node in cluster.nodes_mut() {
        node.set_c_storage(settings.c_storage, settings.c_memory_budget);
    }

    let mut outputs = Vec::new();
    let mut basis_sel: Option<Basis> = None;
    let mut beta: Vec<f32> = Vec::new();

    for &m in stages {
        let stage_start = std::time::Instant::now();
        // Grow (or create) the basis; only dirty C column tiles recompute.
        let dirty = match basis_sel.as_mut() {
            None => {
                let b = basis::select_random(&mut cluster, m, train_ds.d(), dpad, settings.seed)?;
                basis_sel = Some(b);
                0..basis_sel.as_ref().unwrap().col_tiles()
            }
            Some(b) => {
                let old_cols = b.m();
                basis::grow_random(
                    &mut cluster,
                    b,
                    m - old_cols,
                    train_ds.d(),
                    dpad,
                    settings.seed ^ m as u64,
                )?;
                // Dirty tiles: the one containing old_cols (partial) onward.
                (old_cols / crate::runtime::tiles::TM)..b.col_tiles()
            }
        };
        let b = basis_sel.as_ref().unwrap();
        basis::install_w_shares(&mut cluster, &backend, b, settings.gamma(), dpad)?;
        let gamma = settings.gamma();
        let z_prep = Arc::new(
            b.z_tiles
                .iter()
                .map(|t| backend.prepare(t, &[crate::runtime::tiles::TM, dpad]))
                .collect::<Result<Vec<_>>>()?,
        );
        let backend2 = Arc::clone(&backend);
        cluster.try_par_compute(Step::Kernel, |_, node| {
            node.compute_c_block_p(backend2.as_ref(), &z_prep, m, gamma, dirty.clone())?;
            node.prepare_hot(backend2.as_ref())
        })?;

        // Warm start: zero-extend β for the new points.
        beta.resize(m, 0.0);
        let mut problem = DistProblem::new(
            &mut cluster,
            Arc::clone(&backend),
            m,
            settings.lambda,
            settings.loss,
        )
        .with_pipeline(settings.eval_pipeline);
        let opts = TronOptions {
            tol: settings.tol,
            max_iters: settings.max_iters,
            ..TronOptions::default()
        };
        let (beta_new, stats) = tron::minimize(&mut problem, &beta, &opts)?;
        beta = beta_new;
        let recomputed_tiles = (0..cluster.p())
            .map(|j| cluster.node(j).cstore.recomputed_tiles())
            .sum();
        outputs.push(StageOutput {
            m,
            model: TrainedModel {
                basis: b.z.clone(),
                beta: beta.clone(),
                gamma: settings.gamma(),
                loss: settings.loss,
            },
            stats,
            stage_wall_secs: stage_start.elapsed().as_secs_f64(),
            recomputed_tiles,
        });
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::{Backend, BasisSelection, CStorage, EvalPipeline, ExecutorChoice};
    use crate::data::synth;
    use crate::runtime::make_backend;

    fn tiny_settings(m: usize, nodes: usize) -> Settings {
        Settings {
            dataset: "covtype_like".into(),
            m,
            nodes,
            lambda: 0.01,
            sigma: 2.0,
            loss: Loss::SqHinge,
            basis: BasisSelection::Random,
            backend: Backend::Native,
            executor: ExecutorChoice::Serial,
            c_storage: CStorage::Materialized,
            eval_pipeline: EvalPipeline::Fused,
            c_memory_budget: 256 << 20,
            max_iters: 60,
            tol: 1e-3,
            seed: 42,
            kmeans_iters: 2,
            kmeans_max_m: 512,
            artifacts_dir: "artifacts".into(),
        }
    }

    fn tiny_data() -> (Dataset, Dataset) {
        let mut spec = synth::spec("covtype_like");
        spec.n_train = 1200;
        spec.n_test = 400;
        synth::generate(&spec, 5)
    }

    #[test]
    fn trains_above_chance_and_better_with_more_basis() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let small = train(
            &tiny_settings(16, 4),
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        let big = train(
            &tiny_settings(256, 4),
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        let acc_small = small.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        let acc_big = big.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(acc_small > 0.5, "small-m accuracy {acc_small}");
        assert!(acc_big > acc_small - 0.02, "{acc_big} vs {acc_small}");
        assert!(acc_big > 0.6, "big-m accuracy {acc_big}");
    }

    #[test]
    fn objective_decreases_and_counts_recorded() {
        let (train_ds, _) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let out = train(
            &tiny_settings(64, 3),
            &train_ds,
            backend,
            CostModel::free(),
        )
        .unwrap();
        assert!(out.stats.f_history.len() >= 2);
        assert!(out.stats.final_f < out.stats.f_history[0]);
        assert!(out.fg_evals >= out.stats.iterations);
        assert!(out.hd_evals >= 1);
        assert!(out.wall.wall_secs(Step::Kernel) > 0.0);
    }

    #[test]
    fn node_count_does_not_change_the_model_much() {
        // The distributed objective is identical for any p. The random
        // basis SAMPLE differs across p (each node draws its own share), so
        // accuracies agree only statistically; reruns at the same p must be
        // bit-identical.
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut accs = Vec::new();
        for p in [1, 5, 5] {
            let out = train(
                &tiny_settings(96, p),
                &train_ds,
                Arc::clone(&backend),
                CostModel::free(),
            )
            .unwrap();
            accs.push(out.model.accuracy(backend.as_ref(), &test_ds).unwrap());
        }
        assert_eq!(accs[1], accs[2], "same p, same seed must reproduce");
        assert!(
            (accs[0] - accs[1]).abs() < 0.08,
            "p=1: {} vs p=5: {}",
            accs[0],
            accs[1]
        );
    }

    #[test]
    fn kmeans_basis_path_trains() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut s = tiny_settings(24, 3);
        s.basis = BasisSelection::KMeans;
        let out = train(&s, &train_ds, Arc::clone(&backend), CostModel::free()).unwrap();
        let acc = out.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(acc > 0.52, "kmeans-basis accuracy {acc}");
        assert!(out.sim.step_secs(Step::KMeans) > 0.0);
    }

    #[test]
    fn stagewise_warm_start_reaches_same_quality() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let s = tiny_settings(0, 4); // m overridden by stages
        let stages = train_stagewise(
            &s,
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
            &[32, 96, 192],
        )
        .unwrap();
        assert_eq!(stages.len(), 3);
        let cold = train(
            &tiny_settings(192, 4),
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        let acc_staged = stages[2]
            .model
            .accuracy(backend.as_ref(), &test_ds)
            .unwrap();
        let acc_cold = cold.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(
            (acc_staged - acc_cold).abs() < 0.05,
            "staged {acc_staged} vs cold {acc_cold}"
        );
        // Later stages should need no more iterations than a cold start
        // (warm start benefit) — allow slack for stochastic variation.
        assert!(stages[2].stats.iterations <= cold.stats.iterations + 20);
    }

    #[test]
    fn all_losses_train() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        for loss in [Loss::SqHinge, Loss::Logistic, Loss::Squared] {
            let mut s = tiny_settings(64, 2);
            s.loss = loss;
            if loss == Loss::Logistic {
                s.lambda = 0.001;
            }
            let out = train(&s, &train_ds, Arc::clone(&backend), CostModel::free()).unwrap();
            let acc = out.model.accuracy(backend.as_ref(), &test_ds).unwrap();
            assert!(acc > 0.52, "{}: accuracy {acc}", loss.name());
        }
    }
}
