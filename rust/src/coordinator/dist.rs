//! Steps 4a–4c of Algorithm 1: distributed evaluation of f, ∇f and H·d for
//!
//! ```text
//! f(β) = λ/2 βᵀWβ + L(Cβ, y)
//! ∇f   = λWβ + Cᵀ D (Cβ − y)
//! H·d  = λWd + Cᵀ D C d
//! ```
//!
//! Per evaluation: β (or d) is broadcast down the tree; every node computes
//! its row-block partials with tile ops on the compute backend; partial
//! m-vectors and scalars are AllReduce-summed back up. The master (node 0)
//! then assembles f/g/Hd — all O(m) work, exactly the paper's split.

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::config::settings::Loss;
use crate::metrics::Step;
use crate::runtime::tiles::TM;
use crate::runtime::Compute;
use crate::Result;

use super::cstore::CBlockStore;
use super::node::{pad_m_tiles, unpad_m_tiles, WorkerNode};
use super::tron::Objective;

/// The distributed formulation-(4) objective over a simulated cluster.
pub struct DistProblem<'a> {
    pub cluster: &'a mut Cluster<WorkerNode>,
    pub backend: Arc<dyn Compute>,
    pub m: usize,
    pub lambda: f32,
    pub loss: Loss,
    /// Count of fg / hd evaluations (the 4a/4b/4c call counts of §4.4).
    pub fg_evals: usize,
    pub hd_evals: usize,
}

impl<'a> DistProblem<'a> {
    pub fn new(
        cluster: &'a mut Cluster<WorkerNode>,
        backend: Arc<dyn Compute>,
        m: usize,
        lambda: f32,
        loss: Loss,
    ) -> Self {
        DistProblem {
            cluster,
            backend,
            m,
            lambda,
            loss,
            fg_evals: 0,
            hd_evals: 0,
        }
    }

    fn col_tiles(&self) -> usize {
        self.m.div_ceil(TM).max(1)
    }

    /// Node-local loss+gradient partial for one node. Returns
    /// (loss_partial, reg_partial, grad_tiles) and refreshes the node's
    /// cached Gauss-Newton diagonal. All C applications go through the
    /// node's [`crate::coordinator::cstore::CBlockStore`], so the same code
    /// serves materialized and streaming storage bit-identically.
    fn node_fg(
        node: &mut WorkerNode,
        backend: &dyn Compute,
        loss: Loss,
        v_tiles: &[Vec<f32>],
        beta: &[f32],
        lambda: f32,
    ) -> Result<(f32, f32, Vec<Vec<f32>>)> {
        let ct = node.cstore.col_tiles();
        let mut loss_partial = 0.0f32;
        let mut grad_tiles = vec![vec![0.0f32; TM]; ct];
        assert!(
            node.cstore.ready(),
            "compute_c_block must run before TRON"
        );
        assert_eq!(
            node.y_prep.len(),
            node.row_tiles(),
            "prepare_hot must run before TRON"
        );
        for i in 0..node.row_tiles() {
            if ct == 1 {
                // Fused per-tile dispatch: one call instead of three (the
                // streaming store computes its kernel tile once inside it).
                let out = node.cstore.fgrad_tile(
                    backend,
                    loss,
                    i,
                    &v_tiles[0],
                    &node.y_prep[i],
                    &node.mask_prep[i],
                )?;
                loss_partial += out.loss;
                for (g, v) in grad_tiles[0].iter_mut().zip(&out.vec) {
                    *g += v;
                }
                node.dcoef_tiles[i] = out.dcoef;
            } else {
                // o = Σ_j C_ij β_j
                let mut o = vec![0.0f32; crate::runtime::tiles::TB];
                for j in 0..ct {
                    let part = node.cstore.matvec_tile(backend, i, j, &v_tiles[j])?;
                    for (a, b) in o.iter_mut().zip(&part) {
                        *a += b;
                    }
                }
                let stage = backend.loss_stage(loss, &o, &node.y_tiles[i], &node.masks[i])?;
                loss_partial += stage.loss;
                for j in 0..ct {
                    let part = node.cstore.matvec_t_tile(backend, i, j, &stage.vec)?;
                    for (g, v) in grad_tiles[j].iter_mut().zip(&part) {
                        *g += v;
                    }
                }
                node.dcoef_tiles[i] = stage.dcoef;
            }
        }
        // Regularizer part: this node's (Wβ) entries.
        let mut reg_partial = 0.0f32;
        for (k, wv) in node.wv_entries(backend, v_tiles)? {
            reg_partial += beta[k] * wv;
            grad_tiles[k / TM][k % TM] += lambda * wv;
        }
        Ok((loss_partial, reg_partial, grad_tiles))
    }

    /// Node-local Hd partial using the cached diagonal.
    fn node_hd(
        node: &WorkerNode,
        backend: &dyn Compute,
        v_tiles: &[Vec<f32>],
        lambda: f32,
    ) -> Result<Vec<Vec<f32>>> {
        let ct = node.cstore.col_tiles();
        let mut hd_tiles = vec![vec![0.0f32; TM]; ct];
        for i in 0..node.row_tiles() {
            if ct == 1 {
                let part =
                    node.cstore
                        .hd_tile(backend, i, &v_tiles[0], &node.dcoef_tiles[i])?;
                for (h, v) in hd_tiles[0].iter_mut().zip(&part) {
                    *h += v;
                }
            } else {
                let mut z = vec![0.0f32; crate::runtime::tiles::TB];
                for j in 0..ct {
                    let part = node.cstore.matvec_tile(backend, i, j, &v_tiles[j])?;
                    for (a, b) in z.iter_mut().zip(&part) {
                        *a += b;
                    }
                }
                for (zi, w) in z.iter_mut().zip(&node.dcoef_tiles[i]) {
                    *zi *= w;
                }
                for j in 0..ct {
                    let part = node.cstore.matvec_t_tile(backend, i, j, &z)?;
                    for (h, v) in hd_tiles[j].iter_mut().zip(&part) {
                        *h += v;
                    }
                }
            }
        }
        // λ(Wd) entries.
        for (k, wv) in node.wv_entries(backend, v_tiles)? {
            hd_tiles[k / TM][k % TM] += lambda * wv;
        }
        Ok(hd_tiles)
    }
}

impl Objective for DistProblem<'_> {
    fn dim(&self) -> usize {
        self.m
    }

    /// Steps 4a + 4b: broadcast β; nodes compute partials; two AllReduce
    /// instances (scalars for f, an m-vector for ∇f) — the paper's call
    /// structure.
    fn eval_fg(&mut self, beta: &[f32]) -> Result<(f64, Vec<f32>)> {
        assert_eq!(beta.len(), self.m);
        self.fg_evals += 1;
        let v_tiles = pad_m_tiles(beta, self.col_tiles());
        self.cluster
            .broadcast_meter(Step::Tron, self.m * std::mem::size_of::<f32>());
        let backend = Arc::clone(&self.backend);
        let loss = self.loss;
        let lambda = self.lambda;
        let partials = self.cluster.try_par_compute(Step::Tron, |_, node| {
            Self::node_fg(node, backend.as_ref(), loss, &v_tiles, beta, lambda)
        })?;
        // AllReduce 1: the two scalars (4a).
        let scalar_partials: Vec<Vec<f32>> = partials
            .iter()
            .map(|(l, r, _)| vec![*l, *r])
            .collect();
        let scalars = self.cluster.allreduce_sum(Step::Tron, scalar_partials);
        // AllReduce 2: the gradient m-vector (4b).
        let grad_partials: Vec<Vec<f32>> = partials
            .into_iter()
            .map(|(_, _, g)| g.concat())
            .collect();
        let grad_padded = self.cluster.allreduce_sum(Step::Tron, grad_partials);
        let grad_tiles: Vec<Vec<f32>> = grad_padded
            .chunks(TM)
            .map(|c| c.to_vec())
            .collect();
        let grad = unpad_m_tiles(&grad_tiles, self.m);
        let f = 0.5 * self.lambda as f64 * scalars[1] as f64 + scalars[0] as f64;
        Ok((f, grad))
    }

    /// Step 4c: same sequence as the gradient with β replaced by d and the
    /// cached D diagonal.
    fn eval_hd(&mut self, d: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(d.len(), self.m);
        self.hd_evals += 1;
        let v_tiles = pad_m_tiles(d, self.col_tiles());
        self.cluster
            .broadcast_meter(Step::Tron, self.m * std::mem::size_of::<f32>());
        let backend = Arc::clone(&self.backend);
        let lambda = self.lambda;
        let partials = self.cluster.try_par_compute(Step::Tron, |_, node| {
            Self::node_hd(node, backend.as_ref(), &v_tiles, lambda)
        })?;
        let hd_partials: Vec<Vec<f32>> = partials.into_iter().map(|t| t.concat()).collect();
        let hd_padded = self.cluster.allreduce_sum(Step::Tron, hd_partials);
        let hd_tiles: Vec<Vec<f32>> = hd_padded.chunks(TM).map(|c| c.to_vec()).collect();
        Ok(unpad_m_tiles(&hd_tiles, self.m))
    }
}
