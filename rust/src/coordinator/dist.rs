//! Steps 4a–4c of Algorithm 1: distributed evaluation of f, ∇f and H·d for
//!
//! ```text
//! f(β) = λ/2 βᵀWβ + L(Cβ, y)
//! ∇f   = λWβ + Cᵀ D (Cβ − y)
//! H·d  = λWd + Cᵀ D C d
//! ```
//!
//! Per evaluation: β (or d) is broadcast down the tree; every node computes
//! its row-block partials with tile ops on the compute backend; the partial
//! scalars and m-vector come back summed up the tree. The master (node 0)
//! then assembles f/g/Hd — all O(m) work, exactly the paper's split.
//!
//! Two pipelines drive the cluster, bit-identical by construction:
//!
//! * **Fused** (default): each node packs its two scalars and its padded
//!   gradient tiles into ONE flat buffer (`[loss, reg, grad…]`, length
//!   m_padded + 2) and the cluster's fused compute+reduce phase tree-sums
//!   it inside the same dispatch — one barrier and one AllReduce
//!   round-trip per f/g evaluation (and one per Hd). This is the
//!   communication-round optimization Hsieh et al. argue for when latency,
//!   not bytes, dominates.
//! * **Split**: the paper's literal call structure — a compute barrier,
//!   then a scalar AllReduce (4a) and an m-vector AllReduce (4b). Kept as
//!   the metering reference; both paths fold the same f32 partials in the
//!   same deterministic tree order, so β is bit-identical between them.

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::config::settings::{EvalPipeline, Loss};
use crate::metrics::Step;
use crate::runtime::tiles::TM;
use crate::runtime::Compute;
use crate::Result;

use super::cstore::CBlockStore;
use super::node::{pad_m_tiles, unpad_m_flat, WorkerNode};
use super::solver::Objective;

/// Leading scalar slots of the fused f/g reduce buffer: `[loss, reg]`.
const FG_SCALARS: usize = 2;

/// The distributed formulation-(4) objective over a simulated cluster.
pub struct DistProblem<'a> {
    pub cluster: &'a mut Cluster<WorkerNode>,
    pub backend: Arc<dyn Compute>,
    pub m: usize,
    pub lambda: f32,
    pub loss: Loss,
    /// Fused one-phase evaluations (default) or the split reference path.
    pub pipeline: EvalPipeline,
    /// Count of fg / hd evaluations (the 4a/4b/4c call counts of §4.4).
    pub fg_evals: usize,
    pub hd_evals: usize,
}

impl<'a> DistProblem<'a> {
    pub fn new(
        cluster: &'a mut Cluster<WorkerNode>,
        backend: Arc<dyn Compute>,
        m: usize,
        lambda: f32,
        loss: Loss,
    ) -> Self {
        DistProblem {
            cluster,
            backend,
            m,
            lambda,
            loss,
            pipeline: EvalPipeline::Fused,
            fg_evals: 0,
            hd_evals: 0,
        }
    }

    /// Builder-style pipeline selection.
    pub fn with_pipeline(mut self, pipeline: EvalPipeline) -> Self {
        self.pipeline = pipeline;
        self
    }

    fn col_tiles(&self) -> usize {
        self.m.div_ceil(TM).max(1)
    }

    /// Node-local loss+gradient partial for one node, emitted FLAT for the
    /// reduce tree: `out[0]` = loss partial, `out[1]` = βᵀ(Wβ) partial,
    /// `out[2..]` = the padded gradient (element k of ∇f at flat index
    /// `FG_SCALARS + k`). Also refreshes the node's cached Gauss-Newton
    /// diagonal. All C applications go through the node's
    /// [`crate::coordinator::cstore::CBlockStore`], so the same code
    /// serves materialized and streaming storage bit-identically.
    fn node_fg(
        node: &mut WorkerNode,
        backend: &dyn Compute,
        loss: Loss,
        v_tiles: &[Vec<f32>],
        beta: &[f32],
        lambda: f32,
    ) -> Result<Vec<f32>> {
        let ct = node.cstore.col_tiles();
        assert!(
            node.cstore.ready(),
            "compute_c_block must run before TRON"
        );
        assert_eq!(
            node.y_prep.len(),
            node.row_tiles(),
            "prepare_hot must run before TRON"
        );
        // ONE backend dispatch covers the whole C block — both matvec
        // halves of every (row tile × column tile) with the loss stage in
        // between, in the same accumulation order the per-tile loop used.
        let blk = node.cstore.fgrad_block(
            backend,
            loss,
            v_tiles,
            &node.y_prep,
            &node.mask_prep,
            &node.y_tiles,
            &node.masks,
        )?;
        let mut out = vec![0.0f32; FG_SCALARS + ct * TM];
        out[FG_SCALARS..].copy_from_slice(&blk.grad);
        node.dcoef_tiles = blk.dcoef;
        // Regularizer part: this node's (Wβ) entries. Flat tile layout puts
        // gradient element k at FG_SCALARS + k directly.
        let mut reg_partial = 0.0f32;
        for (k, wv) in node.wv_entries(backend, v_tiles)? {
            reg_partial += beta[k] * wv;
            out[FG_SCALARS + k] += lambda * wv;
        }
        out[0] = blk.loss;
        out[1] = reg_partial;
        Ok(out)
    }

    /// Node-local Hd partial using the cached diagonal, emitted FLAT
    /// (padded Hd element k at index k).
    fn node_hd(
        node: &WorkerNode,
        backend: &dyn Compute,
        v_tiles: &[Vec<f32>],
        lambda: f32,
    ) -> Result<Vec<f32>> {
        // ONE backend dispatch for the node's whole Hd partial.
        let mut out = node
            .cstore
            .hd_block(backend, v_tiles, &node.dcoef_tiles)?;
        // λ(Wd) entries.
        for (k, wv) in node.wv_entries(backend, v_tiles)? {
            out[k] += lambda * wv;
        }
        Ok(out)
    }

    /// Assemble f from the reduced `[loss, reg, …]` buffer head. Pub so
    /// every solver assembles the objective from reduced partials the same
    /// way (TRON's fused f/g buffer, BCD's per-round block buffer).
    pub fn assemble_f(&self, loss_sum: f32, reg_sum: f32) -> f64 {
        0.5 * self.lambda as f64 * reg_sum as f64 + loss_sum as f64
    }
}

impl Objective for DistProblem<'_> {
    fn dim(&self) -> usize {
        self.m
    }

    /// Ledger snapshot: simulated seconds and AllReduce round-trips spent
    /// by this problem's cluster so far (solvers stamp curve points with
    /// deltas from solve start).
    fn ledger(&self) -> (f64, u64) {
        (
            self.cluster.clock.total_secs(),
            self.cluster.clock.comm_rounds(),
        )
    }

    /// Steps 4a + 4b: broadcast β; nodes compute flat partials; the fused
    /// pipeline tree-sums scalars AND gradient in the same phase (one
    /// barrier + one AllReduce round-trip), the split pipeline replays the
    /// paper's compute barrier + two AllReduce instances.
    fn eval_fg(&mut self, beta: &[f32]) -> Result<(f64, Vec<f32>)> {
        assert_eq!(beta.len(), self.m);
        self.fg_evals += 1;
        let v_tiles = pad_m_tiles(beta, self.col_tiles());
        self.cluster
            .broadcast_meter(Step::Tron, self.m * std::mem::size_of::<f32>());
        let backend = Arc::clone(&self.backend);
        let loss = self.loss;
        let lambda = self.lambda;
        // Backend call-count delta around the evaluation = dispatches this
        // evaluation issued (one per node with the whole-node block ops).
        let calls0 = backend.call_count();
        let out = match self.pipeline {
            EvalPipeline::Fused => {
                let reduced = self.cluster.try_par_compute_reduce(Step::Tron, |_, node| {
                    Self::node_fg(node, backend.as_ref(), loss, &v_tiles, beta, lambda)
                })?;
                let f = self.assemble_f(reduced[0], reduced[1]);
                let grad = unpad_m_flat(&reduced[FG_SCALARS..], self.m);
                (f, grad)
            }
            EvalPipeline::Split => {
                let partials = self.cluster.try_par_compute(Step::Tron, |_, node| {
                    Self::node_fg(node, backend.as_ref(), loss, &v_tiles, beta, lambda)
                })?;
                // AllReduce 1: the two scalars (4a).
                let scalar_partials: Vec<Vec<f32>> =
                    partials.iter().map(|p| vec![p[0], p[1]]).collect();
                let scalars = self.cluster.allreduce_sum(Step::Tron, scalar_partials);
                // AllReduce 2: the gradient m-vector (4b).
                let grad_partials: Vec<Vec<f32>> = partials
                    .into_iter()
                    .map(|mut p| p.split_off(FG_SCALARS))
                    .collect();
                let grad_padded = self.cluster.allreduce_sum(Step::Tron, grad_partials);
                let f = self.assemble_f(scalars[0], scalars[1]);
                (f, unpad_m_flat(&grad_padded, self.m))
            }
        };
        self.cluster
            .charge_dispatches(backend.call_count().saturating_sub(calls0));
        Ok(out)
    }

    /// Step 4c: same sequence as the gradient with β replaced by d and the
    /// cached D diagonal (fused: one phase; split: barrier + AllReduce).
    fn eval_hd(&mut self, d: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(d.len(), self.m);
        self.hd_evals += 1;
        let v_tiles = pad_m_tiles(d, self.col_tiles());
        self.cluster
            .broadcast_meter(Step::Tron, self.m * std::mem::size_of::<f32>());
        let backend = Arc::clone(&self.backend);
        let lambda = self.lambda;
        let calls0 = backend.call_count();
        let out = match self.pipeline {
            EvalPipeline::Fused => {
                let reduced = self.cluster.try_par_compute_reduce(Step::Tron, |_, node| {
                    Self::node_hd(node, backend.as_ref(), &v_tiles, lambda)
                })?;
                unpad_m_flat(&reduced, self.m)
            }
            EvalPipeline::Split => {
                let partials = self.cluster.try_par_compute(Step::Tron, |_, node| {
                    Self::node_hd(node, backend.as_ref(), &v_tiles, lambda)
                })?;
                let hd_padded = self.cluster.allreduce_sum(Step::Tron, partials);
                unpad_m_flat(&hd_padded, self.m)
            }
        };
        self.cluster
            .charge_dispatches(backend.call_count().saturating_sub(calls0));
        Ok(out)
    }
}
