//! Per-node worker state for Algorithm 1.
//!
//! Each of the p nodes owns a row shard of the training data, the matching
//! row block of C (tiled for the fixed-shape AOT modules), and its share of
//! W: either references into its own C rows (random basis ⊂ training set —
//! the paper's step-3 observation that "the corresponding row block of W is
//! a subset of the C row block") or an explicitly computed W row block
//! (K-means basis, which is not a subset — §3.2).

use crate::linalg::Mat;
use crate::runtime::backend::Prepared;
use crate::runtime::tiles::{row_masks, TiledMatrix, TB, TM};
use crate::runtime::Compute;
use crate::Result;

/// How this node's share of W is represented.
#[derive(Clone, Debug)]
pub enum WShare {
    /// Basis points are training rows: (local_row, global_basis_index)
    /// pairs — W rows come for free from C rows.
    FromC(Vec<(usize, usize)>),
    /// Explicit W row block for global basis indices [k0, k0+rows):
    /// computed kernel values (rows × m), tiled.
    Explicit { k0: usize, block: TiledMatrix },
}

/// One simulated worker node.
pub struct WorkerNode {
    /// Local feature shard (n_j × d), unpadded.
    pub x: Mat,
    /// Local labels.
    pub y: Vec<f32>,
    /// Feature row tiles padded to (TB × dpad), one per row tile.
    pub x_tiles: Vec<Vec<f32>>,
    /// Row-validity masks per tile.
    pub masks: Vec<Vec<f32>>,
    /// Label tiles (padded with zeros).
    pub y_tiles: Vec<Vec<f32>>,
    /// Kernel row block C_j (n_j × m), tiled.
    pub c: TiledMatrix,
    /// This node's share of W.
    pub w_share: WShare,
    /// Cached Gauss-Newton diagonal per row tile (from the last f/g eval at
    /// the current β) — consumed by the Hd products of step 4c.
    pub dcoef_tiles: Vec<Vec<f32>>,
    /// Padded feature width in use.
    pub dpad: usize,
    /// Prepared (device-resident on PJRT) operands for the TRON hot path:
    /// C tiles, labels and masks. Built by [`WorkerNode::prepare_hot`]
    /// after step 3; every f/g/Hd call then ships only O(TB + TM) bytes.
    pub c_prep: Vec<Vec<Prepared>>,
    pub y_prep: Vec<Prepared>,
    pub mask_prep: Vec<Prepared>,
    /// Prepared explicit W row-block tiles (K-means basis only).
    pub w_prep: Vec<Vec<Prepared>>,
    /// Prepared feature row tiles (for repeated kernel-tile calls).
    pub x_prep: Vec<Prepared>,
}

impl WorkerNode {
    /// Build a node from its data shard (pads feature tiles; C comes later
    /// in step 3).
    pub fn new(x: Mat, y: Vec<f32>, dpad: usize) -> Self {
        assert!(dpad >= x.cols());
        let n_j = x.rows();
        let x_tiles = pad_feature_tiles(&x, dpad);
        let masks = row_masks(n_j);
        let y_tiles = pad_label_tiles(&y);
        WorkerNode {
            c: TiledMatrix::zeros(n_j, 0),
            dcoef_tiles: vec![vec![0.0; TB]; x_tiles.len()],
            x: x.clone(),
            y,
            x_tiles,
            masks,
            y_tiles,
            w_share: WShare::FromC(Vec::new()),
            dpad,
            c_prep: Vec::new(),
            y_prep: Vec::new(),
            mask_prep: Vec::new(),
            w_prep: Vec::new(),
            x_prep: Vec::new(),
        }
    }

    /// Prepare the hot-path operands (one upload per C tile; labels and
    /// masks once). Must be called after [`WorkerNode::compute_c_block`]
    /// and again after any stage-wise growth.
    pub fn prepare_hot(&mut self, backend: &dyn Compute) -> Result<()> {
        self.c_prep.clear();
        for i in 0..self.c.row_tiles() {
            let mut row = Vec::with_capacity(self.c.col_tiles());
            for j in 0..self.c.col_tiles() {
                row.push(backend.prepare(self.c.tile(i, j), &[TB, TM])?);
            }
            self.c_prep.push(row);
        }
        if self.y_prep.len() != self.y_tiles.len() {
            self.y_prep = self
                .y_tiles
                .iter()
                .map(|t| backend.prepare(t, &[TB]))
                .collect::<Result<_>>()?;
            self.mask_prep = self
                .masks
                .iter()
                .map(|t| backend.prepare(t, &[TB]))
                .collect::<Result<_>>()?;
        }
        self.w_prep.clear();
        if let WShare::Explicit { block, .. } = &self.w_share {
            for i in 0..block.row_tiles() {
                let mut row = Vec::with_capacity(block.col_tiles());
                for j in 0..block.col_tiles() {
                    row.push(backend.prepare(block.tile(i, j), &[TB, TM])?);
                }
                self.w_prep.push(row);
            }
        }
        Ok(())
    }

    pub fn n_local(&self) -> usize {
        self.x.rows()
    }

    pub fn row_tiles(&self) -> usize {
        self.x_tiles.len()
    }

    /// Step 3: (re)compute the C row block columns for basis tiles
    /// `dirty_cols` against the padded basis tiles `z_tiles`. Convenience
    /// wrapper that prepares z locally; the trainer uses
    /// [`WorkerNode::compute_c_block_p`] with basis tiles prepared once and
    /// shared across nodes.
    pub fn compute_c_block(
        &mut self,
        backend: &dyn Compute,
        z_tiles: &[Vec<f32>],
        m: usize,
        gamma: f32,
        dirty_cols: std::ops::Range<usize>,
    ) -> Result<()> {
        let z_prep: Vec<Prepared> = z_tiles
            .iter()
            .map(|t| backend.prepare(t, &[TM, self.dpad]))
            .collect::<Result<_>>()?;
        self.compute_c_block_p(backend, &z_prep, m, gamma, dirty_cols)
    }

    /// Step 3 with pre-prepared basis tiles (the hot production path).
    pub fn compute_c_block_p(
        &mut self,
        backend: &dyn Compute,
        z_prep: &[Prepared],
        m: usize,
        gamma: f32,
        dirty_cols: std::ops::Range<usize>,
    ) -> Result<()> {
        if self.c.cols() != m {
            let prev = self.c.cols();
            if m > prev {
                self.c.grow_cols(m);
            } else {
                self.c = TiledMatrix::zeros(self.n_local(), m);
            }
        }
        assert_eq!(z_prep.len(), self.c.col_tiles());
        if self.x_prep.is_empty() {
            self.x_prep = self
                .x_tiles
                .iter()
                .map(|t| backend.prepare(t, &[TB, self.dpad]))
                .collect::<Result<_>>()?;
        }
        for i in 0..self.row_tiles() {
            for j in dirty_cols.clone() {
                let tile =
                    backend.kernel_block_p(&self.x_prep[i], &z_prep[j], self.dpad, gamma)?;
                self.c.tile_mut(i, j).copy_from_slice(&tile);
            }
        }
        Ok(())
    }

    /// The node's contribution to (Wβ): a sparse set of (global_k, value)
    /// entries, each `value = <W_k, β> = <C_row or W_row, β>`.
    pub fn wv_entries(&self, backend: &dyn Compute, v_tiles: &[Vec<f32>]) -> Result<Vec<(usize, f32)>> {
        match &self.w_share {
            WShare::FromC(rows) => {
                let mut out = Vec::with_capacity(rows.len());
                for &(local, global_k) in rows {
                    out.push((global_k, row_dot(&self.c, local, v_tiles)));
                }
                Ok(out)
            }
            WShare::Explicit { k0, block } => {
                // block is (rows × m) tiled; rows are basis k0..k0+rows.
                let mut acc = vec![0.0f32; block.row_tiles() * TB];
                for i in 0..block.row_tiles() {
                    let mut tile_acc = vec![0.0f32; TB];
                    for j in 0..block.col_tiles() {
                        let part = if let Some(prow) = self.w_prep.get(i) {
                            backend.matvec_p(&prow[j], &v_tiles[j])?
                        } else {
                            backend.matvec(block.tile(i, j), &v_tiles[j])?
                        };
                        for (a, b) in tile_acc.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    acc[i * TB..(i + 1) * TB].copy_from_slice(&tile_acc);
                }
                Ok((0..block.rows())
                    .map(|r| (k0 + r, acc[r]))
                    .collect())
            }
        }
    }
}

/// Dot of one logical C row with a tiled m-vector.
fn row_dot(c: &TiledMatrix, row: usize, v_tiles: &[Vec<f32>]) -> f32 {
    let ti = row / TB;
    let r = row % TB;
    let mut s = 0.0f32;
    for j in 0..c.col_tiles() {
        let tile = c.tile(ti, j);
        s += crate::linalg::mat::dot(&tile[r * TM..(r + 1) * TM], &v_tiles[j]);
    }
    s
}

/// Pad a shard's features into (TB × dpad) row tiles.
pub fn pad_feature_tiles(x: &Mat, dpad: usize) -> Vec<Vec<f32>> {
    let nt = x.rows().div_ceil(TB).max(1);
    let mut out = Vec::with_capacity(nt);
    for t in 0..nt {
        let mut tile = vec![0.0f32; TB * dpad];
        let live = (x.rows() - t * TB).min(TB);
        for r in 0..live {
            let row = x.row(t * TB + r);
            tile[r * dpad..r * dpad + row.len()].copy_from_slice(row);
        }
        out.push(tile);
    }
    out
}

/// Pad labels into TB tiles (zeros beyond n_j; masked out downstream).
pub fn pad_label_tiles(y: &[f32]) -> Vec<Vec<f32>> {
    let nt = y.len().div_ceil(TB).max(1);
    (0..nt)
        .map(|t| {
            let mut tile = vec![0.0f32; TB];
            let live = (y.len() - t * TB).min(TB);
            tile[..live].copy_from_slice(&y[t * TB..t * TB + live]);
            tile
        })
        .collect()
}

/// Pad an m-vector into TM tiles.
pub fn pad_m_tiles(v: &[f32], col_tiles: usize) -> Vec<Vec<f32>> {
    let mut out = vec![vec![0.0f32; TM]; col_tiles];
    for (k, &val) in v.iter().enumerate() {
        out[k / TM][k % TM] = val;
    }
    out
}

/// Flatten TM tiles back to an m-vector.
pub fn unpad_m_tiles(tiles: &[Vec<f32>], m: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(m);
    for k in 0..m {
        out.push(tiles[k / TM][k % TM]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn feature_tiles_pad_rows_and_width() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(300, 54, |_, _| rng.normal_f32());
        let tiles = pad_feature_tiles(&x, 64);
        assert_eq!(tiles.len(), 2);
        assert_eq!(tiles[0].len(), TB * 64);
        // row 0 contents + zero padding beyond col 54
        assert_eq!(&tiles[0][0..54], x.row(0));
        assert!(tiles[0][54..64].iter().all(|&v| v == 0.0));
        // rows beyond 300 are all zero in tile 1
        let dead = &tiles[1][(300 - TB) * 64..];
        assert!(dead.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn m_tile_roundtrip() {
        let v: Vec<f32> = (0..300).map(|i| i as f32).collect();
        let tiles = pad_m_tiles(&v, 2);
        assert_eq!(tiles.len(), 2);
        assert_eq!(tiles[0][255], 255.0);
        assert_eq!(tiles[1][0], 256.0);
        assert_eq!(unpad_m_tiles(&tiles, 300), v);
    }

    #[test]
    fn label_tiles_pad() {
        let y = vec![1.0f32; 10];
        let t = pad_label_tiles(&y);
        assert_eq!(t.len(), 1);
        assert_eq!(t[0][9], 1.0);
        assert_eq!(t[0][10], 0.0);
    }

    #[test]
    fn row_dot_matches_dense() {
        let mut rng = Rng::new(2);
        let dense = Mat::from_fn(40, 300, |_, _| rng.normal_f32());
        let c = TiledMatrix::from_mat(&dense);
        let v: Vec<f32> = (0..300).map(|_| rng.normal_f32()).collect();
        let v_tiles = pad_m_tiles(&v, c.col_tiles());
        for row in [0, 7, 39] {
            let want = crate::linalg::mat::dot(dense.row(row), &v);
            let got = row_dot(&c, row, &v_tiles);
            assert!((got - want).abs() < 1e-3, "row {row}: {got} vs {want}");
        }
    }
}
