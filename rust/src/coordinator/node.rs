//! Per-node worker state for Algorithm 1.
//!
//! Each of the p nodes owns a row shard of the training data, a
//! [`CBlockStore`] holding (or streaming) the matching row block of C
//! (tiled for the fixed-shape AOT modules), and its share of W: either
//! references into its own C rows (random basis ⊂ training set — the
//! paper's step-3 observation that "the corresponding row block of W is a
//! subset of the C row block") or an explicitly computed W row block
//! (K-means basis, which is not a subset — §3.2).

use std::sync::Arc;

use crate::config::settings::CStorage;
use crate::linalg::Mat;
use crate::runtime::backend::Prepared;
use crate::runtime::tiles::{row_masks, TiledMatrix, TB, TM};
use crate::runtime::Compute;
use crate::Result;

use super::cstore::{make_store, CBlockStore, MaterializedStore};

/// How this node's share of W is represented.
#[derive(Clone, Debug)]
pub enum WShare {
    /// Basis points are training rows: (local_row, global_basis_index)
    /// pairs — W rows come for free from C rows.
    FromC(Vec<(usize, usize)>),
    /// Explicit W row block for global basis indices [k0, k0+rows):
    /// computed kernel values (rows × m), tiled.
    Explicit { k0: usize, block: TiledMatrix },
}

/// One simulated worker node.
pub struct WorkerNode {
    /// Local feature shard (n_j × d), unpadded.
    pub x: Mat,
    /// Local labels.
    pub y: Vec<f32>,
    /// Feature row tiles padded to (TB × dpad), one per row tile.
    pub x_tiles: Vec<Vec<f32>>,
    /// Row-validity masks per tile.
    pub masks: Vec<Vec<f32>>,
    /// Label tiles (padded with zeros).
    pub y_tiles: Vec<Vec<f32>>,
    /// The kernel row block C_j (n_j × m) behind the storage-mode
    /// abstraction: fully materialized, streamed per dispatch, or a
    /// budgeted mix (see [`crate::coordinator::cstore`]).
    pub cstore: Box<dyn CBlockStore>,
    /// This node's share of W.
    pub w_share: WShare,
    /// Cached Gauss-Newton diagonal per row tile (from the last f/g eval at
    /// the current β) — consumed by the Hd products of step 4c.
    pub dcoef_tiles: Vec<Vec<f32>>,
    /// Padded feature width in use.
    pub dpad: usize,
    /// Prepared (device-resident on PJRT) operands for the TRON hot path:
    /// labels and masks (C operands live in the store). Built by
    /// [`WorkerNode::prepare_hot`] after step 3; every f/g/Hd call then
    /// ships only O(TB + TM) bytes.
    pub y_prep: Vec<Prepared>,
    pub mask_prep: Vec<Prepared>,
    /// Prepared explicit W row-block tiles (K-means basis only).
    pub w_prep: Vec<Vec<Prepared>>,
    /// Prepared feature row tiles, shared with the store (streaming modes
    /// recompute kernel tiles from these).
    pub x_prep: Arc<Vec<Prepared>>,
    /// BCD scratch (see [`crate::coordinator::solver::bcd`]): cached
    /// margins `z = C_j β` per row tile, kept in sync from per-round block
    /// delta broadcasts. Empty unless a BCD solve is active.
    pub bcd_margins: Vec<Vec<f32>>,
    /// BCD scratch: this node's replica of β as padded TM tiles, updated
    /// from the same block deltas (no full-β broadcast per round).
    pub bcd_beta_tiles: Vec<Vec<f32>>,
}

impl WorkerNode {
    /// Build a node from its data shard (pads feature tiles; C comes later
    /// in step 3).
    pub fn new(x: Mat, y: Vec<f32>, dpad: usize) -> Self {
        assert!(dpad >= x.cols());
        let n_j = x.rows();
        let x_tiles = pad_feature_tiles(&x, dpad);
        let masks = row_masks(n_j);
        let y_tiles = pad_label_tiles(&y);
        WorkerNode {
            cstore: Box::new(MaterializedStore::new()),
            dcoef_tiles: vec![vec![0.0; TB]; x_tiles.len()],
            x: x.clone(),
            y,
            x_tiles,
            masks,
            y_tiles,
            w_share: WShare::FromC(Vec::new()),
            dpad,
            y_prep: Vec::new(),
            mask_prep: Vec::new(),
            w_prep: Vec::new(),
            x_prep: Arc::new(Vec::new()),
            bcd_margins: Vec::new(),
            bcd_beta_tiles: Vec::new(),
        }
    }

    /// Select how this node stores its C row block. Must be called before
    /// [`WorkerNode::compute_c_block`] (an existing block is discarded).
    pub fn set_c_storage(&mut self, choice: CStorage, budget_bytes: usize) {
        self.cstore = make_store(choice, budget_bytes);
    }

    /// Prepare the hot-path operands (labels and masks once; W tiles on
    /// change). C operands are prepared incrementally inside the store's
    /// rebuild — only dirty column tiles re-upload after stage-wise growth.
    pub fn prepare_hot(&mut self, backend: &dyn Compute) -> Result<()> {
        if self.y_prep.len() != self.y_tiles.len() {
            self.y_prep = self
                .y_tiles
                .iter()
                .map(|t| backend.prepare(t, &[TB]))
                .collect::<Result<_>>()?;
            self.mask_prep = self
                .masks
                .iter()
                .map(|t| backend.prepare(t, &[TB]))
                .collect::<Result<_>>()?;
        }
        self.w_prep.clear();
        if let WShare::Explicit { block, .. } = &self.w_share {
            for i in 0..block.row_tiles() {
                let mut row = Vec::with_capacity(block.col_tiles());
                for j in 0..block.col_tiles() {
                    row.push(backend.prepare(block.tile(i, j), &[TB, TM])?);
                }
                self.w_prep.push(row);
            }
        }
        Ok(())
    }

    pub fn n_local(&self) -> usize {
        self.x.rows()
    }

    pub fn row_tiles(&self) -> usize {
        self.x_tiles.len()
    }

    /// Step 3: (re)compute the C row block columns for basis tiles
    /// `dirty_cols` against the padded basis tiles `z_tiles`. Convenience
    /// wrapper that prepares z locally; the trainer uses
    /// [`WorkerNode::compute_c_block_p`] with basis tiles prepared once and
    /// shared across nodes.
    pub fn compute_c_block(
        &mut self,
        backend: &dyn Compute,
        z_tiles: &[Vec<f32>],
        m: usize,
        gamma: f32,
        dirty_cols: std::ops::Range<usize>,
    ) -> Result<()> {
        let z_prep: Vec<Prepared> = z_tiles
            .iter()
            .map(|t| backend.prepare(t, &[TM, self.dpad]))
            .collect::<Result<_>>()?;
        self.compute_c_block_p(backend, &Arc::new(z_prep), m, gamma, dirty_cols)
    }

    /// Step 3 with pre-prepared basis tiles shared across nodes (the hot
    /// production path). Delegates the representation — materialize, cache
    /// W rows, or nothing at all — to the configured [`CBlockStore`].
    /// W shares must be installed first (streaming modes cache those rows).
    pub fn compute_c_block_p(
        &mut self,
        backend: &dyn Compute,
        z_prep: &Arc<Vec<Prepared>>,
        m: usize,
        gamma: f32,
        dirty_cols: std::ops::Range<usize>,
    ) -> Result<()> {
        if self.x_prep.is_empty() {
            let prepped: Vec<Prepared> = self
                .x_tiles
                .iter()
                .map(|t| backend.prepare(t, &[TB, self.dpad]))
                .collect::<Result<_>>()?;
            self.x_prep = Arc::new(prepped);
        }
        let w_rows: Vec<(usize, usize)> = match &self.w_share {
            WShare::FromC(rows) => rows.clone(),
            WShare::Explicit { .. } => Vec::new(),
        };
        self.cstore.rebuild(
            backend,
            &self.x_prep,
            z_prep,
            self.n_local(),
            m,
            gamma,
            self.dpad,
            dirty_cols,
            &w_rows,
        )
    }

    /// The node's contribution to (Wβ): a sparse set of (global_k, value)
    /// entries, each `value = <W_k, β> = <C_row or W_row, β>`.
    pub fn wv_entries(&self, backend: &dyn Compute, v_tiles: &[Vec<f32>]) -> Result<Vec<(usize, f32)>> {
        match &self.w_share {
            WShare::FromC(rows) => {
                let mut out = Vec::with_capacity(rows.len());
                for &(local, global_k) in rows {
                    out.push((global_k, self.cstore.row_dot(local, v_tiles)?));
                }
                Ok(out)
            }
            WShare::Explicit { k0, block } => {
                // block is (rows × m) tiled; rows are basis k0..k0+rows.
                let mut acc = vec![0.0f32; block.row_tiles() * TB];
                for i in 0..block.row_tiles() {
                    let mut tile_acc = vec![0.0f32; TB];
                    for j in 0..block.col_tiles() {
                        let part = if let Some(prow) = self.w_prep.get(i) {
                            backend.matvec_p(&prow[j], &v_tiles[j])?
                        } else {
                            backend.matvec(block.tile(i, j), &v_tiles[j])?
                        };
                        for (a, b) in tile_acc.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    acc[i * TB..(i + 1) * TB].copy_from_slice(&tile_acc);
                }
                Ok((0..block.rows())
                    .map(|r| (k0 + r, acc[r]))
                    .collect())
            }
        }
    }
}

/// Pad a shard's features into (TB × dpad) row tiles.
pub fn pad_feature_tiles(x: &Mat, dpad: usize) -> Vec<Vec<f32>> {
    let nt = x.rows().div_ceil(TB).max(1);
    let mut out = Vec::with_capacity(nt);
    for t in 0..nt {
        let mut tile = vec![0.0f32; TB * dpad];
        let live = (x.rows() - t * TB).min(TB);
        for r in 0..live {
            let row = x.row(t * TB + r);
            tile[r * dpad..r * dpad + row.len()].copy_from_slice(row);
        }
        out.push(tile);
    }
    out
}

/// Pad labels into TB tiles (zeros beyond n_j; masked out downstream).
pub fn pad_label_tiles(y: &[f32]) -> Vec<Vec<f32>> {
    let nt = y.len().div_ceil(TB).max(1);
    (0..nt)
        .map(|t| {
            let mut tile = vec![0.0f32; TB];
            let live = (y.len() - t * TB).min(TB);
            tile[..live].copy_from_slice(&y[t * TB..t * TB + live]);
            tile
        })
        .collect()
}

/// Pad an m-vector into TM tiles.
pub fn pad_m_tiles(v: &[f32], col_tiles: usize) -> Vec<Vec<f32>> {
    let mut out = vec![vec![0.0f32; TM]; col_tiles];
    for (k, &val) in v.iter().enumerate() {
        out[k / TM][k % TM] = val;
    }
    out
}

/// Read an m-vector straight out of a FLAT padded buffer: concatenated TM
/// tiles place element k at index k, so the only padding is the tail and
/// no per-tile re-chunking round-trip is needed. This is the unpad for
/// reduce buffers, which arrive flat (the per-tile inverse lives only in
/// this module's tests, pinning the layout equivalence).
pub fn unpad_m_flat(flat: &[f32], m: usize) -> Vec<f32> {
    assert!(flat.len() >= m, "flat buffer shorter than m");
    flat[..m].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Per-tile unpad (element k from tile k/TM, offset k%TM) — the shape
    /// the hot path no longer uses; kept here to pin the flat layout.
    fn unpad_m_tiles(tiles: &[Vec<f32>], m: usize) -> Vec<f32> {
        (0..m).map(|k| tiles[k / TM][k % TM]).collect()
    }

    #[test]
    fn feature_tiles_pad_rows_and_width() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(300, 54, |_, _| rng.normal_f32());
        let tiles = pad_feature_tiles(&x, 64);
        assert_eq!(tiles.len(), 2);
        assert_eq!(tiles[0].len(), TB * 64);
        // row 0 contents + zero padding beyond col 54
        assert_eq!(&tiles[0][0..54], x.row(0));
        assert!(tiles[0][54..64].iter().all(|&v| v == 0.0));
        // rows beyond 300 are all zero in tile 1
        let dead = &tiles[1][(300 - TB) * 64..];
        assert!(dead.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn m_tile_roundtrip() {
        let v: Vec<f32> = (0..300).map(|i| i as f32).collect();
        let tiles = pad_m_tiles(&v, 2);
        assert_eq!(tiles.len(), 2);
        assert_eq!(tiles[0][255], 255.0);
        assert_eq!(tiles[1][0], 256.0);
        assert_eq!(unpad_m_tiles(&tiles, 300), v);
    }

    #[test]
    fn flat_unpad_matches_tiled_unpad() {
        let v: Vec<f32> = (0..300).map(|i| (i as f32).sin()).collect();
        let tiles = pad_m_tiles(&v, 2);
        let flat = tiles.concat();
        assert_eq!(unpad_m_flat(&flat, 300), unpad_m_tiles(&tiles, 300));
        assert_eq!(unpad_m_flat(&flat, 300), v);
    }

    #[test]
    fn label_tiles_pad() {
        let y = vec![1.0f32; 10];
        let t = pad_label_tiles(&y);
        assert_eq!(t.len(), 1);
        assert_eq!(t[0][9], 1.0);
        assert_eq!(t[0][10], 0.0);
    }
}
