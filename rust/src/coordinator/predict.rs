//! Test-set scoring with a trained formulation-(4) model:
//! o(x) = Σ_k β_k k(x, z̄_k), evaluated with the fused predict tile module
//! (kernel block + matvec in one dispatch).
//!
//! [`score_rows`] is the shared per-shard scoring loop: the serial
//! [`predict`] entry point runs it over the whole batch on the caller's
//! thread, while [`super::session::Session::predict`] re-shards the batch
//! over the live cluster and runs the SAME loop per node in one metered
//! executor phase. Each row's score depends only on its own features
//! (accumulated over the basis tiles in a fixed order), so any row
//! partition scores bit-identically to any other.

use crate::linalg::Mat;
use crate::runtime::tiles::{TB, TM};
use crate::runtime::Compute;
use crate::Result;

use super::node::{pad_feature_tiles, pad_m_tiles};
use super::trainer::TrainedModel;

/// Decision values for every row of `x` against TM×dpad padded basis tiles
/// and TM-padded β tiles: one fused `predict_block` dispatch per
/// (row tile × basis tile), accumulated in basis-tile order.
pub fn score_rows(
    backend: &dyn Compute,
    x: &Mat,
    z_tiles: &[Vec<f32>],
    beta_tiles: &[Vec<f32>],
    gamma: f32,
    dpad: usize,
) -> Result<Vec<f32>> {
    let n = x.rows();
    if n == 0 {
        return Ok(Vec::new());
    }
    let x_tiles = pad_feature_tiles(x, dpad);
    let mut scores = Vec::with_capacity(n);
    for (t, x_tile) in x_tiles.iter().enumerate() {
        let mut acc = vec![0.0f32; TB];
        for (j, z_tile) in z_tiles.iter().enumerate() {
            // β padding entries are zero, so the kernel values computed
            // against zero-padding basis rows contribute nothing.
            let part = backend.predict_block(x_tile, z_tile, gamma, &beta_tiles[j], dpad)?;
            for (a, b) in acc.iter_mut().zip(&part) {
                *a += b;
            }
        }
        let live = (n - t * TB).min(TB);
        scores.extend_from_slice(&acc[..live]);
    }
    Ok(scores)
}

/// Decision values for every row of `x` (serial coordinator loop).
pub fn predict(backend: &dyn Compute, model: &TrainedModel, x: &Mat) -> Result<Vec<f32>> {
    let dpad = backend.pad_d(model.basis.cols().max(x.cols()))?;
    let z_tiles = super::basis::tiles_of(&model.basis, dpad);
    let col_tiles = model.beta.len().div_ceil(TM).max(1);
    assert_eq!(z_tiles.len(), col_tiles);
    let beta_tiles = pad_m_tiles(&model.beta, col_tiles);
    score_rows(backend, x, &z_tiles, &beta_tiles, model.gamma, dpad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::Loss;
    use crate::rng::Rng;

    /// predict == direct dense evaluation of Σ β_k exp(-γ‖x-z_k‖²).
    #[test]
    fn matches_dense_evaluation() {
        let mut rng = Rng::new(1);
        let d = 20;
        let m = 300; // exercises 2 basis tiles
        let n = 70;
        let basis = Mat::from_fn(m, d, |_, _| rng.normal_f32());
        let beta: Vec<f32> = (0..m).map(|_| 0.05 * rng.normal_f32()).collect();
        let x = Mat::from_fn(n, d, |_, _| rng.normal_f32());
        let gamma = 0.3f32;
        let model = TrainedModel {
            basis: basis.clone(),
            beta: beta.clone(),
            gamma,
            loss: Loss::SqHinge,
        };
        let backend = crate::runtime::backend::NativeCompute::new();
        let got = predict(&backend, &model, &x).unwrap();
        assert_eq!(got.len(), n);
        for i in 0..n {
            let mut want = 0.0f32;
            for k in 0..m {
                let mut d2 = 0.0f32;
                for j in 0..d {
                    let diff = x.at(i, j) - basis.at(k, j);
                    d2 += diff * diff;
                }
                want += beta[k] * (-gamma * d2).exp();
            }
            assert!((got[i] - want).abs() < 1e-3, "row {i}: {} vs {want}", got[i]);
        }
    }
}
