//! The memory-bounded kernel-operator layer: how a node's kernel row block
//! C_j is represented and applied.
//!
//! The paper's formulation keeps per-node memory at O(n_j·m) by fully
//! materializing C_j — which turns memory into a hard cap once m grows.
//! This layer makes that a dial instead. A [`CBlockStore`] owns the C row
//! block behind the tile ops the TRON hot path needs, with three modes:
//!
//! * [`MaterializedStore`] — today's behavior: tiled C plus prepared
//!   operands, fastest, O(n_j·m) bytes per node. On the native backend the
//!   prepared copy ALIASES the host tile ([`Compute::prepare_shared`]), so
//!   a materialized row tile costs one tile of memory, not two.
//! * [`StreamingStore`] — no stored C at all: every f/g/Hd dispatch
//!   recomputes its kernel tile from the already-prepared feature/basis
//!   tiles via the fused `*_from_x` backend ops (the tile is computed once
//!   per dispatch and consumed in place). Peak C-block memory is O(1 tile);
//!   compute grows by the kernel-tile recompute, which the stores count so
//!   the simulated ledger can charge it honestly.
//! * [`RowbufStreamingStore`] (`streaming:rowbuf`) — streaming plus a
//!   row-tile-scoped scratch of O(col_tiles) prepared tiles: a multi-tile
//!   f/g (or Hd) evaluation touches tile (i, j) twice — once in the matvec
//!   accumulation, once in the matvec_t after the loss stage — and plain
//!   streaming recomputes it both times. The scratch keeps the tiles of
//!   the CURRENT row tile between those two halves, halving the streamed
//!   recompute for m > TM at O(col_tiles)-tile extra memory.
//! * [`AutoStore`] — materializes row tiles while they fit a per-node byte
//!   budget and streams the rest.
//!
//! All three produce BIT-IDENTICAL training output: the streamed tile is
//! `kernel_block` of the same prepared operands, so every matvec/matvec_t
//! consumes the same f32 bits in the same order (enforced by
//! `rust/tests/c_storage.rs`).
//!
//! One nuance: with a random (training-row) basis the node's W share reads
//! individual C *rows* (`row_dot`). Streaming modes cache exactly those
//! rows — the node's W-share row block, O(m_j·m) like the explicit K-means
//! W share — so the hot path never recomputes a whole tile to read one row.

use std::collections::{BTreeMap, BTreeSet};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::settings::{CStorage, Loss};
use crate::linalg::mat::dot;
use crate::runtime::backend::Prepared;
use crate::runtime::tiles::{TB, TM};
use crate::runtime::{BlockOut, Compute, RowTiles, StageOut};
use crate::Result;

/// How a node's C row block is stored and applied. Implementations must be
/// `Send` (nodes move across the threaded executor's workers).
pub trait CBlockStore: Send {
    /// Mode name for reports ("materialized" / "streaming" /
    /// "streaming:rowbuf" / "auto").
    fn kind(&self) -> &'static str;

    /// Logical C columns (m) currently installed.
    fn cols(&self) -> usize;

    /// Basis column tiles currently installed.
    fn col_tiles(&self) -> usize;

    /// True once `rebuild` has run (the TRON hot path asserts this).
    fn ready(&self) -> bool;

    /// (Re)bind the store to the node's prepared feature tiles and the
    /// shared prepared basis tiles, recomputing/re-preparing whatever this
    /// mode stores. `dirty_cols` is the stage-wise hint of which column
    /// tiles changed; a shrink of m (or a first build) forces a full
    /// recompute regardless — the stale-column hazard guard. `w_rows` are
    /// the node's (local_row, global_k) W-share rows when the basis is a
    /// subset of the training rows.
    #[allow(clippy::too_many_arguments)]
    fn rebuild(
        &mut self,
        backend: &dyn Compute,
        x_prep: &Arc<Vec<Prepared>>,
        z_prep: &Arc<Vec<Prepared>>,
        rows: usize,
        m: usize,
        gamma: f32,
        dpad: usize,
        dirty_cols: Range<usize>,
        w_rows: &[(usize, usize)],
    ) -> Result<()>;

    /// C[i,j] · v (one TB vector).
    fn matvec_tile(
        &self,
        backend: &dyn Compute,
        i: usize,
        j: usize,
        v: &[f32],
    ) -> Result<Vec<f32>>;

    /// C[i,j]ᵀ · r (one TM vector).
    fn matvec_t_tile(
        &self,
        backend: &dyn Compute,
        i: usize,
        j: usize,
        r: &[f32],
    ) -> Result<Vec<f32>>;

    /// Fused f/g over row tile i (single basis column tile only).
    fn fgrad_tile(
        &self,
        backend: &dyn Compute,
        loss: Loss,
        i: usize,
        beta_tile: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut>;

    /// Fused Hd over row tile i (single basis column tile only).
    fn hd_tile(
        &self,
        backend: &dyn Compute,
        i: usize,
        d_tile: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>>;

    /// Whole-node fused f/g: ONE backend dispatch covering every
    /// (row tile × column tile) of the block — both matvec halves plus the
    /// loss stage — regardless of how the tiles are stored. Bit-identical
    /// to driving the per-tile ops above (same accumulation order).
    /// `y`/`mask` are the host label/mask tiles, `y_prep`/`mask_prep`
    /// their prepared twins (single-column fused ops consume the prepared
    /// form, the multi-column loss stage the host form).
    #[allow(clippy::too_many_arguments)]
    fn fgrad_block(
        &self,
        backend: &dyn Compute,
        loss: Loss,
        v_tiles: &[Vec<f32>],
        y_prep: &[Prepared],
        mask_prep: &[Prepared],
        y: &[Vec<f32>],
        mask: &[Vec<f32>],
    ) -> Result<BlockOut>;

    /// Whole-node fused Hd: ONE backend dispatch for the node's flat
    /// `col_tiles·TM` Hd partial. `dcoef` holds the per-row-tile diagonals
    /// cached by the last `fgrad_block`.
    fn hd_block(
        &self,
        backend: &dyn Compute,
        v_tiles: &[Vec<f32>],
        dcoef: &[Vec<f32>],
    ) -> Result<Vec<f32>>;

    /// Dot of logical C row `row` with a tiled m-vector (FromC W shares).
    fn row_dot(&self, row: usize, v_tiles: &[Vec<f32>]) -> Result<f32>;

    /// Peak C-block bytes this store holds across dispatches: stored tiles
    /// plus prepared copies, plus one transient tile when any row streams.
    fn peak_c_bytes(&self) -> usize;

    /// Bytes held by the streamed-row W-share cache (reported separately:
    /// it is the W share, not the C block).
    fn w_cache_bytes(&self) -> usize;

    /// Kernel-tile computations this store performed beyond the one-time
    /// materialized build: streaming f/g/Hd dispatches plus W-row cache
    /// builds (zero for materialized).
    fn recomputed_tiles(&self) -> u64;
}

/// Construct the configured store (`budget_bytes` feeds `Auto`).
pub fn make_store(choice: CStorage, budget_bytes: usize) -> Box<dyn CBlockStore> {
    match choice {
        CStorage::Materialized => Box::new(MaterializedStore::new()),
        CStorage::Streaming => Box::new(StreamingStore::new()),
        CStorage::StreamingRowbuf => Box::new(RowbufStreamingStore::new()),
        CStorage::Auto => Box::new(AutoStore::new(budget_bytes)),
    }
}

/// Everything needed to recompute a kernel tile on demand.
#[derive(Clone)]
struct StreamCtx {
    x_prep: Arc<Vec<Prepared>>,
    z_prep: Arc<Vec<Prepared>>,
    gamma: f32,
    dpad: usize,
}

/// Which row tiles to materialize.
#[derive(Clone, Copy, Debug)]
enum MatPolicy {
    All,
    None,
    Budget(usize),
}

/// One materialized row of tiles: host tiles + prepared copies. The host
/// tiles serve `row_dot`; the prepared copies serve the hot-path dispatch
/// (device-resident under PJRT). They are created via
/// [`Compute::prepare_shared`], so on the native backend the "copy" is the
/// SAME `Arc` buffer as the host tile — materialized C is held once.
#[derive(Default)]
struct MatRowTiles {
    tiles: Vec<Arc<Vec<f32>>>,
    preps: Vec<Prepared>,
}

impl MatRowTiles {
    /// Recompute the dirty column tiles and re-prepare only those —
    /// stage-wise basis growth stays O(new columns).
    fn rebuild(
        &mut self,
        backend: &dyn Compute,
        x: &Prepared,
        z_prep: &[Prepared],
        dpad: usize,
        gamma: f32,
        dirty: Range<usize>,
    ) -> Result<()> {
        let ct = z_prep.len();
        debug_assert_eq!(dirty.end, ct, "dirty range must run through the last tile");
        // A fresh slot (e.g. a row tile newly promoted to materialized) has
        // no valid tiles at all — every column is dirty for it.
        let dirty = if self.tiles.is_empty() { 0..ct } else { dirty };
        // Placeholders for newly-added slots are never read: new slots are
        // always inside the dirty range, which replaces the whole Arc.
        self.tiles.resize_with(ct, || Arc::new(Vec::new()));
        for j in dirty.clone() {
            let tile = backend.kernel_block_p(x, &z_prep[j], dpad, gamma)?;
            self.tiles[j] = Arc::new(tile);
        }
        self.preps.truncate(dirty.start.min(self.preps.len()));
        for j in self.preps.len()..ct {
            self.preps
                .push(backend.prepare_shared(&self.tiles[j], &[TB, TM])?);
        }
        Ok(())
    }

    /// Bytes this slot holds: every host tile, plus every prepared copy
    /// that does NOT alias its host tile (PJRT device uploads do not; the
    /// native shared preparation does).
    fn bytes(&self) -> usize {
        let tile = TB * TM * 4;
        let copies = self
            .preps
            .iter()
            .zip(&self.tiles)
            .filter(|(p, t)| !p.aliases(t))
            .count();
        (self.tiles.len() + copies) * tile
    }
}

/// The row-tile-scoped streaming scratch: prepared kernel tiles of ONE row
/// tile, kept between the matvec and matvec_t halves of an evaluation.
#[derive(Default)]
struct RowScratch {
    /// Which row tile the buffered tiles belong to (`None` = empty).
    row_tile: Option<usize>,
    tiles: Vec<Option<Prepared>>,
}

impl RowScratch {
    fn clear(&mut self) {
        self.row_tile = None;
        self.tiles.clear();
    }
}

/// The shared store core: a materialized prefix of row tiles (per policy)
/// plus streaming for the rest, with a W-share row cache for streamed rows.
struct Core {
    policy: MatPolicy,
    ctx: Option<StreamCtx>,
    /// Per row tile: `Some` = materialized, `None` = streamed.
    slots: Vec<Option<MatRowTiles>>,
    /// local_row → padded C row (col_tiles·TM) for rows in streamed tiles.
    wcache: BTreeMap<usize, Vec<f32>>,
    /// Row-tile-scoped tile scratch (`streaming:rowbuf` only): caches each
    /// recomputed tile of the current row tile so the matvec_t half of an
    /// evaluation reuses what its matvec half computed. Interior mutability
    /// because dispatches take `&self`; a node is driven by one executor
    /// worker at a time, so the lock is uncontended.
    rowbuf: Option<Mutex<RowScratch>>,
    /// Whether the backend's shared preparations alias host tiles (native:
    /// yes) — the factor between one and two tiles per materialized tile,
    /// used by both the byte accounting and the Auto budget.
    prep_aliased: bool,
    recomputed: AtomicU64,
    cols: usize,
}

impl Core {
    fn new(policy: MatPolicy) -> Self {
        Core {
            policy,
            ctx: None,
            slots: Vec::new(),
            wcache: BTreeMap::new(),
            rowbuf: None,
            prep_aliased: false,
            recomputed: AtomicU64::new(0),
            cols: 0,
        }
    }

    fn with_rowbuf(mut self) -> Self {
        self.rowbuf = Some(Mutex::new(RowScratch::default()));
        self
    }

    fn ctx(&self) -> Result<&StreamCtx> {
        self.ctx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("C-block store used before rebuild"))
    }

    fn col_tiles(&self) -> usize {
        self.cols.div_ceil(TM).max(1)
    }

    fn bump(&self) {
        self.recomputed.fetch_add(1, Ordering::Relaxed);
    }

    #[allow(clippy::too_many_arguments)]
    fn rebuild(
        &mut self,
        backend: &dyn Compute,
        x_prep: &Arc<Vec<Prepared>>,
        z_prep: &Arc<Vec<Prepared>>,
        rows: usize,
        m: usize,
        gamma: f32,
        dpad: usize,
        dirty_cols: Range<usize>,
        w_rows: &[(usize, usize)],
    ) -> Result<()> {
        anyhow::ensure!(m > 0, "C block needs at least one basis column");
        let ct = z_prep.len();
        anyhow::ensure!(
            ct == m.div_ceil(TM).max(1),
            "basis tiles ({ct}) do not match the column tiles of m={m}"
        );
        let rt = x_prep.len();
        anyhow::ensure!(
            rt == rows.div_ceil(TB).max(1),
            "feature tiles ({rt}) do not match the row tiles of n={rows}"
        );
        // Stale-column hazard guard: a shrink of m (or a first build / a
        // changed row layout) invalidates every stored tile — force a full
        // recompute no matter what `dirty_cols` claims.
        let full = self.cols == 0 || m < self.cols || self.slots.len() != rt;
        let dirty = if full {
            0..ct
        } else {
            // Growth: recompute from the tile holding the first new column
            // (it was partial) through the new last tile, honoring a wider
            // caller-provided range.
            dirty_cols.start.min(self.cols / TM)..ct
        };
        if full {
            self.slots.clear();
            self.wcache.clear();
        }
        // The basis changed: any buffered kernel tiles are stale.
        if let Some(rb) = &self.rowbuf {
            rb.lock().unwrap().clear();
        }
        self.ctx = Some(StreamCtx {
            x_prep: Arc::clone(x_prep),
            z_prep: Arc::clone(z_prep),
            gamma,
            dpad,
        });
        // Per materialized row tile: the host tiles, plus prepared copies
        // only where the backend cannot alias them (PJRT uploads; native
        // shares the buffer).
        self.prep_aliased = backend.prepared_aliases_host();
        let row_bytes = ct * TB * TM * 4 * if self.prep_aliased { 1 } else { 2 };
        let n_mat = match self.policy {
            MatPolicy::All => rt,
            MatPolicy::None => 0,
            MatPolicy::Budget(b) => (b / row_bytes).min(rt),
        };
        self.slots.resize_with(rt, || None);
        for i in 0..rt {
            if i < n_mat {
                let slot = self.slots[i].get_or_insert_with(MatRowTiles::default);
                slot.rebuild(backend, &x_prep[i], z_prep, dpad, gamma, dirty.clone())?;
            } else {
                // Budget no longer covers this row tile (columns grew):
                // drop to streaming; its W rows are cached below.
                self.slots[i] = None;
            }
        }
        self.rebuild_wcache(backend, n_mat, ct, w_rows, dirty)?;
        self.cols = m;
        Ok(())
    }

    /// (Re)build the W-share row cache for rows living in streamed row
    /// tiles. Rows already cached at the current width refresh only the
    /// dirty column tiles; new or re-shaped rows compute every tile. Each
    /// needed (row tile, col tile) kernel tile is computed once and feeds
    /// every cached row in it.
    fn rebuild_wcache(
        &mut self,
        backend: &dyn Compute,
        n_mat: usize,
        ct: usize,
        w_rows: &[(usize, usize)],
        dirty: Range<usize>,
    ) -> Result<()> {
        let mut by_tile: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &(local, _) in w_rows {
            let ti = local / TB;
            if ti >= n_mat {
                by_tile.entry(ti).or_default().push(local);
            }
        }
        let needed: BTreeSet<usize> = by_tile.values().flatten().copied().collect();
        self.wcache.retain(|row, _| needed.contains(row));
        if by_tile.is_empty() {
            return Ok(());
        }
        let ctx = self.ctx()?.clone();
        for (ti, locals) in &by_tile {
            let any_fresh = locals
                .iter()
                .any(|l| self.wcache.get(l).map(|v| v.len()) != Some(ct * TM));
            let cols = if any_fresh { 0..ct } else { dirty.clone() };
            for j in cols {
                let tile =
                    backend.kernel_block_p(&ctx.x_prep[*ti], &ctx.z_prep[j], ctx.dpad, ctx.gamma)?;
                // W-cache builds are kernel work the materialized path gets
                // for free from its stored C — charge them as recompute.
                self.bump();
                for &local in locals {
                    let row = self.wcache.entry(local).or_default();
                    if row.len() != ct * TM {
                        row.resize(ct * TM, 0.0);
                    }
                    let r = local % TB;
                    row[j * TM..(j + 1) * TM].copy_from_slice(&tile[r * TM..(r + 1) * TM]);
                }
            }
        }
        Ok(())
    }

    /// Get-or-recompute the scratch's prepared kernel tile (i, j). A
    /// dispatch for a DIFFERENT row tile evicts the whole scratch — that is
    /// the row-tile scoping that bounds it at O(col_tiles) tiles. The tile
    /// bits are `kernel_block_p` of the same prepared operands the
    /// materialized path uses, so every op on them is bit-identical.
    fn scratch_tile<'s>(
        &self,
        backend: &dyn Compute,
        scratch: &'s mut RowScratch,
        i: usize,
        j: usize,
    ) -> Result<&'s Prepared> {
        let ct = self.col_tiles();
        if scratch.row_tile != Some(i) || scratch.tiles.len() != ct {
            scratch.tiles.clear();
            scratch.tiles.resize_with(ct, || None);
            scratch.row_tile = Some(i);
        }
        if scratch.tiles[j].is_none() {
            let ctx = self.ctx()?;
            let tile =
                backend.kernel_block_p(&ctx.x_prep[i], &ctx.z_prep[j], ctx.dpad, ctx.gamma)?;
            self.bump();
            // Shared preparation: native aliases the freshly computed tile
            // (no copy on the hot path); device backends upload as usual.
            scratch.tiles[j] = Some(backend.prepare_shared(&Arc::new(tile), &[TB, TM])?);
        }
        Ok(scratch.tiles[j].as_ref().expect("tile buffered above"))
    }

    fn matvec_tile(
        &self,
        backend: &dyn Compute,
        i: usize,
        j: usize,
        v: &[f32],
    ) -> Result<Vec<f32>> {
        if let Some(Some(slot)) = self.slots.get(i) {
            return backend.matvec_p(&slot.preps[j], v);
        }
        if let Some(rb) = &self.rowbuf {
            let mut scratch = rb.lock().unwrap();
            let prep = self.scratch_tile(backend, &mut scratch, i, j)?;
            return backend.matvec_p(prep, v);
        }
        let ctx = self.ctx()?;
        self.bump();
        backend.matvec_from_x(&ctx.x_prep[i], &ctx.z_prep[j], ctx.dpad, ctx.gamma, v)
    }

    fn matvec_t_tile(
        &self,
        backend: &dyn Compute,
        i: usize,
        j: usize,
        r: &[f32],
    ) -> Result<Vec<f32>> {
        if let Some(Some(slot)) = self.slots.get(i) {
            return backend.matvec_t_p(&slot.preps[j], r);
        }
        if let Some(rb) = &self.rowbuf {
            let mut scratch = rb.lock().unwrap();
            let prep = self.scratch_tile(backend, &mut scratch, i, j)?;
            return backend.matvec_t_p(prep, r);
        }
        let ctx = self.ctx()?;
        self.bump();
        backend.matvec_t_from_x(&ctx.x_prep[i], &ctx.z_prep[j], ctx.dpad, ctx.gamma, r)
    }

    fn fgrad_tile(
        &self,
        backend: &dyn Compute,
        loss: Loss,
        i: usize,
        beta_tile: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        debug_assert_eq!(
            self.col_tiles(),
            1,
            "fused fgrad_tile covers only single-column-tile m"
        );
        if let Some(Some(slot)) = self.slots.get(i) {
            return backend.fgrad_p(loss, &slot.preps[0], beta_tile, y, mask);
        }
        if let Some(rb) = &self.rowbuf {
            // Single-column-tile m: the fused dispatch consumes the tile
            // once, but buffering it still lets the Hd products of a
            // SINGLE-row-tile node reuse it across dispatches. With more
            // than one row tile the dispatches cycle through row tiles, so
            // the row-scoped scratch can never be re-hit — fall through to
            // the fused op rather than pay a useless prepare per dispatch.
            if self.slots.len() <= 1 {
                let mut scratch = rb.lock().unwrap();
                let prep = self.scratch_tile(backend, &mut scratch, i, 0)?;
                return backend.fgrad_p(loss, prep, beta_tile, y, mask);
            }
        }
        let ctx = self.ctx()?;
        self.bump();
        backend.fgrad_from_x(
            loss,
            &ctx.x_prep[i],
            &ctx.z_prep[0],
            ctx.dpad,
            ctx.gamma,
            beta_tile,
            y,
            mask,
        )
    }

    fn hd_tile(
        &self,
        backend: &dyn Compute,
        i: usize,
        d_tile: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(
            self.col_tiles(),
            1,
            "fused hd_tile covers only single-column-tile m"
        );
        if let Some(Some(slot)) = self.slots.get(i) {
            return backend.hd_p(&slot.preps[0], d_tile, dcoef);
        }
        if let Some(rb) = &self.rowbuf {
            // Same single-row-tile-only buffering rationale as fgrad_tile.
            if self.slots.len() <= 1 {
                let mut scratch = rb.lock().unwrap();
                let prep = self.scratch_tile(backend, &mut scratch, i, 0)?;
                return backend.hd_p(prep, d_tile, dcoef);
            }
        }
        let ctx = self.ctx()?;
        self.bump();
        backend.hd_from_x(
            &ctx.x_prep[i],
            &ctx.z_prep[0],
            ctx.dpad,
            ctx.gamma,
            d_tile,
            dcoef,
        )
    }

    /// Build the per-row-tile operand list for a whole-node block dispatch
    /// and charge the streamed kernel-tile recompute the backend will
    /// perform for it: 1 fused tile per streamed row tile when there is a
    /// single column tile, `ct` buffered computes when the rowbuf scratch
    /// keeps the row between the matvec halves, `2·ct` otherwise (both
    /// halves recompute every tile) — the same per-evaluation charges the
    /// per-tile dispatch paths above accrue.
    fn block_rows<'s>(&'s self, ctx: &'s StreamCtx) -> Vec<RowTiles<'s>> {
        let keep_row = self.rowbuf.is_some();
        let rows: Vec<RowTiles<'s>> = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, slot)| match slot {
                Some(s) => RowTiles::Prepared(&s.preps),
                None => RowTiles::FromX {
                    x: &ctx.x_prep[i],
                    keep_row,
                },
            })
            .collect();
        let streamed = self.slots.iter().filter(|s| s.is_none()).count() as u64;
        if streamed > 0 {
            let ct = self.col_tiles() as u64;
            let per = if ct == 1 {
                1
            } else if keep_row {
                ct
            } else {
                2 * ct
            };
            self.recomputed.fetch_add(streamed * per, Ordering::Relaxed);
        }
        rows
    }

    #[allow(clippy::too_many_arguments)]
    fn fgrad_block(
        &self,
        backend: &dyn Compute,
        loss: Loss,
        v_tiles: &[Vec<f32>],
        y_prep: &[Prepared],
        mask_prep: &[Prepared],
        y: &[Vec<f32>],
        mask: &[Vec<f32>],
    ) -> Result<BlockOut> {
        let ctx = self.ctx()?;
        let rows = self.block_rows(ctx);
        backend.fgrad_block(
            loss,
            &rows,
            &ctx.z_prep[..],
            ctx.dpad,
            ctx.gamma,
            v_tiles,
            y_prep,
            mask_prep,
            y,
            mask,
        )
    }

    fn hd_block(
        &self,
        backend: &dyn Compute,
        v_tiles: &[Vec<f32>],
        dcoef: &[Vec<f32>],
    ) -> Result<Vec<f32>> {
        let ctx = self.ctx()?;
        let rows = self.block_rows(ctx);
        backend.hd_block(&rows, &ctx.z_prep[..], ctx.dpad, ctx.gamma, v_tiles, dcoef)
    }

    fn row_dot(&self, row: usize, v_tiles: &[Vec<f32>]) -> Result<f32> {
        let ti = row / TB;
        if let Some(Some(slot)) = self.slots.get(ti) {
            let r = row % TB;
            let mut s = 0.0f32;
            for (j, v) in v_tiles.iter().enumerate() {
                s += dot(&slot.tiles[j][r * TM..(r + 1) * TM], v);
            }
            return Ok(s);
        }
        let cached = self.wcache.get(&row).ok_or_else(|| {
            anyhow::anyhow!("W row {row} not cached in the streaming C store")
        })?;
        anyhow::ensure!(
            cached.len() == v_tiles.len() * TM,
            "stale W-row cache for row {row}"
        );
        let mut s = 0.0f32;
        for (j, v) in v_tiles.iter().enumerate() {
            s += dot(&cached[j * TM..(j + 1) * TM], v);
        }
        Ok(s)
    }

    fn peak_c_bytes(&self) -> usize {
        let held: usize = self.slots.iter().flatten().map(MatRowTiles::bytes).sum();
        let streams_any = self.slots.iter().any(|s| s.is_none());
        let transient = if self.rowbuf.is_some() {
            // The rowbuf scratch holds up to one full row of prepared tiles.
            self.col_tiles() * TB * TM * 4
        } else if streams_any {
            TB * TM * 4
        } else {
            0
        };
        held + transient
    }

    fn w_cache_bytes(&self) -> usize {
        self.wcache.values().map(|v| v.len() * 4).sum()
    }
}

macro_rules! impl_cblock_store {
    ($ty:ty, $kind:expr) => {
        impl CBlockStore for $ty {
            fn kind(&self) -> &'static str {
                $kind
            }

            fn cols(&self) -> usize {
                self.0.cols
            }

            fn col_tiles(&self) -> usize {
                self.0.col_tiles()
            }

            fn ready(&self) -> bool {
                self.0.ctx.is_some()
            }

            fn rebuild(
                &mut self,
                backend: &dyn Compute,
                x_prep: &Arc<Vec<Prepared>>,
                z_prep: &Arc<Vec<Prepared>>,
                rows: usize,
                m: usize,
                gamma: f32,
                dpad: usize,
                dirty_cols: Range<usize>,
                w_rows: &[(usize, usize)],
            ) -> Result<()> {
                self.0.rebuild(
                    backend, x_prep, z_prep, rows, m, gamma, dpad, dirty_cols, w_rows,
                )
            }

            fn matvec_tile(
                &self,
                backend: &dyn Compute,
                i: usize,
                j: usize,
                v: &[f32],
            ) -> Result<Vec<f32>> {
                self.0.matvec_tile(backend, i, j, v)
            }

            fn matvec_t_tile(
                &self,
                backend: &dyn Compute,
                i: usize,
                j: usize,
                r: &[f32],
            ) -> Result<Vec<f32>> {
                self.0.matvec_t_tile(backend, i, j, r)
            }

            fn fgrad_tile(
                &self,
                backend: &dyn Compute,
                loss: Loss,
                i: usize,
                beta_tile: &[f32],
                y: &Prepared,
                mask: &Prepared,
            ) -> Result<StageOut> {
                self.0.fgrad_tile(backend, loss, i, beta_tile, y, mask)
            }

            fn hd_tile(
                &self,
                backend: &dyn Compute,
                i: usize,
                d_tile: &[f32],
                dcoef: &[f32],
            ) -> Result<Vec<f32>> {
                self.0.hd_tile(backend, i, d_tile, dcoef)
            }

            fn fgrad_block(
                &self,
                backend: &dyn Compute,
                loss: Loss,
                v_tiles: &[Vec<f32>],
                y_prep: &[Prepared],
                mask_prep: &[Prepared],
                y: &[Vec<f32>],
                mask: &[Vec<f32>],
            ) -> Result<BlockOut> {
                self.0
                    .fgrad_block(backend, loss, v_tiles, y_prep, mask_prep, y, mask)
            }

            fn hd_block(
                &self,
                backend: &dyn Compute,
                v_tiles: &[Vec<f32>],
                dcoef: &[Vec<f32>],
            ) -> Result<Vec<f32>> {
                self.0.hd_block(backend, v_tiles, dcoef)
            }

            fn row_dot(&self, row: usize, v_tiles: &[Vec<f32>]) -> Result<f32> {
                self.0.row_dot(row, v_tiles)
            }

            fn peak_c_bytes(&self) -> usize {
                self.0.peak_c_bytes()
            }

            fn w_cache_bytes(&self) -> usize {
                self.0.w_cache_bytes()
            }

            fn recomputed_tiles(&self) -> u64 {
                self.0.recomputed.load(Ordering::Relaxed)
            }
        }
    };
}

/// Fully materialized C (tiled host copies + prepared operands).
pub struct MaterializedStore(Core);

impl MaterializedStore {
    pub fn new() -> Self {
        MaterializedStore(Core::new(MatPolicy::All))
    }
}

impl Default for MaterializedStore {
    fn default() -> Self {
        Self::new()
    }
}

/// No stored C: every dispatch recomputes its kernel tile.
pub struct StreamingStore(Core);

impl StreamingStore {
    pub fn new() -> Self {
        StreamingStore(Core::new(MatPolicy::None))
    }
}

impl Default for StreamingStore {
    fn default() -> Self {
        Self::new()
    }
}

/// Streaming with a row-tile-scoped scratch of O(col_tiles) prepared
/// tiles: the matvec_t half of a multi-tile evaluation reuses the tiles
/// its matvec half recomputed, halving streamed recompute for m > TM.
pub struct RowbufStreamingStore(Core);

impl RowbufStreamingStore {
    pub fn new() -> Self {
        RowbufStreamingStore(Core::new(MatPolicy::None).with_rowbuf())
    }
}

impl Default for RowbufStreamingStore {
    fn default() -> Self {
        Self::new()
    }
}

/// Budgeted hybrid: materialize row tiles while they fit, stream the rest.
pub struct AutoStore(Core);

impl AutoStore {
    pub fn new(budget_bytes: usize) -> Self {
        AutoStore(Core::new(MatPolicy::Budget(budget_bytes)))
    }
}

impl_cblock_store!(MaterializedStore, "materialized");
impl_cblock_store!(StreamingStore, "streaming");
impl_cblock_store!(RowbufStreamingStore, "streaming:rowbuf");
impl_cblock_store!(AutoStore, "auto");

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::runtime::backend::NativeCompute;
    use crate::runtime::native;

    const D: usize = 32;

    struct Fixture {
        backend: NativeCompute,
        x_tiles: Vec<Vec<f32>>,
        z_tiles: Vec<Vec<f32>>,
        x_prep: Arc<Vec<Prepared>>,
        z_prep: Arc<Vec<Prepared>>,
        rows: usize,
        m: usize,
    }

    fn fixture(rows: usize, m: usize, seed: u64) -> Fixture {
        let mut rng = Rng::new(seed);
        let backend = NativeCompute::new();
        let rt = rows.div_ceil(TB).max(1);
        let ct = m.div_ceil(TM).max(1);
        // Zero-pad dead rows/cols exactly like the production tiling.
        let x_tiles: Vec<Vec<f32>> = (0..rt)
            .map(|t| {
                let live = rows.saturating_sub(t * TB).min(TB);
                let mut tile = vec![0.0f32; TB * D];
                for v in tile.iter_mut().take(live * D) {
                    *v = rng.normal_f32();
                }
                tile
            })
            .collect();
        let z_tiles: Vec<Vec<f32>> = (0..ct)
            .map(|t| {
                let live = m.saturating_sub(t * TM).min(TM);
                let mut tile = vec![0.0f32; TM * D];
                for v in tile.iter_mut().take(live * D) {
                    *v = rng.normal_f32();
                }
                tile
            })
            .collect();
        let x_prep = Arc::new(
            x_tiles
                .iter()
                .map(|t| backend.prepare(t, &[TB, D]).unwrap())
                .collect::<Vec<_>>(),
        );
        let z_prep = Arc::new(
            z_tiles
                .iter()
                .map(|t| backend.prepare(t, &[TM, D]).unwrap())
                .collect::<Vec<_>>(),
        );
        Fixture {
            backend,
            x_tiles,
            z_tiles,
            x_prep,
            z_prep,
            rows,
            m,
        }
    }

    fn rebuild(store: &mut dyn CBlockStore, f: &Fixture, w_rows: &[(usize, usize)]) {
        let ct = f.z_prep.len();
        store
            .rebuild(
                &f.backend, &f.x_prep, &f.z_prep, f.rows, f.m, 0.5, D, 0..ct, w_rows,
            )
            .unwrap();
    }

    #[test]
    fn streaming_ops_match_materialized_bitwise() {
        let f = fixture(300, 300, 1);
        let w_rows = vec![(0usize, 0usize), (7, 1), (299, 2)];
        let mut mat = MaterializedStore::new();
        let mut st = StreamingStore::new();
        rebuild(&mut mat, &f, &w_rows);
        rebuild(&mut st, &f, &w_rows);
        assert_eq!(mat.col_tiles(), 2);
        assert_eq!(st.cols(), 300);

        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..TM).map(|_| rng.normal_f32()).collect();
        let r: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
        for i in 0..2 {
            for j in 0..2 {
                let a = mat.matvec_tile(&f.backend, i, j, &v).unwrap();
                let b = st.matvec_tile(&f.backend, i, j, &v).unwrap();
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                );
                let a = mat.matvec_t_tile(&f.backend, i, j, &r).unwrap();
                let b = st.matvec_t_tile(&f.backend, i, j, &r).unwrap();
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                );
            }
        }
        // row_dot agrees bitwise and matches the dense kernel row.
        let v_tiles = vec![v.clone(), r[..TM].to_vec()];
        for &(row, _) in &w_rows {
            let a = mat.row_dot(row, &v_tiles).unwrap();
            let b = st.row_dot(row, &v_tiles).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "row {row}");
            let ti = row / TB;
            let rr = row % TB;
            let mut want = 0.0f32;
            for j in 0..2 {
                let tile = native::kernel_block(&f.x_tiles[ti], &f.z_tiles[j], D, 0.5);
                want += dot(&tile[rr * TM..(rr + 1) * TM], &v_tiles[j]);
            }
            assert_eq!(a.to_bits(), want.to_bits(), "row {row}");
        }
        assert_eq!(mat.recomputed_tiles(), 0);
        assert!(st.recomputed_tiles() > 0);
        assert_eq!(st.peak_c_bytes(), TB * TM * 4);
        // Native shares each host tile with its prepared copy (Arc), so a
        // fully materialized 2×2 tile grid costs exactly 4 tiles — not 8.
        assert_eq!(mat.peak_c_bytes(), 2 * 2 * TB * TM * 4);
        assert!(st.w_cache_bytes() >= 3 * 2 * TM * 4);
    }

    #[test]
    fn rowbuf_ops_match_materialized_bitwise_and_halve_recompute() {
        let f = fixture(300, 300, 1);
        let w_rows = vec![(0usize, 0usize), (7, 1), (299, 2)];
        let mut mat = MaterializedStore::new();
        let mut st = StreamingStore::new();
        let mut rb = RowbufStreamingStore::new();
        rebuild(&mut mat, &f, &w_rows);
        rebuild(&mut st, &f, &w_rows);
        rebuild(&mut rb, &f, &w_rows);
        let w_builds = rb.recomputed_tiles();
        assert_eq!(w_builds, st.recomputed_tiles(), "same W-cache builds");

        // The multi-tile evaluation shape of dist.rs: per row tile, the
        // matvec over every column tile, then the matvec_t over every
        // column tile. Plain streaming recomputes each tile twice; the
        // rowbuf scratch computes it once and reuses it.
        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..TM).map(|_| rng.normal_f32()).collect();
        let r: Vec<f32> = (0..TB).map(|_| rng.normal_f32()).collect();
        for i in 0..2 {
            for j in 0..2 {
                let a = mat.matvec_tile(&f.backend, i, j, &v).unwrap();
                let b = rb.matvec_tile(&f.backend, i, j, &v).unwrap();
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                );
            }
            for j in 0..2 {
                let a = mat.matvec_t_tile(&f.backend, i, j, &r).unwrap();
                let b = rb.matvec_t_tile(&f.backend, i, j, &r).unwrap();
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                );
            }
        }
        // 2 row tiles × 2 col tiles, each computed ONCE (the matvec_t pass
        // hit the scratch every time).
        assert_eq!(rb.recomputed_tiles() - w_builds, 4);
        // row_dot still rides the W cache, bit-identically.
        let v_tiles = vec![v.clone(), r[..TM].to_vec()];
        for &(row, _) in &w_rows {
            let a = mat.row_dot(row, &v_tiles).unwrap();
            let b = rb.row_dot(row, &v_tiles).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "row {row}");
        }
        // Bounded scratch: O(col_tiles) prepared tiles, nothing else.
        assert_eq!(rb.peak_c_bytes(), 2 * TB * TM * 4);
        assert_eq!(rb.kind(), "streaming:rowbuf");
    }

    #[test]
    fn rowbuf_scratch_evicts_on_row_tile_change_and_rebuild() {
        let f = fixture(300, 300, 2);
        let mut rb = RowbufStreamingStore::new();
        rebuild(&mut rb, &f, &[]);
        let v: Vec<f32> = (0..TM).map(|i| (i as f32 * 0.01).cos()).collect();
        rb.matvec_tile(&f.backend, 0, 0, &v).unwrap();
        let after_first = rb.recomputed_tiles();
        // Same (row, col) tile again: served from scratch.
        rb.matvec_tile(&f.backend, 0, 0, &v).unwrap();
        assert_eq!(rb.recomputed_tiles(), after_first);
        // Different row tile: scratch evicted, tile recomputed.
        rb.matvec_tile(&f.backend, 1, 0, &v).unwrap();
        assert_eq!(rb.recomputed_tiles(), after_first + 1);
        // Back to row tile 0: its buffered tile is gone (row-tile scoping).
        rb.matvec_tile(&f.backend, 0, 0, &v).unwrap();
        assert_eq!(rb.recomputed_tiles(), after_first + 2);
        // A rebuild (stage-wise growth) invalidates the scratch: the next
        // dispatch must recompute against the new basis.
        let grown = fixture(300, 400, 2);
        rb.rebuild(
            &grown.backend,
            &grown.x_prep,
            &grown.z_prep,
            grown.rows,
            grown.m,
            0.5,
            D,
            (300 / TM)..grown.z_prep.len(),
            &[],
        )
        .unwrap();
        let before = rb.recomputed_tiles();
        let mut fresh = StreamingStore::new();
        rebuild(&mut fresh, &grown, &[]);
        let a = rb.matvec_tile(&grown.backend, 0, 0, &v).unwrap();
        let b = fresh.matvec_tile(&grown.backend, 0, 0, &v).unwrap();
        assert_eq!(rb.recomputed_tiles(), before + 1, "stale scratch reused");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn rowbuf_fused_single_tile_ops_match_and_reuse_across_dispatches() {
        // Single row tile, single column tile: the fused f/g dispatch
        // buffers the tile and every later Hd dispatch reuses it.
        let f = fixture(200, 96, 5);
        let mut mat = MaterializedStore::new();
        let mut rb = RowbufStreamingStore::new();
        rebuild(&mut mat, &f, &[]);
        rebuild(&mut rb, &f, &[]);
        let mut rng = Rng::new(3);
        let beta: Vec<f32> = (0..TM).map(|_| 0.1 * rng.normal_f32()).collect();
        let y: Vec<f32> = (0..TB)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let mask = vec![1.0f32; TB];
        let yp = f.backend.prepare(&y, &[TB]).unwrap();
        let mp = f.backend.prepare(&mask, &[TB]).unwrap();
        let a = mat
            .fgrad_tile(&f.backend, Loss::SqHinge, 0, &beta, &yp, &mp)
            .unwrap();
        let b = rb
            .fgrad_tile(&f.backend, Loss::SqHinge, 0, &beta, &yp, &mp)
            .unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(rb.recomputed_tiles(), 1);
        let ha = mat.hd_tile(&f.backend, 0, &beta, &a.dcoef).unwrap();
        let hb = rb.hd_tile(&f.backend, 0, &beta, &b.dcoef).unwrap();
        for (x, w) in ha.iter().zip(&hb) {
            assert_eq!(x.to_bits(), w.to_bits());
        }
        // The Hd dispatch reused the buffered tile — no extra recompute.
        assert_eq!(rb.recomputed_tiles(), 1);
    }

    #[test]
    fn fused_single_tile_ops_match_bitwise() {
        let f = fixture(300, 96, 2);
        let mut mat = MaterializedStore::new();
        let mut st = StreamingStore::new();
        rebuild(&mut mat, &f, &[]);
        rebuild(&mut st, &f, &[]);
        let mut rng = Rng::new(5);
        let beta: Vec<f32> = (0..TM).map(|_| 0.2 * rng.normal_f32()).collect();
        let y: Vec<f32> = (0..TB)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let mask = vec![1.0f32; TB];
        let yp = f.backend.prepare(&y, &[TB]).unwrap();
        let mp = f.backend.prepare(&mask, &[TB]).unwrap();
        for i in 0..2 {
            let a = mat
                .fgrad_tile(&f.backend, Loss::SqHinge, i, &beta, &yp, &mp)
                .unwrap();
            let b = st
                .fgrad_tile(&f.backend, Loss::SqHinge, i, &beta, &yp, &mp)
                .unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            for (x, w) in a.vec.iter().zip(&b.vec) {
                assert_eq!(x.to_bits(), w.to_bits());
            }
            let ha = mat.hd_tile(&f.backend, i, &beta, &a.dcoef).unwrap();
            let hb = st.hd_tile(&f.backend, i, &beta, &b.dcoef).unwrap();
            for (x, w) in ha.iter().zip(&hb) {
                assert_eq!(x.to_bits(), w.to_bits());
            }
        }
    }

    #[test]
    fn auto_budget_materializes_prefix_and_streams_rest() {
        let f = fixture(600, 96, 3);
        // On native the prepared copy aliases the host tile, so one row of
        // tiles costs ct * TB*TM*4 = 256 KiB (ct = 1): budget for exactly
        // one of the three row tiles.
        let mut auto = AutoStore::new(300 * 1024);
        let mut mat = MaterializedStore::new();
        let w_rows = vec![(3usize, 0usize), (400, 1), (599, 2)];
        rebuild(&mut auto, &f, &w_rows);
        rebuild(&mut mat, &f, &w_rows);
        // Held bytes: one materialized row tile (shared host/prep buffer)
        // + 1 transient streaming tile.
        assert_eq!(auto.peak_c_bytes(), (1 + 1) * TB * TM * 4);
        let mut rng = Rng::new(7);
        let v: Vec<f32> = (0..TM).map(|_| rng.normal_f32()).collect();
        for i in 0..3 {
            let a = mat.matvec_tile(&f.backend, i, 0, &v).unwrap();
            let b = auto.matvec_tile(&f.backend, i, 0, &v).unwrap();
            for (x, w) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), w.to_bits(), "row tile {i}");
            }
        }
        let v_tiles = vec![v];
        for &(row, _) in &w_rows {
            let a = mat.row_dot(row, &v_tiles).unwrap();
            let b = auto.row_dot(row, &v_tiles).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "row {row}");
        }
        // Only the streamed row tiles recompute: two W-cache tile builds at
        // rebuild (row tiles 1 and 2) + the two streamed matvec dispatches.
        assert_eq!(auto.recomputed_tiles(), 4);
    }

    #[test]
    fn shrink_forces_full_recompute() {
        let big = fixture(200, 300, 4);
        let small = fixture(200, 100, 4);
        // Same x (same seed order for x tiles); different z. Build at
        // m=300, then shrink to m=100 with a deliberately stale dirty
        // range — the guard must recompute everything anyway.
        let mut store = MaterializedStore::new();
        rebuild(&mut store, &big, &[]);
        assert_eq!(store.col_tiles(), 2);
        store
            .rebuild(
                &big.backend,
                &small.x_prep,
                &small.z_prep,
                small.rows,
                small.m,
                0.5,
                D,
                1..1, // stale: claims nothing changed
                &[],
            )
            .unwrap();
        assert_eq!(store.cols(), 100);
        assert_eq!(store.col_tiles(), 1);
        let mut fresh = MaterializedStore::new();
        rebuild(&mut fresh, &small, &[]);
        let v: Vec<f32> = (0..TM).map(|i| (i as f32 * 0.01).sin()).collect();
        let a = store.matvec_tile(&small.backend, 0, 0, &v).unwrap();
        let b = fresh.matvec_tile(&small.backend, 0, 0, &v).unwrap();
        for (x, w) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn partial_tile_growth_recomputes_only_new_column_tile() {
        // m = 300 -> 400 keeps ct = 2: dirty.start = 300/TM = 1, so only
        // the second column tile recomputes and only its prep re-uploads —
        // the O(new columns) stage-wise contract, asserted by op counts.
        // Same seed => identical x tiles and an identical z column tile 0
        // (both fixtures draw its full 256 live rows), exactly like a real
        // grown basis.
        let small = fixture(300, 300, 8);
        let big = fixture(300, 400, 8);
        let w_rows = vec![(5usize, 0usize), (290, 1)];

        let mut mat = MaterializedStore::new();
        rebuild(&mut mat, &small, &[]);
        let calls0 = big.backend.call_count();
        mat.rebuild(
            &big.backend,
            &big.x_prep,
            &big.z_prep,
            big.rows,
            big.m,
            0.5,
            D,
            (300 / TM)..big.z_prep.len(),
            &[],
        )
        .unwrap();
        // 2 row tiles x 1 dirty column tile; column tile 0 untouched.
        assert_eq!(big.backend.call_count() - calls0, 2);

        let mut st = StreamingStore::new();
        rebuild(&mut st, &small, &w_rows);
        let calls1 = big.backend.call_count();
        st.rebuild(
            &big.backend,
            &big.x_prep,
            &big.z_prep,
            big.rows,
            big.m,
            0.5,
            D,
            (300 / TM)..big.z_prep.len(),
            &w_rows,
        )
        .unwrap();
        // Cached W rows are already at full width, so only the dirty column
        // tile of each affected row tile rebuilds (row tiles 0 and 1).
        assert_eq!(big.backend.call_count() - calls1, 2);

        // The incrementally-grown stores must match fresh full builds
        // bitwise — through the prepared tiles (matvec) AND the host tiles
        // / W cache (row_dot).
        let mut fresh_mat = MaterializedStore::new();
        let mut fresh_st = StreamingStore::new();
        rebuild(&mut fresh_mat, &big, &[]);
        rebuild(&mut fresh_st, &big, &w_rows);
        let v: Vec<f32> = (0..TM).map(|i| (i as f32 * 0.03).sin()).collect();
        let v_tiles = vec![v.clone(), v.clone()];
        for i in 0..2 {
            for j in 0..2 {
                let a = mat.matvec_tile(&big.backend, i, j, &v).unwrap();
                let b = fresh_mat.matvec_tile(&big.backend, i, j, &v).unwrap();
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "tile ({i},{j})");
                }
            }
        }
        for &(row, _) in &w_rows {
            let a = st.row_dot(row, &v_tiles).unwrap();
            let b = fresh_st.row_dot(row, &v_tiles).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "row {row}");
            let m_dot = mat.row_dot(row, &v_tiles).unwrap();
            assert_eq!(a.to_bits(), m_dot.to_bits(), "row {row} vs materialized");
        }
    }

    #[test]
    fn growth_recomputes_only_dirty_tiles_and_extends_wcache() {
        // Start at m=100 (1 col tile), grow to m=300 (2 col tiles).
        let small = fixture(300, 100, 6);
        let big = fixture(300, 300, 6);
        let w_rows = vec![(1usize, 0usize), (280, 1)];
        let mut st = StreamingStore::new();
        rebuild(&mut st, &small, &w_rows);
        st.rebuild(
            &big.backend,
            &big.x_prep,
            &big.z_prep,
            big.rows,
            big.m,
            0.5,
            D,
            (100 / TM)..big.z_prep.len(),
            &w_rows,
        )
        .unwrap();
        let mut fresh = StreamingStore::new();
        rebuild(&mut fresh, &big, &w_rows);
        let v_tiles: Vec<Vec<f32>> = (0..2)
            .map(|t| (0..TM).map(|i| ((t * TM + i) as f32 * 0.02).cos()).collect())
            .collect();
        for &(row, _) in &w_rows {
            let a = st.row_dot(row, &v_tiles).unwrap();
            let b = fresh.row_dot(row, &v_tiles).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "row {row}");
        }
    }
}
