//! Mid-training checkpoints: persist a [`Session`](super::session::Session)
//! solve at a round boundary and resume it to a BITWISE-identical end
//! state — final β, convergence curve, sim-ledger counters and eval
//! counts all match an uninterrupted run exactly.
//!
//! A checkpoint is a dependency-free little-endian binary (same wire
//! helpers as the phase-trace format, `crate::trace::wire`):
//!
//! ```text
//! magic    8 bytes  b"DKMCKPT1"
//! version  1 byte   format version (currently 1)
//! config   fixed    the run fingerprint: m, d, p, λ/γ/tol bits, loss,
//!                   solver, seed, eval pipeline, max_iters — compared
//!                   FIELD BY FIELD at resume so a mismatch names the
//!                   offending flag instead of producing garbage
//! basis_fp 8 bytes  FNV-1a-64 over the basis f32 bits
//! clock    var      full [`ClockSnapshot`] of the simulated cluster
//! evals    32 bytes problem-level and session-level f/g and Hd counters
//! state    var      tagged [`SolverState`] payload (0 = TRON, 1 = BCD)
//! ```
//!
//! Deliberately NOT in the config fingerprint: `--exec`, `--sched`,
//! `--skew` and the C-storage policy. Those change how phases are *run*,
//! not what they compute — every executor is bit-identical by
//! construction — so a run checkpointed under one executor may resume
//! under another. (Under streaming C storage the *recompute-flops* ledger
//! line of a resumed run can differ from the uninterrupted one, because
//! the rebuild re-materializes tiles the original run had already paid
//! for; β and every other counter still match.)
//!
//! Writes are atomic (temp file + rename), so a crash mid-checkpoint
//! leaves the previous checkpoint intact.

use std::path::Path;

use crate::cluster::ClockSnapshot;
use crate::config::settings::{EvalPipeline, Loss, Settings, SolverChoice};
use crate::trace::wire::{put_clock, read_clock, Reader, Writer};
use crate::Result;

use super::solver::{BcdState, CurvePoint, SolverState, TronState};

const MAGIC: &[u8; 8] = b"DKMCKPT1";

/// Bumped whenever the payload layout changes; old binaries then reject
/// new files (and vice versa) instead of silently misreading them.
const FORMAT_VERSION: u8 = 1;

/// The run fingerprint stored in every checkpoint: everything that shapes
/// the NUMBERS of a solve. Resume compares each field against the live
/// settings/dataset and names the first mismatch.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointConfig {
    /// Basis size m.
    pub m: u64,
    /// Feature width d.
    pub d: u64,
    /// Cluster size p.
    pub p: u64,
    pub lambda: f32,
    pub gamma: f32,
    pub loss: Loss,
    pub solver: SolverChoice,
    pub seed: u64,
    pub eval_pipeline: EvalPipeline,
    pub tol: f32,
    pub max_iters: u64,
}

impl CheckpointConfig {
    /// The fingerprint of a live run: its settings plus the dataset's
    /// feature width.
    pub fn of(settings: &Settings, d: usize, gamma: f32) -> CheckpointConfig {
        CheckpointConfig {
            m: settings.m as u64,
            d: d as u64,
            p: settings.nodes as u64,
            lambda: settings.lambda,
            gamma,
            loss: settings.loss,
            solver: settings.solver,
            seed: settings.seed,
            eval_pipeline: settings.eval_pipeline,
            tol: settings.tol,
            max_iters: settings.max_iters as u64,
        }
    }

    /// Field-by-field comparison (floats by BITS), erroring with the
    /// specific flag that diverged so the user knows what to fix.
    pub fn ensure_matches(&self, live: &CheckpointConfig) -> Result<()> {
        macro_rules! same {
            ($field:ident, $flag:literal) => {
                anyhow::ensure!(
                    self.$field == live.$field,
                    "checkpoint was taken with {} = {:?}, this run has {:?}",
                    $flag,
                    self.$field,
                    live.$field
                );
            };
        }
        same!(m, "--m");
        same!(d, "the dataset feature width");
        same!(p, "--nodes");
        anyhow::ensure!(
            self.lambda.to_bits() == live.lambda.to_bits(),
            "checkpoint was taken with --lambda = {:?}, this run has {:?}",
            self.lambda,
            live.lambda
        );
        anyhow::ensure!(
            self.gamma.to_bits() == live.gamma.to_bits(),
            "checkpoint was taken with kernel gamma = {:?}, this run has {:?}",
            self.gamma,
            live.gamma
        );
        same!(loss, "--loss");
        same!(solver, "--solver");
        same!(seed, "--seed");
        same!(eval_pipeline, "--pipeline");
        anyhow::ensure!(
            self.tol.to_bits() == live.tol.to_bits(),
            "checkpoint was taken with --tol = {:?}, this run has {:?}",
            self.tol,
            live.tol
        );
        same!(max_iters, "--max-iters");
        Ok(())
    }
}

/// One persisted round boundary of a session solve: the run fingerprint,
/// the basis identity, the full simulated-cluster ledger, the eval
/// counters of both the in-flight [`DistProblem`] and the owning session,
/// and the solver's complete resumable loop state.
///
/// [`DistProblem`]: super::dist::DistProblem
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub config: CheckpointConfig,
    /// FNV-1a-64 over the basis f32 bits
    /// ([`crate::trace::fingerprint_f32s`]): the basis is rebuilt
    /// deterministically from the seed at resume, and this catches the
    /// rebuild diverging (different dataset file, code drift).
    pub basis_fp: u64,
    /// The simulated cluster clock at the checkpointed round boundary.
    pub clock: ClockSnapshot,
    /// `DistProblem::fg_evals` / `hd_evals` at the boundary (the solve in
    /// flight).
    pub problem_fg: u64,
    pub problem_hd: u64,
    /// `Session::fg_evals` / `hd_evals` at the boundary (completed earlier
    /// solves; the in-flight solve is merged in only when it finishes).
    pub session_fg: u64,
    pub session_hd: u64,
    /// The solver's resumable loop state.
    pub state: SolverState,
}

fn loss_tag(loss: Loss) -> u8 {
    match loss {
        Loss::SqHinge => 0,
        Loss::Logistic => 1,
        Loss::Squared => 2,
    }
}

fn loss_from_tag(tag: u8) -> Result<Loss> {
    match tag {
        0 => Ok(Loss::SqHinge),
        1 => Ok(Loss::Logistic),
        2 => Ok(Loss::Squared),
        other => anyhow::bail!("unknown loss tag {other} in checkpoint"),
    }
}

fn pipeline_tag(p: EvalPipeline) -> u8 {
    match p {
        EvalPipeline::Fused => 0,
        EvalPipeline::Split => 1,
    }
}

fn pipeline_from_tag(tag: u8) -> Result<EvalPipeline> {
    match tag {
        0 => Ok(EvalPipeline::Fused),
        1 => Ok(EvalPipeline::Split),
        other => anyhow::bail!("unknown eval-pipeline tag {other} in checkpoint"),
    }
}

fn put_solver(w: &mut Writer, s: SolverChoice) {
    match s {
        SolverChoice::Tron => {
            w.u8(0);
            w.u64(0);
        }
        SolverChoice::Bcd { block } => {
            w.u8(1);
            w.u64(block as u64);
        }
    }
}

fn read_solver(r: &mut Reader) -> Result<SolverChoice> {
    let tag = r.u8()?;
    let block = r.u64()? as usize;
    match tag {
        0 => Ok(SolverChoice::Tron),
        1 => Ok(SolverChoice::Bcd { block }),
        other => anyhow::bail!("unknown solver tag {other} in checkpoint"),
    }
}

fn put_curve(w: &mut Writer, curve: &[CurvePoint]) {
    w.u64(curve.len() as u64);
    for c in curve {
        w.f64(c.cum_secs);
        w.u64(c.comm_rounds);
        w.f64(c.f);
        w.f64(c.gnorm);
    }
}

fn read_curve(r: &mut Reader) -> Result<Vec<CurvePoint>> {
    let n = r.len_prefix()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(CurvePoint {
            cum_secs: r.f64()?,
            comm_rounds: r.u64()?,
            f: r.f64()?,
            gnorm: r.f64()?,
        });
    }
    Ok(out)
}

fn put_f64s(w: &mut Writer, xs: &[f64]) {
    w.u64(xs.len() as u64);
    for &x in xs {
        w.f64(x);
    }
}

fn read_f64s(r: &mut Reader) -> Result<Vec<f64>> {
    let n = r.len_prefix()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.f64()?);
    }
    Ok(out)
}

fn put_state(w: &mut Writer, state: &SolverState) {
    match state {
        SolverState::Tron(st) => {
            w.u8(0);
            w.u64(st.passes);
            w.u64(st.accepted);
            w.f64(st.f);
            w.f64(st.gnorm);
            w.f64(st.gnorm0);
            w.f64(st.delta);
            w.u64(st.fg_evals);
            w.u64(st.hd_evals);
            w.f32s(&st.x);
            w.f32s(&st.g);
            put_curve(w, &st.curve);
            w.f64(st.ledger_t0);
            w.u64(st.ledger_r0);
        }
        SolverState::Bcd(st) => {
            w.u8(1);
            w.u64(st.rounds);
            w.u64(st.fg_evals);
            w.u64(st.pending_block);
            w.f32s(&st.pending_delta);
            w.f64(st.sweep_sq);
            w.u8(st.has_gnorm0 as u8);
            w.f64(st.gnorm0);
            w.f64(st.last_gnorm);
            w.f32s(&st.beta);
            w.u64(st.factors.len() as u64);
            for f in &st.factors {
                put_f64s(w, f);
            }
            w.u64(st.node_margins.len() as u64);
            for node in &st.node_margins {
                w.u64(node.len() as u64);
                for tile in node {
                    w.f32s(tile);
                }
            }
            put_curve(w, &st.curve);
            w.f64(st.ledger_t0);
            w.u64(st.ledger_r0);
        }
    }
}

fn read_state(r: &mut Reader) -> Result<SolverState> {
    match r.u8()? {
        0 => {
            let passes = r.u64()?;
            let accepted = r.u64()?;
            let f = r.f64()?;
            let gnorm = r.f64()?;
            let gnorm0 = r.f64()?;
            let delta = r.f64()?;
            let fg_evals = r.u64()?;
            let hd_evals = r.u64()?;
            let x = r.f32s()?;
            let g = r.f32s()?;
            let curve = read_curve(r)?;
            Ok(SolverState::Tron(TronState {
                passes,
                accepted,
                x,
                f,
                g,
                gnorm,
                gnorm0,
                delta,
                fg_evals,
                hd_evals,
                curve,
                ledger_t0: r.f64()?,
                ledger_r0: r.u64()?,
            }))
        }
        1 => {
            let rounds = r.u64()?;
            let fg_evals = r.u64()?;
            let pending_block = r.u64()?;
            let pending_delta = r.f32s()?;
            let sweep_sq = r.f64()?;
            let has_gnorm0 = r.u8()? != 0;
            let gnorm0 = r.f64()?;
            let last_gnorm = r.f64()?;
            let beta = r.f32s()?;
            let nb = r.len_prefix()?;
            let mut factors = Vec::with_capacity(nb);
            for _ in 0..nb {
                factors.push(read_f64s(r)?);
            }
            let p = r.len_prefix()?;
            let mut node_margins = Vec::with_capacity(p);
            for _ in 0..p {
                let rt = r.len_prefix()?;
                let mut node = Vec::with_capacity(rt);
                for _ in 0..rt {
                    node.push(r.f32s()?);
                }
                node_margins.push(node);
            }
            let curve = read_curve(r)?;
            Ok(SolverState::Bcd(BcdState {
                rounds,
                beta,
                pending_block,
                pending_delta,
                sweep_sq,
                has_gnorm0,
                gnorm0,
                last_gnorm,
                fg_evals,
                factors,
                node_margins,
                curve,
                ledger_t0: r.f64()?,
                ledger_r0: r.u64()?,
            }))
        }
        other => anyhow::bail!("unknown solver-state tag {other} in checkpoint"),
    }
}

impl Checkpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.bytes(MAGIC);
        w.u8(FORMAT_VERSION);
        let c = &self.config;
        w.u64(c.m);
        w.u64(c.d);
        w.u64(c.p);
        w.f32(c.lambda);
        w.f32(c.gamma);
        w.u8(loss_tag(c.loss));
        put_solver(&mut w, c.solver);
        w.u64(c.seed);
        w.u8(pipeline_tag(c.eval_pipeline));
        w.f32(c.tol);
        w.u64(c.max_iters);
        w.u64(self.basis_fp);
        put_clock(&mut w, &self.clock);
        w.u64(self.problem_fg);
        w.u64(self.problem_hd);
        w.u64(self.session_fg);
        w.u64(self.session_hd);
        put_state(&mut w, &self.state);
        w.into_bytes()
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint> {
        let mut r = Reader::new(buf);
        anyhow::ensure!(
            r.take(8)? == MAGIC,
            "not a DKM checkpoint file (bad magic)"
        );
        let version = r.u8()?;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "checkpoint format version {version}, this build reads version {FORMAT_VERSION}"
        );
        let config = CheckpointConfig {
            m: r.u64()?,
            d: r.u64()?,
            p: r.u64()?,
            lambda: r.f32()?,
            gamma: r.f32()?,
            loss: loss_from_tag(r.u8()?)?,
            solver: read_solver(&mut r)?,
            seed: r.u64()?,
            eval_pipeline: pipeline_from_tag(r.u8()?)?,
            tol: r.f32()?,
            max_iters: r.u64()?,
        };
        let ck = Checkpoint {
            config,
            basis_fp: r.u64()?,
            clock: read_clock(&mut r)?,
            problem_fg: r.u64()?,
            problem_hd: r.u64()?,
            session_fg: r.u64()?,
            session_hd: r.u64()?,
            state: read_state(&mut r)?,
        };
        r.done()?;
        Ok(ck)
    }

    /// Atomic save: write a sibling temp file, then rename over `path`, so
    /// a crash mid-write never corrupts the previous checkpoint.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("ckpt.tmp");
        std::fs::write(&tmp, self.to_bytes())
            .map_err(|e| anyhow::anyhow!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            anyhow::anyhow!("rename {} -> {}: {e}", tmp.display(), path.display())
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let buf = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Checkpoint::from_bytes(&buf)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{CostModel, SimClock};
    use crate::metrics::Step;

    fn sample_clock() -> ClockSnapshot {
        let mut c = SimClock::new(CostModel {
            latency_s: 1e-3,
            per_byte_s: 1e-9,
        });
        c.add_compute(Step::Tron, 0.125);
        c.add_reduce(Step::Tron, 4, 4096);
        c.add_barrier();
        c.add_faults(2);
        c.add_retries(1);
        c.add_straggler(0.5, 1.5);
        c.snapshot()
    }

    fn sample_curve() -> Vec<CurvePoint> {
        vec![
            CurvePoint {
                cum_secs: 0.0,
                comm_rounds: 0,
                f: 10.0,
                gnorm: 3.0,
            },
            CurvePoint {
                cum_secs: 0.25,
                comm_rounds: 7,
                f: 1.0 / 3.0,
                gnorm: 0.1,
            },
        ]
    }

    fn sample_config() -> CheckpointConfig {
        CheckpointConfig {
            m: 64,
            d: 9,
            p: 4,
            lambda: 1e-3,
            gamma: 0.37,
            loss: Loss::SqHinge,
            solver: SolverChoice::Tron,
            seed: 42,
            eval_pipeline: EvalPipeline::Fused,
            tol: 1e-3,
            max_iters: 50,
        }
    }

    fn tron_checkpoint() -> Checkpoint {
        Checkpoint {
            config: sample_config(),
            basis_fp: 0xDEADBEEFCAFE,
            clock: sample_clock(),
            problem_fg: 5,
            problem_hd: 11,
            session_fg: 2,
            session_hd: 3,
            state: SolverState::Tron(TronState {
                passes: 4,
                accepted: 3,
                x: vec![0.1, -0.2, 1.0 / 3.0],
                f: 0.625,
                g: vec![1e-3, -2e-4, 5e-5],
                gnorm: 0.01,
                gnorm0: 3.0,
                delta: 0.75,
                fg_evals: 5,
                hd_evals: 11,
                curve: sample_curve(),
                ledger_t0: 0.001,
                ledger_r0: 1,
            }),
        }
    }

    fn bcd_checkpoint() -> Checkpoint {
        Checkpoint {
            config: CheckpointConfig {
                solver: SolverChoice::Bcd { block: 32 },
                ..sample_config()
            },
            basis_fp: 7,
            clock: sample_clock(),
            problem_fg: 9,
            problem_hd: 0,
            session_fg: 0,
            session_hd: 0,
            state: SolverState::Bcd(BcdState {
                rounds: 9,
                beta: vec![0.5, -0.25, 0.125, 1.0 / 7.0],
                pending_block: 1,
                pending_delta: vec![1e-2, -1e-3],
                sweep_sq: 0.04,
                has_gnorm0: true,
                gnorm0: 2.0,
                last_gnorm: 0.2,
                fg_evals: 9,
                factors: vec![vec![2.0, 0.5, 1.5, 0.0], vec![3.0]],
                node_margins: vec![
                    vec![vec![0.1, 0.2], vec![0.3]],
                    vec![vec![-0.4, 0.5]],
                ],
                curve: sample_curve(),
                ledger_t0: 0.0,
                ledger_r0: 0,
            }),
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dkm_checkpoint_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn tron_checkpoint_round_trips_bitwise() {
        let ck = tron_checkpoint();
        let path = tmp("tron.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        // Spot-check float identity at the bit level (PartialEq would
        // also pass for -0.0 vs 0.0).
        let (SolverState::Tron(a), SolverState::Tron(b)) = (&ck.state, &back.state) else {
            panic!("state variant changed in round trip");
        };
        for (x, y) in a.x.iter().zip(&b.x) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.f.to_bits(), b.f.to_bits());
        assert_eq!(a.delta.to_bits(), b.delta.to_bits());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bcd_checkpoint_round_trips_bitwise() {
        let ck = bcd_checkpoint();
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck, back);
        let (SolverState::Bcd(a), SolverState::Bcd(b)) = (&ck.state, &back.state) else {
            panic!("state variant changed in round trip");
        };
        for (fa, fb) in a.factors.iter().zip(&b.factors) {
            for (x, y) in fa.iter().zip(fb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(a.node_margins, b.node_margins);
    }

    #[test]
    fn rejects_corruption() {
        let bytes = tron_checkpoint().to_bytes();

        // Truncation anywhere.
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..10]).is_err());

        // Trailing garbage.
        let mut grown = bytes.clone();
        grown.push(0);
        assert!(Checkpoint::from_bytes(&grown).is_err());

        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        let err = Checkpoint::from_bytes(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");

        // Unknown version.
        let mut bad = bytes.clone();
        bad[8] = 99;
        let err = Checkpoint::from_bytes(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("version 99"), "{err:#}");
    }

    #[test]
    fn config_mismatch_names_the_flag() {
        let ck = sample_config();
        let mut live = sample_config();
        live.seed = 43;
        let err = ck.ensure_matches(&live).unwrap_err();
        assert!(format!("{err:#}").contains("--seed"), "{err:#}");

        let mut live = sample_config();
        live.solver = SolverChoice::Bcd { block: 16 };
        let err = ck.ensure_matches(&live).unwrap_err();
        assert!(format!("{err:#}").contains("--solver"), "{err:#}");

        let mut live = sample_config();
        live.lambda = 2e-3;
        assert!(ck.ensure_matches(&live).is_err());

        assert!(ck.ensure_matches(&sample_config()).is_ok());
    }
}
