//! The paper's system contribution: Algorithm 1 — distributed training of
//! the Nyström formulation (4) with TRON over an AllReduce tree.
//!
//! * [`node`] — per-node state: data shard, padded row tiles, the C-block
//!   store, and the node's share of W.
//! * [`cstore`] — the memory-bounded kernel-operator layer: how the C row
//!   block is represented (materialized / streaming / budgeted auto) behind
//!   the [`cstore::CBlockStore`] trait, with bit-identical results.
//! * [`dist`] — the distributed function / gradient / Hessian-vector
//!   products (steps 4a–4c): node-local tile ops + AllReduce.
//! * [`solver`] — the master-side solver layer behind the `Solver` trait:
//!   TRON (the paper's trust-region Newton) and distributed block
//!   coordinate descent, both priced on the same ledger.
//! * [`basis`] — basis selection: random (paper's large-m default),
//!   distributed K-means (small m), and the auto policy of §3.2.
//! * [`session`] — the stateful `Session` handle: ONE owner of the
//!   cluster/backend/basis/β that amortizes setup across solves, stage-wise
//!   growth, λ/loss re-solves and distributed prediction.
//! * [`trainer`] — the one-shot entry points (`train`, `train_stagewise`),
//!   thin wrappers over a `Session`, plus the `TrainedModel` bundle.
//! * [`model_io`] — `TrainedModel` persistence (save/load, bit-exact).
//! * [`checkpoint`] — mid-training checkpoints: a solve's round-boundary
//!   state (solver loop state, sim ledger, eval counters, basis
//!   fingerprint) persisted so an interrupted run resumes to a bitwise
//!   identical end state.
//! * [`predict`] — serial test-set scoring with a trained model snapshot
//!   (cluster-resident sessions score through `Session::predict`).
//! * [`serving`] — prediction-only sessions: a `TrainedModel` loaded onto
//!   a serving cluster (basis tiles + β, no training state), `&self`
//!   multi-slot batch scoring with a double-buffered β swap.

pub mod basis;
pub mod checkpoint;
pub mod cstore;
pub mod dist;
pub mod model_io;
pub mod node;
pub mod predict;
pub mod serving;
pub mod session;
pub mod solver;
pub mod trainer;

pub use checkpoint::{Checkpoint, CheckpointConfig};
pub use cstore::{make_store, CBlockStore};
pub use node::WorkerNode;
pub use serving::ServingSession;
pub use session::{growth_settings, Session, Solve};
pub use solver::{
    make_solver, BcdOptions, BcdSolver, BcdState, CurvePoint, Objective, SolveStats, Solver,
    SolverState, Start, TronOptions, TronSolver, TronState,
};
pub use trainer::{train, train_stagewise, StageOutput, TrainOutput, TrainedModel};
