//! The paper's system contribution: Algorithm 1 — distributed training of
//! the Nyström formulation (4) with TRON over an AllReduce tree.
//!
//! * [`node`] — per-node state: data shard, padded row tiles, the C-block
//!   store, and the node's share of W.
//! * [`cstore`] — the memory-bounded kernel-operator layer: how the C row
//!   block is represented (materialized / streaming / budgeted auto) behind
//!   the [`cstore::CBlockStore`] trait, with bit-identical results.
//! * [`dist`] — the distributed function / gradient / Hessian-vector
//!   products (steps 4a–4c): node-local tile ops + AllReduce.
//! * [`tron`] — the trust-region Newton solver (Lin–Weng–Keerthi) run by
//!   the master.
//! * [`basis`] — basis selection: random (paper's large-m default),
//!   distributed K-means (small m), and the auto policy of §3.2.
//! * [`trainer`] — the end-to-end Algorithm-1 driver + stage-wise basis
//!   growth (§3, "Stage-wise addition of basis points").
//! * [`predict`] — distributed test-set scoring with the trained model.

pub mod basis;
pub mod cstore;
pub mod dist;
pub mod node;
pub mod predict;
pub mod trainer;
pub mod tron;

pub use cstore::{make_store, CBlockStore};
pub use node::WorkerNode;
pub use trainer::{train, TrainOutput, TrainedModel};
pub use tron::{TronOptions, TronStats};
