//! TRON: trust-region Newton method (Lin, Weng & Keerthi, 2007) — the
//! solver the paper runs on the master (step 4), with every f/∇f/H·d
//! evaluation delegated to an [`Objective`] (distributed or local).
//!
//! The inner solver is Steihaug conjugate gradient truncated at the trust
//! region boundary; the update/radius logic follows LIBLINEAR's tron.cpp.
//! "Typically, TRON requires at most a few hundred iterations, with each
//! iteration involving one function/gradient computation and a few Hd
//! computations" (paper §3).

use crate::Result;

use super::super::dist::DistProblem;
use super::{CurvePoint, HookedProblem, Objective, RoundHook, SolveStats, Solver, SolverState, Start};

#[derive(Clone, Debug)]
pub struct TronOptions {
    /// Stop when ‖g‖ ≤ tol · ‖g₀‖.
    pub tol: f32,
    /// Bound on TOTAL outer passes (accepted + rejected steps). Every pass
    /// costs one f/g evaluation, so `fg_evals ≤ max_iters + 1` no matter
    /// how the objective behaves — a persistently rejecting objective
    /// cannot burn unbounded evaluations.
    pub max_iters: usize,
    /// Relative CG residual tolerance.
    pub cg_tol: f32,
    /// Cap on CG steps per TRON iteration.
    pub max_cg: usize,
    /// Print per-iteration progress.
    pub verbose: bool,
}

impl Default for TronOptions {
    fn default() -> Self {
        TronOptions {
            tol: 1e-3,
            max_iters: 300,
            cg_tol: 0.1,
            max_cg: 50,
            verbose: false,
        }
    }
}

/// TRON behind the [`Solver`] trait: the paper's Algorithm-1 solver as a
/// peer of [`super::bcd::BcdSolver`]. A thin shell over [`minimize`] — the
/// numerical path is exactly the standalone function's, so β is
/// bit-identical to driving `minimize` by hand.
pub struct TronSolver {
    pub opts: TronOptions,
}

impl TronSolver {
    pub fn new(opts: TronOptions) -> Self {
        TronSolver { opts }
    }
}

impl Solver for TronSolver {
    fn name(&self) -> &'static str {
        "tron"
    }

    fn solve_hooked(
        &mut self,
        problem: &mut DistProblem<'_>,
        start: Start<'_>,
        on_round: Option<RoundHook<'_>>,
    ) -> Result<(Vec<f32>, SolveStats)> {
        match on_round {
            None => minimize_hooked(problem, start, &self.opts),
            Some(hook) => {
                let mut hooked = HookedProblem {
                    inner: problem,
                    hook,
                };
                minimize_hooked(&mut hooked, start, &self.opts)
            }
        }
    }
}

/// TRON's complete resumable loop state, captured at the bottom of an
/// outer pass (after the radius update, accept/reject and degeneracy
/// guards). Every field is restored bitwise on [`Start::Resume`], so the
/// continued run's remaining passes — and everything they charge to the
/// ledger — replay the uninterrupted run's exactly. Counters are u64 so
/// the checkpoint wire format is width-stable across platforms.
#[derive(Clone, Debug, PartialEq)]
pub struct TronState {
    /// Total outer passes taken (accepted + rejected).
    pub passes: u64,
    /// Accepted trust-region steps (the `iterations` stat).
    pub accepted: u64,
    /// Current iterate.
    pub x: Vec<f32>,
    /// f and ∇f at `x` (restoring these is what lets resume skip the
    /// initial evaluation — the uninterrupted run never re-evaluated
    /// here either).
    pub f: f64,
    pub g: Vec<f32>,
    pub gnorm: f64,
    /// ‖g₀‖ of the ORIGINAL cold start (the stopping tolerance is
    /// relative to it, so it must survive the interruption).
    pub gnorm0: f64,
    /// Trust-region radius.
    pub delta: f64,
    pub fg_evals: u64,
    pub hd_evals: u64,
    /// Convergence curve so far (resume appends to it).
    pub curve: Vec<CurvePoint>,
    /// Ledger baselines captured at the ORIGINAL solve start; curve points
    /// are deltas from these, so the resumed curve stays continuous with
    /// the restored clock.
    pub ledger_t0: f64,
    pub ledger_r0: u64,
}

fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

fn norm64(a: &[f32]) -> f64 {
    dot64(a, a).sqrt()
}

/// Minimize `obj` from `x0`. Returns (x*, stats). Curve points are
/// stamped from the objective's ledger (deltas from solve start) after
/// the initial evaluation and each accepted step.
pub fn minimize(
    obj: &mut dyn Objective,
    x0: &[f32],
    opts: &TronOptions,
) -> Result<(Vec<f32>, SolveStats)> {
    minimize_hooked(obj, Start::Cold(x0), opts)
}

/// One convergence-curve point, stamped as deltas from the solve-start
/// ledger baselines.
fn stamp(stats: &mut SolveStats, ledger: (f64, u64), base: (f64, u64), f: f64, gnorm: f64) {
    stats.curve.push(CurvePoint {
        cum_secs: ledger.0 - base.0,
        comm_rounds: ledger.1 - base.1,
        f,
        gnorm,
    });
}

/// [`minimize`] with a resumable [`Start`]: `Cold` is the classic path,
/// numerically unchanged; `Resume` restores the full loop state a round
/// snapshot captured and continues WITHOUT re-evaluating f/g at the
/// restored iterate — the remaining passes replay the uninterrupted run's
/// bitwise. When the objective wants round snapshots
/// ([`Objective::wants_rounds`]), the complete state is pushed through
/// [`Objective::on_round`] at the bottom of every pass, after all the
/// guards — i.e. only at points the loop is guaranteed to re-enter, so a
/// resume never skips a termination check the original run would have hit.
pub fn minimize_hooked(
    obj: &mut dyn Objective,
    start: Start<'_>,
    opts: &TronOptions,
) -> Result<(Vec<f32>, SolveStats)> {
    // Radius update constants (LIBLINEAR).
    const ETA0: f64 = 1e-4;
    const ETA1: f64 = 0.25;
    const ETA2: f64 = 0.75;
    const SIGMA1: f64 = 0.25;
    const SIGMA2: f64 = 0.5;
    const SIGMA3: f64 = 4.0;

    let n = obj.dim();
    let mut stats = SolveStats {
        solver: "tron",
        ..SolveStats::default()
    };
    let st = match start {
        Start::Cold(x0) => {
            assert_eq!(x0.len(), n);
            let (ledger_t0, ledger_r0) = obj.ledger();
            let x = x0.to_vec();
            let (f, g) = obj.eval_fg(&x)?;
            stats.fg_evals += 1;
            let gnorm0 = norm64(&g);
            stamp(
                &mut stats,
                obj.ledger(),
                (ledger_t0, ledger_r0),
                f,
                gnorm0,
            );
            if gnorm0 == 0.0 {
                stats.final_f = f;
                stats.converged = true;
                return Ok((x, stats));
            }
            TronState {
                passes: 0,
                accepted: 0,
                x,
                f,
                g,
                gnorm: gnorm0,
                gnorm0,
                delta: gnorm0,
                fg_evals: stats.fg_evals as u64,
                hd_evals: 0,
                curve: std::mem::take(&mut stats.curve),
                ledger_t0,
                ledger_r0,
            }
        }
        Start::Resume(SolverState::Tron(st)) => {
            anyhow::ensure!(
                st.x.len() == n,
                "tron resume: checkpoint has {} coordinates, the problem has {n}",
                st.x.len()
            );
            st.clone()
        }
        Start::Resume(other) => anyhow::bail!(
            "checkpoint holds {} solver state — rerun with --solver {} to resume it",
            other.solver_name(),
            other.solver_name()
        ),
    };
    let TronState {
        passes,
        accepted,
        mut x,
        mut f,
        mut g,
        mut gnorm,
        gnorm0,
        mut delta,
        fg_evals,
        hd_evals,
        curve,
        ledger_t0,
        ledger_r0,
    } = st;
    stats.fg_evals = fg_evals as usize;
    stats.hd_evals = hd_evals as usize;
    stats.curve = curve;
    let base = (ledger_t0, ledger_r0);

    // `accepted` counts successful steps (the convergence curve); `passes`
    // counts EVERY trip through the loop. Bounding passes — not accepts —
    // is what bounds the work: a rejected step still pays a full f/g
    // evaluation, and an objective that rejects forever used to spin here
    // until the `delta` underflow guard fired (if it ever did).
    let mut accepted = accepted as usize;
    let mut passes = passes as usize;
    while passes < opts.max_iters {
        if gnorm <= opts.tol as f64 * gnorm0 {
            stats.converged = true;
            break;
        }
        passes += 1;
        let (s, r, cg_steps) = trcg(obj, &g, delta, opts)?;
        stats.hd_evals += cg_steps;

        let mut x_new = x.clone();
        for (xi, si) in x_new.iter_mut().zip(&s) {
            *xi += si;
        }
        let (f_new, g_new) = obj.eval_fg(&x_new)?;
        stats.fg_evals += 1;

        // Predicted reduction: -(gᵀs + ½ sᵀHs) = -½(gᵀs - sᵀr).
        let gs = dot64(&g, &s);
        let prered = -0.5 * (gs - dot64(&s, &r));
        let actred = f - f_new;
        let snorm = norm64(&s);
        // LIBLINEAR clamps the initial radius to the first step length
        // ONCE, on the very first pass — not again on every rejected pass
        // before the first accept.
        if passes == 1 {
            delta = delta.min(snorm);
        }

        // Radius update via one-dimensional quadratic interpolation.
        let denom = f_new - f - gs;
        let alpha = if denom <= 0.0 {
            SIGMA3
        } else {
            (-0.5 * (gs / denom)).max(SIGMA1)
        };
        if actred < ETA0 * prered {
            delta = (alpha * snorm).min(SIGMA2 * delta);
        } else if actred < ETA1 * prered {
            delta = (SIGMA1 * delta).max((alpha * snorm).min(SIGMA2 * delta));
        } else if actred < ETA2 * prered {
            delta = (SIGMA1 * delta).max((alpha * snorm).min(SIGMA3 * delta));
        } else {
            delta = delta.max((alpha * snorm).min(SIGMA3 * delta));
        }

        if actred > ETA0 * prered {
            // Accept.
            x = x_new;
            f = f_new;
            g = g_new;
            gnorm = norm64(&g);
            stamp(&mut stats, obj.ledger(), base, f, gnorm);
            accepted += 1;
            if opts.verbose {
                eprintln!(
                    "tron it {accepted:4} f {f:.6e} |g| {gnorm:.3e} delta {delta:.3e} cg {cg_steps}"
                );
            }
        } else if opts.verbose {
            eprintln!("tron reject: actred {actred:.3e} prered {prered:.3e} delta {delta:.3e}");
        }

        // Degenerate-progress guards (LIBLINEAR).
        if f < -1e32 {
            anyhow::bail!("tron: objective unbounded below");
        }
        if prered.abs() <= 0.0 && actred <= 0.0 {
            break;
        }
        if actred.abs() <= 1e-12 * f.abs() && prered.abs() <= 1e-12 * f.abs() {
            break;
        }
        if delta <= 1e-30 {
            break;
        }
        // Round boundary: every guard above passed, so the loop WILL come
        // back around (or stop at the top-of-loop checks, which resume
        // re-evaluates identically from this state). Safe snapshot point.
        if obj.wants_rounds() {
            let snap = SolverState::Tron(TronState {
                passes: passes as u64,
                accepted: accepted as u64,
                x: x.clone(),
                f,
                g: g.clone(),
                gnorm,
                gnorm0,
                delta,
                fg_evals: stats.fg_evals as u64,
                hd_evals: stats.hd_evals as u64,
                curve: stats.curve.clone(),
                ledger_t0,
                ledger_r0,
            });
            obj.on_round(&snap)?;
        }
    }
    // A run can hit the tolerance exactly on its last permitted pass; the
    // top-of-loop check never sees it, so re-check before reporting.
    if gnorm <= opts.tol as f64 * gnorm0 {
        stats.converged = true;
    }
    stats.iterations = accepted;
    stats.final_f = f;
    stats.final_gnorm = gnorm;
    Ok((x, stats))
}

/// Steihaug trust-region CG: approximately solve H s = -g with ‖s‖ ≤ delta.
/// Returns (s, residual r = -g - Hs, #Hd products).
fn trcg(
    obj: &mut dyn Objective,
    g: &[f32],
    delta: f64,
    opts: &TronOptions,
) -> Result<(Vec<f32>, Vec<f32>, usize)> {
    let n = g.len();
    let mut s = vec![0.0f32; n];
    let mut r: Vec<f32> = g.iter().map(|v| -v).collect();
    let mut d = r.clone();
    let mut rtr = dot64(&r, &r);
    let cg_tol = opts.cg_tol as f64 * norm64(g);
    let mut steps = 0;

    while steps < opts.max_cg {
        if rtr.sqrt() <= cg_tol {
            break;
        }
        let hd = obj.eval_hd(&d)?;
        steps += 1;
        let dhd = dot64(&d, &hd);
        if dhd <= 0.0 {
            // Negative curvature: go to the boundary along d.
            let tau = boundary_tau(&s, &d, delta);
            for i in 0..n {
                s[i] += (tau * d[i] as f64) as f32;
                r[i] -= (tau * hd[i] as f64) as f32;
            }
            break;
        }
        let alpha = rtr / dhd;
        let mut s_try = s.clone();
        for i in 0..n {
            s_try[i] += (alpha * d[i] as f64) as f32;
        }
        if norm64(&s_try) > delta {
            // Hit the boundary.
            let tau = boundary_tau(&s, &d, delta);
            for i in 0..n {
                s[i] += (tau * d[i] as f64) as f32;
                r[i] -= (tau * hd[i] as f64) as f32;
            }
            break;
        }
        s = s_try;
        for i in 0..n {
            r[i] -= (alpha * hd[i] as f64) as f32;
        }
        let rtr_new = dot64(&r, &r);
        let beta = rtr_new / rtr;
        rtr = rtr_new;
        for i in 0..n {
            d[i] = r[i] + (beta * d[i] as f64) as f32;
        }
    }
    Ok((s, r, steps))
}

/// Largest τ ≥ 0 with ‖s + τ d‖ = delta.
fn boundary_tau(s: &[f32], d: &[f32], delta: f64) -> f64 {
    let std_ = dot64(s, d);
    let dtd = dot64(d, d);
    let sts = dot64(s, s);
    let disc = (std_ * std_ + dtd * (delta * delta - sts)).max(0.0);
    if std_ >= 0.0 {
        (delta * delta - sts) / (std_ + disc.sqrt())
    } else {
        (disc.sqrt() - std_) / dtd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic ½ xᵀAx - bᵀx with SPD A: one Newton step should nail it.
    struct Quad {
        a: Vec<f64>, // n x n
        b: Vec<f64>,
        n: usize,
    }

    impl Objective for Quad {
        fn dim(&self) -> usize {
            self.n
        }

        fn eval_fg(&mut self, x: &[f32]) -> Result<(f64, Vec<f32>)> {
            let n = self.n;
            let mut ax = vec![0.0f64; n];
            for i in 0..n {
                for j in 0..n {
                    ax[i] += self.a[i * n + j] * x[j] as f64;
                }
            }
            let f = 0.5 * ax.iter().zip(x).map(|(a, x)| a * *x as f64).sum::<f64>()
                - self.b.iter().zip(x).map(|(b, x)| b * *x as f64).sum::<f64>();
            let g = (0..n).map(|i| (ax[i] - self.b[i]) as f32).collect();
            Ok((f, g))
        }

        fn eval_hd(&mut self, d: &[f32]) -> Result<Vec<f32>> {
            let n = self.n;
            let mut hd = vec![0.0f32; n];
            for i in 0..n {
                let mut s = 0.0f64;
                for j in 0..n {
                    s += self.a[i * n + j] * d[j] as f64;
                }
                hd[i] = s as f32;
            }
            Ok(hd)
        }
    }

    fn spd_quad(n: usize, seed: u64) -> Quad {
        let mut rng = crate::rng::Rng::new(seed);
        let g: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { n as f64 } else { 0.0 };
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k] / n as f64;
                }
                a[i * n + j] = s;
            }
        }
        let b = (0..n).map(|i| (i as f64 % 5.0) - 2.0).collect();
        Quad { a, b, n }
    }

    #[test]
    fn solves_quadratic_to_tolerance() {
        let mut q = spd_quad(20, 1);
        let x0 = vec![0.0f32; 20];
        let opts = TronOptions {
            tol: 1e-5,
            ..TronOptions::default()
        };
        let (x, stats) = minimize(&mut q, &x0, &opts).unwrap();
        assert!(stats.converged, "{stats:?}");
        // Check Ax ≈ b.
        let (_, g) = q.eval_fg(&x).unwrap();
        assert!(norm64(&g) <= 1e-4 * norm64(&q.eval_fg(&x0).unwrap().1));
    }

    #[test]
    fn quadratic_converges_fast() {
        let mut q = spd_quad(40, 2);
        let (_, stats) = minimize(&mut q, &vec![0.0; 40], &TronOptions::default()).unwrap();
        assert!(stats.iterations <= 20, "took {} iters", stats.iterations);
        assert!(stats.converged);
    }

    #[test]
    fn f_curve_monotone_nonincreasing() {
        let mut q = spd_quad(15, 3);
        let (_, stats) = minimize(&mut q, &vec![1.0; 15], &TronOptions::default()).unwrap();
        for w in stats.f_curve().windows(2) {
            assert!(w[1] <= w[0] + 1e-10, "{:?}", stats.f_curve());
        }
        // Local objective: the ledger stays at zero, so curve points carry
        // no simulated time or comm.
        assert!(stats.curve.iter().all(|c| c.cum_secs == 0.0 && c.comm_rounds == 0));
    }

    #[test]
    fn zero_gradient_returns_immediately() {
        // b = 0, x0 = 0 is already optimal.
        let mut q = spd_quad(5, 4);
        q.b = vec![0.0; 5];
        let (x, stats) = minimize(&mut q, &vec![0.0; 5], &TronOptions::default()).unwrap();
        assert_eq!(x, vec![0.0; 5]);
        assert!(stats.converged);
        assert_eq!(stats.fg_evals, 1);
    }

    #[test]
    fn respects_iteration_cap() {
        let mut q = spd_quad(30, 5);
        let opts = TronOptions {
            tol: 1e-12,
            max_iters: 2,
            ..TronOptions::default()
        };
        let (_, stats) = minimize(&mut q, &vec![0.0; 30], &opts).unwrap();
        assert!(stats.iterations <= 2);
        assert!(stats.fg_evals <= 3, "work not bounded: {}", stats.fg_evals);
    }

    /// An objective TRON always rejects: f is constant (actred = 0) while
    /// the gradient stays nonzero and the curvature is zero, so every step
    /// predicts a reduction it never delivers. Before the pass bound, this
    /// burned one f/g evaluation per `delta`-halving until the 1e-30
    /// underflow guard — ~100 evaluations regardless of `max_iters`.
    struct AlwaysReject {
        n: usize,
    }

    impl Objective for AlwaysReject {
        fn dim(&self) -> usize {
            self.n
        }

        fn eval_fg(&mut self, _x: &[f32]) -> Result<(f64, Vec<f32>)> {
            Ok((0.0, vec![1.0; self.n]))
        }

        fn eval_hd(&mut self, _d: &[f32]) -> Result<Vec<f32>> {
            Ok(vec![0.0; self.n])
        }
    }

    #[test]
    fn rejecting_objective_is_bounded_by_max_iters() {
        let mut obj = AlwaysReject { n: 8 };
        let opts = TronOptions {
            max_iters: 5,
            ..TronOptions::default()
        };
        let (x, stats) = minimize(&mut obj, &vec![0.0; 8], &opts).unwrap();
        // One evaluation at x0 plus at most one per outer pass.
        assert!(
            stats.fg_evals <= opts.max_iters + 1,
            "unbounded rejected passes: {} fg evals",
            stats.fg_evals
        );
        assert_eq!(stats.iterations, 0, "no step was ever accepted");
        assert!(!stats.converged);
        assert_eq!(x, vec![0.0; 8], "rejected steps must not move x");
    }

    #[test]
    fn iterations_counts_accepted_steps_only() {
        // Zero accepted steps (gradient already zero): iterations = 0.
        let mut q = spd_quad(5, 4);
        q.b = vec![0.0; 5];
        let (_, stats) = minimize(&mut q, &vec![0.0; 5], &TronOptions::default()).unwrap();
        assert_eq!(stats.iterations, 0);
        // A convergent run: the loss curve has exactly one entry per
        // accepted step plus the initial f.
        let mut q = spd_quad(15, 3);
        let (_, stats) = minimize(&mut q, &vec![1.0; 15], &TronOptions::default()).unwrap();
        assert!(stats.iterations >= 1);
        assert_eq!(stats.curve.len(), stats.iterations + 1);
        assert!(stats.fg_evals >= stats.iterations + 1);
        assert_eq!(stats.solver, "tron");
    }

    /// Wraps an objective to collect every round snapshot, exactly like
    /// the checkpoint hook does through `HookedProblem`.
    struct Snapshotting<Q: Objective> {
        inner: Q,
        states: Vec<SolverState>,
    }

    impl<Q: Objective> Objective for Snapshotting<Q> {
        fn dim(&self) -> usize {
            self.inner.dim()
        }

        fn eval_fg(&mut self, x: &[f32]) -> Result<(f64, Vec<f32>)> {
            self.inner.eval_fg(x)
        }

        fn eval_hd(&mut self, d: &[f32]) -> Result<Vec<f32>> {
            self.inner.eval_hd(d)
        }

        fn wants_rounds(&self) -> bool {
            true
        }

        fn on_round(&mut self, s: &SolverState) -> Result<()> {
            self.states.push(s.clone());
            Ok(())
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn round_snapshots_do_not_perturb_the_solve() {
        let x0 = vec![1.0f32; 15];
        let (x_plain, st_plain) =
            minimize(&mut spd_quad(15, 3), &x0, &TronOptions::default()).unwrap();
        let mut snap = Snapshotting {
            inner: spd_quad(15, 3),
            states: Vec::new(),
        };
        let (x_snap, st_snap) =
            minimize_hooked(&mut snap, Start::Cold(&x0), &TronOptions::default()).unwrap();
        assert_eq!(bits(&x_plain), bits(&x_snap));
        assert_eq!(st_plain.final_f.to_bits(), st_snap.final_f.to_bits());
        assert_eq!(st_plain.curve, st_snap.curve);
        // One snapshot at the bottom of every completed pass (the last
        // pass may break out of a guard before the snapshot point).
        assert!(!snap.states.is_empty());
        assert!(snap.states.len() <= st_snap.fg_evals);
    }

    #[test]
    fn resume_from_any_round_is_bit_identical_to_the_full_run() {
        let x0 = vec![1.0f32; 15];
        let opts = TronOptions::default();
        let mut snap = Snapshotting {
            inner: spd_quad(15, 3),
            states: Vec::new(),
        };
        let (x_full, st_full) = minimize_hooked(&mut snap, Start::Cold(&x0), &opts).unwrap();
        assert!(snap.states.len() >= 2, "need rounds to resume from");
        for state in &snap.states {
            let mut fresh = spd_quad(15, 3);
            let (x_res, st_res) =
                minimize_hooked(&mut fresh, Start::Resume(state), &opts).unwrap();
            assert_eq!(bits(&x_full), bits(&x_res), "resume at {state:?}");
            assert_eq!(st_full.final_f.to_bits(), st_res.final_f.to_bits());
            assert_eq!(st_full.final_gnorm.to_bits(), st_res.final_gnorm.to_bits());
            assert_eq!(st_full.iterations, st_res.iterations);
            assert_eq!(st_full.fg_evals, st_res.fg_evals);
            assert_eq!(st_full.hd_evals, st_res.hd_evals);
            assert_eq!(st_full.curve, st_res.curve);
            assert_eq!(st_full.converged, st_res.converged);
        }
    }

    #[test]
    fn resume_rejects_mismatched_state() {
        let mut q = spd_quad(5, 4);
        let bad = SolverState::Tron(TronState {
            passes: 1,
            accepted: 1,
            x: vec![0.0; 9],
            f: 0.0,
            g: vec![0.0; 9],
            gnorm: 1.0,
            gnorm0: 1.0,
            delta: 1.0,
            fg_evals: 2,
            hd_evals: 1,
            curve: Vec::new(),
            ledger_t0: 0.0,
            ledger_r0: 0,
        });
        let err = minimize_hooked(&mut q, Start::Resume(&bad), &TronOptions::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("9 coordinates"), "{err:#}");
    }

    #[test]
    fn boundary_tau_lands_on_sphere() {
        let s = vec![0.5f32, 0.0];
        let d = vec![1.0f32, 1.0];
        let delta = 2.0;
        let tau = boundary_tau(&s, &d, delta);
        let x = [s[0] as f64 + tau, tau];
        let norm = (x[0] * x[0] + x[1] * x[1]).sqrt();
        assert!((norm - delta).abs() < 1e-9);
    }
}
