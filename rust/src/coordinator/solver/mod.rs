//! The solver abstraction: step 4 of Algorithm 1 behind a trait.
//!
//! The paper runs one solver — TRON on the master, with f/∇f/H·d farmed
//! out over the AllReduce tree — but the substrate underneath (cluster
//! phases, the C-block stores, the sim ledger) is solver-agnostic. This
//! module makes that explicit:
//!
//! * [`Objective`] — what a master-side solver needs from the distributed
//!   problem: f/g and Hd evaluations plus a ledger snapshot for stamping
//!   convergence-curve points.
//! * [`Solver`] — the driver interface [`Session::solve`] dispatches on:
//!   take the distributed problem and a warm start, return β and a
//!   solver-neutral [`SolveStats`].
//! * [`tron`] — trust-region Newton (the paper's Algorithm 1): one global
//!   Newton-ish step per round, every evaluation a full β broadcast and an
//!   m-vector AllReduce.
//! * [`bcd`] — distributed parallel block minimization (Hsieh et al.
//!   arXiv:1608.02010, Tu et al. arXiv:1602.05310): one β column block
//!   per round, O(block) bytes broadcast per round and one AllReduce of
//!   `block + 2` floats — the opposite comm/compute tradeoff.
//!
//! Both solvers run on the SAME cluster primitives and are priced on the
//! same ledger, so `benches/solvers.rs` can compare their round economics
//! (comm_rounds and barriers vs objective decrease per simulated second)
//! like for like.
//!
//! Both solvers are also **resumable**: [`Solver::solve_hooked`] starts
//! from a [`Start`] — a cold β or a [`SolverState`] snapshotted at a round
//! boundary — and fires a round hook with the complete loop state after
//! every round. The checkpoint subsystem
//! ([`crate::coordinator::checkpoint`]) persists those states; a resumed
//! run replays the uninterrupted run's remaining rounds bit-identically.
//!
//! [`Session::solve`]: super::session::Session::solve

pub mod bcd;
pub mod tron;

use crate::config::settings::{Settings, SolverChoice};
use crate::Result;

use super::dist::DistProblem;

pub use bcd::{BcdOptions, BcdSolver, BcdState};
pub use tron::{minimize, TronOptions, TronSolver, TronState};

/// Anything a master-side solver can minimize. Gradients are f32 vectors
/// (they travel over the AllReduce tree); f accumulates in f64 on the
/// master.
pub trait Objective {
    fn dim(&self) -> usize;
    fn eval_fg(&mut self, x: &[f32]) -> Result<(f64, Vec<f32>)>;
    fn eval_hd(&mut self, d: &[f32]) -> Result<Vec<f32>>;

    /// Snapshot of (simulated seconds, AllReduce round-trips) accumulated
    /// so far by whatever substrate evaluates this objective. Solvers
    /// stamp [`CurvePoint`]s with deltas from solve start, so the curve is
    /// comparable across solvers with different per-round comm costs.
    /// Purely local objectives keep the default zeros — their curves then
    /// carry only f and ‖g‖.
    fn ledger(&self) -> (f64, u64) {
        (0.0, 0)
    }

    /// Resumable solvers ask this before cloning their loop state at each
    /// round boundary; the default `false` keeps round snapshots free for
    /// plain objectives.
    fn wants_rounds(&self) -> bool {
        false
    }

    /// Round-boundary notification from resumable solvers, carrying the
    /// complete loop state a later [`Start::Resume`] needs. Only fired
    /// when [`Objective::wants_rounds`] is true; [`HookedProblem`] routes
    /// it to the session's checkpoint writer. Default: no-op.
    fn on_round(&mut self, _state: &SolverState) -> Result<()> {
        Ok(())
    }
}

/// Where a solve begins: a cold/warm start from a β vector, or the exact
/// mid-solve loop state a previous run snapshotted at a round boundary.
/// Resume restores every number the solver's loop carries bitwise, so the
/// continued run replays the uninterrupted run's remaining rounds exactly.
pub enum Start<'a> {
    Cold(&'a [f32]),
    Resume(&'a SolverState),
}

/// A solver's complete resumable loop state, snapshotted at a round
/// boundary (after the round's bookkeeping, before the next round's first
/// evaluation). The variant must match the solver that resumes it.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverState {
    Tron(TronState),
    Bcd(BcdState),
}

impl SolverState {
    pub fn solver_name(&self) -> &'static str {
        match self {
            SolverState::Tron(_) => "tron",
            SolverState::Bcd(_) => "bcd",
        }
    }

    /// The β the solve had committed when this state was captured.
    pub fn beta(&self) -> &[f32] {
        match self {
            SolverState::Tron(st) => &st.x,
            SolverState::Bcd(st) => &st.beta,
        }
    }

    /// Outer rounds completed when this state was captured (TRON passes /
    /// BCD block rounds).
    pub fn rounds_done(&self) -> u64 {
        match self {
            SolverState::Tron(st) => st.passes,
            SolverState::Bcd(st) => st.rounds,
        }
    }
}

/// The round hook [`Solver::solve_hooked`] fires at each round boundary:
/// a read view of the distributed problem (for ledger/eval-count capture)
/// plus the solver's resumable state. Checkpoint cadence lives in the
/// hook, not the solver.
pub type RoundHook<'h> = &'h mut dyn FnMut(&DistProblem<'_>, &SolverState) -> Result<()>;

/// Adapter wiring a session-level round hook into an [`Objective`]: the
/// TRON core is generic over objectives and only sees
/// [`Objective::on_round`]; this routes that to the hook with a read view
/// of the distributed problem. (BCD owns its problem borrow and calls the
/// hook directly.)
pub(crate) struct HookedProblem<'p, 'a, 'h> {
    pub inner: &'p mut DistProblem<'a>,
    pub hook: RoundHook<'h>,
}

impl Objective for HookedProblem<'_, '_, '_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_fg(&mut self, x: &[f32]) -> Result<(f64, Vec<f32>)> {
        self.inner.eval_fg(x)
    }

    fn eval_hd(&mut self, d: &[f32]) -> Result<Vec<f32>> {
        self.inner.eval_hd(d)
    }

    fn ledger(&self) -> (f64, u64) {
        self.inner.ledger()
    }

    fn wants_rounds(&self) -> bool {
        true
    }

    fn on_round(&mut self, state: &SolverState) -> Result<()> {
        (self.hook)(&*self.inner, state)
    }
}

/// One point of the solver-neutral convergence curve: where the objective
/// stood after each accepted round, stamped with the simulated time and
/// communication the solve had spent by then.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CurvePoint {
    /// Simulated seconds since solve start (0.0 for local objectives).
    pub cum_secs: f64,
    /// AllReduce round-trips since solve start.
    pub comm_rounds: u64,
    /// Objective value.
    pub f: f64,
    /// Gradient norm: the full ‖∇f‖ for TRON, the current block-gradient
    /// norm for BCD (the quantity each solver actually monitors).
    pub gnorm: f64,
}

/// Solver-neutral statistics of one solve. `curve[0]` is always the
/// objective at the warm start; one more point per accepted round.
#[derive(Clone, Debug, Default)]
pub struct SolveStats {
    /// Which solver produced this (`"tron"` / `"bcd"`).
    pub solver: &'static str,
    /// Accepted outer rounds (TRON: accepted trust-region steps; BCD:
    /// completed block rounds).
    pub iterations: usize,
    /// f/g evaluations (TRON: 4a/4b calls; BCD: one per round + final).
    pub fg_evals: usize,
    /// Hd evaluations (BCD never evaluates Hd: 0).
    pub hd_evals: usize,
    pub final_f: f64,
    /// Final monitored gradient norm (see [`CurvePoint::gnorm`]).
    pub final_gnorm: f64,
    /// The convergence curve (initial point + one per accepted round).
    pub curve: Vec<CurvePoint>,
    pub converged: bool,
}

impl SolveStats {
    /// Objective at the warm start (first curve point).
    pub fn f0(&self) -> f64 {
        self.curve.first().map(|c| c.f).unwrap_or(self.final_f)
    }

    /// The f values of the curve (the loss-curve shape callers plot).
    pub fn f_curve(&self) -> Vec<f64> {
        self.curve.iter().map(|c| c.f).collect()
    }
}

/// A master-side solver over the distributed formulation-(4) objective.
/// Implementations drive the cluster only through [`DistProblem`] — its
/// `Objective` evaluations and (for block solvers) its cluster handle —
/// so every solver is priced on the same ledger.
pub trait Solver {
    fn name(&self) -> &'static str;

    /// Minimize from the warm start `x0`. Returns (β*, stats).
    fn solve(
        &mut self,
        problem: &mut DistProblem<'_>,
        x0: &[f32],
    ) -> Result<(Vec<f32>, SolveStats)> {
        self.solve_hooked(problem, Start::Cold(x0), None)
    }

    /// Minimize from a [`Start`] (cold β or a resumable mid-solve state),
    /// firing `on_round` with the complete loop state at every round
    /// boundary. Cold + no hook is exactly [`Solver::solve`]; a resumed
    /// run replays the uninterrupted run's remaining rounds bitwise.
    fn solve_hooked(
        &mut self,
        problem: &mut DistProblem<'_>,
        start: Start<'_>,
        on_round: Option<RoundHook<'_>>,
    ) -> Result<(Vec<f32>, SolveStats)>;
}

/// Resolve the configured solver: `--solver tron` (default) or
/// `--solver bcd[:block]`, with the solver-scoped `--tol` / `--max-iters`
/// knobs applied to whichever is selected.
pub fn make_solver(settings: &Settings) -> Box<dyn Solver> {
    match settings.solver {
        SolverChoice::Tron => Box::new(TronSolver::new(TronOptions {
            tol: settings.tol,
            max_iters: settings.max_iters,
            ..TronOptions::default()
        })),
        SolverChoice::Bcd { block } => Box::new(BcdSolver::new(BcdOptions {
            block,
            tol: settings.tol,
            max_rounds: settings.max_iters,
            verbose: false,
        })),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn make_solver_respects_choice_and_knobs() {
        let mut s = Settings::default();
        assert_eq!(make_solver(&s).name(), "tron");
        s.solver = SolverChoice::Bcd { block: 32 };
        assert_eq!(make_solver(&s).name(), "bcd");
    }

    #[test]
    fn stats_f0_falls_back_to_final_f() {
        let mut st = SolveStats {
            final_f: 7.0,
            ..SolveStats::default()
        };
        assert_eq!(st.f0(), 7.0);
        st.curve.push(CurvePoint {
            f: 9.0,
            ..CurvePoint::default()
        });
        assert_eq!(st.f0(), 9.0);
        assert_eq!(st.f_curve(), vec![9.0]);
    }
}
