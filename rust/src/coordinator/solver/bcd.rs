//! Distributed parallel block minimization (BCD) for formulation (4), in
//! the style of Hsieh et al. (arXiv:1608.02010) and Tu et al.
//! (arXiv:1602.05310): instead of TRON's one global Newton step per round
//! — each evaluation a full m-float β broadcast plus an m-vector AllReduce
//! — each outer round updates ONE β column block, and only that block's
//! delta (`block` floats) travels.
//!
//! ## Round anatomy (exactly one barrier + one AllReduce round-trip)
//!
//! Every node caches its margins `z_j = C_j β` per row tile and a replica
//! of β (padded tiles), both kept in sync from the per-round block-delta
//! broadcast — so no round ever re-broadcasts full β. One fused
//! compute+reduce phase per round does, on each node:
//!
//! 1. apply the previous round's delta: `z_j += C_j[:, b_prev] Δ`,
//!    replica update (one `matvec_tile` per row tile);
//! 2. the loss stage at the cached margins (same backend op the TRON
//!    path's fused evaluations use) → loss partial + residual;
//! 3. the block gradient partial `C_j[:, b]ᵀ r` sliced to the block, plus
//!    the node's λ(Wβ) share entries and the βᵀWβ regularizer partial —
//!    packed flat as `[loss, reg, g_b…]` and tree-summed in the same
//!    dispatch.
//!
//! The master then takes a damped Newton step on the block through a
//! once-factored majorizer `H̄_b = κ·C_bᵀC_b + λ·W_bb` where κ bounds the
//! loss curvature (1 for sqhinge/squared — exact for squared — 1/4 for
//! logistic). Majorization makes every block step decrease f without a
//! line search, which is what keeps the round at ONE communication
//! round-trip; the `solvers` suite pins that metering.
//!
//! ## Setup
//!
//! One extra fused phase at solve start builds the per-block Gram and W
//! sub-matrix partials (masked column extraction through the same
//! `CBlockStore` ops, so every storage mode works) and initializes the
//! margins/replica from a single full-β broadcast. Setup is metered like
//! any other phase but is one-time — the per-round invariant above is
//! what the regression suite asserts, as a delta between two runs.
//!
//! Block order is deterministic (cyclic over tile-aligned blocks) and all
//! per-node math is fixed-order f32, so β is bit-identical across
//! executors and across the fused/split pipelines — the same contract the
//! TRON path holds.

use std::sync::Arc;

use crate::config::settings::Loss;
use crate::linalg::chol::{cholesky, cholesky_solve_factored};
use crate::metrics::Step;
use crate::runtime::tiles::{TB, TM};
use crate::runtime::Compute;
use crate::Result;

use super::super::dist::DistProblem;
use super::super::node::{pad_m_tiles, WorkerNode};
use super::{CurvePoint, Objective, RoundHook, SolveStats, Solver, SolverState, Start};
use crate::config::settings::EvalPipeline;

/// Leading scalar slots of the per-round reduce buffer: `[loss, reg]`
/// (same convention as the TRON pipeline's fused f/g buffer).
const SCALARS: usize = 2;

#[derive(Clone, Debug)]
pub struct BcdOptions {
    /// Coordinates per block (clamped to the TM tile width; blocks never
    /// straddle column tiles).
    pub block: usize,
    /// Stop when a full sweep's aggregated block-gradient norm drops to
    /// `tol` × the first sweep's.
    pub tol: f32,
    /// Cap on outer block rounds (each costs one barrier + one AllReduce).
    pub max_rounds: usize,
    pub verbose: bool,
}

impl Default for BcdOptions {
    fn default() -> Self {
        BcdOptions {
            block: 64,
            tol: 1e-3,
            max_rounds: 300,
            verbose: false,
        }
    }
}

/// One tile-aligned coordinate block: global indices
/// `tile·TM + lo .. tile·TM + hi`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Block {
    tile: usize,
    lo: usize,
    hi: usize,
}

impl Block {
    fn len(&self) -> usize {
        self.hi - self.lo
    }

    fn base(&self) -> usize {
        self.tile * TM + self.lo
    }
}

/// Deterministic tile-aligned partition of the m coordinates.
fn partition(m: usize, bs: usize) -> Vec<Block> {
    let bs = bs.clamp(1, TM);
    let ct = m.div_ceil(TM).max(1);
    let mut out = Vec::new();
    for tile in 0..ct {
        let cols = (m - tile * TM).min(TM);
        let mut lo = 0;
        while lo < cols {
            let hi = (lo + bs).min(cols);
            out.push(Block { tile, lo, hi });
            lo = hi;
        }
    }
    out
}

/// Upper bound κ on the loss's second derivative along the margins —
/// matches the loss-stage conventions (`dcoef`) of the runtime: sqhinge
/// and squared losses have unit curvature (squared exactly), logistic's
/// σ(1−σ) is at most 1/4. `κ·CᵀC + λW ⪰ ∇²f`, so the block step never
/// overshoots and f decreases monotonically without a line search.
fn curvature_bound(loss: Loss) -> f64 {
    match loss {
        Loss::SqHinge | Loss::Squared => 1.0,
        Loss::Logistic => 0.25,
    }
}

pub struct BcdSolver {
    pub opts: BcdOptions,
}

impl BcdSolver {
    pub fn new(opts: BcdOptions) -> Self {
        BcdSolver { opts }
    }
}

/// Sentinel for [`BcdState::pending_block`] when no delta is pending
/// (only possible before round 1).
pub const BCD_NO_PENDING: u64 = u64::MAX;

/// BCD's complete resumable loop state, captured at the bottom of a block
/// round (after the Newton step was computed but before the nodes apply
/// it). Restoring it bitwise — INCLUDING the per-node incremental margin
/// caches, which a fresh `C·β` would round differently — makes the
/// continued run replay the uninterrupted run's remaining rounds exactly.
/// Counters are u64 so the checkpoint wire format is width-stable.
#[derive(Clone, Debug, PartialEq)]
pub struct BcdState {
    /// Completed block rounds.
    pub rounds: u64,
    /// Master β with every applied delta committed (the pending one is
    /// NOT in it yet — exactly the loop-top invariant).
    pub beta: Vec<f32>,
    /// Index (into the deterministic block partition) of the block whose
    /// delta is pending, or [`BCD_NO_PENDING`].
    pub pending_block: u64,
    /// The pending delta itself (`block` floats).
    pub pending_delta: Vec<f32>,
    /// Running Σ‖g_b‖² of the current (partial) sweep.
    pub sweep_sq: f64,
    /// First-sweep gradient norm, once a full sweep has completed (the
    /// stopping tolerance is relative to it).
    pub has_gnorm0: bool,
    pub gnorm0: f64,
    pub last_gnorm: f64,
    pub fg_evals: u64,
    /// Per-block Cholesky factors of the majorizer `H̄_b` (f64, n×n
    /// lower-triangular each), computed once at setup — carried in full so
    /// resume never re-runs the setup phase.
    pub factors: Vec<Vec<f64>>,
    /// Per-node cached margins `z_j = C_j β` (row tile × TB), accumulated
    /// incrementally across rounds.
    pub node_margins: Vec<Vec<Vec<f32>>>,
    /// Convergence curve so far (resume appends to it).
    pub curve: Vec<CurvePoint>,
    /// Ledger baselines of the ORIGINAL solve start.
    pub ledger_t0: f64,
    pub ledger_r0: u64,
}

/// Initialize the node's BCD scratch (β replica + cached margins) from a
/// freshly broadcast β, and emit this node's flat curvature partials:
/// for each block, the masked Gram `C_bᵀC_b` then the `W_bb` share rows,
/// concatenated `[G_0, W_0, G_1, W_1, …]`.
fn node_setup(
    node: &mut WorkerNode,
    backend: &dyn Compute,
    beta_tiles: &[Vec<f32>],
    blocks: &[Block],
) -> Result<Vec<f32>> {
    assert!(node.cstore.ready(), "compute_c_block must run before BCD");
    let ct = node.cstore.col_tiles();
    let rt = node.row_tiles();
    node.bcd_beta_tiles = beta_tiles.to_vec();
    let mut margins = vec![vec![0.0f32; TB]; rt];
    for (i, z) in margins.iter_mut().enumerate() {
        for (j, bt) in beta_tiles.iter().enumerate() {
            // A zero β tile contributes exact zeros — skip the matvec
            // (bit-identical; matters for the all-zero cold start).
            if bt.iter().all(|&v| v == 0.0) {
                continue;
            }
            let part = node.cstore.matvec_tile(backend, i, j, bt)?;
            for (zi, p) in z.iter_mut().zip(&part) {
                *zi += p;
            }
        }
    }
    node.bcd_margins = margins;

    let total: usize = blocks.iter().map(|b| 2 * b.len() * b.len()).sum();
    let mut out = Vec::with_capacity(total);
    let mut unit = vec![0.0f32; TM];
    for b in blocks {
        let n = b.len();
        // Masked Gram partial: extract the block's C columns per row tile
        // (unit-vector matvecs through the store, so streaming modes work
        // and their recompute is honestly counted), zero dead rows, and
        // accumulate C_bᵀC_b in fixed order.
        let mut gram = vec![0.0f32; n * n];
        let mut cols = vec![vec![0.0f32; TB]; n];
        for i in 0..rt {
            for (t, col) in cols.iter_mut().enumerate() {
                unit[b.lo + t] = 1.0;
                *col = node.cstore.matvec_tile(backend, i, b.tile, &unit)?;
                unit[b.lo + t] = 0.0;
                for (c, mk) in col.iter_mut().zip(&node.masks[i]) {
                    *c *= mk;
                }
            }
            for a in 0..n {
                for c in 0..n {
                    let mut s = 0.0f32;
                    for r in 0..TB {
                        s += cols[a][r] * cols[c][r];
                    }
                    gram[a * n + c] += s;
                }
            }
        }
        out.extend_from_slice(&gram);
        // W_bb partial from this node's W-share rows: column k' of W
        // restricted to the block, via the same wv_entries path the TRON
        // regularizer terms use.
        let mut wbb = vec![0.0f32; n * n];
        let mut e_tiles = vec![vec![0.0f32; TM]; ct];
        let base = b.base();
        for c in 0..n {
            e_tiles[b.tile][b.lo + c] = 1.0;
            for (k, val) in node.wv_entries(backend, &e_tiles)? {
                if k >= base && k < base + n {
                    wbb[(k - base) * n + c] += val;
                }
            }
            e_tiles[b.tile][b.lo + c] = 0.0;
        }
        out.extend_from_slice(&wbb);
    }
    Ok(out)
}

/// Apply the previous round's block delta to the node's cached margins
/// and β replica (the node-side commit of the delta broadcast).
fn apply_pending(
    node: &mut WorkerNode,
    backend: &dyn Compute,
    pending: &Option<(Block, Vec<f32>)>,
) -> Result<()> {
    let Some((b, delta)) = pending else {
        return Ok(());
    };
    let mut dpad = vec![0.0f32; TM];
    dpad[b.lo..b.hi].copy_from_slice(delta);
    for i in 0..node.row_tiles() {
        let dz = node.cstore.matvec_tile(backend, i, b.tile, &dpad)?;
        for (z, d) in node.bcd_margins[i].iter_mut().zip(&dz) {
            *z += d;
        }
    }
    for (t, d) in delta.iter().enumerate() {
        node.bcd_beta_tiles[b.tile][b.lo + t] += d;
    }
    Ok(())
}

/// One node's round partial, flat for the reduce tree:
/// `[loss, βᵀ(Wβ) partial, g_b…]` — or just the two scalars when `block`
/// is None (the final f-only evaluation).
fn node_round(
    node: &mut WorkerNode,
    backend: &dyn Compute,
    loss: Loss,
    lambda: f32,
    pending: &Option<(Block, Vec<f32>)>,
    block: Option<Block>,
) -> Result<Vec<f32>> {
    apply_pending(node, backend, pending)?;
    let n = block.map(|b| b.len()).unwrap_or(0);
    let mut out = vec![0.0f32; SCALARS + n];
    for i in 0..node.row_tiles() {
        let st = backend.loss_stage(loss, &node.bcd_margins[i], &node.y_tiles[i], &node.masks[i])?;
        out[0] += st.loss;
        if let Some(b) = block {
            let gt = node.cstore.matvec_t_tile(backend, i, b.tile, &st.vec)?;
            for t in 0..n {
                out[SCALARS + t] += gt[b.lo + t];
            }
        }
    }
    for (k, wv) in node.wv_entries(backend, &node.bcd_beta_tiles)? {
        out[1] += node.bcd_beta_tiles[k / TM][k % TM] * wv;
        if let Some(b) = block {
            let base = b.base();
            if k >= base && k < base + n {
                out[SCALARS + (k - base)] += lambda * wv;
            }
        }
    }
    Ok(out)
}

/// Factor `H̄_b = κ·G_b + λ·W_bb` for every block from the reduced setup
/// buffer, escalating a tiny diagonal jitter if f32-rounded PSD terms land
/// numerically indefinite (jitter only damps the step — the fixed point
/// `g_b = 0` is unchanged).
fn factor_blocks(
    blocks: &[Block],
    reduced: &[f32],
    kappa: f64,
    lambda: f64,
) -> Result<Vec<Vec<f64>>> {
    let mut factors = Vec::with_capacity(blocks.len());
    let mut off = 0usize;
    for b in blocks {
        let n = b.len();
        let gram = &reduced[off..off + n * n];
        let wbb = &reduced[off + n * n..off + 2 * n * n];
        off += 2 * n * n;
        let h: Vec<f64> = (0..n * n)
            .map(|i| kappa * gram[i] as f64 + lambda * wbb[i] as f64)
            .collect();
        let mean_diag = (0..n).map(|i| h[i * n + i]).sum::<f64>().abs() / n as f64;
        let mut jitter = 0.0f64;
        let mut factor = None;
        for _ in 0..6 {
            let mut a = h.clone();
            for i in 0..n {
                a[i * n + i] += jitter;
            }
            if let Some(l) = cholesky(&a, n) {
                factor = Some(l);
                break;
            }
            jitter = if jitter == 0.0 {
                mean_diag.max(1e-12) * 1e-10
            } else {
                jitter * 100.0
            };
        }
        factors.push(factor.ok_or_else(|| {
            anyhow::anyhow!(
                "bcd: block majorizer at k={} is not positive definite",
                b.base()
            )
        })?);
    }
    Ok(factors)
}

fn norm64(v: &[f32]) -> f64 {
    v.iter().map(|x| *x as f64 * *x as f64).sum::<f64>().sqrt()
}

impl Solver for BcdSolver {
    fn name(&self) -> &'static str {
        "bcd"
    }

    fn solve_hooked(
        &mut self,
        problem: &mut DistProblem<'_>,
        start: Start<'_>,
        mut on_round: Option<RoundHook<'_>>,
    ) -> Result<(Vec<f32>, SolveStats)> {
        let m = problem.m;
        let ct = m.div_ceil(TM).max(1);
        let blocks = partition(m, self.opts.block);
        let nb = blocks.len();
        let kappa = curvature_bound(problem.loss);
        let lambda = problem.lambda;
        let loss = problem.loss;
        let pipeline = problem.pipeline;
        let backend = Arc::clone(&problem.backend);
        let mut stats = SolveStats {
            solver: "bcd",
            ..SolveStats::default()
        };

        let mut beta: Vec<f32>;
        let factors: Vec<Vec<f64>>;
        let mut pending: Option<(Block, Vec<f32>)>;
        let mut sweep_sq: f64;
        let mut gnorm0: Option<f64>;
        let mut last_gnorm: f64;
        let mut rounds: usize;
        let t0: f64;
        let r0: u64;
        match start {
            Start::Cold(x0) => {
                assert_eq!(x0.len(), m);
                let (lt0, lr0) = problem.ledger();
                t0 = lt0;
                r0 = lr0;

                // ---- setup: full-β broadcast, margins/replica init,
                // per-block majorizer factors (one fused phase, one-time).
                beta = x0.to_vec();
                let beta_tiles = pad_m_tiles(&beta, ct);
                problem
                    .cluster
                    .broadcast_meter(Step::Tron, m * std::mem::size_of::<f32>());
                let calls0 = backend.call_count();
                let reduced = {
                    let backend = backend.as_ref();
                    let blocks = &blocks;
                    let beta_tiles = &beta_tiles;
                    problem.cluster.try_par_compute_reduce(Step::Tron, |_, node| {
                        node_setup(node, backend, beta_tiles, blocks)
                    })?
                };
                problem
                    .cluster
                    .charge_dispatches(backend.call_count().saturating_sub(calls0));
                factors = factor_blocks(&blocks, &reduced, kappa, lambda as f64)?;
                pending = None;
                sweep_sq = 0.0;
                gnorm0 = None;
                last_gnorm = 0.0;
                rounds = 0;
            }
            Start::Resume(SolverState::Bcd(st)) => {
                // ---- resume: restore the master loop state AND the
                // per-node caches bitwise; the once-factored majorizers
                // travel in the state, so no setup phase runs (the
                // restored ledger already paid for the original one).
                anyhow::ensure!(
                    st.beta.len() == m,
                    "bcd resume: checkpoint has {} coordinates, the problem has {m}",
                    st.beta.len()
                );
                anyhow::ensure!(
                    st.factors.len() == nb,
                    "bcd resume: checkpoint has {} block factors, the partition has {nb} \
                     (was --solver bcd:block changed?)",
                    st.factors.len()
                );
                let p = problem.cluster.p();
                anyhow::ensure!(
                    st.node_margins.len() == p,
                    "bcd resume: checkpoint has margin caches for {} nodes, the cluster has {p}",
                    st.node_margins.len()
                );
                beta = st.beta.clone();
                let beta_tiles = pad_m_tiles(&beta, ct);
                for (j, node) in problem.cluster.nodes_mut().iter_mut().enumerate() {
                    anyhow::ensure!(
                        st.node_margins[j].len() == node.row_tiles(),
                        "bcd resume: node {j} has {} row tiles, the checkpoint stored {}",
                        node.row_tiles(),
                        st.node_margins[j].len()
                    );
                    node.bcd_margins = st.node_margins[j].clone();
                    node.bcd_beta_tiles = beta_tiles.clone();
                }
                factors = st.factors.clone();
                pending = if st.pending_block == BCD_NO_PENDING {
                    None
                } else {
                    let bi = st.pending_block as usize;
                    anyhow::ensure!(bi < nb, "bcd resume: pending block {bi} out of range");
                    let b = blocks[bi];
                    anyhow::ensure!(
                        st.pending_delta.len() == b.len(),
                        "bcd resume: pending delta has {} entries, block {bi} has {}",
                        st.pending_delta.len(),
                        b.len()
                    );
                    Some((b, st.pending_delta.clone()))
                };
                sweep_sq = st.sweep_sq;
                gnorm0 = st.has_gnorm0.then_some(st.gnorm0);
                last_gnorm = st.last_gnorm;
                rounds = st.rounds as usize;
                stats.fg_evals = st.fg_evals as usize;
                stats.curve = st.curve.clone();
                t0 = st.ledger_t0;
                r0 = st.ledger_r0;
            }
            Start::Resume(other) => anyhow::bail!(
                "checkpoint holds {} solver state — rerun with --solver {} to resume it",
                other.solver_name(),
                other.solver_name()
            ),
        }

        // ---- outer block rounds: one barrier + one AllReduce each.
        while rounds < self.opts.max_rounds {
            let bi = rounds % nb;
            let block = blocks[bi];
            let n = block.len();
            if let Some((_, d)) = &pending {
                problem
                    .cluster
                    .broadcast_meter(Step::Tron, d.len() * std::mem::size_of::<f32>());
            }
            let calls0 = backend.call_count();
            let reduced = run_phase(problem, &backend, loss, lambda, &pending, Some(block), pipeline)?;
            problem
                .cluster
                .charge_dispatches(backend.call_count().saturating_sub(calls0));
            problem.fg_evals += 1;
            stats.fg_evals += 1;
            // Master-side commit of the delta the nodes just applied.
            if let Some((pb, d)) = pending.take() {
                for (t, dv) in d.iter().enumerate() {
                    beta[pb.base() + t] += dv;
                }
            }
            let f = problem.assemble_f(reduced[0], reduced[1]);
            let gb = &reduced[SCALARS..SCALARS + n];
            let gnorm = norm64(gb);
            last_gnorm = gnorm;
            let (ts, rs) = problem.ledger();
            stats.curve.push(CurvePoint {
                cum_secs: ts - t0,
                comm_rounds: rs - r0,
                f,
                gnorm,
            });
            if self.opts.verbose {
                eprintln!(
                    "bcd round {rounds:4} block k={:3}+{n:<3} f {f:.6e} |g_b| {gnorm:.3e}",
                    block.base()
                );
            }
            rounds += 1;
            sweep_sq += gnorm * gnorm;
            if rounds % nb == 0 {
                // Sweep boundary: every block's gradient was seen at most
                // nb−1 rounds ago — the aggregate is the stopping monitor.
                let sweep = sweep_sq.sqrt();
                sweep_sq = 0.0;
                let g0 = *gnorm0.get_or_insert(sweep);
                if sweep <= self.opts.tol as f64 * g0 {
                    stats.converged = true;
                    break;
                }
            }
            // Damped Newton block step through the once-factored majorizer.
            let rhs: Vec<f64> = gb.iter().map(|v| -(*v as f64)).collect();
            let step64 = cholesky_solve_factored(&factors[bi], n, &rhs);
            pending = Some((block, step64.iter().map(|v| *v as f32).collect()));
            // Round boundary: the convergence check above passed, so the
            // loop WILL come back around (or stop at the round cap, which
            // resume re-checks identically). Safe snapshot point.
            if let Some(h) = on_round.as_mut() {
                let state = SolverState::Bcd(BcdState {
                    rounds: rounds as u64,
                    beta: beta.clone(),
                    pending_block: bi as u64,
                    pending_delta: pending.as_ref().map(|(_, d)| d.clone()).unwrap_or_default(),
                    sweep_sq,
                    has_gnorm0: gnorm0.is_some(),
                    gnorm0: gnorm0.unwrap_or(0.0),
                    last_gnorm,
                    fg_evals: stats.fg_evals as u64,
                    factors: factors.clone(),
                    node_margins: (0..problem.cluster.p())
                        .map(|j| problem.cluster.node(j).bcd_margins.clone())
                        .collect(),
                    curve: stats.curve.clone(),
                    ledger_t0: t0,
                    ledger_r0: r0,
                });
                h(&*problem, &state)?;
            }
        }
        stats.iterations = rounds;

        // ---- final f: flush the last pending delta and evaluate once, so
        // final_f is f at the returned β and the curve ends there.
        if let Some((_, d)) = &pending {
            problem
                .cluster
                .broadcast_meter(Step::Tron, d.len() * std::mem::size_of::<f32>());
        }
        let calls0 = backend.call_count();
        let reduced = run_phase(problem, &backend, loss, lambda, &pending, None, pipeline)?;
        problem
            .cluster
            .charge_dispatches(backend.call_count().saturating_sub(calls0));
        problem.fg_evals += 1;
        stats.fg_evals += 1;
        if let Some((pb, d)) = pending.take() {
            for (t, dv) in d.iter().enumerate() {
                beta[pb.base() + t] += dv;
            }
        }
        let f = problem.assemble_f(reduced[0], reduced[1]);
        let (ts, rs) = problem.ledger();
        stats.curve.push(CurvePoint {
            cum_secs: ts - t0,
            comm_rounds: rs - r0,
            f,
            gnorm: last_gnorm,
        });
        stats.final_f = f;
        stats.final_gnorm = last_gnorm;
        Ok((beta, stats))
    }
}

/// One cluster round: fused (one barrier + one AllReduce round-trip) or
/// the split reference (compute barrier, scalar AllReduce, block-gradient
/// AllReduce) — the same per-node partials folded in the same tree order,
/// so β is bit-identical between the pipelines, exactly like the TRON
/// evaluations.
fn run_phase(
    problem: &mut DistProblem<'_>,
    backend: &Arc<dyn Compute>,
    loss: Loss,
    lambda: f32,
    pending: &Option<(Block, Vec<f32>)>,
    block: Option<Block>,
    pipeline: EvalPipeline,
) -> Result<Vec<f32>> {
    let be = backend.as_ref();
    match pipeline {
        EvalPipeline::Fused => problem.cluster.try_par_compute_reduce(Step::Tron, |_, node| {
            node_round(node, be, loss, lambda, pending, block)
        }),
        EvalPipeline::Split => {
            let partials = problem.cluster.try_par_compute(Step::Tron, |_, node| {
                node_round(node, be, loss, lambda, pending, block)
            })?;
            let scalar_partials: Vec<Vec<f32>> =
                partials.iter().map(|p| vec![p[0], p[1]]).collect();
            let mut out = problem.cluster.allreduce_sum(Step::Tron, scalar_partials);
            if block.is_some() {
                let g_partials: Vec<Vec<f32>> = partials
                    .into_iter()
                    .map(|mut p| p.split_off(SCALARS))
                    .collect();
                out.extend(problem.cluster.allreduce_sum(Step::Tron, g_partials));
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_tile_aligned_and_covers_m() {
        let blocks = partition(600, 64);
        // Tile 0: 4×64, tile 1: 64+64+64+64+32... 600-256=344 → 5 full + 24.
        let covered: usize = blocks.iter().map(|b| b.len()).sum();
        assert_eq!(covered, 600);
        for b in &blocks {
            assert!(b.hi <= TM, "{b:?} straddles a tile");
            assert!(b.len() >= 1);
        }
        // Deterministic cyclic order: strictly increasing global base.
        for w in blocks.windows(2) {
            assert!(w[1].base() > w[0].base());
        }
        // Oversized block clamps to one block per tile.
        let big = partition(300, 10_000);
        assert_eq!(big.len(), 2);
        assert_eq!(big[0].len(), TM);
        assert_eq!(big[1].len(), 300 - TM);
    }

    #[test]
    fn curvature_bounds_match_loss_stage_conventions() {
        assert_eq!(curvature_bound(Loss::SqHinge), 1.0);
        assert_eq!(curvature_bound(Loss::Squared), 1.0);
        assert_eq!(curvature_bound(Loss::Logistic), 0.25);
    }
}
