//! Model persistence: serialize a [`TrainedModel`] (basis, β, γ, loss) so
//! a training session's snapshot can be shipped to a serving process and
//! loaded without the training data or cluster.
//!
//! The format is a dependency-free little-endian binary:
//!
//! ```text
//! magic   8 bytes  b"DKMMODL1"
//! version 1 byte   format version (currently 1); loaders reject other
//!                  versions with a clear error instead of misparsing
//! loss    1 byte   0 = sqhinge, 1 = logistic, 2 = squared
//! gamma   4 bytes  f32 LE
//! m       8 bytes  u64 LE (basis rows)
//! d       8 bytes  u64 LE (feature width)
//! basis   m·d·4    f32 LE, row-major
//! beta    m·4      f32 LE
//! ```
//!
//! f32 bits round-trip exactly (`to_le_bytes`/`from_le_bytes`), so a
//! loaded model predicts BIT-IDENTICALLY to the one that was saved —
//! asserted by the tests here and in `rust/tests/session.rs`.

use std::path::Path;

use crate::config::settings::Loss;
use crate::linalg::Mat;
use crate::Result;

use super::trainer::TrainedModel;

const MAGIC: &[u8; 8] = b"DKMMODL1";

/// Bumped whenever the payload layout changes; old binaries then reject
/// new files (and vice versa) instead of silently misreading them.
const FORMAT_VERSION: u8 = 1;

fn loss_tag(loss: Loss) -> u8 {
    match loss {
        Loss::SqHinge => 0,
        Loss::Logistic => 1,
        Loss::Squared => 2,
    }
}

fn loss_from_tag(tag: u8) -> Result<Loss> {
    match tag {
        0 => Ok(Loss::SqHinge),
        1 => Ok(Loss::Logistic),
        2 => Ok(Loss::Squared),
        other => anyhow::bail!("unknown loss tag {other} in model file"),
    }
}

/// Serialize `model` to `path` (overwrites).
pub fn save(model: &TrainedModel, path: impl AsRef<Path>) -> Result<()> {
    let m = model.basis.rows();
    let d = model.basis.cols();
    anyhow::ensure!(
        model.beta.len() == m,
        "model has {} coefficients for {} basis rows",
        model.beta.len(),
        m
    );
    let mut buf = Vec::with_capacity(8 + 2 + 4 + 16 + 4 * (m * d + m));
    buf.extend_from_slice(MAGIC);
    buf.push(FORMAT_VERSION);
    buf.push(loss_tag(model.loss));
    buf.extend_from_slice(&model.gamma.to_le_bytes());
    buf.extend_from_slice(&(m as u64).to_le_bytes());
    buf.extend_from_slice(&(d as u64).to_le_bytes());
    for &v in model.basis.as_slice() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    for &v in &model.beta {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path.as_ref(), &buf)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.as_ref().display()))
}

/// Bounds-checked sequential reader over the file bytes.
struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.off + n <= self.buf.len(),
            "model file truncated at byte {} (need {} more)",
            self.off,
            n
        );
        let out = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }
}

/// Load a model previously written by [`save`].
pub fn load(path: impl AsRef<Path>) -> Result<TrainedModel> {
    let path = path.as_ref();
    let buf =
        std::fs::read(path).map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let mut r = Reader { buf: &buf, off: 0 };
    anyhow::ensure!(
        r.take(8)? == MAGIC,
        "{} is not a DKM model file (bad magic)",
        path.display()
    );
    let version = r.u8()?;
    anyhow::ensure!(
        version == FORMAT_VERSION,
        "{} has model format version {version}, this build reads version {FORMAT_VERSION}",
        path.display()
    );
    let loss = loss_from_tag(r.u8()?)?;
    let gamma = r.f32()?;
    let m = r.u64()? as usize;
    let d = r.u64()? as usize;
    // Guard against a corrupt header asking for an absurd allocation.
    let want = m
        .checked_mul(d)
        .and_then(|md| md.checked_add(m))
        .and_then(|f| f.checked_mul(4))
        .ok_or_else(|| anyhow::anyhow!("model header overflows (m={m}, d={d})"))?;
    anyhow::ensure!(
        r.off + want == buf.len(),
        "model file size mismatch: header says m={m}, d={d} ({} payload bytes) but {} remain",
        want,
        buf.len() - r.off
    );
    // The exact-size check above already bounds the payload; decode it in
    // bulk rather than one bounds-checked read per element, and split the
    // buffer in place rather than copying the halves.
    let mut basis_data: Vec<f32> = buf[r.off..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let beta = basis_data.split_off(m * d);
    Ok(TrainedModel {
        basis: Mat::from_vec(m, d, basis_data),
        beta,
        gamma,
        loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample_model(loss: Loss) -> TrainedModel {
        let mut rng = Rng::new(17);
        let m = 40;
        let d = 9;
        TrainedModel {
            basis: Mat::from_fn(m, d, |_, _| rng.normal_f32()),
            beta: (0..m).map(|_| 0.1 * rng.normal_f32()).collect(),
            gamma: 0.37,
            loss,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("dkm_model_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_is_bit_exact_for_every_loss() {
        for loss in [Loss::SqHinge, Loss::Logistic, Loss::Squared] {
            let model = sample_model(loss);
            let path = tmp(&format!("rt_{}.dkm", loss.name()));
            model.save(&path).unwrap();
            let back = TrainedModel::load(&path).unwrap();
            assert_eq!(back.loss, loss);
            assert_eq!(back.gamma.to_bits(), model.gamma.to_bits());
            assert_eq!(back.basis.rows(), model.basis.rows());
            assert_eq!(back.basis.cols(), model.basis.cols());
            for (a, b) in back.basis.as_slice().iter().zip(model.basis.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in back.beta.iter().zip(&model.beta) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn loaded_model_predicts_bit_identically() {
        let model = sample_model(Loss::SqHinge);
        let path = tmp("predict.dkm");
        model.save(&path).unwrap();
        let back = TrainedModel::load(&path).unwrap();
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(33, model.basis.cols(), |_, _| rng.normal_f32());
        let backend = crate::runtime::backend::NativeCompute::new();
        let a = model.predict(&backend, &x).unwrap();
        let b = back.predict(&backend, &x).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic_truncation_and_size_mismatch() {
        let model = sample_model(Loss::Squared);
        let path = tmp("corrupt.dkm");
        model.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();

        let truncated = tmp("truncated.dkm");
        std::fs::write(&truncated, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load(&truncated).is_err());

        let grown = tmp("grown.dkm");
        let mut extra = bytes.clone();
        extra.extend_from_slice(&[0u8; 4]);
        std::fs::write(&grown, &extra).unwrap();
        assert!(load(&grown).is_err());

        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
        for p in [path, truncated, grown] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn rejects_unknown_format_version() {
        let model = sample_model(Loss::Logistic);
        let path = tmp("version.dkm");
        model.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Byte 8 is the format version (right after the magic).
        bytes[8] = 99;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("format version 99"),
            "{err:#}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_rejects_inconsistent_model() {
        let mut model = sample_model(Loss::SqHinge);
        model.beta.pop();
        assert!(save(&model, tmp("bad.dkm")).is_err());
    }
}
