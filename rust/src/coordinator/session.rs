//! The stateful `Session` API: one handle that owns the sharded cluster,
//! the compute backend, the current basis and β, and the run's metrics —
//! built ONCE and then driven through as many solves, basis growths,
//! hyper-parameter re-solves and prediction batches as the caller wants.
//!
//! The paper's headline advantages — cheap stage-wise addition of basis
//! points (§3) and a distributed part that is simple to drive — are
//! amortization arguments: the expensive state (data shards, the C row
//! blocks, prepared operands, the worker pool) survives across solves.
//! The one-shot [`super::trainer::train`] / `train_stagewise` entry points
//! are thin wrappers over this type; block-solver systems in the same
//! space (Hsieh et al., Tu et al.) expose the same shape of handle.
//!
//! Lifecycle:
//!
//! ```text
//! Session::build(settings, &train, backend, cost)   // shard + basis + C
//!   .solve()?                                       // TRON from current β
//!   .grow_basis(m)?                                 // §3: dirty-tile C update, β zero-extended
//!   .set_lambda(λ) / .set_loss(loss) / .reset_beta()// re-solve on the SAME C
//!   .predict(&x)? / .accuracy(&test)?               // distributed, metered scoring
//!   .model()                                        // snapshot for serving (save/load)
//! ```
//!
//! Prediction is re-sharded over the SAME cluster and runs as ONE executor
//! phase per batch (the fused `predict_block` tile op per node), metered
//! under [`Step::Predict`] on both the wall [`Metrics`] and the simulated
//! [`SimClock`] — the serving path the ROADMAP's live-cluster north star
//! needs, instead of the serial coordinator loop in [`super::predict`].

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cluster::{phase_wall, Cluster, CostModel, SimClock};
use crate::config::settings::{BasisSelection, Loss, Settings};
use crate::data::{shard_rows, Dataset};
use crate::linalg::Mat;
use crate::metrics::{Metrics, Step};
use crate::runtime::tiles::TM;
use crate::runtime::Compute;
use crate::Result;

use super::basis::{self, Basis};
use super::checkpoint::{Checkpoint, CheckpointConfig};
use super::cstore::CBlockStore;
use super::dist::DistProblem;
use super::node::{pad_m_tiles, WorkerNode};
use super::predict::score_rows;
use super::solver::{self, SolveStats};
use super::trainer::{build_cluster, TrainOutput, TrainedModel};

/// FLOPs of one RBF kernel-tile computation at padded width `dpad` (the
/// 2·TB·TM·D inner-product count the micro bench uses).
fn kernel_tile_flops(dpad: usize) -> u64 {
    2 * (crate::runtime::tiles::TB * TM * dpad) as u64
}

/// Report of one [`Session::solve`] call: the solver-neutral statistics
/// of THIS solve plus a snapshot of the session's cumulative ledgers.
#[derive(Clone)]
pub struct Solve {
    pub stats: SolveStats,
    /// f/g and Hd evaluation counts of this solve (4a/4b/4c calls).
    pub fg_evals: usize,
    pub hd_evals: usize,
    /// Wall seconds this solve took (TRON only; build/grow are metered on
    /// the session's cumulative wall clock).
    pub solve_wall_secs: f64,
    /// Cumulative session wall clock (every step so far).
    pub wall: Metrics,
    /// Cumulative simulated p-node ledger.
    pub sim: SimClock,
    /// Peak C-block bytes held by any node so far (the `--c-storage` dial).
    pub peak_c_bytes: usize,
    /// Peak bytes of the streamed-row W-share cache on any node.
    pub peak_w_cache_bytes: usize,
    /// Cumulative kernel-tile recomputations across all nodes (streaming
    /// overhead; charged to the sim ledger as FLOPs).
    pub recomputed_tiles: u64,
}

/// Prediction metering, split out of the session's training ledgers so
/// scoring — which never mutates β, the basis, or the stores — can run
/// through `&self`. Read paths share the session; only this lock is
/// taken, briefly, after the compute phase. [`Session::sim`] and
/// [`Session::wall`] fold it back into the cumulative view.
struct PredictMeter {
    clock: SimClock,
    wall: Metrics,
}

/// A checkpointed mid-solve state waiting to be continued by the next
/// [`Session::solve`] call (set by [`Session::resume_from`]).
struct PendingResume {
    state: solver::SolverState,
    /// `DistProblem` eval counters at the checkpointed round boundary,
    /// restored into the resumed problem so the final counts match the
    /// uninterrupted run.
    problem_fg: u64,
    problem_hd: u64,
}

/// A live training/serving session over the simulated cluster.
pub struct Session {
    settings: Settings,
    backend: Arc<dyn Compute>,
    cluster: Cluster<WorkerNode>,
    basis: Basis,
    beta: Vec<f32>,
    wall: Metrics,
    /// Unpadded feature width of the training data.
    d: usize,
    /// Padded feature width in use (fixed at build).
    dpad: usize,
    /// Kernel γ = 1/(2σ²), fixed at build (σ shapes C, which is resident).
    gamma: f32,
    /// The loss the CURRENT β was solved under — [`Session::model`] stamps
    /// this, not the configured-for-next-solve `settings.loss`, so a
    /// snapshot taken between `set_loss` and the next solve is not
    /// mislabeled.
    solved_loss: Loss,
    fg_evals: usize,
    hd_evals: usize,
    /// Recompute tiles already charged to the ledger as FLOPs.
    charged_tiles: u64,
    /// Ledger counters already mirrored into the wall metrics.
    mirrored_barriers: u64,
    mirrored_rounds: u64,
    mirrored_dispatches: u64,
    /// Straggler observables (integer microseconds) already mirrored.
    mirrored_max_node_us: u64,
    mirrored_sum_node_us: u64,
    /// Set when a growth's C-column install failed part-way: the nodes'
    /// kernel state is inconsistent with the basis, so solve/predict/grow
    /// refuse to run rather than silently use stale C blocks.
    poisoned: bool,
    /// A loaded checkpoint the next [`Session::solve`] continues from.
    pending_resume: Option<PendingResume>,
    /// Interior-mutability ledger for `&self` predict calls (same cost
    /// model as the cluster clock; folded in by `sim`/`wall`).
    predict_meter: Mutex<PredictMeter>,
}

impl Session {
    /// Algorithm-1 steps 1–3: shard the data over `settings.nodes` workers
    /// (with the configured executor and C-storage mode), select the basis
    /// by the CONFIGURED method (`settings.basis`, resolved at
    /// `settings.m`), install W shares, and compute the C row blocks.
    /// β starts at zero; no TRON runs until [`Session::solve`].
    pub fn build(
        settings: &Settings,
        train_ds: &Dataset,
        backend: Arc<dyn Compute>,
        cost: CostModel,
    ) -> Result<Session> {
        settings.validate()?;
        let mut wall = Metrics::new();
        let dpad = backend.pad_d(train_ds.d())?;

        // Step 1: data loading / sharding.
        let mut cluster = wall.time(Step::Load, || {
            build_cluster(train_ds, settings.nodes, dpad, cost)
        });
        // Tracing must begin before the first ledger charge: `Trace::replay`
        // re-runs the records against a fresh clock, so a trace that misses
        // the build-time ingest charge could never verify.
        if settings.trace {
            cluster.start_trace();
        }
        cluster.set_executor(settings.executor.to_executor());
        cluster.set_sched(settings.sched);
        cluster.set_skew(settings.skew.clone());
        cluster.set_faults(settings.faults.clone(), settings.retry_policy());
        for node in cluster.nodes_mut() {
            node.set_c_storage(settings.c_storage, settings.c_memory_budget);
        }
        // Simulated: each node ingests its n/p shard (disk-bound in the
        // paper; we charge the measured shard-build time as compute).
        let load_wall = wall.wall_secs(Step::Load);
        cluster.charge_compute(Step::Load, load_wall / settings.nodes as f64);

        // Step 2 (+ K-means when configured): basis selection & broadcast.
        let basis_sel = wall.time(Step::BasisBcast, || {
            basis::select_for_m(&mut cluster, &backend, settings, settings.m, train_ds.d(), dpad)
        })?;

        let m = basis_sel.m();
        let col_tiles = basis_sel.col_tiles();
        let predict_meter = Mutex::new(PredictMeter {
            clock: SimClock::new(cluster.clock.cost()),
            wall: Metrics::new(),
        });
        let mut session = Session {
            gamma: settings.gamma(),
            solved_loss: settings.loss,
            settings: settings.clone(),
            backend,
            cluster,
            basis: basis_sel,
            beta: vec![0.0f32; m],
            wall,
            d: train_ds.d(),
            dpad,
            fg_evals: 0,
            hd_evals: 0,
            charged_tiles: 0,
            mirrored_barriers: 0,
            mirrored_rounds: 0,
            mirrored_dispatches: 0,
            mirrored_max_node_us: 0,
            mirrored_sum_node_us: 0,
            poisoned: false,
            pending_resume: None,
            predict_meter,
        };
        // Step 3: kernel computation (all column tiles dirty on first build).
        session.install_columns(0..col_tiles)?;
        Ok(session)
    }

    /// Continue an interrupted run from a checkpoint written by a previous
    /// process (`--checkpoint-every`): rebuild the cluster/basis/C blocks
    /// deterministically from `settings` (verifying the checkpoint's run
    /// fingerprint and basis identity field by field), then adopt the
    /// checkpointed timeline — β, the full simulated ledger, and the eval
    /// counters. The next [`Session::solve`] picks the solve up at the
    /// checkpointed round boundary and finishes BITWISE identical to an
    /// uninterrupted run: same β, same curve, same ledger counters.
    ///
    /// `--exec`, `--sched` and `--skew` may differ from the original run
    /// (they are not in the fingerprint); under streaming C storage the
    /// rebuild's recompute-FLOPs line can differ, everything else still
    /// matches.
    pub fn resume_from(
        settings: &Settings,
        train_ds: &Dataset,
        backend: Arc<dyn Compute>,
        cost: CostModel,
        path: impl AsRef<Path>,
    ) -> Result<Session> {
        anyhow::ensure!(
            !settings.trace,
            "--trace cannot be combined with --resume: a trace must start at \
             clock zero, but a resumed ledger embeds the original run's \
             timeline, so the recorded events could never replay to it"
        );
        let ck = Checkpoint::load(path)?;
        let mut session = Session::build(settings, train_ds, backend, cost)?;
        let live = CheckpointConfig::of(&session.settings, session.d, session.gamma);
        ck.config.ensure_matches(&live)?;
        let basis_fp = crate::trace::fingerprint_f32s(session.basis.z.as_slice());
        anyhow::ensure!(
            ck.basis_fp == basis_fp,
            "checkpoint basis fingerprint {:016x} does not match the rebuilt basis \
             {basis_fp:016x} — was the training data changed?",
            ck.basis_fp
        );
        // Adopt the checkpointed timeline wholesale: the restored ledger
        // already carries the build phases' cost from the original run, so
        // the rebuild's own charges are discarded with the old clock.
        session.cluster.clock = SimClock::from_snapshot(&ck.clock);
        session.beta = ck.state.beta().to_vec();
        session.fg_evals = ck.session_fg as usize;
        session.hd_evals = ck.session_hd as usize;
        // Re-baseline the wall-metrics mirror on the restored counters so
        // the next sync charges only post-resume deltas (the build-phase
        // bumps above came from a different timeline).
        session.mirrored_barriers = session.cluster.clock.barriers();
        session.mirrored_rounds = session.cluster.clock.comm_rounds();
        session.mirrored_dispatches = session.cluster.clock.dispatches();
        session.mirrored_max_node_us =
            (session.cluster.clock.max_node_secs() * 1e6) as u64;
        session.mirrored_sum_node_us =
            (session.cluster.clock.sum_node_secs() * 1e6) as u64;
        // The rebuild's tile counters restart from zero — baseline on what
        // the fresh stores report, not the checkpointed total.
        let (_, _, tiles) = session.storage_stats();
        session.charged_tiles = tiles;
        session.pending_resume = Some(PendingResume {
            state: ck.state,
            problem_fg: ck.problem_fg,
            problem_hd: ck.problem_hd,
        });
        Ok(session)
    }

    /// Step 3 worker: (re)install W shares and the C-block columns in
    /// `dirty` on every node, then refresh the prepared hot-path operands.
    /// Wall-timed under [`Step::Kernel`], exactly like the one-shot path.
    fn install_columns(&mut self, dirty: std::ops::Range<usize>) -> Result<()> {
        let t0 = Instant::now();
        basis::install_w_shares(&mut self.cluster, &self.backend, &self.basis, self.gamma, self.dpad)?;
        let m = self.basis.m();
        let gamma = self.gamma;
        // Prepare the basis tiles once; all nodes (and the streaming
        // stores, for the life of the session) share the same operands.
        let z_prep = Arc::new(
            self.basis
                .z_tiles
                .iter()
                .map(|t| self.backend.prepare(t, &[TM, self.dpad]))
                .collect::<Result<Vec<_>>>()?,
        );
        let backend2 = Arc::clone(&self.backend);
        self.cluster.try_par_compute(Step::Kernel, |_, node| {
            node.compute_c_block_p(backend2.as_ref(), &z_prep, m, gamma, dirty.clone())?;
            node.prepare_hot(backend2.as_ref())
        })?;
        self.wall.add_wall(Step::Kernel, t0.elapsed());
        // Keep the wall counters in lockstep with the ledger even before
        // the first solve (build/grow phases bump barriers too).
        self.sync_counters();
        Ok(())
    }

    /// Step 4: run the CONFIGURED solver (`--solver tron|bcd[:block]`)
    /// from the CURRENT β (zero after build; the previous solution after a
    /// solve; zero-extended after growth — the paper's warm starts).
    /// Returns this solve's [`Solve`] report.
    pub fn solve(&mut self) -> Result<Solve> {
        self.check_healthy()?;
        let t0 = Instant::now();
        let m = self.basis.m();
        debug_assert_eq!(self.beta.len(), m);
        let lambda = self.settings.lambda;
        let loss = self.settings.loss;
        let mut solver = solver::make_solver(&self.settings);
        // Checkpoint context, captured BEFORE the cluster borrow below so
        // the round hook only touches locals + the problem it is handed.
        let ck_every = self.settings.checkpoint_every as u64;
        let ck_path = self.settings.checkpoint_path.clone();
        let ck_config = CheckpointConfig::of(&self.settings, self.d, self.gamma);
        let basis_fp = crate::trace::fingerprint_f32s(self.basis.z.as_slice());
        let (session_fg, session_hd) = (self.fg_evals as u64, self.hd_evals as u64);
        let resume = self.pending_resume.take();
        let (beta, stats, fg, hd) = {
            let mut problem = DistProblem::new(
                &mut self.cluster,
                Arc::clone(&self.backend),
                m,
                lambda,
                loss,
            )
            .with_pipeline(self.settings.eval_pipeline);
            let start = match resume.as_ref() {
                Some(r) => {
                    problem.fg_evals = r.problem_fg as usize;
                    problem.hd_evals = r.problem_hd as usize;
                    solver::Start::Resume(&r.state)
                }
                None => solver::Start::Cold(&self.beta),
            };
            // Cadence keys off the solver's ABSOLUTE round count, so a
            // resumed run checkpoints at the same round numbers the
            // uninterrupted run would have.
            let mut hook = |problem: &DistProblem<'_>,
                            state: &solver::SolverState|
             -> Result<()> {
                if state.rounds_done() % ck_every != 0 {
                    return Ok(());
                }
                Checkpoint {
                    config: ck_config.clone(),
                    basis_fp,
                    clock: problem.cluster.clock.snapshot(),
                    problem_fg: problem.fg_evals as u64,
                    problem_hd: problem.hd_evals as u64,
                    session_fg,
                    session_hd,
                    state: state.clone(),
                }
                .save(&ck_path)
            };
            let on_round: Option<solver::RoundHook<'_>> =
                if ck_every > 0 { Some(&mut hook) } else { None };
            let (beta, stats) = solver.solve_hooked(&mut problem, start, on_round)?;
            (beta, stats, problem.fg_evals, problem.hd_evals)
        };
        self.beta = beta;
        self.solved_loss = loss;
        self.fg_evals += fg;
        self.hd_evals += hd;
        let solve_wall = t0.elapsed();
        self.wall.add_wall(Step::Tron, solve_wall);

        // Honest storage accounting: charge the kernel-tile recompute this
        // solve added (cumulative counters, so charge the delta once).
        let (peak_c, peak_w, tiles) = self.storage_stats();
        let fresh = tiles - self.charged_tiles;
        self.cluster
            .charge_recompute_flops(fresh * kernel_tile_flops(self.dpad));
        self.charged_tiles = tiles;
        self.sync_counters();

        Ok(Solve {
            stats,
            fg_evals: fg,
            hd_evals: hd,
            solve_wall_secs: solve_wall.as_secs_f64(),
            wall: self.wall(),
            sim: self.sim(),
            peak_c_bytes: peak_c,
            peak_w_cache_bytes: peak_w,
            recomputed_tiles: tiles,
        })
    }

    /// Stage-wise basis growth (§3): append fresh random training rows up
    /// to `m` total, recompute ONLY the dirty C column tiles, and
    /// zero-extend β so the next [`Session::solve`] warm-starts from the
    /// current solution. Requires a training-row basis — k-means centers
    /// are not training rows and cannot be grown (clear error instead of
    /// the silent fallback the old stage-wise path had).
    pub fn grow_basis(&mut self, m: usize) -> Result<()> {
        self.check_healthy()?;
        let old = self.basis.m();
        anyhow::ensure!(
            m > old,
            "grow_basis: target m={m} must exceed the current m={old}"
        );
        anyhow::ensure!(
            self.basis.train_rows.is_some(),
            "basis growth requires a training-row basis (--basis random): the current \
             basis was selected by k-means, whose centers are not training rows"
        );
        let t0 = Instant::now();
        basis::grow_random(
            &mut self.cluster,
            &mut self.basis,
            m - old,
            self.d,
            self.dpad,
            self.settings.seed ^ m as u64,
        )?;
        self.wall.add_wall(Step::BasisBcast, t0.elapsed());
        // Warm start: zero-extend β for the new points. Done BEFORE the
        // column install so β.len() == basis.m() holds even if a backend
        // error aborts the install below.
        self.beta.resize(m, 0.0);
        // Dirty tiles: the one containing `old` (possibly partial) onward.
        let dirty = (old / TM)..self.basis.col_tiles();
        if let Err(e) = self.install_columns(dirty) {
            // Some nodes may have rebuilt their stores for the grown basis
            // and others not — poison the session so solve/predict cannot
            // run against inconsistent kernel state.
            self.poisoned = true;
            return Err(e);
        }
        Ok(())
    }

    /// Change λ for subsequent solves. C and W are unchanged, so the next
    /// [`Session::solve`] is a warm re-solve on the already-materialized
    /// kernel state — the amortization a λ sweep wants.
    pub fn set_lambda(&mut self, lambda: f32) -> Result<()> {
        anyhow::ensure!(lambda > 0.0, "lambda must be > 0");
        self.settings.lambda = lambda;
        Ok(())
    }

    /// Change the loss for subsequent solves (same C, same W).
    pub fn set_loss(&mut self, loss: Loss) {
        self.settings.loss = loss;
    }

    /// Reset β to zero: the next solve is a COLD solve on the live cluster
    /// (bit-identical to a fresh `train()` at the current settings, since
    /// basis selection does not depend on λ or the loss).
    pub fn reset_beta(&mut self) {
        for b in &mut self.beta {
            *b = 0.0;
        }
    }

    /// Snapshot the current model (basis, β, γ, and the loss the current β
    /// was SOLVED under — not a loss configured after the last solve) —
    /// e.g. to [`TrainedModel::save`] for a serving process.
    pub fn model(&self) -> TrainedModel {
        TrainedModel {
            basis: self.basis.z.clone(),
            beta: self.beta.clone(),
            gamma: self.gamma,
            loss: self.solved_loss,
        }
    }

    /// Distributed batch scoring: the batch is re-sharded over the SAME
    /// cluster and scored in ONE executor phase (each node runs the fused
    /// `predict_block` tile op over its shard), metered under
    /// [`Step::Predict`] on both the wall clock and the simulated ledger
    /// (β broadcast down the tree, one compute barrier, score gather up).
    /// Bit-identical to the serial [`super::predict::predict`] loop: each
    /// row's score depends only on its own features, accumulated over the
    /// basis tiles in the same order.
    ///
    /// Takes `&self`: scoring never mutates β or the stores, so concurrent
    /// read paths (serving threads, an accuracy sweep racing a report) can
    /// share the session. The metering lands on an interior-mutability
    /// side ledger, locked only AFTER the compute phase returns.
    pub fn predict(&self, x: &Mat) -> Result<Vec<f32>> {
        self.check_healthy()?;
        // Narrower batches are fine — trailing absent (sparse) features are
        // zeros, exactly how the serial scoring path pads them. Wider
        // batches are unrepresentable against this basis.
        anyhow::ensure!(
            x.cols() <= self.d,
            "predict: batch has {} features but the session was trained on {}",
            x.cols(),
            self.d
        );
        let t0 = Instant::now();
        let p = self.cluster.p();
        let shards = shard_rows(x.rows(), p);
        // Shards are contiguous row ranges: one panel copy per node (the
        // in-process stand-in for shipping the shard), no per-row index
        // gather — and no copy at all on a single-node cluster, where the
        // lone "shard" is the batch itself.
        let per_node: Vec<Mat> = if p == 1 {
            Vec::new()
        } else {
            shards
                .iter()
                .map(|r| {
                    Mat::from_vec(r.len(), x.cols(), x.row_panel(r.start, r.end).to_vec())
                })
                .collect()
        };
        let beta_tiles = pad_m_tiles(&self.beta, self.basis.col_tiles());
        let backend = Arc::clone(&self.backend);
        let z_tiles = &self.basis.z_tiles;
        let gamma = self.gamma;
        let dpad = self.dpad;
        // One read-only executor phase over p unit scratch slots (node
        // state is untouched — exactly why this can be `&self`).
        let mut scratch = vec![(); p];
        let (parts, node_secs) = self.cluster.executor().run(&mut scratch, &|j, _: &mut ()| {
            let shard = if p == 1 { x } else { &per_node[j] };
            score_rows(backend.as_ref(), shard, z_tiles, &beta_tiles, gamma, dpad)
        });
        // β ships down the tree (the basis is already resident on every
        // node from training); scores gather back up. Same pricing and
        // error window as the old `&mut` path: on a node failure the
        // broadcast, compute and barrier are already on the ledger but the
        // gather (which never happens) and the wall step are not.
        let tree = self.cluster.tree();
        let mut meter = self.predict_meter.lock().unwrap();
        meter
            .clock
            .meter_broadcast(Step::Predict, tree, self.basis.m() * std::mem::size_of::<f32>());
        let (wall_secs, max_node, sum_node) =
            phase_wall(self.cluster.sched(), self.cluster.skew(), &node_secs);
        meter.clock.add_compute(Step::Predict, wall_secs);
        meter.clock.add_straggler(max_node, sum_node);
        meter.clock.add_barrier();
        meter.wall.bump("barriers", 1);
        meter.wall.bump("max_node_us", (max_node * 1e6) as u64);
        meter.wall.bump("sum_node_us", (sum_node * 1e6) as u64);
        let mut out = Vec::with_capacity(x.rows());
        for (j, part) in parts.into_iter().enumerate() {
            match part {
                Ok(scores) => out.extend_from_slice(&scores),
                Err(e) => return Err(e.context(format!("node {j} failed during predict"))),
            }
        }
        let max_shard = shards.iter().map(|r| r.len()).max().unwrap_or(0);
        meter
            .clock
            .meter_gather(Step::Predict, tree, max_shard * std::mem::size_of::<f32>());
        meter.wall.add_wall(Step::Predict, t0.elapsed());
        Ok(out)
    }

    /// Test accuracy through the distributed, metered predict path.
    pub fn accuracy(&self, test: &Dataset) -> Result<f64> {
        let scores = self.predict(&test.x)?;
        Ok(crate::metrics::accuracy(&scores, &test.y))
    }

    // ---- phase tracing ----

    /// Start recording a phase trace on the underlying cluster (see
    /// [`crate::trace`]). Any trace already in flight is discarded.
    pub fn start_trace(&mut self) {
        self.cluster.start_trace();
    }

    pub fn tracing(&self) -> bool {
        self.cluster.tracing()
    }

    /// Finish the in-flight trace (None if tracing was off). The trace's
    /// expected ledger is the cluster clock at this moment — `&self`
    /// predict metering lives on a side ledger and is not part of it.
    pub fn take_trace(&mut self) -> Option<crate::trace::Trace> {
        self.cluster.take_trace()
    }

    // ---- introspection ----

    /// Cumulative wall clock (Load/BasisBcast/Kernel/Tron/Predict),
    /// including `&self` predict calls (folded from the side ledger).
    pub fn wall(&self) -> Metrics {
        let mut w = self.wall.clone();
        w.merge(&self.predict_meter.lock().unwrap().wall);
        w
    }

    /// Cumulative simulated p-node ledger, including `&self` predict
    /// calls (folded from the side ledger).
    pub fn sim(&self) -> SimClock {
        let mut s = self.cluster.clock.clone();
        s.merge(&self.predict_meter.lock().unwrap().clock);
        s
    }

    pub fn beta(&self) -> &[f32] {
        &self.beta
    }

    /// Current basis size m.
    pub fn m(&self) -> usize {
        self.basis.m()
    }

    /// Cluster size p.
    pub fn p(&self) -> usize {
        self.cluster.p()
    }

    pub fn lambda(&self) -> f32 {
        self.settings.lambda
    }

    /// The loss configured for the NEXT solve (snapshots via
    /// [`Session::model`] carry the loss the current β was solved under).
    pub fn loss(&self) -> Loss {
        self.settings.loss
    }

    /// Cumulative f/g and Hd evaluation counts across all solves.
    pub fn evals(&self) -> (usize, usize) {
        (self.fg_evals, self.hd_evals)
    }

    /// Peak per-node storage: (C-block bytes, W-row-cache bytes).
    pub fn peak_bytes(&self) -> (usize, usize) {
        let (c, w, _) = self.storage_stats();
        (c, w)
    }

    /// Refuse to operate on a session whose last growth failed part-way
    /// (inconsistent per-node kernel state).
    fn check_healthy(&self) -> Result<()> {
        anyhow::ensure!(
            !self.poisoned,
            "session is poisoned: a basis growth failed while rebuilding the C blocks, \
             leaving per-node kernel state inconsistent — build a fresh session"
        );
        Ok(())
    }

    fn storage_stats(&self) -> (usize, usize, u64) {
        let mut tiles = 0u64;
        let mut peak_c = 0usize;
        let mut peak_w = 0usize;
        for j in 0..self.cluster.p() {
            let store = &self.cluster.node(j).cstore;
            tiles += store.recomputed_tiles();
            peak_c = peak_c.max(store.peak_c_bytes());
            peak_w = peak_w.max(store.w_cache_bytes());
        }
        (peak_c, peak_w, tiles)
    }

    /// Mirror the ledger's synchronization counters into the wall metrics
    /// (delta since the last mirror) so both reports show rounds next to
    /// seconds.
    fn sync_counters(&mut self) {
        let b = self.cluster.clock.barriers();
        let r = self.cluster.clock.comm_rounds();
        let d = self.cluster.clock.dispatches();
        self.wall.bump("barriers", b - self.mirrored_barriers);
        self.wall.bump("comm_rounds", r - self.mirrored_rounds);
        self.wall.bump("dispatches", d - self.mirrored_dispatches);
        self.mirrored_barriers = b;
        self.mirrored_rounds = r;
        self.mirrored_dispatches = d;
        // Straggler observables ride the same mirror, quantized to µs so
        // they fit the integer counter map (monotone, so deltas are safe).
        let mx = (self.cluster.clock.max_node_secs() * 1e6) as u64;
        let sm = (self.cluster.clock.sum_node_secs() * 1e6) as u64;
        self.wall.bump("max_node_us", mx - self.mirrored_max_node_us);
        self.wall.bump("sum_node_us", sm - self.mirrored_sum_node_us);
        self.mirrored_max_node_us = mx;
        self.mirrored_sum_node_us = sm;
    }

    /// Consume the session into the one-shot [`TrainOutput`] shape (the
    /// `train()` wrapper's return).
    pub(crate) fn into_output(self, solve: Solve) -> TrainOutput {
        let meter = self.predict_meter.into_inner().unwrap();
        let mut wall = self.wall;
        wall.merge(&meter.wall);
        let mut sim = self.cluster.clock.clone();
        sim.merge(&meter.clock);
        TrainOutput {
            model: TrainedModel {
                basis: self.basis.z,
                beta: self.beta,
                gamma: self.gamma,
                loss: self.solved_loss,
            },
            stats: solve.stats,
            wall,
            sim,
            fg_evals: solve.fg_evals,
            hd_evals: solve.hd_evals,
            peak_c_bytes: solve.peak_c_bytes,
            peak_w_cache_bytes: solve.peak_w_cache_bytes,
            recomputed_tiles: solve.recomputed_tiles,
        }
    }
}

/// Resolve settings for a stage-wise run: the first stage's size becomes
/// `m` (so the basis policy is evaluated at the size it will actually
/// select), the configured basis method is honored for the initial stage,
/// and combinations growth cannot support are rejected up front — k-means
/// centers are not training rows, so a multi-stage run cannot use
/// `--basis kmeans` (clear error), while the adaptive `auto` policy
/// resolves to the growth-capable random selection.
pub fn growth_settings(settings: &Settings, stages: &[usize]) -> Result<Settings> {
    anyhow::ensure!(!stages.is_empty(), "need at least one stage");
    anyhow::ensure!(
        stages.windows(2).all(|w| w[1] > w[0]),
        "stages must be strictly increasing"
    );
    let mut s = settings.clone();
    s.m = stages[0];
    if stages.len() > 1 {
        match s.basis {
            BasisSelection::Random => {}
            BasisSelection::Auto => s.basis = BasisSelection::Random,
            BasisSelection::KMeans => anyhow::bail!(
                "stage-wise growth cannot use --basis kmeans: cluster centers are not \
                 training rows, and growth appends training rows to the basis \
                 (use --basis random or auto for staged runs, or a single stage)"
            ),
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::{Backend, CStorage, EvalPipeline, ExecutorChoice, SolverChoice};
    use crate::data::synth;
    use crate::runtime::make_backend;

    fn tiny_settings(m: usize, nodes: usize) -> Settings {
        Settings {
            dataset: "covtype_like".into(),
            m,
            nodes,
            lambda: 0.01,
            sigma: 2.0,
            loss: Loss::SqHinge,
            basis: BasisSelection::Random,
            backend: Backend::Native,
            executor: ExecutorChoice::Serial,
            c_storage: CStorage::Materialized,
            eval_pipeline: EvalPipeline::Fused,
            max_iters: 40,
            kmeans_iters: 2,
            kmeans_max_m: 512,
            ..Settings::default()
        }
    }

    fn tiny_data() -> (Dataset, Dataset) {
        let mut spec = synth::spec("covtype_like");
        spec.n_train = 900;
        spec.n_test = 300;
        synth::generate(&spec, 5)
    }

    #[test]
    fn build_solve_predict_works_and_meters_predict() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut sess =
            Session::build(&tiny_settings(64, 3), &train_ds, backend, CostModel::free())
                .unwrap();
        assert_eq!(sess.m(), 64);
        assert_eq!(sess.beta().len(), 64);
        let solve = sess.solve().unwrap();
        assert_eq!(solve.stats.solver, "tron");
        assert!(solve.stats.final_f < solve.stats.f0());
        let barriers_before = sess.sim().barriers();
        let acc = sess.accuracy(&test_ds).unwrap();
        assert!(acc > 0.5, "accuracy {acc}");
        // One metered executor phase per predict batch.
        assert_eq!(sess.sim().barriers(), barriers_before + 1);
        assert!(sess.wall().wall_secs(Step::Predict) > 0.0);
        assert!(sess.sim().step_secs(Step::Predict) > 0.0);
        // Mirrored counters agree with the ledger.
        assert_eq!(sess.wall().barriers(), sess.sim().barriers());
        assert_eq!(sess.wall().comm_rounds(), sess.sim().comm_rounds());
        // Straggler observables mirror too (µs quantization tolerance).
        assert!(sess.sim().max_node_secs() > 0.0);
        assert!(sess.sim().sum_node_secs() >= sess.sim().max_node_secs());
        assert!(
            (sess.wall().max_node_secs() - sess.sim().max_node_secs()).abs() < 1e-4,
            "wall mirror {} vs ledger {}",
            sess.wall().max_node_secs(),
            sess.sim().max_node_secs()
        );
        assert!((sess.wall().sum_node_secs() - sess.sim().sum_node_secs()).abs() < 1e-4);
    }

    #[test]
    fn grow_requires_more_columns_and_training_rows() {
        let (train_ds, _) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut sess = Session::build(
            &tiny_settings(64, 3),
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        assert!(sess.grow_basis(64).is_err(), "must grow strictly");
        sess.grow_basis(96).unwrap();
        assert_eq!(sess.m(), 96);
        assert_eq!(sess.beta().len(), 96);

        let mut s = tiny_settings(24, 3);
        s.basis = BasisSelection::KMeans;
        let mut km =
            Session::build(&s, &train_ds, backend, CostModel::free()).unwrap();
        let err = km.grow_basis(48).unwrap_err();
        assert!(format!("{err:#}").contains("k-means"), "{err:#}");
    }

    #[test]
    fn growth_settings_policy() {
        let mut s = tiny_settings(400, 2);
        let g = growth_settings(&s, &[32, 64]).unwrap();
        assert_eq!(g.m, 32);
        assert_eq!(g.basis, BasisSelection::Random);
        s.basis = BasisSelection::Auto;
        assert_eq!(
            growth_settings(&s, &[32, 64]).unwrap().basis,
            BasisSelection::Random
        );
        // Single-stage kmeans is honored.
        s.basis = BasisSelection::KMeans;
        assert_eq!(
            growth_settings(&s, &[32]).unwrap().basis,
            BasisSelection::KMeans
        );
        let err = growth_settings(&s, &[32, 64]).unwrap_err();
        assert!(format!("{err:#}").contains("kmeans"), "{err:#}");
        assert!(growth_settings(&s, &[]).is_err());
        assert!(growth_settings(&s, &[64, 32]).is_err());
    }

    fn sim_cost() -> CostModel {
        CostModel {
            latency_s: 1e-4,
            per_byte_s: 1e-9,
        }
    }

    #[test]
    fn checkpoint_resume_matches_the_uninterrupted_run() {
        let (train_ds, _) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let dir = std::env::temp_dir().join("dkm_session_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        for solver in [SolverChoice::Tron, SolverChoice::Bcd { block: 32 }] {
            let mut s = tiny_settings(64, 3);
            s.solver = solver;
            let mut full =
                Session::build(&s, &train_ds, Arc::clone(&backend), sim_cost()).unwrap();
            let full_solve = full.solve().unwrap();

            // Same run, but leaving a checkpoint after every round.
            let path = dir.join(format!("{}.ckpt", full_solve.stats.solver));
            let mut ck_settings = s.clone();
            ck_settings.checkpoint_every = 1;
            ck_settings.checkpoint_path = path.display().to_string();
            let mut first =
                Session::build(&ck_settings, &train_ds, Arc::clone(&backend), sim_cost())
                    .unwrap();
            first.solve().unwrap();
            assert!(path.exists(), "no checkpoint written for {solver:?}");

            // Resume from the last checkpoint as if `first` had died right
            // after writing it.
            let mut resumed =
                Session::resume_from(&s, &train_ds, Arc::clone(&backend), sim_cost(), &path)
                    .unwrap();
            let reslv = resumed.solve().unwrap();

            // β, objective and every count match the uninterrupted run
            // bitwise. (Simulated COMPUTE seconds fold in measured node
            // times, so only the deterministic counters are compared.)
            assert_eq!(full.beta().len(), resumed.beta().len());
            for (a, b) in full.beta().iter().zip(resumed.beta()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{solver:?} β diverged");
            }
            assert_eq!(full_solve.stats.final_f.to_bits(), reslv.stats.final_f.to_bits());
            assert_eq!(
                full_solve.stats.final_gnorm.to_bits(),
                reslv.stats.final_gnorm.to_bits()
            );
            assert_eq!(full_solve.stats.iterations, reslv.stats.iterations);
            assert_eq!(full_solve.stats.converged, reslv.stats.converged);
            assert_eq!(full_solve.fg_evals, reslv.fg_evals);
            assert_eq!(full_solve.hd_evals, reslv.hd_evals);
            assert_eq!(full.evals(), resumed.evals());
            assert_eq!(full_solve.stats.curve.len(), reslv.stats.curve.len());
            for (a, b) in full_solve.stats.curve.iter().zip(&reslv.stats.curve) {
                assert_eq!(a.f.to_bits(), b.f.to_bits());
                assert_eq!(a.gnorm.to_bits(), b.gnorm.to_bits());
                assert_eq!(a.comm_rounds, b.comm_rounds);
            }
            let (a, b) = (full.sim().snapshot(), resumed.sim().snapshot());
            assert_eq!(a.barriers, b.barriers);
            assert_eq!(a.reduce_round_trips, b.reduce_round_trips);
            assert_eq!(a.dispatches, b.dispatches);
            assert_eq!(a.comm_instances, b.comm_instances);
            assert_eq!(a.comm_bytes, b.comm_bytes);
            assert_eq!(a.faults, b.faults);
            assert_eq!(a.retries, b.retries);
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn resume_rejects_a_mismatched_run() {
        let (train_ds, _) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let dir = std::env::temp_dir().join("dkm_session_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mismatch.ckpt");
        let mut s = tiny_settings(64, 2);
        s.checkpoint_every = 1;
        s.checkpoint_path = path.display().to_string();
        let mut sess =
            Session::build(&s, &train_ds, Arc::clone(&backend), sim_cost()).unwrap();
        sess.solve().unwrap();
        assert!(path.exists());

        let mut wrong = s.clone();
        wrong.lambda = 0.5;
        let err =
            Session::resume_from(&wrong, &train_ds, Arc::clone(&backend), sim_cost(), &path)
                .unwrap_err();
        assert!(format!("{err:#}").contains("--lambda"), "{err:#}");

        let mut wrong = s.clone();
        wrong.solver = SolverChoice::Bcd { block: 16 };
        let err = Session::resume_from(&wrong, &train_ds, backend, sim_cost(), &path)
            .unwrap_err();
        assert!(format!("{err:#}").contains("--solver"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lambda_and_loss_updates_apply_to_next_solve() {
        let (train_ds, test_ds) = tiny_data();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut sess =
            Session::build(&tiny_settings(64, 2), &train_ds, backend, CostModel::free())
                .unwrap();
        sess.solve().unwrap();
        assert!(sess.set_lambda(0.0).is_err());
        sess.set_lambda(0.001).unwrap();
        assert_eq!(sess.lambda(), 0.001);
        sess.set_loss(Loss::Logistic);
        let warm = sess.solve().unwrap();
        assert!(warm.stats.final_f.is_finite());
        let acc = sess.accuracy(&test_ds).unwrap();
        assert!(acc > 0.5, "post-update accuracy {acc}");
    }
}
