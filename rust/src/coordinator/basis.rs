//! Basis selection (paper §3.2) + the shared `Basis` bundle the trainer
//! threads through kernel computation, W-share setup and prediction.
//!
//! * **Random**: each node samples its share of the m basis points from its
//!   local rows (Algorithm 1 step 2); basis ⊂ training set, so W's row
//!   block is a subset of C's rows — no extra kernel work.
//! * **K-means**: cluster centers from [`crate::kmeans`]; better accuracy
//!   at small m, but centers are NOT training rows, so W must be computed
//!   explicitly (its row blocks are distributed round-robin).
//! * **Auto**: the paper's policy — K-means while m ≤ threshold, random
//!   beyond ("we use a distributed K-means algorithm when m is not too
//!   large, and switch to random selection otherwise").

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::config::settings::{BasisSelection, Settings};
use crate::linalg::Mat;
use crate::metrics::Step;
use crate::rng::Rng;
use crate::runtime::tiles::{TiledMatrix, TB, TM};
use crate::runtime::Compute;
use crate::Result;

use super::node::{pad_feature_tiles, WorkerNode, WShare};

/// The selected basis, padded and ready for kernel tile calls.
#[derive(Clone)]
pub struct Basis {
    /// m × d basis points (unpadded).
    pub z: Mat,
    /// TM × dpad padded tiles of z.
    pub z_tiles: Vec<Vec<f32>>,
    /// Per-node (local_row, global_k) pairs when basis ⊂ training rows.
    pub train_rows: Option<Vec<Vec<(usize, usize)>>>,
}

impl Basis {
    pub fn m(&self) -> usize {
        self.z.rows()
    }

    pub fn col_tiles(&self) -> usize {
        self.m().div_ceil(TM).max(1)
    }
}

/// Build basis tiles from an m × d matrix.
pub fn tiles_of(z: &Mat, dpad: usize) -> Vec<Vec<f32>> {
    // Reuse the feature-tile padding but at TM granularity == TB (same
    // constant here; assert to catch future divergence).
    assert_eq!(TB, TM, "basis tiling assumes TB == TM");
    pad_feature_tiles(z, dpad)
}

/// Random selection (Algorithm 1 step 2): each node contributes a share of
/// m proportional to its shard, sampled without replacement.
pub fn select_random(
    cluster: &mut Cluster<WorkerNode>,
    m: usize,
    d: usize,
    dpad: usize,
    seed: u64,
) -> Result<Basis> {
    let p = cluster.p();
    let sizes: Vec<usize> = (0..p).map(|j| cluster.node(j).n_local()).collect();
    let total: usize = sizes.iter().sum();
    if m > total {
        anyhow::bail!("m={m} exceeds training size n={total}");
    }
    let mut rng = Rng::new(seed ^ 0xBA515);
    let mut shares: Vec<usize> = sizes.iter().map(|&s| m * s / total).collect();
    let mut assigned: usize = shares.iter().sum();
    let mut j = 0;
    while assigned < m {
        if shares[j % p] < sizes[j % p] {
            shares[j % p] += 1;
            assigned += 1;
        }
        j += 1;
    }

    let mut z = Mat::zeros(m, d);
    let mut train_rows: Vec<Vec<(usize, usize)>> = vec![Vec::new(); p];
    let mut k = 0;
    for (node_id, &share) in shares.iter().enumerate() {
        let mut rng_j = rng.fork(node_id as u64);
        let locals = rng_j.sample_indices(sizes[node_id], share);
        for local in locals {
            z.row_mut(k).copy_from_slice(cluster.node(node_id).x.row(local));
            train_rows[node_id].push((local, k));
            k += 1;
        }
    }
    debug_assert_eq!(k, m);

    // Step 2 communication: the basis points are broadcast to all nodes
    // (m·d floats through the tree) — the O(m²/p)-class cost of §3.1.
    cluster.broadcast_meter(Step::BasisBcast, m * d * 4);

    Ok(Basis {
        z_tiles: tiles_of(&z, dpad),
        z,
        train_rows: Some(train_rows),
    })
}

/// K-means selection: centers from the distributed clustering substrate.
pub fn select_kmeans(
    cluster: &mut Cluster<WorkerNode>,
    backend: &Arc<dyn Compute>,
    m: usize,
    iters: usize,
    d: usize,
    dpad: usize,
    seed: u64,
) -> Result<Basis> {
    let res = crate::kmeans::distributed_kmeans(cluster, backend, m, iters, d, dpad, seed)?;
    cluster.broadcast_meter(Step::BasisBcast, m * d * 4);
    Ok(Basis {
        z_tiles: tiles_of(&res.centroids, dpad),
        z: res.centroids,
        train_rows: None,
    })
}

/// Resolve the configured selection policy at an explicit basis size
/// (`Auto` is the paper's size-adaptive rule, so it needs the m it will
/// actually select — stage-wise callers pass the stage size).
pub fn method_for(settings: &Settings, m: usize) -> BasisSelection {
    match settings.basis {
        BasisSelection::Auto => {
            if m <= settings.kmeans_max_m {
                BasisSelection::KMeans
            } else {
                BasisSelection::Random
            }
        }
        other => other,
    }
}

/// Select an m-point basis by the CONFIGURED method (`settings.basis`,
/// resolved at this m). This is the single selection entry point:
/// `Session::build` passes `settings.m` (the stage-wise path sets that to
/// the first stage's size via `growth_settings`).
pub fn select_for_m(
    cluster: &mut Cluster<WorkerNode>,
    backend: &Arc<dyn Compute>,
    settings: &Settings,
    m: usize,
    d: usize,
    dpad: usize,
) -> Result<Basis> {
    match method_for(settings, m) {
        BasisSelection::KMeans => select_kmeans(
            cluster,
            backend,
            m,
            settings.kmeans_iters,
            d,
            dpad,
            settings.seed,
        ),
        _ => select_random(cluster, m, d, dpad, settings.seed),
    }
}

/// Install each node's W share for the chosen basis.
///
/// Random basis: W rows come from C rows (FromC). K-means basis: W row
/// blocks are computed explicitly, round-robin over nodes, with the same
/// kernel tile module (the extra cost the paper attributes to K-means
/// basis: "since the basis points do not form a subset of the training
/// points, W needs to be computed").
pub fn install_w_shares(
    cluster: &mut Cluster<WorkerNode>,
    backend: &Arc<dyn Compute>,
    basis: &Basis,
    gamma: f32,
    dpad: usize,
) -> Result<()> {
    let p = cluster.p();
    match &basis.train_rows {
        Some(rows_per_node) => {
            for j in 0..p {
                cluster.node_mut(j).w_share = WShare::FromC(rows_per_node[j].clone());
            }
            Ok(())
        }
        None => {
            let m = basis.m();
            let shards = crate::data::shard_rows(m, p);
            // Build each node's explicit W row block via kernel tiles.
            let z_tiles = basis.z_tiles.clone();
            let z = basis.z.clone();
            let backend2 = Arc::clone(backend);
            cluster.try_par_compute(Step::Kernel, |j, node| {
                let range = shards[j].clone();
                let rows = range.len();
                let k0 = range.start;
                let mut block = TiledMatrix::zeros(rows.max(1), m);
                if rows > 0 {
                    let idx: Vec<usize> = range.collect();
                    let sub = z.gather_rows(&idx);
                    let sub_tiles = pad_feature_tiles(&sub, dpad);
                    for (i, x_tile) in sub_tiles.iter().enumerate() {
                        for (jj, z_tile) in z_tiles.iter().enumerate() {
                            let tile = backend2.kernel_block(x_tile, z_tile, dpad, gamma)?;
                            block.tile_mut(i, jj).copy_from_slice(&tile);
                        }
                    }
                }
                node.w_share = if rows > 0 {
                    WShare::Explicit { k0, block }
                } else {
                    WShare::FromC(Vec::new())
                };
                Ok(())
            })?;
            Ok(())
        }
    }
}

/// Stage-wise basis growth (paper §3): append `extra` fresh random training
/// rows to the basis, avoiding rows already in it. Returns the global
/// indices of the new points per node.
pub fn grow_random(
    cluster: &mut Cluster<WorkerNode>,
    basis: &mut Basis,
    extra: usize,
    d: usize,
    dpad: usize,
    seed: u64,
) -> Result<()> {
    let p = cluster.p();
    let mut train_rows = basis
        .train_rows
        .take()
        .ok_or_else(|| anyhow::anyhow!("stage-wise growth requires a training-row basis"))?;
    let mut used: Vec<std::collections::HashSet<usize>> = train_rows
        .iter()
        .map(|rows| rows.iter().map(|&(l, _)| l).collect())
        .collect();
    let sizes: Vec<usize> = (0..p).map(|j| cluster.node(j).n_local()).collect();
    let free_total: usize = sizes
        .iter()
        .zip(&used)
        .map(|(&s, u)| s - u.len())
        .sum();
    if extra > free_total {
        basis.train_rows = Some(train_rows);
        anyhow::bail!("cannot grow basis by {extra}: only {free_total} unused rows");
    }

    let m_old = basis.m();
    let mut z_new = Mat::zeros(m_old + extra, d);
    for r in 0..m_old {
        z_new.row_mut(r).copy_from_slice(basis.z.row(r));
    }
    let mut rng = Rng::new(seed ^ 0x57A6E);
    let mut k = m_old;
    let mut node_cursor = 0usize;
    while k < m_old + extra {
        let j = node_cursor % p;
        node_cursor += 1;
        if used[j].len() >= sizes[j] {
            continue;
        }
        // Rejection-sample an unused local row.
        let local = loop {
            let cand = rng.below(sizes[j]);
            if !used[j].contains(&cand) {
                break cand;
            }
        };
        used[j].insert(local);
        z_new.row_mut(k).copy_from_slice(cluster.node(j).x.row(local));
        train_rows[j].push((local, k));
        k += 1;
    }
    basis.z = z_new;
    basis.z_tiles = tiles_of(&basis.z, dpad);
    basis.train_rows = Some(train_rows);
    // Only the new basis points transit the tree.
    cluster.broadcast_meter(Step::BasisBcast, extra * d * 4);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::data::{shard_rows, synth};

    fn build(n: usize, p: usize) -> (Cluster<WorkerNode>, usize, usize) {
        let ds = synth::covtype_like(n, 3);
        let d = ds.d();
        let dpad = 64;
        let shards = shard_rows(n, p);
        let nodes: Vec<WorkerNode> = shards
            .iter()
            .map(|r| {
                let idx: Vec<usize> = r.clone().collect();
                WorkerNode::new(ds.x.gather_rows(&idx), ds.y[r.clone()].to_vec(), dpad)
            })
            .collect();
        (Cluster::new(nodes, 2, CostModel::free()), d, dpad)
    }

    #[test]
    fn random_basis_rows_are_training_rows() {
        let (mut cl, d, dpad) = build(500, 4);
        let basis = select_random(&mut cl, 60, d, dpad, 7).unwrap();
        assert_eq!(basis.m(), 60);
        let rows = basis.train_rows.as_ref().unwrap();
        let total: usize = rows.iter().map(|r| r.len()).sum();
        assert_eq!(total, 60);
        // each recorded (local, k) matches the stored z row
        for (j, node_rows) in rows.iter().enumerate() {
            for &(local, k) in node_rows {
                assert_eq!(cl.node(j).x.row(local), basis.z.row(k), "node {j}");
            }
        }
        // global ks are a permutation of 0..m
        let mut ks: Vec<usize> = rows.iter().flatten().map(|&(_, k)| k).collect();
        ks.sort_unstable();
        assert_eq!(ks, (0..60).collect::<Vec<_>>());
    }

    #[test]
    fn random_basis_rejects_m_over_n() {
        let (mut cl, d, dpad) = build(50, 2);
        assert!(select_random(&mut cl, 51, d, dpad, 1).is_err());
    }

    #[test]
    fn grow_random_appends_and_warm_start_mapping_stays() {
        let (mut cl, d, dpad) = build(400, 3);
        let mut basis = select_random(&mut cl, 40, d, dpad, 9).unwrap();
        let z_before = basis.z.clone();
        grow_random(&mut cl, &mut basis, 24, d, dpad, 10).unwrap();
        assert_eq!(basis.m(), 64);
        // old rows unchanged (warm-start contract)
        for r in 0..40 {
            assert_eq!(basis.z.row(r), z_before.row(r));
        }
        // no duplicate locals per node
        for rows in basis.train_rows.as_ref().unwrap() {
            let set: std::collections::HashSet<usize> =
                rows.iter().map(|&(l, _)| l).collect();
            assert_eq!(set.len(), rows.len());
        }
    }

    #[test]
    fn install_w_shares_fromc() {
        let (mut cl, d, dpad) = build(300, 2);
        let basis = select_random(&mut cl, 32, d, dpad, 5).unwrap();
        let backend: Arc<dyn Compute> =
            Arc::new(crate::runtime::backend::NativeCompute::new());
        install_w_shares(&mut cl, &backend, &basis, 0.5, dpad).unwrap();
        let mut total = 0;
        for j in 0..cl.p() {
            match &cl.node(j).w_share {
                WShare::FromC(rows) => total += rows.len(),
                _ => panic!("expected FromC"),
            }
        }
        assert_eq!(total, 32);
    }

    #[test]
    fn install_w_shares_explicit_for_kmeans_basis() {
        let (mut cl, d, dpad) = build(300, 3);
        let backend: Arc<dyn Compute> =
            Arc::new(crate::runtime::backend::NativeCompute::new());
        let basis = select_kmeans(&mut cl, &backend, 20, 2, d, dpad, 3).unwrap();
        assert!(basis.train_rows.is_none());
        install_w_shares(&mut cl, &backend, &basis, 0.5, dpad).unwrap();
        let mut rows_seen = 0;
        for j in 0..cl.p() {
            if let WShare::Explicit { k0, block } = &cl.node(j).w_share {
                // W row k0+r against basis: diagonal entries must be 1
                // (kernel of a point with itself).
                for r in 0..block.rows() {
                    let diag = block.at(r, k0 + r);
                    assert!((diag - 1.0).abs() < 1e-4, "diag {diag}");
                }
                rows_seen += block.rows();
            } else {
                panic!("expected explicit W share");
            }
        }
        assert_eq!(rows_seen, 20);
    }
}
