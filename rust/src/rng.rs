//! Deterministic pseudo-random number generation (no `rand` crate offline).
//!
//! SplitMix64 seeds a Xoshiro256++ generator — the standard pairing: the
//! seed expansion decorrelates low-entropy user seeds, and Xoshiro256++
//! passes BigCrush while costing a handful of ALU ops per draw. Every
//! experiment in the repo threads an explicit seed so runs are reproducible.

/// SplitMix64: used for seed expansion and as a cheap standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the repo-wide PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (worker-node RNGs from a master seed).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias is < 2^-64 * n, negligible here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached second draw omitted: clarity
    /// over speed — data generation is not on the training hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_full_range() {
        let mut r = Rng::new(14);
        let mut idx = r.sample_indices(16, 16);
        idx.sort_unstable();
        assert_eq!(idx, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(15);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut master = Rng::new(1);
        let mut a = master.fork(0);
        let mut b = master.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
