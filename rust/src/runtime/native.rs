//! Pure-Rust implementations of the exact tile-op semantics of the AOT
//! modules. Two roles:
//! * differential-testing oracle for the PJRT path (rust/tests/);
//! * fallback `Compute` backend (`--backend native`).
//!
//! Every function mirrors the L2 graph in `python/compile/model.py`
//! including mask conventions; keep the two in sync.

use super::{AssignOut, StageOut};
use super::tiles::{TB, TM};
use crate::config::settings::Loss;
use crate::linalg::mat::{dot, dot4};

/// Shared tile-distance core of `kernel_block` and `dist2_block`:
/// ||x||² + ||z||² − 2⟨x,z⟩ per (i,k), clamped at 0, like the Pallas
/// kernel (not the naive difference loop) so numerics match closely.
///
/// Register-blocked 1×4: each x row is held against four z rows at a time
/// via `dot4`, whose per-pair bits equal `dot(x_i, z_k)` exactly (the
/// accumulation-order contract in `crate::linalg::simd`).
fn dist2_core(x_tile: &[f32], z_tile: &[f32], d: usize) -> Vec<f32> {
    assert_eq!(x_tile.len(), TB * d);
    assert_eq!(z_tile.len(), TM * d);
    let xsq: Vec<f32> = (0..TB)
        .map(|i| dot(&x_tile[i * d..(i + 1) * d], &x_tile[i * d..(i + 1) * d]))
        .collect();
    let zsq: Vec<f32> = (0..TM)
        .map(|k| dot(&z_tile[k * d..(k + 1) * d], &z_tile[k * d..(k + 1) * d]))
        .collect();
    let mut out = vec![0.0f32; TB * TM];
    for i in 0..TB {
        let xi = &x_tile[i * d..(i + 1) * d];
        let orow = &mut out[i * TM..(i + 1) * TM];
        let mut k = 0;
        while k + 4 <= TM {
            let dots = dot4(
                &z_tile[k * d..(k + 1) * d],
                &z_tile[(k + 1) * d..(k + 2) * d],
                &z_tile[(k + 2) * d..(k + 3) * d],
                &z_tile[(k + 3) * d..(k + 4) * d],
                xi,
            );
            for (l, &dk) in dots.iter().enumerate() {
                orow[k + l] = (xsq[i] + zsq[k + l] - 2.0 * dk).max(0.0);
            }
            k += 4;
        }
        while k < TM {
            let zk = &z_tile[k * d..(k + 1) * d];
            orow[k] = (xsq[i] + zsq[k] - 2.0 * dot(xi, zk)).max(0.0);
            k += 1;
        }
    }
    out
}

/// RBF kernel tile: x (TB, d), z (TM, d), row-major → (TB*TM).
pub fn kernel_block(x_tile: &[f32], z_tile: &[f32], d: usize, gamma: f32) -> Vec<f32> {
    let mut out = dist2_core(x_tile, z_tile, d);
    for v in out.iter_mut() {
        *v = (-gamma * *v).exp();
    }
    out
}

/// o = C v over one tile. Register-blocked four rows at a time; each
/// output element is bitwise `dot(c_row, v)`.
pub fn matvec(c_tile: &[f32], v: &[f32]) -> Vec<f32> {
    assert_eq!(c_tile.len(), TB * TM);
    assert_eq!(v.len(), TM);
    let mut out = vec![0.0f32; TB];
    let mut i = 0;
    while i + 4 <= TB {
        let dots = dot4(
            &c_tile[i * TM..(i + 1) * TM],
            &c_tile[(i + 1) * TM..(i + 2) * TM],
            &c_tile[(i + 2) * TM..(i + 3) * TM],
            &c_tile[(i + 3) * TM..(i + 4) * TM],
            v,
        );
        out[i..i + 4].copy_from_slice(&dots);
        i += 4;
    }
    while i < TB {
        out[i] = dot(&c_tile[i * TM..(i + 1) * TM], v);
        i += 1;
    }
    out
}

/// g = Cᵀ r over one tile.
pub fn matvec_t(c_tile: &[f32], r: &[f32]) -> Vec<f32> {
    assert_eq!(c_tile.len(), TB * TM);
    assert_eq!(r.len(), TB);
    let mut out = vec![0.0f32; TM];
    for i in 0..TB {
        if r[i] != 0.0 {
            crate::linalg::mat::axpy(r[i], &c_tile[i * TM..(i + 1) * TM], &mut out);
        }
    }
    out
}

/// Loss stage (value, dL/do, Gauss-Newton diagonal), masked.
pub fn loss_stage(loss: Loss, o: &[f32], y: &[f32], mask: &[f32]) -> StageOut {
    let n = o.len();
    assert_eq!(y.len(), n);
    assert_eq!(mask.len(), n);
    let mut total = 0.0f32;
    let mut resid = vec![0.0f32; n];
    let mut dcoef = vec![0.0f32; n];
    match loss {
        Loss::SqHinge => {
            for i in 0..n {
                let margin = 1.0 - y[i] * o[i];
                if margin > 0.0 && mask[i] > 0.0 {
                    total += 0.5 * margin * margin;
                    resid[i] = o[i] - y[i];
                    dcoef[i] = 1.0;
                }
            }
        }
        Loss::Logistic => {
            for i in 0..n {
                if mask[i] > 0.0 {
                    let m = y[i] * o[i];
                    // log(1 + exp(-m)), stable form (matches jnp.logaddexp).
                    total += if m > 0.0 {
                        (-m).exp().ln_1p()
                    } else {
                        -m + m.exp().ln_1p()
                    };
                    let sig = 1.0 / (1.0 + m.exp()); // sigma(-m)
                    resid[i] = -y[i] * sig;
                    dcoef[i] = sig * (1.0 - sig);
                }
            }
        }
        Loss::Squared => {
            for i in 0..n {
                if mask[i] > 0.0 {
                    let r = o[i] - y[i];
                    total += 0.5 * r * r;
                    resid[i] = r;
                    dcoef[i] = 1.0;
                }
            }
        }
    }
    StageOut {
        loss: total,
        vec: resid,
        dcoef,
    }
}

/// Fused f/grad tile: o = C β; (loss, Cᵀ resid, dcoef).
pub fn fgrad(loss: Loss, c_tile: &[f32], beta: &[f32], y: &[f32], mask: &[f32]) -> StageOut {
    let o = matvec(c_tile, beta);
    let stage = loss_stage(loss, &o, y, mask);
    let grad = matvec_t(c_tile, &stage.vec);
    StageOut {
        loss: stage.loss,
        vec: grad,
        dcoef: stage.dcoef,
    }
}

/// Fused Hd tile: Cᵀ (D (C d)).
pub fn hd_tile(c_tile: &[f32], d: &[f32], dcoef: &[f32]) -> Vec<f32> {
    let mut z = matvec(c_tile, d);
    for (zi, w) in z.iter_mut().zip(dcoef) {
        *zi *= w;
    }
    matvec_t(c_tile, &z)
}

// ---- streaming (from-features) fused ops: the C tile is recomputed from
// the feature/basis tiles once per dispatch instead of being stored. The
// tile math is `kernel_block` verbatim, so results are bit-identical to the
// materialized path; only where the tile lives differs.

/// Streaming fused f/grad: C tile from (x, z), then `fgrad`. The tile is
/// computed ONCE and reused for both the matvec and the matvec_t inside
/// this dispatch.
#[allow(clippy::too_many_arguments)]
pub fn fgrad_from_x(
    loss: Loss,
    x_tile: &[f32],
    z_tile: &[f32],
    dpad: usize,
    gamma: f32,
    beta: &[f32],
    y: &[f32],
    mask: &[f32],
) -> StageOut {
    let c = kernel_block(x_tile, z_tile, dpad, gamma);
    fgrad(loss, &c, beta, y, mask)
}

/// Streaming fused Hd: C tile from (x, z), then Cᵀ(D(C d)) — one tile
/// computation feeding both the matvec and the matvec_t.
pub fn hd_from_x(
    x_tile: &[f32],
    z_tile: &[f32],
    dpad: usize,
    gamma: f32,
    d: &[f32],
    dcoef: &[f32],
) -> Vec<f32> {
    let c = kernel_block(x_tile, z_tile, dpad, gamma);
    hd_tile(&c, d, dcoef)
}

/// Streaming matvec: C tile from (x, z), then C v (multi-column-tile f/g/Hd
/// passes, where the loss stage sits between the matvec and matvec_t).
pub fn matvec_from_x(
    x_tile: &[f32],
    z_tile: &[f32],
    dpad: usize,
    gamma: f32,
    v: &[f32],
) -> Vec<f32> {
    let c = kernel_block(x_tile, z_tile, dpad, gamma);
    matvec(&c, v)
}

/// Streaming transposed matvec: C tile from (x, z), then Cᵀ r.
pub fn matvec_t_from_x(
    x_tile: &[f32],
    z_tile: &[f32],
    dpad: usize,
    gamma: f32,
    r: &[f32],
) -> Vec<f32> {
    let c = kernel_block(x_tile, z_tile, dpad, gamma);
    matvec_t(&c, r)
}

/// Squared-distance tile (K-means multi-tile path). Same `dist2_core` as
/// `kernel_block` — the kernel tile is exactly `exp(-γ ·)` of this output,
/// element for element.
pub fn dist2_block(x_tile: &[f32], z_tile: &[f32], d: usize) -> Vec<f32> {
    dist2_core(x_tile, z_tile, d)
}

/// K-means assignment over one row tile (rmask marks live rows).
pub fn kmeans_assign(
    x_tile: &[f32],
    cent: &[f32],
    cmask: &[f32],
    rmask: &[f32],
    d: usize,
) -> AssignOut {
    assert_eq!(x_tile.len(), TB * d);
    assert_eq!(cent.len(), TM * d);
    assert_eq!(cmask.len(), TM);
    assert_eq!(rmask.len(), TB);
    let csq: Vec<f32> = (0..TM)
        .map(|k| crate::linalg::mat::dot(&cent[k * d..(k + 1) * d], &cent[k * d..(k + 1) * d]))
        .collect();
    let mut idx = vec![0i32; TB];
    let mut counts = vec![0.0f32; TM];
    let mut sums = vec![0.0f32; TM * d];
    let mut inertia = 0.0f32;
    for i in 0..TB {
        let xi = &x_tile[i * d..(i + 1) * d];
        let xsq = crate::linalg::mat::dot(xi, xi);
        let mut best = f32::INFINITY;
        let mut best_k = 0usize;
        for k in 0..TM {
            let d2 = (xsq + csq[k] - 2.0 * crate::linalg::mat::dot(xi, &cent[k * d..(k + 1) * d]))
                .max(0.0)
                + (1.0 - cmask[k]) * 1e30;
            if d2 < best {
                best = d2;
                best_k = k;
            }
        }
        idx[i] = best_k as i32;
        if rmask[i] > 0.0 {
            counts[best_k] += 1.0;
            crate::linalg::mat::axpy(1.0, xi, &mut sums[best_k * d..(best_k + 1) * d]);
            inertia += best;
        }
    }
    AssignOut {
        idx,
        counts,
        sums,
        inertia,
    }
}

/// Prediction tile: RBF(x, z) β.
pub fn predict_block(
    x_tile: &[f32],
    z_tile: &[f32],
    gamma: f32,
    beta: &[f32],
    d: usize,
) -> Vec<f32> {
    let c = kernel_block(x_tile, z_tile, d, gamma);
    matvec(&c, beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| scale * rng.normal_f32()).collect()
    }

    #[test]
    fn kernel_diag_is_one_for_identical_rows() {
        let mut rng = Rng::new(1);
        let d = 32;
        let x = rand_vec(&mut rng, TB * d, 1.0);
        let mut z = vec![0.0; TM * d];
        z[..TB.min(TM) * d].copy_from_slice(&x[..TB.min(TM) * d]);
        let k = kernel_block(&x, &z, d, 0.7);
        for i in 0..TB.min(TM) {
            assert!((k[i * TM + i] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_pair_adjoint() {
        let mut rng = Rng::new(2);
        let c = rand_vec(&mut rng, TB * TM, 1.0);
        let v = rand_vec(&mut rng, TM, 1.0);
        let r = rand_vec(&mut rng, TB, 1.0);
        let lhs = crate::linalg::mat::dot(&matvec(&c, &v), &r);
        let rhs = crate::linalg::mat::dot(&v, &matvec_t(&c, &r));
        assert!((lhs - rhs).abs() < 2e-2 * lhs.abs().max(1.0));
    }

    #[test]
    fn sqhinge_stage_matches_paper() {
        let mut o = vec![0.0f32; TB];
        let mut y = vec![1.0f32; TB];
        let mut mask = vec![0.0f32; TB];
        o[0] = 2.0; // inactive
        o[1] = 0.5; // active
        y[1] = 1.0;
        mask[0] = 1.0;
        mask[1] = 1.0;
        let s = loss_stage(Loss::SqHinge, &o, &y, &mask);
        assert!((s.loss - 0.125).abs() < 1e-6);
        assert_eq!(s.dcoef[0], 0.0);
        assert_eq!(s.dcoef[1], 1.0);
        assert!((s.vec[1] - (-0.5)).abs() < 1e-6);
        // padding rows contribute nothing even with nonzero o
        assert_eq!(s.vec[2], 0.0);
    }

    #[test]
    fn logistic_matches_finite_difference() {
        // FD on a single-row mask so the f32 loss sum has no cancellation
        // noise from the other TB-1 rows.
        let mut rng = Rng::new(3);
        let o = rand_vec(&mut rng, TB, 1.5);
        let y: Vec<f32> = (0..TB).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let full_mask = vec![1.0f32; TB];
        let s = loss_stage(Loss::Logistic, &o, &y, &full_mask);
        let eps = 1e-3;
        for i in [0, 7, 100] {
            let mut mask = vec![0.0f32; TB];
            mask[i] = 1.0;
            let mut op = o.clone();
            op[i] += eps;
            let lp = loss_stage(Loss::Logistic, &op, &y, &mask).loss;
            let mut om = o.clone();
            om[i] -= eps;
            let lm = loss_stage(Loss::Logistic, &om, &y, &mask).loss;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - s.vec[i]).abs() < 1e-3 * s.vec[i].abs().max(0.1),
                "i={i}: fd {fd} vs {}",
                s.vec[i]
            );
        }
    }

    #[test]
    fn fgrad_consistent_with_stages() {
        let mut rng = Rng::new(4);
        let c = rand_vec(&mut rng, TB * TM, 0.5);
        let beta = rand_vec(&mut rng, TM, 0.2);
        let y: Vec<f32> = (0..TB).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let mask = vec![1.0f32; TB];
        let f = fgrad(Loss::SqHinge, &c, &beta, &y, &mask);
        let o = matvec(&c, &beta);
        let s = loss_stage(Loss::SqHinge, &o, &y, &mask);
        let grad = matvec_t(&c, &s.vec);
        assert!((f.loss - s.loss).abs() < 1e-3);
        for (a, b) in f.vec.iter().zip(&grad) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn from_x_ops_match_materialized_tile_bitwise() {
        let mut rng = Rng::new(11);
        let d = 32;
        let x = rand_vec(&mut rng, TB * d, 1.0);
        let z = rand_vec(&mut rng, TM * d, 1.0);
        let beta = rand_vec(&mut rng, TM, 0.2);
        let r = rand_vec(&mut rng, TB, 0.5);
        let y: Vec<f32> = (0..TB).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let mask = vec![1.0f32; TB];
        let dcoef = vec![1.0f32; TB];
        let c = kernel_block(&x, &z, d, 0.4);

        let want = fgrad(Loss::SqHinge, &c, &beta, &y, &mask);
        let got = fgrad_from_x(Loss::SqHinge, &x, &z, d, 0.4, &beta, &y, &mask);
        assert_eq!(want.loss.to_bits(), got.loss.to_bits());
        for (a, b) in want.vec.iter().zip(&got.vec) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in want.dcoef.iter().zip(&got.dcoef) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        for (a, b) in hd_tile(&c, &beta, &dcoef)
            .iter()
            .zip(&hd_from_x(&x, &z, d, 0.4, &beta, &dcoef))
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in matvec(&c, &beta)
            .iter()
            .zip(&matvec_from_x(&x, &z, d, 0.4, &beta))
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in matvec_t(&c, &r)
            .iter()
            .zip(&matvec_t_from_x(&x, &z, d, 0.4, &r))
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn kmeans_assign_respects_mask_and_counts() {
        let mut rng = Rng::new(5);
        let d = 32;
        let x = rand_vec(&mut rng, TB * d, 1.0);
        let cent = rand_vec(&mut rng, TM * d, 1.0);
        let mut cmask = vec![0.0f32; TM];
        cmask[..10].fill(1.0);
        let rmask = vec![1.0f32; TB];
        let a = kmeans_assign(&x, &cent, &cmask, &rmask, d);
        assert!(a.idx.iter().all(|&i| i < 10));
        assert_eq!(a.counts.iter().sum::<f32>(), TB as f32);
        assert!(a.counts[10..].iter().all(|&c| c == 0.0));
        // sums consistency: total of sums == total of x
        let total_sums: f32 = a.sums.iter().sum();
        let total_x: f32 = x.iter().sum();
        assert!((total_sums - total_x).abs() < 1e-2 * total_x.abs().max(1.0));
    }

    #[test]
    fn kmeans_row_mask_excludes_padding_rows() {
        let mut rng = Rng::new(7);
        let d = 32;
        let x = rand_vec(&mut rng, TB * d, 1.0);
        let cent = rand_vec(&mut rng, TM * d, 1.0);
        let cmask = vec![1.0f32; TM];
        let mut rmask = vec![0.0f32; TB];
        rmask[..100].fill(1.0);
        let a = kmeans_assign(&x, &cent, &cmask, &rmask, d);
        assert_eq!(a.counts.iter().sum::<f32>(), 100.0);
        let full = kmeans_assign(&x, &cent, &cmask, &vec![1.0; TB], d);
        assert!(a.inertia < full.inertia);
    }

    #[test]
    fn dist2_block_matches_kernel_exponent() {
        let mut rng = Rng::new(8);
        let d = 32;
        let x = rand_vec(&mut rng, TB * d, 1.0);
        let z = rand_vec(&mut rng, TM * d, 1.0);
        let d2 = dist2_block(&x, &z, d);
        let k = kernel_block(&x, &z, d, 0.5);
        for i in (0..TB * TM).step_by(999) {
            assert!((k[i] - (-0.5 * d2[i]).exp()).abs() < 1e-4);
        }
    }

    #[test]
    fn predict_is_kernel_then_matvec() {
        let mut rng = Rng::new(6);
        let d = 32;
        let x = rand_vec(&mut rng, TB * d, 1.0);
        let z = rand_vec(&mut rng, TM * d, 1.0);
        let beta = rand_vec(&mut rng, TM, 0.1);
        let p = predict_block(&x, &z, 0.3, &beta, d);
        let c = kernel_block(&x, &z, d, 0.3);
        let o = matvec(&c, &beta);
        for (a, b) in p.iter().zip(&o) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
