//! The tiling/padding contract between datasets and the fixed-shape AOT
//! modules.
//!
//! HLO modules have static shapes, so the runtime zero-pads everything to a
//! (TB, TM, D) grid and loops tiles:
//! * rows are padded to multiples of TB with zero rows, masked out of
//!   losses and reductions via `mask` vectors;
//! * basis columns are padded to multiples of TM with zero columns — for
//!   the RBF kernel a zero *basis row* still yields kernel values, so basis
//!   validity is ALSO handled by masks (β padding entries stay exactly 0:
//!   they start 0 and their gradient entries are masked);
//! * feature width d is zero-padded to the next compiled width D, which is
//!   exact for RBF (padded coordinates contribute 0 to ‖x−z‖²).

use crate::linalg::Mat;

/// Row-tile edge (must match `python/compile/aot.py::TB`).
pub const TB: usize = 256;
/// Basis-tile edge (must match `python/compile/aot.py::TM`).
pub const TM: usize = 256;

/// Round `n` up to a multiple of `tile`.
#[inline]
pub fn round_up(n: usize, tile: usize) -> usize {
    n.div_ceil(tile) * tile
}

/// Zero-pad a row-major matrix to (rows_to, cols_to).
pub fn pad_mat(x: &Mat, rows_to: usize, cols_to: usize) -> Mat {
    assert!(rows_to >= x.rows() && cols_to >= x.cols());
    let mut out = Mat::zeros(rows_to, cols_to);
    for i in 0..x.rows() {
        out.row_mut(i)[..x.cols()].copy_from_slice(x.row(i));
    }
    out
}

/// Zero-pad a vector to `len_to`.
pub fn pad_vec(v: &[f32], len_to: usize) -> Vec<f32> {
    assert!(len_to >= v.len());
    let mut out = vec![0.0; len_to];
    out[..v.len()].copy_from_slice(v);
    out
}

/// Smallest width in `widths` that is >= d (the compiled-D selection).
pub fn pad_dim(widths: &[usize], d: usize) -> Option<usize> {
    widths.iter().copied().filter(|&w| w >= d).min()
}

/// A (rows x cols) matrix stored as a grid of contiguous (TB x TM) tiles —
/// the layout the PJRT modules consume directly. Logical size is
/// (rows, cols); physical size is padded.
#[derive(Clone, Debug)]
pub struct TiledMatrix {
    rows: usize,
    cols: usize,
    row_tiles: usize,
    col_tiles: usize,
    /// tiles[i][j] is the (TB x TM) tile at row-tile i, col-tile j,
    /// row-major within the tile.
    tiles: Vec<Vec<Vec<f32>>>,
}

impl TiledMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let row_tiles = rows.div_ceil(TB).max(1);
        let col_tiles = cols.div_ceil(TM).max(1);
        TiledMatrix {
            rows,
            cols,
            row_tiles,
            col_tiles,
            tiles: vec![vec![vec![0.0; TB * TM]; col_tiles]; row_tiles],
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn row_tiles(&self) -> usize {
        self.row_tiles
    }

    pub fn col_tiles(&self) -> usize {
        self.col_tiles
    }

    /// Logical rows covered by row-tile i (the last tile may be partial).
    pub fn rows_in_tile(&self, i: usize) -> usize {
        debug_assert!(i < self.row_tiles);
        (self.rows - i * TB).min(TB)
    }

    /// Logical cols covered by col-tile j.
    pub fn cols_in_tile(&self, j: usize) -> usize {
        debug_assert!(j < self.col_tiles);
        (self.cols - j * TM).min(TM)
    }

    pub fn tile(&self, i: usize, j: usize) -> &[f32] {
        &self.tiles[i][j]
    }

    pub fn tile_mut(&mut self, i: usize, j: usize) -> &mut [f32] {
        &mut self.tiles[i][j]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.tiles[r / TB][c / TM][(r % TB) * TM + (c % TM)]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.tiles[r / TB][c / TM][(r % TB) * TM + (c % TM)] = v;
    }

    /// Grow the logical column count (stage-wise basis addition). Newly
    /// exposed columns are zero; tiles are allocated as needed. Returns the
    /// range of col-tiles whose contents must be (re)computed: from the
    /// tile containing old `cols` (it was partial) through the new last
    /// tile.
    pub fn grow_cols(&mut self, new_cols: usize) -> std::ops::Range<usize> {
        assert!(new_cols >= self.cols, "grow_cols cannot shrink");
        let first_dirty = self.cols / TM; // tile holding the first new column
        let new_col_tiles = new_cols.div_ceil(TM).max(1);
        for row in &mut self.tiles {
            row.resize(new_col_tiles, vec![0.0; TB * TM]);
        }
        self.cols = new_cols;
        self.col_tiles = new_col_tiles;
        first_dirty..new_col_tiles
    }

    /// Dense copy (tests / debugging).
    pub fn to_mat(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(r, c) = self.at(r, c);
            }
        }
        out
    }

    /// Build from a dense matrix (tests).
    pub fn from_mat(m: &Mat) -> Self {
        let mut out = Self::zeros(m.rows(), m.cols());
        for r in 0..m.rows() {
            for c in 0..m.cols() {
                out.set(r, c, m.at(r, c));
            }
        }
        out
    }

    /// Physical bytes held (padding included) — the O(nm/p) node memory the
    /// paper discusses in §3.1.
    pub fn bytes(&self) -> usize {
        self.row_tiles * self.col_tiles * TB * TM * 4
    }
}

/// Per-row-tile padding masks (1.0 for live rows) for `rows` logical rows.
pub fn row_masks(rows: usize) -> Vec<Vec<f32>> {
    let nt = rows.div_ceil(TB).max(1);
    (0..nt)
        .map(|i| {
            let live = ((rows - i * TB).min(TB)) as usize;
            let mut m = vec![0.0; TB];
            m[..live].fill(1.0);
            m
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 256), 0);
        assert_eq!(round_up(1, 256), 256);
        assert_eq!(round_up(256, 256), 256);
        assert_eq!(round_up(257, 256), 512);
    }

    #[test]
    fn tiled_roundtrip_matches_dense() {
        let mut rng = Rng::new(1);
        let m = Mat::from_fn(300, 270, |_, _| rng.normal_f32());
        let t = TiledMatrix::from_mat(&m);
        assert_eq!(t.row_tiles(), 2);
        assert_eq!(t.col_tiles(), 2);
        assert_eq!(t.to_mat().as_slice(), m.as_slice());
    }

    #[test]
    fn tile_padding_is_zero() {
        let m = Mat::from_fn(10, 10, |_, _| 1.0);
        let t = TiledMatrix::from_mat(&m);
        let tile = t.tile(0, 0);
        assert_eq!(tile[0], 1.0);
        assert_eq!(tile[9], 1.0);
        assert_eq!(tile[10], 0.0); // column padding
        assert_eq!(tile[10 * TM], 0.0); // row padding
    }

    #[test]
    fn rows_cols_in_tile_handle_partials() {
        let t = TiledMatrix::zeros(300, 500);
        assert_eq!(t.rows_in_tile(0), 256);
        assert_eq!(t.rows_in_tile(1), 44);
        assert_eq!(t.cols_in_tile(0), 256);
        assert_eq!(t.cols_in_tile(1), 244);
    }

    #[test]
    fn grow_cols_reports_dirty_tiles() {
        let mut t = TiledMatrix::zeros(10, 200);
        // 200 -> 300: tile 0 (partial, holds cols 200..256) + new tile 1.
        let dirty = t.grow_cols(300);
        assert_eq!(dirty, 0..2);
        assert_eq!(t.cols(), 300);
        assert_eq!(t.col_tiles(), 2);
        // 300 -> 512: tile 1 again (was partial), no new tiles beyond 2.
        let dirty = t.grow_cols(512);
        assert_eq!(dirty, 1..2);
    }

    #[test]
    fn grow_preserves_existing_values() {
        let mut t = TiledMatrix::zeros(4, 4);
        t.set(2, 3, 7.0);
        t.grow_cols(600);
        assert_eq!(t.at(2, 3), 7.0);
        assert_eq!(t.at(2, 500), 0.0);
    }

    #[test]
    fn row_masks_mark_live_rows() {
        let ms = row_masks(300);
        assert_eq!(ms.len(), 2);
        assert_eq!(ms[0].iter().sum::<f32>(), 256.0);
        assert_eq!(ms[1].iter().sum::<f32>(), 44.0);
        assert_eq!(ms[1][43], 1.0);
        assert_eq!(ms[1][44], 0.0);
    }

    #[test]
    fn pad_mat_and_vec() {
        let m = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let p = pad_mat(&m, 2, 4);
        assert_eq!(p.row(0), &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(p.row(1), &[0.0; 4]);
        assert_eq!(pad_vec(&[1.0], 3), vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn pad_dim_selection() {
        assert_eq!(pad_dim(&[32, 64, 128], 54), Some(64));
        assert_eq!(pad_dim(&[32, 64], 64), Some(64));
        assert_eq!(pad_dim(&[32, 64], 100), None);
    }
}
