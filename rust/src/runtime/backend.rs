//! The `Compute` trait: the tile-op interface the coordinator programs
//! against, with the PJRT (AOT artifact, `pjrt` feature) and native (pure
//! Rust) implementations. The two are differential-tested against each
//! other in `rust/tests/runtime_pjrt.rs`.
//!
//! `Compute` is `Send + Sync`: one shared backend (`Arc<dyn Compute>`)
//! serves every simulated node, including concurrently from the worker
//! threads of [`crate::cluster::ThreadedExecutor`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::settings::{Backend, Loss};
use crate::Result;

#[cfg(feature = "pjrt")]
use super::engine::Engine;
use super::{native, AssignOut, BlockOut, StageOut};

use super::tiles::{TB, TM};

/// One row tile's worth of C-block operands for the per-evaluation block
/// ops ([`Compute::fgrad_block`] / [`Compute::hd_block`]): either the
/// materialized prepared C tiles (one per basis column tile) or the
/// prepared feature tile to recompute them from.
pub enum RowTiles<'a> {
    /// Materialized: prepared C tiles, one per basis column tile.
    Prepared(&'a [Prepared]),
    /// Streamed: recompute each C tile from the prepared feature tile
    /// inside the dispatch. `keep_row` asks the backend to hold all
    /// `col_tiles` tiles of this row across the matvec and matvec_t halves
    /// (rowbuf semantics — O(col_tiles)-tile transient memory); otherwise
    /// each tile is recomputed per half (plain streaming — one transient
    /// tile). With a single column tile the tile is always computed once
    /// and consumed fused, whatever the flag says.
    FromX { x: &'a Prepared, keep_row: bool },
}

/// Borrowed-or-computed C tile inside a native block dispatch.
enum Tile<'a> {
    Borrowed(&'a [f32]),
    Owned(Vec<f32>),
}

impl Tile<'_> {
    fn as_slice(&self) -> &[f32] {
        match self {
            Tile::Borrowed(s) => s,
            Tile::Owned(v) => v,
        }
    }
}

/// Resolve column tile `j` of one row for the native block ops: borrow a
/// materialized/kept tile, or recompute it from the feature tile. The
/// recomputed tile is `native::kernel_block` verbatim, so which arm runs
/// never changes bits — only where the tile lives and how often it is
/// (re)built.
fn block_tile<'a>(
    row: &'a RowTiles<'a>,
    kept: &'a Option<Vec<Vec<f32>>>,
    z: &[Prepared],
    dpad: usize,
    gamma: f32,
    j: usize,
) -> Tile<'a> {
    match row {
        RowTiles::Prepared(preps) => Tile::Borrowed(preps[j].host()),
        RowTiles::FromX { x, .. } => match kept {
            Some(tiles) => Tile::Borrowed(&tiles[j]),
            None => Tile::Owned(native::kernel_block(x.host(), z[j].host(), dpad, gamma)),
        },
    }
}

/// Rowbuf-style tile retention for one streamed row of a native block
/// dispatch: all `ct` tiles computed up front (only when asked, and only
/// worthwhile for ct > 1 — a single tile is consumed fused either way).
fn keep_tiles(
    row: &RowTiles<'_>,
    ct: usize,
    z: &[Prepared],
    dpad: usize,
    gamma: f32,
) -> Option<Vec<Vec<f32>>> {
    match row {
        RowTiles::FromX { x, keep_row: true } if ct > 1 => Some(
            (0..ct)
                .map(|j| native::kernel_block(x.host(), z[j].host(), dpad, gamma))
                .collect(),
        ),
        _ => None,
    }
}

/// An operand prepared for repeated hot-path use: resident on the PJRT
/// device (one upload, zero per-call transfer) or a pinned host buffer for
/// the native backend. Created once per C tile / feature panel after the
/// kernel-computation step; every TRON f/g/Hd call then ships only the
/// O(TB + TM) small vectors. This is the §Perf "persistent device buffer"
/// optimization (see EXPERIMENTS.md §Perf for before/after).
///
/// The host variant is an `Arc` so a caller that must ALSO keep the tile
/// on the host (the materialized C store serves `row_dot` from host tiles)
/// can share one buffer with its prepared copy via
/// [`Compute::prepare_shared`] instead of holding the data twice.
pub enum Prepared {
    Host(Arc<Vec<f32>>),
    #[cfg(feature = "pjrt")]
    Device(xla::PjRtBuffer),
}

// SAFETY (pjrt builds): PJRT device buffers are internally synchronized —
// see the Send/Sync rationale on [`Engine`]. Without the feature `Prepared`
// is plain owned data and these impls match what the compiler would derive.
#[cfg(feature = "pjrt")]
unsafe impl Send for Prepared {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Prepared {}

impl Prepared {
    /// Host view (native backend only).
    fn host(&self) -> &[f32] {
        match self {
            Prepared::Host(v) => v,
            #[cfg(feature = "pjrt")]
            Prepared::Device(_) => panic!("device-prepared operand used on native backend"),
        }
    }

    #[cfg(feature = "pjrt")]
    fn device(&self) -> Result<&xla::PjRtBuffer> {
        match self {
            Prepared::Device(b) => Ok(b),
            Prepared::Host(_) => anyhow::bail!("host-prepared operand used on PJRT backend"),
        }
    }

    /// True when this prepared operand is the SAME host allocation as
    /// `host` (a zero-copy [`Compute::prepare_shared`] result) — i.e. it
    /// contributes no extra bytes beyond the host buffer itself.
    pub fn aliases(&self, host: &Arc<Vec<f32>>) -> bool {
        match self {
            Prepared::Host(v) => Arc::ptr_eq(v, host),
            #[cfg(feature = "pjrt")]
            Prepared::Device(_) => false,
        }
    }
}

/// Node-local tile compute. All slices follow the tiling contract of
/// [`super::tiles`]: row tiles are TB long, basis tiles TM, features padded
/// to a compiled width. Implementations must be thread-safe (`Send + Sync`)
/// — the threaded executor calls them from every worker concurrently.
pub trait Compute: Send + Sync {
    /// Supported padded feature widths.
    fn widths(&self) -> Vec<usize>;

    /// Smallest compiled width >= d.
    fn pad_d(&self, d: usize) -> Result<usize> {
        super::tiles::pad_dim(&self.widths(), d)
            .ok_or_else(|| anyhow::anyhow!("feature dim {d} exceeds compiled widths"))
    }

    fn kernel_block(&self, x: &[f32], z: &[f32], dpad: usize, gamma: f32) -> Result<Vec<f32>>;
    fn matvec(&self, c: &[f32], v: &[f32]) -> Result<Vec<f32>>;
    fn matvec_t(&self, c: &[f32], r: &[f32]) -> Result<Vec<f32>>;
    fn loss_stage(&self, loss: Loss, o: &[f32], y: &[f32], mask: &[f32]) -> Result<StageOut>;
    fn fgrad(&self, loss: Loss, c: &[f32], beta: &[f32], y: &[f32], mask: &[f32])
        -> Result<StageOut>;
    fn hd_tile(&self, c: &[f32], d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>>;
    fn dist2_block(&self, x: &[f32], z: &[f32], dpad: usize) -> Result<Vec<f32>>;
    #[allow(clippy::too_many_arguments)]
    fn kmeans_assign(
        &self,
        x: &[f32],
        cent: &[f32],
        cmask: &[f32],
        rmask: &[f32],
        dpad: usize,
    ) -> Result<AssignOut>;
    fn predict_block(
        &self,
        x: &[f32],
        z: &[f32],
        gamma: f32,
        beta: &[f32],
        dpad: usize,
    ) -> Result<Vec<f32>>;

    /// Dispatch count (PJRT executions / native calls) for overhead metrics.
    fn call_count(&self) -> u64;

    fn name(&self) -> &'static str;

    // ---- prepared-operand hot path (one upload, many executions) ----

    /// Prepare an operand for repeated use (shape `dims`, row-major).
    fn prepare(&self, data: &[f32], dims: &[usize]) -> Result<Prepared>;

    /// Prepare an operand the caller also keeps on the host. Backends that
    /// execute from host memory may alias the buffer (zero-copy — the
    /// native path does); device backends upload a copy as usual.
    ///
    /// CONTRACT: this method and [`Compute::prepared_aliases_host`] must be
    /// overridden TOGETHER — the flag is how byte accounting and the Auto
    /// storage budget price what this method returns. (Per-`Prepared`
    /// truth is available via [`Prepared::aliases`]; the flag exists so
    /// the budget can be priced before any tile is built.)
    fn prepare_shared(&self, data: &Arc<Vec<f32>>, dims: &[usize]) -> Result<Prepared> {
        self.prepare(data, dims)
    }

    /// True when [`Compute::prepare_shared`] aliases the host buffer
    /// instead of copying: a materialized C row tile then costs ONE tile
    /// of memory, not two (host copy + prepared copy). Keep in lockstep
    /// with `prepare_shared` — see the contract note there.
    fn prepared_aliases_host(&self) -> bool {
        false
    }

    fn kernel_block_p(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>>;

    fn matvec_p(&self, c: &Prepared, v: &[f32]) -> Result<Vec<f32>>;

    fn matvec_t_p(&self, c: &Prepared, r: &[f32]) -> Result<Vec<f32>>;

    fn fgrad_p(
        &self,
        loss: Loss,
        c: &Prepared,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut>;

    fn hd_p(&self, c: &Prepared, d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>>;

    // ---- streaming (from-features) fused ops: no stored C ----
    //
    // Each op recomputes the kernel tile from the prepared feature tile `x`
    // and basis tile `z` ONCE per dispatch and consumes it in place. Tile
    // math is exactly `kernel_block`, so results are bit-identical to the
    // prepared-C variants above — the memory/compute tradeoff behind
    // `CStorage::Streaming` (see `coordinator::cstore`).

    /// Fused f/grad with the C tile recomputed from (x, z): the tile feeds
    /// both the matvec and the matvec_t of this dispatch.
    #[allow(clippy::too_many_arguments)]
    fn fgrad_from_x(
        &self,
        loss: Loss,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut>;

    /// Fused Hd with the C tile recomputed from (x, z).
    fn hd_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>>;

    /// C v with the C tile recomputed from (x, z).
    fn matvec_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        v: &[f32],
    ) -> Result<Vec<f32>>;

    /// Cᵀ r with the C tile recomputed from (x, z).
    fn matvec_t_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        r: &[f32],
    ) -> Result<Vec<f32>>;

    // ---- per-evaluation block ops: ONE dispatch per node ----
    //
    // One call covers every (row tile × column tile) of a node's C block —
    // both matvec halves of an evaluation — instead of O(row_tiles ·
    // col_tiles) per-tile dispatches. The loop structure inside the block
    // replicates the per-tile formulation exactly (accumulation in (i, j)
    // order from zeros, loss stage between the halves), so results are
    // bit-identical to driving the per-tile ops from the coordinator;
    // only the dispatch count changes.
    //
    // The default implementations below fan back out to the per-tile ops —
    // that is the cfg-free PJRT fallback (a fused device-side block
    // program is ROADMAP item 4(c)). The native backend overrides them
    // with single-dispatch microkernel loops.

    /// Fused per-node f/grad over all row tiles: o_i = Σ_j C_ij v_j, loss
    /// stage per row tile, grad_j += C_ijᵀ resid_i — one `BlockOut` with
    /// the node's loss partial, flat `ct·TM` gradient partial and per-row
    /// dcoef. `y`/`mask` are the host tiles, `y_prep`/`mask_prep` their
    /// prepared twins (the single-column fused ops consume the prepared
    /// form, the multi-column loss stage the host form — exactly like the
    /// per-tile formulation).
    #[allow(clippy::too_many_arguments)]
    fn fgrad_block(
        &self,
        loss: Loss,
        rows: &[RowTiles<'_>],
        z: &[Prepared],
        dpad: usize,
        gamma: f32,
        v_tiles: &[Vec<f32>],
        y_prep: &[Prepared],
        mask_prep: &[Prepared],
        y: &[Vec<f32>],
        mask: &[Vec<f32>],
    ) -> Result<BlockOut> {
        let ct = z.len();
        let mut grad = vec![0.0f32; ct * TM];
        let mut loss_sum = 0.0f32;
        let mut dcoef = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            if ct == 1 {
                let stage = match row {
                    RowTiles::Prepared(preps) => {
                        self.fgrad_p(loss, &preps[0], &v_tiles[0], &y_prep[i], &mask_prep[i])?
                    }
                    RowTiles::FromX { x, .. } => self.fgrad_from_x(
                        loss,
                        x,
                        &z[0],
                        dpad,
                        gamma,
                        &v_tiles[0],
                        &y_prep[i],
                        &mask_prep[i],
                    )?,
                };
                loss_sum += stage.loss;
                for (g, v) in grad.iter_mut().zip(&stage.vec) {
                    *g += v;
                }
                dcoef.push(stage.dcoef);
                continue;
            }
            let mut o = vec![0.0f32; TB];
            match row {
                RowTiles::Prepared(preps) => {
                    for (j, vj) in v_tiles.iter().enumerate() {
                        let part = self.matvec_p(&preps[j], vj)?;
                        for (a, b) in o.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    let stage = self.loss_stage(loss, &o, &y[i], &mask[i])?;
                    loss_sum += stage.loss;
                    for j in 0..ct {
                        let part = self.matvec_t_p(&preps[j], &stage.vec)?;
                        for (g, v) in grad[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                            *g += v;
                        }
                    }
                    dcoef.push(stage.dcoef);
                }
                RowTiles::FromX { x, keep_row: true } => {
                    let tiles: Vec<Vec<f32>> = (0..ct)
                        .map(|j| self.kernel_block_p(x, &z[j], dpad, gamma))
                        .collect::<Result<_>>()?;
                    for (j, vj) in v_tiles.iter().enumerate() {
                        let part = self.matvec(&tiles[j], vj)?;
                        for (a, b) in o.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    let stage = self.loss_stage(loss, &o, &y[i], &mask[i])?;
                    loss_sum += stage.loss;
                    for j in 0..ct {
                        let part = self.matvec_t(&tiles[j], &stage.vec)?;
                        for (g, v) in grad[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                            *g += v;
                        }
                    }
                    dcoef.push(stage.dcoef);
                }
                RowTiles::FromX { x, keep_row: false } => {
                    for (j, vj) in v_tiles.iter().enumerate() {
                        let part = self.matvec_from_x(x, &z[j], dpad, gamma, vj)?;
                        for (a, b) in o.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    let stage = self.loss_stage(loss, &o, &y[i], &mask[i])?;
                    loss_sum += stage.loss;
                    for j in 0..ct {
                        let part = self.matvec_t_from_x(x, &z[j], dpad, gamma, &stage.vec)?;
                        for (g, v) in grad[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                            *g += v;
                        }
                    }
                    dcoef.push(stage.dcoef);
                }
            }
        }
        Ok(BlockOut {
            loss: loss_sum,
            grad,
            dcoef,
        })
    }

    /// Fused per-node Hd over all row tiles: z_i = D_i Σ_j C_ij v_j, then
    /// out_j += C_ijᵀ z_i — the node's flat `ct·TM` Hd partial. `dcoef`
    /// holds the per-row-tile diagonals cached by the last `fgrad_block`.
    fn hd_block(
        &self,
        rows: &[RowTiles<'_>],
        z: &[Prepared],
        dpad: usize,
        gamma: f32,
        v_tiles: &[Vec<f32>],
        dcoef: &[Vec<f32>],
    ) -> Result<Vec<f32>> {
        let ct = z.len();
        let mut out = vec![0.0f32; ct * TM];
        for (i, row) in rows.iter().enumerate() {
            if ct == 1 {
                let part = match row {
                    RowTiles::Prepared(preps) => self.hd_p(&preps[0], &v_tiles[0], &dcoef[i])?,
                    RowTiles::FromX { x, .. } => {
                        self.hd_from_x(x, &z[0], dpad, gamma, &v_tiles[0], &dcoef[i])?
                    }
                };
                for (g, v) in out.iter_mut().zip(&part) {
                    *g += v;
                }
                continue;
            }
            let mut zv = vec![0.0f32; TB];
            match row {
                RowTiles::Prepared(preps) => {
                    for (j, vj) in v_tiles.iter().enumerate() {
                        let part = self.matvec_p(&preps[j], vj)?;
                        for (a, b) in zv.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    for (zi, w) in zv.iter_mut().zip(&dcoef[i]) {
                        *zi *= w;
                    }
                    for j in 0..ct {
                        let part = self.matvec_t_p(&preps[j], &zv)?;
                        for (g, v) in out[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                            *g += v;
                        }
                    }
                }
                RowTiles::FromX { x, keep_row: true } => {
                    let tiles: Vec<Vec<f32>> = (0..ct)
                        .map(|j| self.kernel_block_p(x, &z[j], dpad, gamma))
                        .collect::<Result<_>>()?;
                    for (j, vj) in v_tiles.iter().enumerate() {
                        let part = self.matvec(&tiles[j], vj)?;
                        for (a, b) in zv.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    for (zi, w) in zv.iter_mut().zip(&dcoef[i]) {
                        *zi *= w;
                    }
                    for j in 0..ct {
                        let part = self.matvec_t(&tiles[j], &zv)?;
                        for (g, v) in out[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                            *g += v;
                        }
                    }
                }
                RowTiles::FromX { x, keep_row: false } => {
                    for (j, vj) in v_tiles.iter().enumerate() {
                        let part = self.matvec_from_x(x, &z[j], dpad, gamma, vj)?;
                        for (a, b) in zv.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    for (zi, w) in zv.iter_mut().zip(&dcoef[i]) {
                        *zi *= w;
                    }
                    for j in 0..ct {
                        let part = self.matvec_t_from_x(x, &z[j], dpad, gamma, &zv)?;
                        for (g, v) in out[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                            *g += v;
                        }
                    }
                }
            }
        }
        Ok(out)
    }
}

/// PJRT-backed compute (the paper stack: AOT JAX+Pallas artifacts).
#[cfg(feature = "pjrt")]
pub struct PjrtCompute {
    engine: Engine,
}

#[cfg(feature = "pjrt")]
impl PjrtCompute {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        Ok(PjrtCompute {
            engine: Engine::new(artifacts_dir)?,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

#[cfg(feature = "pjrt")]
impl Compute for PjrtCompute {
    fn widths(&self) -> Vec<usize> {
        self.engine.manifest().ds.clone()
    }

    fn kernel_block(&self, x: &[f32], z: &[f32], dpad: usize, gamma: f32) -> Result<Vec<f32>> {
        self.engine.kernel_block(x, z, dpad, gamma)
    }

    fn matvec(&self, c: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec(c, v)
    }

    fn matvec_t(&self, c: &[f32], r: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec_t(c, r)
    }

    fn loss_stage(&self, loss: Loss, o: &[f32], y: &[f32], mask: &[f32]) -> Result<StageOut> {
        self.engine.loss_stage(loss.name(), o, y, mask)
    }

    fn fgrad(
        &self,
        loss: Loss,
        c: &[f32],
        beta: &[f32],
        y: &[f32],
        mask: &[f32],
    ) -> Result<StageOut> {
        self.engine.fgrad(loss.name(), c, beta, y, mask)
    }

    fn hd_tile(&self, c: &[f32], d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.engine.hd_tile(c, d, dcoef)
    }

    fn dist2_block(&self, x: &[f32], z: &[f32], dpad: usize) -> Result<Vec<f32>> {
        self.engine.dist2_block(x, z, dpad)
    }

    fn kmeans_assign(
        &self,
        x: &[f32],
        cent: &[f32],
        cmask: &[f32],
        rmask: &[f32],
        dpad: usize,
    ) -> Result<AssignOut> {
        self.engine.kmeans_assign(x, cent, cmask, rmask, dpad)
    }

    fn predict_block(
        &self,
        x: &[f32],
        z: &[f32],
        gamma: f32,
        beta: &[f32],
        dpad: usize,
    ) -> Result<Vec<f32>> {
        self.engine.predict_block(x, z, gamma, beta, dpad)
    }

    fn call_count(&self) -> u64 {
        self.engine.call_count()
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, data: &[f32], dims: &[usize]) -> Result<Prepared> {
        Ok(Prepared::Device(self.engine.upload(data, dims)?))
    }

    fn kernel_block_p(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        self.engine
            .kernel_block_b(x.device()?, z.device()?, dpad, gamma)
    }

    fn matvec_p(&self, c: &Prepared, v: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec_b(c.device()?, v)
    }

    fn matvec_t_p(&self, c: &Prepared, r: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec_t_b(c.device()?, r)
    }

    fn fgrad_p(
        &self,
        loss: Loss,
        c: &Prepared,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.engine
            .fgrad_b(loss.name(), c.device()?, beta, y.device()?, mask.device()?)
    }

    fn hd_p(&self, c: &Prepared, d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.engine.hd_b(c.device()?, d, dcoef)
    }

    fn fgrad_from_x(
        &self,
        loss: Loss,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.engine.fgrad_from_x_b(
            loss.name(),
            x.device()?,
            z.device()?,
            dpad,
            gamma,
            beta,
            y.device()?,
            mask.device()?,
        )
    }

    fn hd_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>> {
        self.engine
            .hd_from_x_b(x.device()?, z.device()?, dpad, gamma, d, dcoef)
    }

    fn matvec_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        v: &[f32],
    ) -> Result<Vec<f32>> {
        self.engine
            .matvec_from_x_b(x.device()?, z.device()?, dpad, gamma, v)
    }

    fn matvec_t_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        r: &[f32],
    ) -> Result<Vec<f32>> {
        self.engine
            .matvec_t_from_x_b(x.device()?, z.device()?, dpad, gamma, r)
    }
}

/// Pure-Rust compute (differential oracle / fallback).
#[derive(Default)]
pub struct NativeCompute {
    calls: AtomicU64,
}

impl NativeCompute {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&self) {
        self.calls.fetch_add(1, Ordering::Relaxed);
    }
}

impl Compute for NativeCompute {
    fn widths(&self) -> Vec<usize> {
        // The native path handles any width, but report the artifact grid so
        // padding behaviour is identical across backends.
        vec![32, 64, 128, 256, 512, 1024]
    }

    fn kernel_block(&self, x: &[f32], z: &[f32], dpad: usize, gamma: f32) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::kernel_block(x, z, dpad, gamma))
    }

    fn matvec(&self, c: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec(c, v))
    }

    fn matvec_t(&self, c: &[f32], r: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_t(c, r))
    }

    fn loss_stage(&self, loss: Loss, o: &[f32], y: &[f32], mask: &[f32]) -> Result<StageOut> {
        self.bump();
        Ok(native::loss_stage(loss, o, y, mask))
    }

    fn fgrad(
        &self,
        loss: Loss,
        c: &[f32],
        beta: &[f32],
        y: &[f32],
        mask: &[f32],
    ) -> Result<StageOut> {
        self.bump();
        Ok(native::fgrad(loss, c, beta, y, mask))
    }

    fn hd_tile(&self, c: &[f32], d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::hd_tile(c, d, dcoef))
    }

    fn dist2_block(&self, x: &[f32], z: &[f32], dpad: usize) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::dist2_block(x, z, dpad))
    }

    fn kmeans_assign(
        &self,
        x: &[f32],
        cent: &[f32],
        cmask: &[f32],
        rmask: &[f32],
        dpad: usize,
    ) -> Result<AssignOut> {
        self.bump();
        Ok(native::kmeans_assign(x, cent, cmask, rmask, dpad))
    }

    fn predict_block(
        &self,
        x: &[f32],
        z: &[f32],
        gamma: f32,
        beta: &[f32],
        dpad: usize,
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::predict_block(x, z, gamma, beta, dpad))
    }

    fn call_count(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn prepare(&self, data: &[f32], _dims: &[usize]) -> Result<Prepared> {
        Ok(Prepared::Host(Arc::new(data.to_vec())))
    }

    fn prepare_shared(&self, data: &Arc<Vec<f32>>, _dims: &[usize]) -> Result<Prepared> {
        // Native executes straight from host memory: share the caller's
        // buffer instead of copying it (the materialized-store halving).
        Ok(Prepared::Host(Arc::clone(data)))
    }

    fn prepared_aliases_host(&self) -> bool {
        true
    }

    fn kernel_block_p(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::kernel_block(x.host(), z.host(), dpad, gamma))
    }

    fn matvec_p(&self, c: &Prepared, v: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec(c.host(), v))
    }

    fn matvec_t_p(&self, c: &Prepared, r: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_t(c.host(), r))
    }

    fn fgrad_p(
        &self,
        loss: Loss,
        c: &Prepared,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.bump();
        Ok(native::fgrad(loss, c.host(), beta, y.host(), mask.host()))
    }

    fn hd_p(&self, c: &Prepared, d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::hd_tile(c.host(), d, dcoef))
    }

    fn fgrad_from_x(
        &self,
        loss: Loss,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.bump();
        Ok(native::fgrad_from_x(
            loss,
            x.host(),
            z.host(),
            dpad,
            gamma,
            beta,
            y.host(),
            mask.host(),
        ))
    }

    fn hd_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::hd_from_x(x.host(), z.host(), dpad, gamma, d, dcoef))
    }

    fn matvec_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        v: &[f32],
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_from_x(x.host(), z.host(), dpad, gamma, v))
    }

    fn matvec_t_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        r: &[f32],
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_t_from_x(x.host(), z.host(), dpad, gamma, r))
    }

    // Per-evaluation block ops: ONE bump for the whole node — this is the
    // "one backend dispatch per node per evaluation" the dispatches()
    // ledger counter observes. Loop structure mirrors the per-tile
    // formulation exactly (see the trait-level default), so the override
    // is bit-identical to it and to the pre-block per-tile coordinator
    // loops; `block_tile` only decides where each C tile lives.

    fn fgrad_block(
        &self,
        loss: Loss,
        rows: &[RowTiles<'_>],
        z: &[Prepared],
        dpad: usize,
        gamma: f32,
        v_tiles: &[Vec<f32>],
        _y_prep: &[Prepared],
        _mask_prep: &[Prepared],
        y: &[Vec<f32>],
        mask: &[Vec<f32>],
    ) -> Result<BlockOut> {
        self.bump();
        let ct = z.len();
        let mut grad = vec![0.0f32; ct * TM];
        let mut loss_sum = 0.0f32;
        let mut dcoef = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let kept = keep_tiles(row, ct, z, dpad, gamma);
            if ct == 1 {
                let t = block_tile(row, &kept, z, dpad, gamma, 0);
                let stage = native::fgrad(loss, t.as_slice(), &v_tiles[0], &y[i], &mask[i]);
                loss_sum += stage.loss;
                for (g, v) in grad.iter_mut().zip(&stage.vec) {
                    *g += v;
                }
                dcoef.push(stage.dcoef);
                continue;
            }
            let mut o = vec![0.0f32; TB];
            for (j, vj) in v_tiles.iter().enumerate() {
                let t = block_tile(row, &kept, z, dpad, gamma, j);
                let part = native::matvec(t.as_slice(), vj);
                for (a, b) in o.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            let stage = native::loss_stage(loss, &o, &y[i], &mask[i]);
            loss_sum += stage.loss;
            for j in 0..ct {
                let t = block_tile(row, &kept, z, dpad, gamma, j);
                let part = native::matvec_t(t.as_slice(), &stage.vec);
                for (g, v) in grad[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                    *g += v;
                }
            }
            dcoef.push(stage.dcoef);
        }
        Ok(BlockOut {
            loss: loss_sum,
            grad,
            dcoef,
        })
    }

    fn hd_block(
        &self,
        rows: &[RowTiles<'_>],
        z: &[Prepared],
        dpad: usize,
        gamma: f32,
        v_tiles: &[Vec<f32>],
        dcoef: &[Vec<f32>],
    ) -> Result<Vec<f32>> {
        self.bump();
        let ct = z.len();
        let mut out = vec![0.0f32; ct * TM];
        for (i, row) in rows.iter().enumerate() {
            let kept = keep_tiles(row, ct, z, dpad, gamma);
            if ct == 1 {
                let t = block_tile(row, &kept, z, dpad, gamma, 0);
                let part = native::hd_tile(t.as_slice(), &v_tiles[0], &dcoef[i]);
                for (g, v) in out.iter_mut().zip(&part) {
                    *g += v;
                }
                continue;
            }
            let mut zv = vec![0.0f32; TB];
            for (j, vj) in v_tiles.iter().enumerate() {
                let t = block_tile(row, &kept, z, dpad, gamma, j);
                let part = native::matvec(t.as_slice(), vj);
                for (a, b) in zv.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            for (zi, w) in zv.iter_mut().zip(&dcoef[i]) {
                *zi *= w;
            }
            for j in 0..ct {
                let t = block_tile(row, &kept, z, dpad, gamma, j);
                let part = native::matvec_t(t.as_slice(), &zv);
                for (g, v) in out[j * TM..(j + 1) * TM].iter_mut().zip(&part) {
                    *g += v;
                }
            }
        }
        Ok(out)
    }
}

/// Construct the configured backend. The result is shared (`Arc`) across
/// all simulated nodes — and across the threaded executor's workers: in-
/// process they share one engine and its compiled executables, which is the
/// moral equivalent of each Hadoop node having compiled the same binary.
pub fn make_backend(backend: Backend, artifacts_dir: &str) -> Result<Arc<dyn Compute>> {
    match backend {
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => Ok(Arc::new(PjrtCompute::new(artifacts_dir)?)),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => {
            let _ = artifacts_dir;
            anyhow::bail!(
                "backend 'pjrt' is not compiled into this binary: rebuild with \
                 `cargo build --features pjrt` (requires the `xla` PJRT binding \
                 crate — see README) or use `--backend native`"
            )
        }
        Backend::Native => Ok(Arc::new(NativeCompute::new())),
    }
}

/// Sanity guard shared by all Compute users: tile buffers must match the
/// fixed grid.
pub fn assert_tile_shapes(c: &[f32]) {
    assert_eq!(c.len(), TB * TM, "C tile must be TB*TM");
}
