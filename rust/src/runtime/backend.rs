//! The `Compute` trait: the tile-op interface the coordinator programs
//! against, with the PJRT (AOT artifact, `pjrt` feature) and native (pure
//! Rust) implementations. The two are differential-tested against each
//! other in `rust/tests/runtime_pjrt.rs`.
//!
//! `Compute` is `Send + Sync`: one shared backend (`Arc<dyn Compute>`)
//! serves every simulated node, including concurrently from the worker
//! threads of [`crate::cluster::ThreadedExecutor`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::settings::{Backend, Loss};
use crate::Result;

#[cfg(feature = "pjrt")]
use super::engine::Engine;
use super::{native, AssignOut, StageOut};

use super::tiles::{TB, TM};

/// An operand prepared for repeated hot-path use: resident on the PJRT
/// device (one upload, zero per-call transfer) or a pinned host buffer for
/// the native backend. Created once per C tile / feature panel after the
/// kernel-computation step; every TRON f/g/Hd call then ships only the
/// O(TB + TM) small vectors. This is the §Perf "persistent device buffer"
/// optimization (see EXPERIMENTS.md §Perf for before/after).
///
/// The host variant is an `Arc` so a caller that must ALSO keep the tile
/// on the host (the materialized C store serves `row_dot` from host tiles)
/// can share one buffer with its prepared copy via
/// [`Compute::prepare_shared`] instead of holding the data twice.
pub enum Prepared {
    Host(Arc<Vec<f32>>),
    #[cfg(feature = "pjrt")]
    Device(xla::PjRtBuffer),
}

// SAFETY (pjrt builds): PJRT device buffers are internally synchronized —
// see the Send/Sync rationale on [`Engine`]. Without the feature `Prepared`
// is plain owned data and these impls match what the compiler would derive.
#[cfg(feature = "pjrt")]
unsafe impl Send for Prepared {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Prepared {}

impl Prepared {
    /// Host view (native backend only).
    fn host(&self) -> &[f32] {
        match self {
            Prepared::Host(v) => v,
            #[cfg(feature = "pjrt")]
            Prepared::Device(_) => panic!("device-prepared operand used on native backend"),
        }
    }

    #[cfg(feature = "pjrt")]
    fn device(&self) -> Result<&xla::PjRtBuffer> {
        match self {
            Prepared::Device(b) => Ok(b),
            Prepared::Host(_) => anyhow::bail!("host-prepared operand used on PJRT backend"),
        }
    }

    /// True when this prepared operand is the SAME host allocation as
    /// `host` (a zero-copy [`Compute::prepare_shared`] result) — i.e. it
    /// contributes no extra bytes beyond the host buffer itself.
    pub fn aliases(&self, host: &Arc<Vec<f32>>) -> bool {
        match self {
            Prepared::Host(v) => Arc::ptr_eq(v, host),
            #[cfg(feature = "pjrt")]
            Prepared::Device(_) => false,
        }
    }
}

/// Node-local tile compute. All slices follow the tiling contract of
/// [`super::tiles`]: row tiles are TB long, basis tiles TM, features padded
/// to a compiled width. Implementations must be thread-safe (`Send + Sync`)
/// — the threaded executor calls them from every worker concurrently.
pub trait Compute: Send + Sync {
    /// Supported padded feature widths.
    fn widths(&self) -> Vec<usize>;

    /// Smallest compiled width >= d.
    fn pad_d(&self, d: usize) -> Result<usize> {
        super::tiles::pad_dim(&self.widths(), d)
            .ok_or_else(|| anyhow::anyhow!("feature dim {d} exceeds compiled widths"))
    }

    fn kernel_block(&self, x: &[f32], z: &[f32], dpad: usize, gamma: f32) -> Result<Vec<f32>>;
    fn matvec(&self, c: &[f32], v: &[f32]) -> Result<Vec<f32>>;
    fn matvec_t(&self, c: &[f32], r: &[f32]) -> Result<Vec<f32>>;
    fn loss_stage(&self, loss: Loss, o: &[f32], y: &[f32], mask: &[f32]) -> Result<StageOut>;
    fn fgrad(&self, loss: Loss, c: &[f32], beta: &[f32], y: &[f32], mask: &[f32])
        -> Result<StageOut>;
    fn hd_tile(&self, c: &[f32], d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>>;
    fn dist2_block(&self, x: &[f32], z: &[f32], dpad: usize) -> Result<Vec<f32>>;
    #[allow(clippy::too_many_arguments)]
    fn kmeans_assign(
        &self,
        x: &[f32],
        cent: &[f32],
        cmask: &[f32],
        rmask: &[f32],
        dpad: usize,
    ) -> Result<AssignOut>;
    fn predict_block(
        &self,
        x: &[f32],
        z: &[f32],
        gamma: f32,
        beta: &[f32],
        dpad: usize,
    ) -> Result<Vec<f32>>;

    /// Dispatch count (PJRT executions / native calls) for overhead metrics.
    fn call_count(&self) -> u64;

    fn name(&self) -> &'static str;

    // ---- prepared-operand hot path (one upload, many executions) ----

    /// Prepare an operand for repeated use (shape `dims`, row-major).
    fn prepare(&self, data: &[f32], dims: &[usize]) -> Result<Prepared>;

    /// Prepare an operand the caller also keeps on the host. Backends that
    /// execute from host memory may alias the buffer (zero-copy — the
    /// native path does); device backends upload a copy as usual.
    ///
    /// CONTRACT: this method and [`Compute::prepared_aliases_host`] must be
    /// overridden TOGETHER — the flag is how byte accounting and the Auto
    /// storage budget price what this method returns. (Per-`Prepared`
    /// truth is available via [`Prepared::aliases`]; the flag exists so
    /// the budget can be priced before any tile is built.)
    fn prepare_shared(&self, data: &Arc<Vec<f32>>, dims: &[usize]) -> Result<Prepared> {
        self.prepare(data, dims)
    }

    /// True when [`Compute::prepare_shared`] aliases the host buffer
    /// instead of copying: a materialized C row tile then costs ONE tile
    /// of memory, not two (host copy + prepared copy). Keep in lockstep
    /// with `prepare_shared` — see the contract note there.
    fn prepared_aliases_host(&self) -> bool {
        false
    }

    fn kernel_block_p(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>>;

    fn matvec_p(&self, c: &Prepared, v: &[f32]) -> Result<Vec<f32>>;

    fn matvec_t_p(&self, c: &Prepared, r: &[f32]) -> Result<Vec<f32>>;

    fn fgrad_p(
        &self,
        loss: Loss,
        c: &Prepared,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut>;

    fn hd_p(&self, c: &Prepared, d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>>;

    // ---- streaming (from-features) fused ops: no stored C ----
    //
    // Each op recomputes the kernel tile from the prepared feature tile `x`
    // and basis tile `z` ONCE per dispatch and consumes it in place. Tile
    // math is exactly `kernel_block`, so results are bit-identical to the
    // prepared-C variants above — the memory/compute tradeoff behind
    // `CStorage::Streaming` (see `coordinator::cstore`).

    /// Fused f/grad with the C tile recomputed from (x, z): the tile feeds
    /// both the matvec and the matvec_t of this dispatch.
    #[allow(clippy::too_many_arguments)]
    fn fgrad_from_x(
        &self,
        loss: Loss,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut>;

    /// Fused Hd with the C tile recomputed from (x, z).
    fn hd_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>>;

    /// C v with the C tile recomputed from (x, z).
    fn matvec_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        v: &[f32],
    ) -> Result<Vec<f32>>;

    /// Cᵀ r with the C tile recomputed from (x, z).
    fn matvec_t_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        r: &[f32],
    ) -> Result<Vec<f32>>;
}

/// PJRT-backed compute (the paper stack: AOT JAX+Pallas artifacts).
#[cfg(feature = "pjrt")]
pub struct PjrtCompute {
    engine: Engine,
}

#[cfg(feature = "pjrt")]
impl PjrtCompute {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        Ok(PjrtCompute {
            engine: Engine::new(artifacts_dir)?,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

#[cfg(feature = "pjrt")]
impl Compute for PjrtCompute {
    fn widths(&self) -> Vec<usize> {
        self.engine.manifest().ds.clone()
    }

    fn kernel_block(&self, x: &[f32], z: &[f32], dpad: usize, gamma: f32) -> Result<Vec<f32>> {
        self.engine.kernel_block(x, z, dpad, gamma)
    }

    fn matvec(&self, c: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec(c, v)
    }

    fn matvec_t(&self, c: &[f32], r: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec_t(c, r)
    }

    fn loss_stage(&self, loss: Loss, o: &[f32], y: &[f32], mask: &[f32]) -> Result<StageOut> {
        self.engine.loss_stage(loss.name(), o, y, mask)
    }

    fn fgrad(
        &self,
        loss: Loss,
        c: &[f32],
        beta: &[f32],
        y: &[f32],
        mask: &[f32],
    ) -> Result<StageOut> {
        self.engine.fgrad(loss.name(), c, beta, y, mask)
    }

    fn hd_tile(&self, c: &[f32], d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.engine.hd_tile(c, d, dcoef)
    }

    fn dist2_block(&self, x: &[f32], z: &[f32], dpad: usize) -> Result<Vec<f32>> {
        self.engine.dist2_block(x, z, dpad)
    }

    fn kmeans_assign(
        &self,
        x: &[f32],
        cent: &[f32],
        cmask: &[f32],
        rmask: &[f32],
        dpad: usize,
    ) -> Result<AssignOut> {
        self.engine.kmeans_assign(x, cent, cmask, rmask, dpad)
    }

    fn predict_block(
        &self,
        x: &[f32],
        z: &[f32],
        gamma: f32,
        beta: &[f32],
        dpad: usize,
    ) -> Result<Vec<f32>> {
        self.engine.predict_block(x, z, gamma, beta, dpad)
    }

    fn call_count(&self) -> u64 {
        self.engine.call_count()
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, data: &[f32], dims: &[usize]) -> Result<Prepared> {
        Ok(Prepared::Device(self.engine.upload(data, dims)?))
    }

    fn kernel_block_p(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        self.engine
            .kernel_block_b(x.device()?, z.device()?, dpad, gamma)
    }

    fn matvec_p(&self, c: &Prepared, v: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec_b(c.device()?, v)
    }

    fn matvec_t_p(&self, c: &Prepared, r: &[f32]) -> Result<Vec<f32>> {
        self.engine.matvec_t_b(c.device()?, r)
    }

    fn fgrad_p(
        &self,
        loss: Loss,
        c: &Prepared,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.engine
            .fgrad_b(loss.name(), c.device()?, beta, y.device()?, mask.device()?)
    }

    fn hd_p(&self, c: &Prepared, d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.engine.hd_b(c.device()?, d, dcoef)
    }

    fn fgrad_from_x(
        &self,
        loss: Loss,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.engine.fgrad_from_x_b(
            loss.name(),
            x.device()?,
            z.device()?,
            dpad,
            gamma,
            beta,
            y.device()?,
            mask.device()?,
        )
    }

    fn hd_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>> {
        self.engine
            .hd_from_x_b(x.device()?, z.device()?, dpad, gamma, d, dcoef)
    }

    fn matvec_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        v: &[f32],
    ) -> Result<Vec<f32>> {
        self.engine
            .matvec_from_x_b(x.device()?, z.device()?, dpad, gamma, v)
    }

    fn matvec_t_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        r: &[f32],
    ) -> Result<Vec<f32>> {
        self.engine
            .matvec_t_from_x_b(x.device()?, z.device()?, dpad, gamma, r)
    }
}

/// Pure-Rust compute (differential oracle / fallback).
#[derive(Default)]
pub struct NativeCompute {
    calls: AtomicU64,
}

impl NativeCompute {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&self) {
        self.calls.fetch_add(1, Ordering::Relaxed);
    }
}

impl Compute for NativeCompute {
    fn widths(&self) -> Vec<usize> {
        // The native path handles any width, but report the artifact grid so
        // padding behaviour is identical across backends.
        vec![32, 64, 128, 256, 512, 1024]
    }

    fn kernel_block(&self, x: &[f32], z: &[f32], dpad: usize, gamma: f32) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::kernel_block(x, z, dpad, gamma))
    }

    fn matvec(&self, c: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec(c, v))
    }

    fn matvec_t(&self, c: &[f32], r: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_t(c, r))
    }

    fn loss_stage(&self, loss: Loss, o: &[f32], y: &[f32], mask: &[f32]) -> Result<StageOut> {
        self.bump();
        Ok(native::loss_stage(loss, o, y, mask))
    }

    fn fgrad(
        &self,
        loss: Loss,
        c: &[f32],
        beta: &[f32],
        y: &[f32],
        mask: &[f32],
    ) -> Result<StageOut> {
        self.bump();
        Ok(native::fgrad(loss, c, beta, y, mask))
    }

    fn hd_tile(&self, c: &[f32], d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::hd_tile(c, d, dcoef))
    }

    fn dist2_block(&self, x: &[f32], z: &[f32], dpad: usize) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::dist2_block(x, z, dpad))
    }

    fn kmeans_assign(
        &self,
        x: &[f32],
        cent: &[f32],
        cmask: &[f32],
        rmask: &[f32],
        dpad: usize,
    ) -> Result<AssignOut> {
        self.bump();
        Ok(native::kmeans_assign(x, cent, cmask, rmask, dpad))
    }

    fn predict_block(
        &self,
        x: &[f32],
        z: &[f32],
        gamma: f32,
        beta: &[f32],
        dpad: usize,
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::predict_block(x, z, gamma, beta, dpad))
    }

    fn call_count(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn prepare(&self, data: &[f32], _dims: &[usize]) -> Result<Prepared> {
        Ok(Prepared::Host(Arc::new(data.to_vec())))
    }

    fn prepare_shared(&self, data: &Arc<Vec<f32>>, _dims: &[usize]) -> Result<Prepared> {
        // Native executes straight from host memory: share the caller's
        // buffer instead of copying it (the materialized-store halving).
        Ok(Prepared::Host(Arc::clone(data)))
    }

    fn prepared_aliases_host(&self) -> bool {
        true
    }

    fn kernel_block_p(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::kernel_block(x.host(), z.host(), dpad, gamma))
    }

    fn matvec_p(&self, c: &Prepared, v: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec(c.host(), v))
    }

    fn matvec_t_p(&self, c: &Prepared, r: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_t(c.host(), r))
    }

    fn fgrad_p(
        &self,
        loss: Loss,
        c: &Prepared,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.bump();
        Ok(native::fgrad(loss, c.host(), beta, y.host(), mask.host()))
    }

    fn hd_p(&self, c: &Prepared, d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::hd_tile(c.host(), d, dcoef))
    }

    fn fgrad_from_x(
        &self,
        loss: Loss,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        beta: &[f32],
        y: &Prepared,
        mask: &Prepared,
    ) -> Result<StageOut> {
        self.bump();
        Ok(native::fgrad_from_x(
            loss,
            x.host(),
            z.host(),
            dpad,
            gamma,
            beta,
            y.host(),
            mask.host(),
        ))
    }

    fn hd_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::hd_from_x(x.host(), z.host(), dpad, gamma, d, dcoef))
    }

    fn matvec_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        v: &[f32],
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_from_x(x.host(), z.host(), dpad, gamma, v))
    }

    fn matvec_t_from_x(
        &self,
        x: &Prepared,
        z: &Prepared,
        dpad: usize,
        gamma: f32,
        r: &[f32],
    ) -> Result<Vec<f32>> {
        self.bump();
        Ok(native::matvec_t_from_x(x.host(), z.host(), dpad, gamma, r))
    }
}

/// Construct the configured backend. The result is shared (`Arc`) across
/// all simulated nodes — and across the threaded executor's workers: in-
/// process they share one engine and its compiled executables, which is the
/// moral equivalent of each Hadoop node having compiled the same binary.
pub fn make_backend(backend: Backend, artifacts_dir: &str) -> Result<Arc<dyn Compute>> {
    match backend {
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => Ok(Arc::new(PjrtCompute::new(artifacts_dir)?)),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => {
            let _ = artifacts_dir;
            anyhow::bail!(
                "backend 'pjrt' is not compiled into this binary: rebuild with \
                 `cargo build --features pjrt` (requires the `xla` PJRT binding \
                 crate — see README) or use `--backend native`"
            )
        }
        Backend::Native => Ok(Arc::new(NativeCompute::new())),
    }
}

/// Sanity guard shared by all Compute users: tile buffers must match the
/// fixed grid.
pub fn assert_tile_shapes(c: &[f32]) {
    assert_eq!(c.len(), TB * TM, "C tile must be TB*TM");
}
