//! PJRT engine: compiles the AOT HLO-text modules once and dispatches typed
//! tile ops on the training hot path. `pjrt` feature only.
//!
//! Follows the /opt/xla-example/load_hlo pattern: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute`. Modules are compiled lazily on first use and cached for the
//! life of the engine (one compiled executable per module).
//!
//! The engine is shared by every simulated node, which under the threaded
//! executor means concurrent use from worker threads: the executable cache
//! is behind a `Mutex` (held only for lookup/compile — dispatch happens on
//! a cloned `Arc` outside the lock, so executions overlap freely) and the
//! call/compile counters are atomics / mutexed scalars.

// If this module fails to compile with "unresolved import `xla`" /
// "use of undeclared crate", you enabled `--features pjrt` without wiring
// the `xla` PJRT binding crate into rust/Cargo.toml — see the `pjrt`
// feature comment there for the two-line fix.
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::Result;

use super::artifacts::Manifest;
use super::tiles::{TB, TM};
use super::{AssignOut, StageOut};

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    calls: AtomicU64,
    compile_secs: Mutex<f64>,
}

// SAFETY: the PJRT C API is thread-safe — clients, loaded executables and
// device buffers may be used concurrently from multiple threads (the CPU
// plugin synchronizes internally). The `xla` binding wraps raw pointers
// without declaring this, so it does not derive Send/Sync; all remaining
// interior state of `Engine` is Mutex-/atomic-protected above.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create the engine over an artifacts directory (no compilation yet).
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        if manifest.tb != TB || manifest.tm != TM {
            anyhow::bail!(
                "artifact tile grid ({}, {}) != compiled-in ({TB}, {TM}); \
                 re-run `make artifacts`",
                manifest.tb,
                manifest.tm
            );
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            exes: Mutex::new(BTreeMap::new()),
            calls: AtomicU64::new(0),
            compile_secs: Mutex::new(0.0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Total module executions so far (dispatch-overhead accounting).
    pub fn call_count(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Cumulative compile time (excluded from hot-path timings by warmup).
    pub fn compile_secs(&self) -> f64 {
        *self.compile_secs.lock().unwrap()
    }

    /// Pre-compile a set of modules (so hot-path timings exclude compiles).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Look up (or lazily compile) a module's executable. The lock is held
    /// across compilation so a module is compiled exactly once even when
    /// worker threads race to it; callers dispatch on the returned `Arc`
    /// after the lock is released.
    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let mut exes = self.exes.lock().unwrap();
        if let Some(exe) = exes.get(name) {
            return Ok(Arc::clone(exe));
        }
        let spec = self.manifest.module(name)?;
        let start = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        *self.compile_secs.lock().unwrap() += start.elapsed().as_secs_f64();
        let exe = Arc::new(exe);
        exes.insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute a module on literal inputs; returns the decomposed output
    /// tuple (modules are lowered with return_tuple=True).
    fn exec(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        let bufs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    /// Execute on device buffers (the hot path: operands prepared once with
    /// [`Engine::upload`], only the small per-call vectors are copied).
    fn exec_b(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        let bufs = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow::anyhow!("execute_b {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    /// Copy a host array to a persistent device buffer (CPU PJRT: one
    /// memcpy, then zero per-call transfer for the life of the buffer).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload {dims:?}: {e:?}"))
    }

    fn lit1(&self, v: &[f32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    fn lit2(&self, v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(v.len(), rows * cols);
        xla::Literal::vec1(v)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    fn vec_f32(lit: &xla::Literal, what: &str) -> Result<Vec<f32>> {
        lit.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{what}: {e:?}"))
    }

    fn scalar_f32(lit: &xla::Literal, what: &str) -> Result<f32> {
        lit.get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("{what}: {e:?}"))
    }

    // ---------------- typed tile ops (the hot path) ----------------

    /// C tile = RBF(x_tile, z_tile): x (TB, dpad), z (TM, dpad) → (TB*TM).
    pub fn kernel_block(
        &self,
        x_tile: &[f32],
        z_tile: &[f32],
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let name = format!("kernel_block_d{dpad}");
        let out = self.exec(
            &name,
            &[
                self.lit2(x_tile, TB, dpad)?,
                self.lit2(z_tile, TM, dpad)?,
                self.lit1(&[gamma]),
            ],
        )?;
        Self::vec_f32(&out[0], "kernel_block out")
    }

    /// o tile = C v: c (TB*TM), v (TM) → (TB).
    pub fn matvec(&self, c_tile: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let out = self.exec("matvec", &[self.lit2(c_tile, TB, TM)?, self.lit1(v)])?;
        Self::vec_f32(&out[0], "matvec out")
    }

    /// g tile = Cᵀ r: c (TB*TM), r (TB) → (TM).
    pub fn matvec_t(&self, c_tile: &[f32], r: &[f32]) -> Result<Vec<f32>> {
        let out = self.exec("matvec_t", &[self.lit2(c_tile, TB, TM)?, self.lit1(r)])?;
        Self::vec_f32(&out[0], "matvec_t out")
    }

    /// Loss stage: (o, y, mask) → (loss_sum, resid, dcoef).
    pub fn loss_stage(
        &self,
        loss: &str,
        o: &[f32],
        y: &[f32],
        mask: &[f32],
    ) -> Result<StageOut> {
        let name = format!("loss_{loss}");
        let out = self.exec(
            &name,
            &[self.lit1(o), self.lit1(y), self.lit1(mask)],
        )?;
        Ok(StageOut {
            loss: Self::scalar_f32(&out[0], "loss")?,
            vec: Self::vec_f32(&out[1], "resid")?,
            dcoef: Self::vec_f32(&out[2], "dcoef")?,
        })
    }

    /// Fused f/grad for one row tile (m <= TM): (c, β, y, mask) →
    /// (loss_sum, grad (TM), dcoef (TB)).
    pub fn fgrad(
        &self,
        loss: &str,
        c_tile: &[f32],
        beta: &[f32],
        y: &[f32],
        mask: &[f32],
    ) -> Result<StageOut> {
        let name = format!("fgrad_{loss}");
        let out = self.exec(
            &name,
            &[
                self.lit2(c_tile, TB, TM)?,
                self.lit1(beta),
                self.lit1(y),
                self.lit1(mask),
            ],
        )?;
        Ok(StageOut {
            loss: Self::scalar_f32(&out[0], "loss")?,
            vec: Self::vec_f32(&out[1], "grad")?,
            dcoef: Self::vec_f32(&out[2], "dcoef")?,
        })
    }

    /// Fused Hd loss term for one row tile (m <= TM): Cᵀ(D(C d)).
    pub fn hd_tile(&self, c_tile: &[f32], d: &[f32], dcoef: &[f32]) -> Result<Vec<f32>> {
        let out = self.exec(
            "hd_tile",
            &[self.lit2(c_tile, TB, TM)?, self.lit1(d), self.lit1(dcoef)],
        )?;
        Self::vec_f32(&out[0], "hd out")
    }

    /// Squared-distance tile: x (TB, dpad), z (TM, dpad) → (TB*TM).
    pub fn dist2_block(&self, x_tile: &[f32], z_tile: &[f32], dpad: usize) -> Result<Vec<f32>> {
        let name = format!("dist2_block_d{dpad}");
        let out = self.exec(
            &name,
            &[self.lit2(x_tile, TB, dpad)?, self.lit2(z_tile, TM, dpad)?],
        )?;
        Self::vec_f32(&out[0], "dist2_block out")
    }

    /// K-means assignment for one row tile.
    pub fn kmeans_assign(
        &self,
        x_tile: &[f32],
        cent: &[f32],
        cmask: &[f32],
        rmask: &[f32],
        dpad: usize,
    ) -> Result<AssignOut> {
        let name = format!("kmeans_assign_d{dpad}");
        let out = self.exec(
            &name,
            &[
                self.lit2(x_tile, TB, dpad)?,
                self.lit2(cent, TM, dpad)?,
                self.lit1(cmask),
                self.lit1(rmask),
            ],
        )?;
        Ok(AssignOut {
            idx: out[0]
                .to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("idx: {e:?}"))?,
            counts: Self::vec_f32(&out[1], "counts")?,
            sums: Self::vec_f32(&out[2], "sums")?,
            inertia: Self::scalar_f32(&out[3], "inertia")?,
        })
    }

    // -------- buffer (prepared-operand) variants of the hot ops --------

    /// C tile from prepared operands: x, z already on device.
    pub fn kernel_block_b(
        &self,
        x: &xla::PjRtBuffer,
        z: &xla::PjRtBuffer,
        dpad: usize,
        gamma: f32,
    ) -> Result<Vec<f32>> {
        let name = format!("kernel_block_d{dpad}");
        let g = self.upload(&[gamma], &[1])?;
        let out = self.exec_b(&name, &[x, z, &g])?;
        Self::vec_f32(&out[0], "kernel_block out")
    }

    pub fn matvec_b(&self, c: &xla::PjRtBuffer, v: &[f32]) -> Result<Vec<f32>> {
        let vb = self.upload(v, &[v.len()])?;
        let out = self.exec_b("matvec", &[c, &vb])?;
        Self::vec_f32(&out[0], "matvec out")
    }

    pub fn matvec_t_b(&self, c: &xla::PjRtBuffer, r: &[f32]) -> Result<Vec<f32>> {
        let rb = self.upload(r, &[r.len()])?;
        let out = self.exec_b("matvec_t", &[c, &rb])?;
        Self::vec_f32(&out[0], "matvec_t out")
    }

    pub fn fgrad_b(
        &self,
        loss: &str,
        c: &xla::PjRtBuffer,
        beta: &[f32],
        y: &xla::PjRtBuffer,
        mask: &xla::PjRtBuffer,
    ) -> Result<StageOut> {
        let name = format!("fgrad_{loss}");
        let bb = self.upload(beta, &[beta.len()])?;
        let out = self.exec_b(&name, &[c, &bb, y, mask])?;
        Ok(StageOut {
            loss: Self::scalar_f32(&out[0], "loss")?,
            vec: Self::vec_f32(&out[1], "grad")?,
            dcoef: Self::vec_f32(&out[2], "dcoef")?,
        })
    }

    pub fn hd_b(
        &self,
        c: &xla::PjRtBuffer,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>> {
        let db = self.upload(d, &[d.len()])?;
        let dc = self.upload(dcoef, &[dcoef.len()])?;
        let out = self.exec_b("hd_tile", &[c, &db, &dc])?;
        Self::vec_f32(&out[0], "hd out")
    }

    // ---- streaming (from-features) fused variants: the C tile is
    // recomputed with the `kernel_block` module once per dispatch, staged
    // to a transient device buffer, and consumed by the follow-on module.
    // Same modules, same tile bits as the materialized path — only where
    // the tile lives differs (no persistent C buffers).

    /// Streaming fused f/grad: tile from (x, z), then the fgrad module.
    #[allow(clippy::too_many_arguments)]
    pub fn fgrad_from_x_b(
        &self,
        loss: &str,
        x: &xla::PjRtBuffer,
        z: &xla::PjRtBuffer,
        dpad: usize,
        gamma: f32,
        beta: &[f32],
        y: &xla::PjRtBuffer,
        mask: &xla::PjRtBuffer,
    ) -> Result<StageOut> {
        let tile = self.kernel_block_b(x, z, dpad, gamma)?;
        let cb = self.upload(&tile, &[TB, TM])?;
        let name = format!("fgrad_{loss}");
        let bb = self.upload(beta, &[beta.len()])?;
        let out = self.exec_b(&name, &[&cb, &bb, y, mask])?;
        Ok(StageOut {
            loss: Self::scalar_f32(&out[0], "loss")?,
            vec: Self::vec_f32(&out[1], "grad")?,
            dcoef: Self::vec_f32(&out[2], "dcoef")?,
        })
    }

    /// Streaming fused Hd: tile from (x, z), then the hd_tile module.
    pub fn hd_from_x_b(
        &self,
        x: &xla::PjRtBuffer,
        z: &xla::PjRtBuffer,
        dpad: usize,
        gamma: f32,
        d: &[f32],
        dcoef: &[f32],
    ) -> Result<Vec<f32>> {
        let tile = self.kernel_block_b(x, z, dpad, gamma)?;
        let cb = self.upload(&tile, &[TB, TM])?;
        let db = self.upload(d, &[d.len()])?;
        let dc = self.upload(dcoef, &[dcoef.len()])?;
        let out = self.exec_b("hd_tile", &[&cb, &db, &dc])?;
        Self::vec_f32(&out[0], "hd out")
    }

    /// Streaming matvec: tile from (x, z), then C v.
    pub fn matvec_from_x_b(
        &self,
        x: &xla::PjRtBuffer,
        z: &xla::PjRtBuffer,
        dpad: usize,
        gamma: f32,
        v: &[f32],
    ) -> Result<Vec<f32>> {
        let tile = self.kernel_block_b(x, z, dpad, gamma)?;
        let cb = self.upload(&tile, &[TB, TM])?;
        let vb = self.upload(v, &[v.len()])?;
        let out = self.exec_b("matvec", &[&cb, &vb])?;
        Self::vec_f32(&out[0], "matvec out")
    }

    /// Streaming transposed matvec: tile from (x, z), then Cᵀ r.
    pub fn matvec_t_from_x_b(
        &self,
        x: &xla::PjRtBuffer,
        z: &xla::PjRtBuffer,
        dpad: usize,
        gamma: f32,
        r: &[f32],
    ) -> Result<Vec<f32>> {
        let tile = self.kernel_block_b(x, z, dpad, gamma)?;
        let cb = self.upload(&tile, &[TB, TM])?;
        let rb = self.upload(r, &[r.len()])?;
        let out = self.exec_b("matvec_t", &[&cb, &rb])?;
        Self::vec_f32(&out[0], "matvec_t out")
    }

    /// Prediction tile: decision values for TB test rows against one basis
    /// tile: kernel_block + matvec fused.
    pub fn predict_block(
        &self,
        x_tile: &[f32],
        z_tile: &[f32],
        gamma: f32,
        beta: &[f32],
        dpad: usize,
    ) -> Result<Vec<f32>> {
        let name = format!("predict_block_d{dpad}");
        let out = self.exec(
            &name,
            &[
                self.lit2(x_tile, TB, dpad)?,
                self.lit2(z_tile, TM, dpad)?,
                self.lit1(&[gamma]),
                self.lit1(beta),
            ],
        )?;
        Self::vec_f32(&out[0], "predict out")
    }
}

// Tests for the engine live in rust/tests/runtime_pjrt.rs (they need the
// artifacts directory and a PJRT client, i.e. integration scope).
