//! Artifact manifest: the schema contract with `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::config::Json;
use crate::Result;

/// Shape + dtype of one module input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            shape,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT module entry.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tb: usize,
    pub tm: usize,
    pub ds: Vec<usize>,
    pub losses: Vec<String>,
    pub modules: Vec<ModuleSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "read {}: {e}\n(hint: run `make artifacts` first)",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version")?.as_usize()?;
        if version != 1 {
            anyhow::bail!("unsupported manifest version {version}");
        }
        let tb = j.get("tb")?.as_usize()?;
        let tm = j.get("tm")?.as_usize()?;
        let ds = j
            .get("ds")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let losses = j
            .get("losses")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let mut modules = Vec::new();
        for m in j.get("modules")?.as_arr()? {
            let name = m.get("name")?.as_str()?.to_string();
            let inputs = m
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()
                .map_err(|e| anyhow::anyhow!("module {name}: {e}"))?;
            let outputs = m
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()
                .map_err(|e| anyhow::anyhow!("module {name}: {e}"))?;
            modules.push(ModuleSpec {
                file: dir.join(m.get("file")?.as_str()?),
                name,
                inputs,
                outputs,
            });
        }
        Ok(Manifest {
            tb,
            tm,
            ds,
            losses,
            modules,
            dir,
        })
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("module {name:?} not in manifest"))
    }

    /// Smallest supported padded width >= d.
    pub fn pad_d(&self, d: usize) -> Result<usize> {
        self.ds
            .iter()
            .copied()
            .filter(|&w| w >= d)
            .min()
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "feature dim {d} exceeds the largest compiled width {:?}",
                    self.ds.iter().max()
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "version": 1, "tb": 256, "tm": 256, "ds": [32, 64], "losses": ["sqhinge"],
 "modules": [
  {"name": "matvec", "file": "matvec.hlo.txt",
   "inputs": [{"shape": [256, 256], "dtype": "f32"}, {"shape": [256], "dtype": "f32"}],
   "outputs": [{"shape": [256], "dtype": "f32"}]}
 ]
}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.tb, 256);
        assert_eq!(m.losses, vec!["sqhinge"]);
        let mv = m.module("matvec").unwrap();
        assert_eq!(mv.inputs.len(), 2);
        assert_eq!(mv.inputs[0].shape, vec![256, 256]);
        assert_eq!(mv.file, PathBuf::from("/tmp/a/matvec.hlo.txt"));
    }

    #[test]
    fn missing_module_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.module("nope").is_err());
    }

    #[test]
    fn pad_d_picks_next_width() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert_eq!(m.pad_d(10).unwrap(), 32);
        assert_eq!(m.pad_d(32).unwrap(), 32);
        assert_eq!(m.pad_d(33).unwrap(), 64);
        assert!(m.pad_d(65).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from(".")).is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // Integration-style: only runs when `make artifacts` has been run.
        if let Ok(m) = Manifest::load("artifacts") {
            assert_eq!(m.tb, 256);
            assert!(m.module("kernel_block_d64").is_ok());
            assert!(m.module("fgrad_sqhinge").is_ok());
        }
    }
}
