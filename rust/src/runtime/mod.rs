//! Runtime: loads the AOT artifacts (HLO text lowered from JAX + Pallas at
//! build time) and executes them on the training hot path via the PJRT CPU
//! client (`xla` crate). Python never runs here.
//!
//! * [`artifacts`] — manifest schema shared with `python/compile/aot.py`.
//! * [`engine`] — PJRT client + compiled executables + typed dispatch for
//!   every module (kernel tiles, matvec family, loss stages, k-means,
//!   prediction).
//! * [`tiles`] — the padding/tiling contract: datasets are zero-padded to
//!   the (TB, TM, D) grid the modules were lowered for.
//! * [`native`] — pure-Rust implementations of the exact same ops, used as
//!   a differential-testing oracle and as a fallback backend.
//! * [`backend`] — the `Compute` trait the coordinator programs against,
//!   with PJRT and native implementations.

pub mod artifacts;
pub mod backend;
pub mod engine;
pub mod native;
pub mod tiles;

pub use artifacts::Manifest;
pub use backend::{make_backend, Compute};
pub use engine::Engine;
pub use tiles::{pad_dim, TiledMatrix, TB, TM};
