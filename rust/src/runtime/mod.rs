//! Runtime: the node-local tile-compute layer the coordinator programs
//! against. Two backends implement the same [`Compute`] trait:
//!
//! * **native** (always built) — pure-Rust implementations of every op,
//!   used as a differential-testing oracle and as the default backend.
//! * **pjrt** (behind the off-by-default `pjrt` cargo feature) — loads the
//!   AOT artifacts (HLO text lowered from JAX + Pallas at build time) and
//!   executes them via the PJRT CPU client (`xla` crate). Python never
//!   runs here.
//!
//! Backends are `Send + Sync`: one shared instance serves every worker
//! thread of the [`crate::cluster::ThreadedExecutor`] concurrently.
//!
//! * [`artifacts`] — manifest schema shared with `python/compile/aot.py`
//!   (pure JSON; built regardless of the `pjrt` feature).
//! * [`engine`] — PJRT client + compiled executables + typed dispatch for
//!   every module (kernel tiles, matvec family, loss stages, k-means,
//!   prediction). `pjrt` feature only.
//! * [`tiles`] — the padding/tiling contract: datasets are zero-padded to
//!   the (TB, TM, D) grid the modules were lowered for.
//! * [`native`] — pure-Rust implementations of the exact same ops.
//! * [`backend`] — the `Compute` trait with both implementations.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod native;
pub mod tiles;

pub use artifacts::Manifest;
pub use backend::{make_backend, Compute, RowTiles};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use tiles::{pad_dim, TiledMatrix, TB, TM};

/// Loss/grad stage output: (loss_sum, vec, dcoef). Shared by every backend
/// (defined here so the native path builds without the `pjrt` feature).
pub struct StageOut {
    pub loss: f32,
    pub vec: Vec<f32>,
    pub dcoef: Vec<f32>,
}

/// Output of one per-node block evaluation (`Compute::fgrad_block`): the
/// node's loss partial, its flat `col_tiles·TM` gradient partial, and the
/// per-row-tile Gauss-Newton diagonals TRON caches for the Hd passes.
pub struct BlockOut {
    pub loss: f32,
    pub grad: Vec<f32>,
    pub dcoef: Vec<Vec<f32>>,
}

/// K-means assignment output for one row tile.
pub struct AssignOut {
    pub idx: Vec<i32>,
    pub counts: Vec<f32>,
    pub sums: Vec<f32>,
    pub inertia: f32,
}
