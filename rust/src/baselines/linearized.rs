//! Formulation (3): the linearized kernel machine of Zhang et al. [29].
//!
//! ```text
//! W = U Λ Uᵀ  (eigendecomposition, O(m³))
//! A = C U Λ^{-1/2}  (O(nm²))
//! min_w  λ/2 ‖w‖² + L(A w, y)  (linear machine, TRON)
//! ```
//!
//! Mathematically equivalent to formulation (4) — same kernel, same m, same
//! model class, different parameterization (w = Λ^{1/2} Uᵀ β). The paper's
//! Table 1 measures exactly the setup costs this route pays and (4) avoids:
//! we expose `eig_secs`, `a_secs` and `Fraction of time for A` so the bench
//! regenerates the table's rows.
//!
//! Eigenvalues below `EIG_FLOOR · λ_max` are dropped (W is often numerically
//! rank-deficient for clustered basis points) — this is the pseudo-inverse
//! semantics of the Nyström literature.

use crate::config::settings::{Loss, Settings};
use crate::data::Dataset;
use crate::linalg::{sym_eig, Mat};
use crate::metrics::accuracy;
use crate::rng::Rng;
use crate::runtime::native;
use crate::Result;

use crate::coordinator::solver::{tron, Objective, SolveStats, TronOptions};

const EIG_FLOOR: f64 = 1e-10;

/// Timing breakdown + model for one formulation-(3) run.
pub struct LinearizedOutput {
    /// Basis points used (m × d).
    pub basis: Mat,
    /// Linear weights in the A-feature space (length = retained rank).
    pub w: Vec<f32>,
    /// U Λ^{-1/2} (m × rank): maps kernel columns to features at predict.
    pub proj: Mat,
    pub gamma: f32,
    pub loss: Loss,
    pub stats: SolveStats,
    /// Kernel (C and W) computation seconds.
    pub kernel_secs: f64,
    /// Eigen-decomposition seconds (the O(m³) part).
    pub eig_secs: f64,
    /// A = C U Λ^{-1/2} formation seconds (the O(nm²) part).
    pub a_secs: f64,
    pub tron_secs: f64,
    pub total_secs: f64,
    pub rank: usize,
}

impl LinearizedOutput {
    /// Fraction of total time spent forming A (Table 1's last row).
    pub fn a_fraction(&self) -> f64 {
        self.a_secs / self.total_secs.max(1e-12)
    }

    /// Decision values: o = A(x) w where A(x) = k(x, Z) proj.
    pub fn predict(&self, x: &Mat) -> Vec<f32> {
        let c = rbf_matrix(x, &self.basis, self.gamma);
        let feats = c.gemm_nn(&self.proj);
        let mut o = vec![0.0f32; x.rows()];
        feats.matvec(&self.w, &mut o);
        o
    }

    pub fn accuracy(&self, test: &Dataset) -> f64 {
        accuracy(&self.predict(&test.x), &test.y)
    }
}

/// Dense RBF kernel matrix (rows of `x` vs rows of `z`).
pub fn rbf_matrix(x: &Mat, z: &Mat, gamma: f32) -> Mat {
    let mut out = Mat::zeros(x.rows(), z.rows());
    let xsq: Vec<f32> = (0..x.rows())
        .map(|i| crate::linalg::mat::dot(x.row(i), x.row(i)))
        .collect();
    let zsq: Vec<f32> = (0..z.rows())
        .map(|k| crate::linalg::mat::dot(z.row(k), z.row(k)))
        .collect();
    for i in 0..x.rows() {
        let xi = x.row(i);
        let orow = out.row_mut(i);
        for k in 0..z.rows() {
            let d2 = (xsq[i] + zsq[k] - 2.0 * crate::linalg::mat::dot(xi, z.row(k))).max(0.0);
            orow[k] = (-gamma * d2).exp();
        }
    }
    out
}

/// The linear objective λ/2‖w‖² + L(Aw, y) for TRON.
struct LinearProblem<'a> {
    a: &'a Mat,
    y: &'a [f32],
    lambda: f32,
    loss: Loss,
    dcoef: Vec<f32>,
}

impl Objective for LinearProblem<'_> {
    fn dim(&self) -> usize {
        self.a.cols()
    }

    fn eval_fg(&mut self, w: &[f32]) -> Result<(f64, Vec<f32>)> {
        let n = self.a.rows();
        let mut o = vec![0.0f32; n];
        self.a.matvec(w, &mut o);
        let mask = vec![1.0f32; n];
        let stage = native::loss_stage(self.loss, &o, self.y, &mask);
        self.dcoef = stage.dcoef;
        let mut grad = vec![0.0f32; w.len()];
        self.a.matvec_t(&stage.vec, &mut grad);
        let mut wtw = 0.0f64;
        for (gi, wi) in grad.iter_mut().zip(w) {
            *gi += self.lambda * wi;
            wtw += (*wi as f64) * (*wi as f64);
        }
        let f = 0.5 * self.lambda as f64 * wtw + stage.loss as f64;
        Ok((f, grad))
    }

    fn eval_hd(&mut self, d: &[f32]) -> Result<Vec<f32>> {
        let n = self.a.rows();
        let mut z = vec![0.0f32; n];
        self.a.matvec(d, &mut z);
        for (zi, dc) in z.iter_mut().zip(&self.dcoef) {
            *zi *= dc;
        }
        let mut hd = vec![0.0f32; d.len()];
        self.a.matvec_t(&z, &mut hd);
        for (hi, di) in hd.iter_mut().zip(d) {
            *hi += self.lambda * di;
        }
        Ok(hd)
    }
}

/// Train formulation (3) end to end on a single machine (the configuration
/// the paper's Table 1 uses), timing each phase.
pub fn train_linearized(
    settings: &Settings,
    train_ds: &Dataset,
) -> Result<LinearizedOutput> {
    let total_start = std::time::Instant::now();
    let m = settings.m;
    let gamma = settings.gamma();
    anyhow::ensure!(m <= train_ds.n(), "m={m} > n={}", train_ds.n());

    // Basis: random training rows (same policy as formulation (4) random).
    let mut rng = Rng::new(settings.seed ^ 0xBA515);
    let idx = rng.sample_indices(train_ds.n(), m);
    let basis = train_ds.x.gather_rows(&idx);

    // Kernel matrices C (n × m) and W (m × m).
    let kstart = std::time::Instant::now();
    let c = rbf_matrix(&train_ds.x, &basis, gamma);
    let w_mat = rbf_matrix(&basis, &basis, gamma);
    let kernel_secs = kstart.elapsed().as_secs_f64();

    // Eigen-decomposition of W — the O(m³) cost formulation (4) avoids.
    let estart = std::time::Instant::now();
    let w64: Vec<f64> = w_mat.as_slice().iter().map(|&v| v as f64).collect();
    let (evals, evecs) = sym_eig(&w64, m);
    let eig_secs = estart.elapsed().as_secs_f64();

    // Retained spectrum & projection U Λ^{-1/2}.
    let emax = evals.iter().cloned().fold(0.0f64, f64::max);
    let keep: Vec<usize> = (0..m)
        .filter(|&j| evals[j] > EIG_FLOOR * emax.max(1e-300))
        .collect();
    let rank = keep.len();
    let mut proj = Mat::zeros(m, rank);
    for (col_new, &j) in keep.iter().enumerate() {
        let s = 1.0 / evals[j].sqrt();
        for i in 0..m {
            *proj.at_mut(i, col_new) = (evecs[i * m + j] * s) as f32;
        }
    }

    // A = C proj — the O(nm²) (here O(nm·rank)) transformed design matrix.
    let astart = std::time::Instant::now();
    let a = c.gemm_nn(&proj);
    let a_secs = astart.elapsed().as_secs_f64();

    // Linear TRON.
    let tstart = std::time::Instant::now();
    let mut problem = LinearProblem {
        a: &a,
        y: &train_ds.y,
        lambda: settings.lambda,
        loss: settings.loss,
        dcoef: Vec::new(),
    };
    let opts = TronOptions {
        tol: settings.tol,
        max_iters: settings.max_iters,
        ..TronOptions::default()
    };
    let (w, stats) = tron::minimize(&mut problem, &vec![0.0f32; rank], &opts)?;
    let tron_secs = tstart.elapsed().as_secs_f64();

    Ok(LinearizedOutput {
        basis,
        w,
        proj,
        gamma,
        loss: settings.loss,
        stats,
        kernel_secs,
        eig_secs,
        a_secs,
        tron_secs,
        total_secs: total_start.elapsed().as_secs_f64(),
        rank,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::{Backend, BasisSelection};
    use crate::data::synth;

    fn settings(m: usize) -> Settings {
        Settings {
            m,
            nodes: 1,
            lambda: 0.01,
            sigma: 0.7,
            loss: Loss::SqHinge,
            basis: BasisSelection::Random,
            backend: Backend::Native,
            max_iters: 60,
            tol: 1e-3,
            seed: 42,
            ..Settings::default()
        }
    }

    fn tiny() -> (Dataset, Dataset) {
        let mut spec = synth::spec("covtype_like");
        spec.n_train = 900;
        spec.n_test = 300;
        synth::generate(&spec, 5)
    }

    #[test]
    fn trains_and_predicts_above_chance() {
        let (train_ds, test_ds) = tiny();
        let out = train_linearized(&settings(64), &train_ds).unwrap();
        let acc = out.accuracy(&test_ds);
        assert!(acc > 0.55, "accuracy {acc}");
        assert!(out.rank <= 64 && out.rank > 0);
        assert!(out.eig_secs >= 0.0 && out.a_secs >= 0.0);
    }

    /// The paper's Table-1 claim in miniature: (3) and (4) give the same
    /// accuracy at the same m (they are the same model reparameterized).
    #[test]
    fn matches_formulation_4_accuracy() {
        use crate::cluster::CostModel;
        use crate::runtime::make_backend;
        use std::sync::Arc;
        let (train_ds, test_ds) = tiny();
        let s = settings(96);
        let lin = train_linearized(&s, &train_ds).unwrap();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let f4 = crate::coordinator::train(
            &s,
            &train_ds,
            Arc::clone(&backend),
            CostModel::free(),
        )
        .unwrap();
        let acc3 = lin.accuracy(&test_ds);
        let acc4 = f4.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(
            (acc3 - acc4).abs() < 0.04,
            "formulation (3): {acc3} vs (4): {acc4}"
        );
    }

    #[test]
    fn eig_time_grows_superlinearly_with_m() {
        let (train_ds, _) = tiny();
        let t64 = train_linearized(&settings(64), &train_ds).unwrap();
        let t256 = train_linearized(&settings(256), &train_ds).unwrap();
        // 4x m should be >> 4x eig time (O(m³)); allow noise with 6x.
        if t64.eig_secs > 1e-4 {
            assert!(
                t256.eig_secs > 6.0 * t64.eig_secs,
                "eig {} -> {}",
                t64.eig_secs,
                t256.eig_secs
            );
        }
    }

    #[test]
    fn degenerate_duplicate_basis_is_handled() {
        // Duplicate rows make W singular; the eigen floor must drop the
        // null directions instead of producing NaNs.
        let (mut train_ds, _) = tiny();
        for i in 0..50 {
            let row: Vec<f32> = train_ds.x.row(0).to_vec();
            train_ds.x.row_mut(i + 1).copy_from_slice(&row);
        }
        let out = train_linearized(&settings(48), &train_ds).unwrap();
        assert!(out.w.iter().all(|v| v.is_finite()));
        assert!(out.rank <= 48);
    }
}
