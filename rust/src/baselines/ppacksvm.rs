//! P-packSVM (Zhu et al., ICDM 2009): distributed primal stochastic
//! gradient descent for the *full* (non-approximated) kernel SVM, with
//! iteration packing — the paper's Table-5 comparator.
//!
//! The solver is Pegasos-style SGD in the kernel feature space, w = s·Σ
//! α_j φ(x_j). Training rows (and their α entries) are partitioned over p
//! nodes. Each round processes a **pack** of r examples:
//!
//! 1. the pack's features are broadcast to all nodes;
//! 2. every node computes the pack outputs restricted to its local support
//!    vectors; an AllReduce sums the r outputs (one communication instance
//!    per pack — this is the packing trick: r iterations, one round-trip);
//! 3. the master replays the r SGD steps sequentially, correcting later
//!    pack members' outputs with the pack's r × r kernel matrix (the
//!    O(r²) term the paper mentions, which is why r stays ~100);
//! 4. the α updates are scattered back to the owner nodes.
//!
//! The number of rounds is n/r per epoch — still O(n) communication
//! instances, which is exactly why the paper's §4.5 notes it "will be
//! hugely inefficient" on a high-latency AllReduce: the same `C + D·B`
//! ledger that prices our TRON rounds prices these.

use crate::cluster::{Cluster, CostModel};
use crate::config::settings::Loss;
use crate::coordinator::TrainedModel;
use crate::data::{shard_rows, Dataset};
use crate::linalg::Mat;
use crate::metrics::Step;
use crate::rng::Rng;
use crate::Result;

#[derive(Clone, Debug)]
pub struct PPackOptions {
    /// Pack size r (paper: ~100).
    pub pack: usize,
    /// Number of epochs (Table 5 runs 1).
    pub epochs: usize,
    /// SVM regularization λ (Pegasos step schedule η_t = 1/(λ t)).
    pub lambda: f32,
    pub seed: u64,
    /// Nodes p.
    pub nodes: usize,
}

impl Default for PPackOptions {
    fn default() -> Self {
        PPackOptions {
            pack: 100,
            epochs: 1,
            lambda: 1e-4,
            seed: 42,
            nodes: 8,
        }
    }
}

/// One P-packSVM node: a row shard and its α coefficients.
pub struct PPackNode {
    x: Mat,
    alpha: Vec<f32>,
    /// Local indices with α ≠ 0 (the node's support vectors).
    active: Vec<usize>,
}

pub struct PPackOutput {
    pub model: TrainedModel,
    /// Simulated cluster ledger (same cost model semantics as the trainer).
    pub sim: crate::cluster::SimClock,
    pub wall_secs: f64,
    pub rounds: usize,
    pub n_support: usize,
}

/// RBF between one vector and one matrix row.
#[inline]
fn rbf(a: &[f32], b: &[f32], gamma: f32) -> f32 {
    let mut d2 = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let diff = x - y;
        d2 += diff * diff;
    }
    (-gamma * d2).exp()
}

/// Train a full-kernel SVM with P-packSVM on the simulated cluster.
pub fn train_ppacksvm(
    train_ds: &Dataset,
    gamma: f32,
    opts: &PPackOptions,
    cost: CostModel,
) -> Result<PPackOutput> {
    anyhow::ensure!(opts.pack >= 1, "pack size must be >= 1");
    let wall_start = std::time::Instant::now();
    let n = train_ds.n();
    let shards = shard_rows(n, opts.nodes);
    let nodes: Vec<PPackNode> = shards
        .iter()
        .map(|r| {
            let idx: Vec<usize> = r.clone().collect();
            PPackNode {
                x: train_ds.x.gather_rows(&idx),
                alpha: vec![0.0; r.len()],
                active: Vec::new(),
            }
        })
        .collect();
    let mut cluster = Cluster::new(nodes, 2, cost);
    let shard_starts: Vec<usize> = shards.iter().map(|r| r.start).collect();
    let owner_of = |global: usize| -> (usize, usize) {
        // Contiguous shards: find the owning node by range.
        let j = match shard_starts.binary_search(&global) {
            Ok(j) => j,
            Err(j) => j - 1,
        };
        (j, global - shard_starts[j])
    };

    let mut rng = Rng::new(opts.seed);
    let mut order: Vec<usize> = (0..n).collect();
    let mut scale = 1.0f32; // the s in w = s·Σ α φ(x)
    let mut t: u64 = 0; // SGD step counter
    let mut rounds = 0usize;

    for _epoch in 0..opts.epochs {
        rng.shuffle(&mut order);
        for pack_rows in order.chunks(opts.pack) {
            let r = pack_rows.len();
            // 1. Broadcast the pack (features + labels).
            let pack_x = train_ds.x.gather_rows(pack_rows);
            let pack_y: Vec<f32> = pack_rows.iter().map(|&i| train_ds.y[i]).collect();
            cluster.broadcast_meter(Step::Tron, r * (train_ds.d() + 1) * 4);

            // 2. Distributed pack outputs over local supports.
            let partials = cluster.par_compute(Step::Tron, |_, node| {
                let mut o = vec![0.0f32; r];
                for &l in &node.active {
                    let a = node.alpha[l];
                    let xr = node.x.row(l);
                    for (oi, pi) in o.iter_mut().zip(0..r) {
                        *oi += a * rbf(xr, pack_x.row(pi), gamma);
                    }
                }
                o
            });
            let mut o = cluster.allreduce_sum(Step::Tron, partials);
            for oi in o.iter_mut() {
                *oi *= scale;
            }

            // 3. Master: replay r sequential Pegasos steps with intra-pack
            //    corrections from the pack kernel (the O(r²) work).
            let mut q = vec![0.0f32; r * r];
            for a in 0..r {
                for b in 0..r {
                    q[a * r + b] = rbf(pack_x.row(a), pack_x.row(b), gamma);
                }
            }
            let mut updates: Vec<(usize, f32)> = Vec::new(); // (global, Δα unscaled)
            for i in 0..r {
                t += 1;
                let eta = 1.0 / (opts.lambda * t as f32);
                let shrink = 1.0 - eta * opts.lambda; // = 1 - 1/t
                // Shrink applies to w, i.e. to the scale.
                scale *= shrink.max(1e-9);
                for u in o.iter_mut().take(r).skip(i) {
                    *u *= shrink.max(1e-9);
                }
                if pack_y[i] * o[i] < 1.0 {
                    // Margin violation: α_i += η y_i (unscaled: η y / s).
                    let delta_unscaled = eta * pack_y[i] / scale;
                    updates.push((pack_rows[i], delta_unscaled));
                    // Correct the not-yet-processed pack outputs.
                    for jj in (i + 1)..r {
                        o[jj] += eta * pack_y[i] * q[i * r + jj];
                    }
                }
            }

            // 4. Scatter α updates to owners (metered as one tree pass).
            cluster.broadcast_meter(Step::Tron, updates.len() * 8);
            for (global, delta) in updates {
                let (j, local) = owner_of(global);
                let node = cluster.node_mut(j);
                if node.alpha[local] == 0.0 {
                    node.active.push(local);
                }
                node.alpha[local] += delta;
            }
            rounds += 1;
        }
    }

    // Assemble the model: support vectors with scaled α as a basis-β pair
    // (prediction shares the formulation-(4) predict path).
    let mut sv_rows: Vec<usize> = Vec::new();
    let mut beta: Vec<f32> = Vec::new();
    for (j, start) in shard_starts.iter().enumerate() {
        let node = cluster.node(j);
        for &l in &node.active {
            let a = node.alpha[l] * scale;
            if a != 0.0 {
                sv_rows.push(start + l);
                beta.push(a);
            }
        }
    }
    let n_support = sv_rows.len();
    let basis = train_ds.x.gather_rows(&sv_rows);
    Ok(PPackOutput {
        model: TrainedModel {
            basis,
            beta,
            gamma,
            loss: Loss::SqHinge,
        },
        sim: cluster.clock,
        wall_secs: wall_start.elapsed().as_secs_f64(),
        rounds,
        n_support,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::Backend;
    use crate::data::synth;
    use crate::runtime::make_backend;

    fn tiny() -> (Dataset, Dataset) {
        let mut spec = synth::spec("mnist8m_like");
        spec.n_train = 800;
        spec.n_test = 200;
        synth::generate(&spec, 9)
    }

    #[test]
    fn learns_separable_clusters() {
        let (train_ds, test_ds) = tiny();
        let gamma = 1.0 / (2.0 * 18.0f32 * 18.0);
        let opts = PPackOptions {
            pack: 50,
            epochs: 1,
            lambda: 1e-4,
            seed: 1,
            nodes: 4,
        };
        let out = train_ppacksvm(&train_ds, gamma, &opts, CostModel::free()).unwrap();
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let acc = out.model.accuracy(backend.as_ref(), &test_ds).unwrap();
        assert!(acc > 0.80, "accuracy {acc}");
        assert!(out.n_support > 0);
        assert_eq!(out.rounds, 800usize.div_ceil(50));
    }

    #[test]
    fn rounds_scale_with_n_over_r() {
        let (train_ds, _) = tiny();
        let gamma = 0.002;
        for (pack, want) in [(100, 8), (200, 4)] {
            let opts = PPackOptions {
                pack,
                epochs: 1,
                lambda: 1e-3,
                seed: 2,
                nodes: 2,
            };
            let out = train_ppacksvm(&train_ds, gamma, &opts, CostModel::free()).unwrap();
            assert_eq!(out.rounds, want);
        }
    }

    #[test]
    fn comm_instances_are_o_n_over_r() {
        // The paper's point: P-pack pays ~n/r AllReduce rounds; on a
        // high-latency tree that dominates.
        let (train_ds, _) = tiny();
        let opts = PPackOptions {
            pack: 100,
            epochs: 1,
            lambda: 1e-3,
            seed: 3,
            nodes: 8,
        };
        let crude = train_ppacksvm(&train_ds, 0.002, &opts, CostModel::hadoop_crude()).unwrap();
        let mpi = train_ppacksvm(&train_ds, 0.002, &opts, CostModel::mpi()).unwrap();
        let crude_comm = crude.sim.comm_secs(Step::Tron);
        let mpi_comm = mpi.sim.comm_secs(Step::Tron);
        assert!(
            crude_comm > 50.0 * mpi_comm,
            "crude {crude_comm} vs mpi {mpi_comm}"
        );
    }

    #[test]
    fn node_count_invariance_of_model() {
        let (train_ds, test_ds) = tiny();
        let gamma = 1.0 / (2.0 * 18.0f32 * 18.0);
        let backend = make_backend(Backend::Native, "artifacts").unwrap();
        let mut accs = Vec::new();
        for nodes in [1, 5] {
            let opts = PPackOptions {
                pack: 50,
                epochs: 1,
                lambda: 1e-4,
                seed: 4,
                nodes,
            };
            let out = train_ppacksvm(&train_ds, gamma, &opts, CostModel::free()).unwrap();
            accs.push(out.model.accuracy(backend.as_ref(), &test_ds).unwrap());
        }
        // The algorithm is sequential-equivalent: same seed → same updates
        // regardless of p (up to fp reassociation in the AllReduce).
        assert!((accs[0] - accs[1]).abs() < 0.02, "{accs:?}");
    }
}
