//! The paper's comparators, implemented on the same substrates:
//!
//! * [`linearized`] — formulation (3) (Zhang et al. 2012): eigendecompose
//!   W, form A = C U Λ^{-1/2}, train a *linear* machine on A. This is what
//!   Table 1 shows blowing up with m (O(m³) eig + O(nm²) for A).
//! * [`ppacksvm`] — P-packSVM (Zhu et al. 2009): distributed primal kernel
//!   SGD with iteration packing, the full-kernel comparator of Table 5.

pub mod linearized;
pub mod ppacksvm;

pub use linearized::{train_linearized, LinearizedOutput};
pub use ppacksvm::{train_ppacksvm, PPackOptions, PPackOutput};
